//! Integration: full quantize → save → load → dequantize → evaluate chain
//! across methods, plus pipeline invariants (rate accounting, SDBA balance)
//! and failure injection. Artifact-free (native paths only).

use glvq::baselines;
use glvq::config::GlvqConfig;
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::eval::native_fwd;
use glvq::glvq::optimizer::GlvqGroupQuantizer;
use glvq::glvq::pipeline::{dequantized_store, quantize_model, CalibSet, PipelineOpts};
use glvq::model::{init_params, ModelConfig};
use glvq::quant::format::QuantizedModel;
use glvq::util::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t",
        vocab: 256,
        d_model: 64,
        n_layer: 2,
        n_head: 2,
        d_ff: 128,
        seq_len: 32,
        batch_train: 2,
        batch_eval: 2,
    }
}

#[test]
fn full_chain_all_methods_roundtrip_through_disk() {
    let cfg = tiny_cfg();
    let specs = cfg.param_specs();
    let store = init_params(&cfg, 1);
    let calib = CalibSet::random(&specs, 32, 2);
    let dir = std::env::temp_dir().join(format!("glvq_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    for method in ["rtn", "gptq", "omniquant_lite", "kmeans_vq", "quip_lite", "tcq"] {
        let q = baselines::by_name(method).unwrap();
        let opts = PipelineOpts { group_size: 64, target_bits: 3.0, bit_allocation: false, threads: 2, ..Default::default() };
        let (qm, report) = quantize_model(&specs, &store, &calib, &*q, &opts).unwrap();
        assert!(report.total_recon_error().is_finite(), "{method}");

        let path = dir.join(format!("{method}.glvq"));
        qm.save(&path).unwrap();
        let loaded = QuantizedModel::load(&path).unwrap();
        assert_eq!(qm, loaded, "{method}: container not round-trip stable");

        // dequantized store must run end-to-end through the native model
        let dq = dequantized_store(&loaded, &store);
        let mut rng = Rng::new(3);
        let x: Vec<i32> = (0..cfg.seq_len * 2).map(|_| rng.below(256) as i32).collect();
        let y: Vec<i32> = (0..cfg.seq_len * 2).map(|_| rng.below(256) as i32).collect();
        let nll = native_fwd::nll_sum(&cfg, &dq, &x, &y, 2).unwrap();
        assert!(nll.is_finite() && nll > 0.0, "{method}: nll {nll}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn glvq_chain_with_sdba_hits_rate_and_beats_rtn() {
    let cfg = tiny_cfg();
    let specs = cfg.param_specs();
    // heavy-tailed weights so the lattice/companding machinery matters
    let mut store = init_params(&cfg, 2);
    let mut rng = Rng::new(9);
    for name in cfg.quantizable_names() {
        let t = store.entries.get_mut(&name).unwrap();
        for v in t.data.iter_mut() {
            *v = rng.student_t(4.0) as f32 * 0.02;
        }
    }
    let calib = CalibSet::random(&specs, 48, 3);

    let mut gcfg = GlvqConfig::default();
    gcfg.lattice_dim = 8;
    gcfg.group_size = 64;
    gcfg.iters = 10;
    let glvq = GlvqGroupQuantizer::new(gcfg);
    let opts = PipelineOpts { group_size: 64, target_bits: 2.0, bit_allocation: true, threads: 2, ..Default::default() };
    let (qm, rep_glvq) = quantize_model(&specs, &store, &calib, &glvq, &opts).unwrap();

    // SDBA must keep the exact mean rate
    assert!((qm.avg_bits() - 2.0).abs() < 1e-9, "avg bits {}", qm.avg_bits());

    let rtn = baselines::by_name("rtn").unwrap();
    let (_, rep_rtn) = quantize_model(&specs, &store, &calib, &*rtn, &opts).unwrap();
    assert!(
        rep_glvq.total_recon_error() < rep_rtn.total_recon_error(),
        "glvq {} vs rtn {}",
        rep_glvq.total_recon_error(),
        rep_rtn.total_recon_error()
    );
}

#[test]
fn streaming_decoder_agrees_with_dense_on_full_model() {
    let cfg = tiny_cfg();
    let specs = cfg.param_specs();
    let store = init_params(&cfg, 4);
    let calib = CalibSet::random(&specs, 24, 5);
    let mut gcfg = GlvqConfig::default();
    gcfg.lattice_dim = 8;
    gcfg.group_size = 64;
    gcfg.iters = 6;
    let glvq = GlvqGroupQuantizer::new(gcfg);
    let opts = PipelineOpts { group_size: 64, target_bits: 2.0, bit_allocation: false, threads: 2, ..Default::default() };
    let (qm, _) = quantize_model(&specs, &store, &calib, &glvq, &opts).unwrap();

    let sm = StreamingMatmul::new(8, 1);
    let mut rng = Rng::new(6);
    for qt in &qm.tensors {
        let x: Vec<f32> = (0..qt.cols).map(|_| rng.normal_f32()).collect();
        let mut stats = DecodeStats::default();
        // single-vector decode is the batch-1 case of the shared engine
        // (the old `StreamingMatvec` wrapper is gone)
        let y = sm.matvec(qt, &x, &mut stats);
        let want = qt.dequantize().matvec(&x);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{}: {a} vs {b}", qt.name);
        }
    }
}

#[test]
fn quantization_error_visible_in_model_loss_ordering() {
    // 2-bit must hurt more than 4-bit on the same model — the end-to-end
    // rate/distortion direction every table depends on.
    let cfg = tiny_cfg();
    let specs = cfg.param_specs();
    let store = init_params(&cfg, 7);
    let calib = CalibSet::random(&specs, 32, 8);
    let rtn = baselines::by_name("rtn").unwrap();
    let mut rng = Rng::new(12);
    let x: Vec<i32> = (0..cfg.seq_len * 2).map(|_| rng.below(256) as i32).collect();
    let y: Vec<i32> = (0..cfg.seq_len * 2).map(|_| rng.below(256) as i32).collect();
    let base = native_fwd::nll_sum(&cfg, &store, &x, &y, 2).unwrap();

    let mut nlls = Vec::new();
    for bits in [4.0, 2.0, 1.0] {
        let opts = PipelineOpts { group_size: 64, target_bits: bits, bit_allocation: false, threads: 2, ..Default::default() };
        let (qm, _) = quantize_model(&specs, &store, &calib, &*rtn, &opts).unwrap();
        let dq = dequantized_store(&qm, &store);
        nlls.push(native_fwd::nll_sum(&cfg, &dq, &x, &y, 2).unwrap());
    }
    let d4 = (nlls[0] - base).abs();
    let d2 = (nlls[1] - base).abs();
    let d1 = (nlls[2] - base).abs();
    assert!(d4 <= d2 && d2 <= d1, "distortion not monotone: {d4} {d2} {d1}");
}

#[test]
fn pipeline_rejects_mismatched_calibration() {
    let cfg = tiny_cfg();
    let specs = cfg.param_specs();
    let store = init_params(&cfg, 1);
    // calibration with the wrong activation dimension
    let mut calib = CalibSet::random(&specs, 16, 2);
    let first = cfg.quantizable_names()[0].clone();
    calib
        .acts
        .insert(first, glvq::linalg::Mat::zeros(3, 16));
    let rtn = baselines::by_name("rtn").unwrap();
    let opts = PipelineOpts::default();
    assert!(quantize_model(&specs, &store, &calib, &*rtn, &opts).is_err());
}

#[test]
fn entropy_container_v2_roundtrips_and_streams_exactly() {
    // the ISSUE acceptance chain: quantize with --entropy → .glvq v2 on
    // disk → load → identical reconstruction, and the streaming matvec
    // over the entropy-coded tensor matches full dequantize + dense matvec
    let cfg = tiny_cfg();
    let specs = cfg.param_specs();
    let mut store = init_params(&cfg, 21);
    // heavy-tailed weights → peaked Babai codes → real compression
    let mut rng = Rng::new(22);
    for name in cfg.quantizable_names() {
        let t = store.entries.get_mut(&name).unwrap();
        for v in t.data.iter_mut() {
            *v = rng.student_t(4.0) as f32 * 0.02;
        }
    }
    let calib = CalibSet::random(&specs, 32, 23);
    let mut gcfg = GlvqConfig::default();
    gcfg.lattice_dim = 8;
    gcfg.group_size = 64;
    gcfg.iters = 8;
    let glvq = GlvqGroupQuantizer::new(gcfg);
    // 3 bits: the post-Babai histogram is clearly peaked vs the 8-symbol
    // alphabet, so the compressed payload beats fixed-width with margin
    let base = PipelineOpts {
        group_size: 64,
        target_bits: 3.0,
        bit_allocation: false,
        threads: 2,
        ..Default::default()
    };
    let ent = PipelineOpts { entropy: true, ..base.clone() };
    let (qm_fixed, _) = quantize_model(&specs, &store, &calib, &glvq, &base).unwrap();
    let (qm, _) = quantize_model(&specs, &store, &calib, &glvq, &ent).unwrap();
    assert!(qm.has_entropy_payloads());

    let dir = std::env::temp_dir().join(format!("glvq_v2_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m_entropy.glvq");
    qm.save(&path).unwrap();
    // on-disk version is 2; the v1 writer path is byte-compatible elsewhere
    let header = std::fs::read(&path).unwrap();
    assert_eq!(u32::from_le_bytes(header[4..8].try_into().unwrap()), 2);

    let loaded = QuantizedModel::load(&path).unwrap();
    assert_eq!(qm, loaded, "v2 container not round-trip stable");

    // lossless vs the fixed-width container, and actually smaller on
    // heavy-tailed codes
    let sm = StreamingMatmul::new(8, 1);
    let mut rng = Rng::new(24);
    for (qt, qtf) in loaded.tensors.iter().zip(&qm_fixed.tensors) {
        let dense = qt.dequantize();
        assert_eq!(dense.data, qtf.dequantize().data, "{}", qt.name);
        let x: Vec<f32> = (0..qt.cols).map(|_| rng.normal_f32()).collect();
        let want = dense.matvec(&x);
        let mut stats = DecodeStats::default();
        let y = sm.matvec(qt, &x, &mut stats);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{}: {a} vs {b}", qt.name);
        }
    }
    let (payload_e, _) = loaded.size_bytes();
    let (payload_f, _) = qm_fixed.size_bytes();
    assert!(
        payload_e < payload_f,
        "entropy payload {payload_e} not smaller than fixed {payload_f}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v1_containers_from_the_seed_writer_still_load() {
    // all-fixed models save as v1 — the exact seed-era byte format — and
    // must keep loading plus match the original model
    let cfg = tiny_cfg();
    let specs = cfg.param_specs();
    let store = init_params(&cfg, 31);
    let calib = CalibSet::random(&specs, 16, 32);
    let rtn = baselines::by_name("rtn").unwrap();
    let opts = PipelineOpts {
        group_size: 64,
        target_bits: 3.0,
        bit_allocation: false,
        threads: 2,
        ..Default::default()
    };
    let (qm, _) = quantize_model(&specs, &store, &calib, &*rtn, &opts).unwrap();
    assert!(!qm.has_entropy_payloads());

    let dir = std::env::temp_dir().join(format!("glvq_v1_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m_v1.glvq");
    qm.save(&path).unwrap();
    let header = std::fs::read(&path).unwrap();
    assert_eq!(u32::from_le_bytes(header[4..8].try_into().unwrap()), 1);
    let loaded = QuantizedModel::load(&path).unwrap();
    assert_eq!(qm, loaded);
    std::fs::remove_dir_all(&dir).ok();
}
