//! Property test for the KV-cache serving path (ISSUE 3 acceptance):
//!
//! 1. **f32-cached incremental forward is bit-identical to the full
//!    recompute** across random prompts and batch sizes — the cache is a
//!    pure speedup, not an approximation. Verified by driving the
//!    cache-aware backend and the cacheless backend through the same
//!    lockstep generation loops and asserting float-exact logits.
//! 2. **Quantized-KV serving stays within a documented NLL tolerance** of
//!    the f32 path: ≤ 0.15 nats per token at 8-bit pages on the tiny
//!    model (the measured gap is far smaller; the bound is deliberately
//!    loose so the test pins the contract, not the noise).

use glvq::coordinator::server::{CachedNativeBackend, LmBackend, NativeBackend};
use glvq::eval::native_fwd::argmax_logit;
use glvq::kvcache::{Kv, KvCacheOpts, PagedKvCache, SeqId};
use glvq::model::{init_params, ModelConfig};
use glvq::util::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 48,
        batch_train: 2,
        batch_eval: 2,
    }
}

/// Drive a lockstep generation: every step queries last-position logits
/// for all prefixes, appends each argmax, and records the logits.
fn lockstep_generate(
    backend: &mut dyn LmBackend,
    prompts: &[Vec<i32>],
    steps: usize,
) -> Vec<Vec<Vec<f32>>> {
    let mut prefixes: Vec<Vec<i32>> = prompts.to_vec();
    let mut trace: Vec<Vec<Vec<f32>>> = Vec::new();
    for _ in 0..steps {
        let views: Vec<&[i32]> = prefixes.iter().map(|p| p.as_slice()).collect();
        let logits = backend.logits_last_batch(&views).expect("forward failed");
        for (p, l) in prefixes.iter_mut().zip(&logits) {
            p.push(argmax_logit(l));
        }
        trace.push(logits);
    }
    backend.end_batch();
    trace
}

#[test]
fn f32_cached_lockstep_is_bit_identical_to_full_recompute() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(123);
    for trial in 0..4 {
        let batch = [1usize, 2, 4, 3][trial];
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|_| {
                let len = 1 + rng.below(12);
                (0..len).map(|_| rng.below(256) as i32).collect()
            })
            .collect();
        let mut plain = NativeBackend { cfg, store: init_params(&cfg, trial as u64) };
        let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut cached = CachedNativeBackend::dense(cfg, init_params(&cfg, trial as u64), kv);

        let a = lockstep_generate(&mut plain, &prompts, 10);
        let b = lockstep_generate(&mut cached, &prompts, 10);
        for (step, (la, lb)) in a.iter().zip(&b).enumerate() {
            for (bi, (ra, rb)) in la.iter().zip(lb).enumerate() {
                assert_eq!(
                    ra, rb,
                    "trial {trial} step {step} row {bi}: cached logits not bit-identical"
                );
            }
        }
        // the cache actually carried state (prefill + one-token steps)
        let stats = cached.cache_stats().expect("cached backend reports stats");
        assert!(stats.peak_pages > 0 && stats.appended_rows > 0);
        assert_eq!(stats.pages_in_use, 0, "end_batch must evict everything");
    }
}

/// NLL of a fixed continuation under last-position logits, lockstep style.
fn continuation_nll(backend: &mut dyn LmBackend, prompt: &[i32], cont: &[i32]) -> f64 {
    let mut prefix = prompt.to_vec();
    let mut nll = 0.0f64;
    for &tok in cont {
        let views: Vec<&[i32]> = vec![prefix.as_slice()];
        let logits = backend.logits_last_batch(&views).expect("forward failed");
        let row = &logits[0];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let lse: f32 = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
        nll -= (row[tok as usize] - lse) as f64;
        prefix.push(tok);
    }
    backend.end_batch();
    nll
}

#[test]
fn quantized_kv_nll_within_documented_tolerance() {
    // Documented tolerance: 8-bit lattice-quantized KV pages shift the
    // per-token NLL of this model by < 0.15 nats vs the exact f32 cache.
    const NLL_TOL_PER_TOKEN: f64 = 0.15;
    let cfg = tiny_cfg();
    let mut rng = Rng::new(7);
    let prompt: Vec<i32> = (0..10).map(|_| rng.below(256) as i32).collect();
    let cont: Vec<i32> = (0..20).map(|_| rng.below(256) as i32).collect();

    let kv_f32 = KvCacheOpts { page_rows: 4, ..Default::default() };
    let kv_q8 = KvCacheOpts { page_rows: 4, quantize: true, kv_bits: 8, ..Default::default() };
    let mut exact = CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv_f32);
    let mut quant = CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv_q8);

    let nll_exact = continuation_nll(&mut exact, &prompt, &cont);
    let nll_quant = continuation_nll(&mut quant, &prompt, &cont);
    assert!(nll_exact.is_finite() && nll_quant.is_finite());
    let per_tok = (nll_exact - nll_quant).abs() / cont.len() as f64;
    assert!(
        per_tok < NLL_TOL_PER_TOKEN,
        "quantized-KV NLL drift {per_tok:.4} nats/token exceeds {NLL_TOL_PER_TOKEN}"
    );
    // and the quantized path really exercised quantized pages
    let stats = quant.cache_stats().expect("stats");
    assert!(stats.pages_quantized > 0 && stats.decoded_bytes > 0);
    assert_eq!(exact.cache_stats().expect("stats").pages_quantized, 0);
}

// ---------------------------------------------------------------------
// spill / restore × shared prefix pages (ISSUE 7)
// ---------------------------------------------------------------------

/// Append rows for `tokens[start..]` to every (layer, K|V) stream; row
/// content is a pure function of (token, position, stream).
fn fill_rows(c: &mut PagedKvCache, s: SeqId, n_layer: usize, tokens: &[i32], start: usize) {
    let w = c.width();
    for (p, &t) in tokens.iter().enumerate().skip(start) {
        for l in 0..n_layer {
            for which in [Kv::K, Kv::V] {
                let stream = (2 * l + usize::from(matches!(which, Kv::V))) as f32;
                let row: Vec<f32> = (0..w)
                    .map(|j| t as f32 + 0.25 * stream + 0.01 * p as f32 + 0.001 * j as f32)
                    .collect();
                c.append(s, l, which, &row).unwrap();
            }
        }
    }
}

/// Concatenated contents of rows `[0, rows)` of every stream of `s`.
fn snap(c: &mut PagedKvCache, s: SeqId, n_layer: usize, rows: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for l in 0..n_layer {
        for which in [Kv::K, Kv::V] {
            let mut v = Vec::new();
            c.visit(s, l, which, rows, |_, chunk| v.extend_from_slice(chunk));
            out.push(v);
        }
    }
    out
}

#[test]
fn spill_snapshots_shared_pages_instead_of_freeing_them() {
    // quantize-to-spill a sequence whose pages are claimed by another
    // sequence and held by the prefix index: the resident originals must
    // be snapshot-copied on the way out, never freed or re-encoded —
    // the other claimer keeps reading the exact f32 rows throughout
    let opts = KvCacheOpts { page_rows: 4, prefix_share: true, ..Default::default() };
    let mut c = PagedKvCache::new(1, 4, opts);
    let ta: Vec<i32> = (0..8).collect();
    let (a, ca) = c.new_seq_shared(&ta, 8);
    assert_eq!(ca, 0);
    fill_rows(&mut c, a, 1, &ta, 0);
    c.publish_prefix(a, &ta);
    // B extends A's prompt and claims its two full pages by reference
    let tb: Vec<i32> = (0..12).collect();
    let (b, cb) = c.new_seq_shared(&tb, 11);
    assert_eq!(cb, 8);
    fill_rows(&mut c, b, 1, &tb, 8);
    let b_before = snap(&mut c, b, 1, 12);

    let sp = c.spill(a, true).expect("live sequence spills");
    assert_eq!(sp.pages(), 4, "2 shared pages x (K, V)");
    c.check_invariants().unwrap();
    assert_eq!(snap(&mut c, b, 1, 12), b_before, "spill(A) disturbed B's rows");

    // the parked copy resumes under a fresh id with the same shape; the
    // shared rows were parked compressed, so content tolerance is pinned
    // by the NLL test above, not re-asserted here
    let a2 = c.restore(sp).expect("unbounded arena restores");
    assert_eq!(c.rows(a2, 0, Kv::K), 8);
    c.check_invariants().unwrap();
    assert_eq!(snap(&mut c, b, 1, 12), b_before, "restore(A) disturbed B's rows");

    c.evict(a2);
    c.evict(b);
    c.drop_cold_prefixes();
    assert_eq!(c.stats().pages_in_use, 0);
    c.check_invariants().unwrap();
}

#[test]
fn capacity_refused_restore_returns_the_spilled_seq_untouched() {
    // park a sequence as f32, squeeze the arena so the parked pages no
    // longer fit, and verify the refused restore hands back the untouched
    // SpilledSeq — which later restores bit-exactly once room exists
    let opts =
        KvCacheOpts { page_rows: 4, prefix_share: true, max_pages: 10, ..Default::default() };
    let mut c = PagedKvCache::new(1, 4, opts);
    let ta: Vec<i32> = (0..8).map(|i| (i % 5) as i32).collect();
    let (a, _) = c.new_seq_shared(&ta, 8);
    fill_rows(&mut c, a, 1, &ta, 0);
    c.publish_prefix(a, &ta);
    let a_before = snap(&mut c, a, 1, 8);

    let sp = c.spill(a, false).expect("live sequence spills");
    assert_eq!(sp.pages(), 4);
    // the published pages stay resident (cold, owned by the index), and
    // a fresh claim still reads the exact f32 rows
    assert_eq!(c.stats().pages_in_use, 4);
    let (d, cd) = c.new_seq_shared(&ta, 8);
    assert_eq!(cd, 8);
    assert_eq!(snap(&mut c, d, 1, 8), a_before, "cold pages changed across spill");
    c.evict(d);
    c.check_invariants().unwrap();

    // an exclusive sequence eats the headroom: 10-page cap, 8 exclusive
    // pages force one cold node out, leaving 2 reclaimable < sp.pages()
    let b = c.new_seq();
    for p in 0..16 {
        let row = [p as f32; 4];
        c.append(b, 0, Kv::K, &row).unwrap();
        c.append(b, 0, Kv::V, &row).unwrap();
    }
    assert!(c.free_pages().expect("bounded arena") < sp.pages());
    let sp = match c.restore(sp) {
        Err(sp) => sp,
        Ok(_) => panic!("restore must be refused at capacity"),
    };
    assert_eq!(sp.pages(), 4, "refused restore hands the parked state back whole");
    c.check_invariants().unwrap();

    // free capacity and retry: the same SpilledSeq restores bit-exactly
    c.evict(b);
    let a2 = c.restore(sp).expect("capacity freed");
    assert_eq!(snap(&mut c, a2, 1, 8), a_before, "f32 park must restore bit-exactly");
    c.check_invariants().unwrap();
}
