//! Property test for the KV-cache serving path (ISSUE 3 acceptance):
//!
//! 1. **f32-cached incremental forward is bit-identical to the full
//!    recompute** across random prompts and batch sizes — the cache is a
//!    pure speedup, not an approximation. Verified by driving the
//!    cache-aware backend and the cacheless backend through the same
//!    lockstep generation loops and asserting float-exact logits.
//! 2. **Quantized-KV serving stays within a documented NLL tolerance** of
//!    the f32 path: ≤ 0.15 nats per token at 8-bit pages on the tiny
//!    model (the measured gap is far smaller; the bound is deliberately
//!    loose so the test pins the contract, not the noise).

use glvq::coordinator::server::{CachedNativeBackend, LmBackend, NativeBackend};
use glvq::eval::native_fwd::argmax_logit;
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::util::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 48,
        batch_train: 2,
        batch_eval: 2,
    }
}

/// Drive a lockstep generation: every step queries last-position logits
/// for all prefixes, appends each argmax, and records the logits.
fn lockstep_generate(
    backend: &mut dyn LmBackend,
    prompts: &[Vec<i32>],
    steps: usize,
) -> Vec<Vec<Vec<f32>>> {
    let mut prefixes: Vec<Vec<i32>> = prompts.to_vec();
    let mut trace: Vec<Vec<Vec<f32>>> = Vec::new();
    for _ in 0..steps {
        let views: Vec<&[i32]> = prefixes.iter().map(|p| p.as_slice()).collect();
        let logits = backend.logits_last_batch(&views).expect("forward failed");
        for (p, l) in prefixes.iter_mut().zip(&logits) {
            p.push(argmax_logit(l));
        }
        trace.push(logits);
    }
    backend.end_batch();
    trace
}

#[test]
fn f32_cached_lockstep_is_bit_identical_to_full_recompute() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(123);
    for trial in 0..4 {
        let batch = [1usize, 2, 4, 3][trial];
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|_| {
                let len = 1 + rng.below(12);
                (0..len).map(|_| rng.below(256) as i32).collect()
            })
            .collect();
        let mut plain = NativeBackend { cfg, store: init_params(&cfg, trial as u64) };
        let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut cached = CachedNativeBackend::dense(cfg, init_params(&cfg, trial as u64), kv);

        let a = lockstep_generate(&mut plain, &prompts, 10);
        let b = lockstep_generate(&mut cached, &prompts, 10);
        for (step, (la, lb)) in a.iter().zip(&b).enumerate() {
            for (bi, (ra, rb)) in la.iter().zip(lb).enumerate() {
                assert_eq!(
                    ra, rb,
                    "trial {trial} step {step} row {bi}: cached logits not bit-identical"
                );
            }
        }
        // the cache actually carried state (prefill + one-token steps)
        let stats = cached.cache_stats().expect("cached backend reports stats");
        assert!(stats.peak_pages > 0 && stats.appended_rows > 0);
        assert_eq!(stats.pages_in_use, 0, "end_batch must evict everything");
    }
}

/// NLL of a fixed continuation under last-position logits, lockstep style.
fn continuation_nll(backend: &mut dyn LmBackend, prompt: &[i32], cont: &[i32]) -> f64 {
    let mut prefix = prompt.to_vec();
    let mut nll = 0.0f64;
    for &tok in cont {
        let views: Vec<&[i32]> = vec![prefix.as_slice()];
        let logits = backend.logits_last_batch(&views).expect("forward failed");
        let row = &logits[0];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let lse: f32 = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
        nll -= (row[tok as usize] - lse) as f64;
        prefix.push(tok);
    }
    backend.end_batch();
    nll
}

#[test]
fn quantized_kv_nll_within_documented_tolerance() {
    // Documented tolerance: 8-bit lattice-quantized KV pages shift the
    // per-token NLL of this model by < 0.15 nats vs the exact f32 cache.
    const NLL_TOL_PER_TOKEN: f64 = 0.15;
    let cfg = tiny_cfg();
    let mut rng = Rng::new(7);
    let prompt: Vec<i32> = (0..10).map(|_| rng.below(256) as i32).collect();
    let cont: Vec<i32> = (0..20).map(|_| rng.below(256) as i32).collect();

    let kv_f32 = KvCacheOpts { page_rows: 4, ..Default::default() };
    let kv_q8 = KvCacheOpts { page_rows: 4, quantize: true, kv_bits: 8, ..Default::default() };
    let mut exact = CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv_f32);
    let mut quant = CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv_q8);

    let nll_exact = continuation_nll(&mut exact, &prompt, &cont);
    let nll_quant = continuation_nll(&mut quant, &prompt, &cont);
    assert!(nll_exact.is_finite() && nll_quant.is_finite());
    let per_tok = (nll_exact - nll_quant).abs() / cont.len() as f64;
    assert!(
        per_tok < NLL_TOL_PER_TOKEN,
        "quantized-KV NLL drift {per_tok:.4} nats/token exceeds {NLL_TOL_PER_TOKEN}"
    );
    // and the quantized path really exercised quantized pages
    let stats = quant.cache_stats().expect("stats");
    assert!(stats.pages_quantized > 0 && stats.decoded_bytes > 0);
    assert_eq!(exact.cache_stats().expect("stats").pages_quantized, 0);
}
