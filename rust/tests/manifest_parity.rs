//! Integration: the rust-native model metadata must match the manifest the
//! python AOT export wrote — names, order, shapes, quantizable flags.
//! Skips (with a loud message) when artifacts are absent.

use glvq::model::ModelConfig;
use glvq::runtime::Engine;

fn engine() -> Option<Engine> {
    match Engine::new(std::path::Path::new("artifacts")) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            None
        }
    }
}

#[test]
fn param_specs_match_manifest_exactly() {
    let Some(engine) = engine() else { return };
    for (name, arts) in &engine.models {
        let cfg = ModelConfig::by_name(name).expect("known model name");
        let specs = cfg.param_specs();
        assert_eq!(specs.len(), arts.params.len(), "model {name} param count");
        for (spec, (mname, mshape, mq)) in specs.iter().zip(&arts.params) {
            assert_eq!(&spec.name, mname, "model {name} param order");
            assert_eq!(&spec.shape, mshape, "model {name} shape of {mname}");
            assert_eq!(spec.quantizable, *mq, "model {name} flag of {mname}");
        }
    }
}

#[test]
fn configs_match_manifest() {
    let Some(engine) = engine() else { return };
    for (name, arts) in &engine.models {
        let cfg = ModelConfig::by_name(name).unwrap();
        assert_eq!(cfg.d_model, arts.config.d_model);
        assert_eq!(cfg.n_layer, arts.config.n_layer);
        assert_eq!(cfg.n_head, arts.config.n_head);
        assert_eq!(cfg.d_ff, arts.config.d_ff);
        assert_eq!(cfg.seq_len, arts.config.seq_len);
        assert_eq!(cfg.vocab, arts.config.vocab);
    }
}

#[test]
fn all_artifact_files_exist_and_parse_as_hlo() {
    let Some(engine) = engine() else { return };
    let mut files: Vec<String> = Vec::new();
    for arts in engine.models.values() {
        files.extend(arts.programs.values().cloned());
    }
    for g in engine.glvq.values() {
        files.extend(g.programs.values().cloned());
    }
    assert!(!files.is_empty());
    for f in files {
        let path = std::path::Path::new("artifacts").join(&f);
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{f}: {e}"));
        assert!(text.starts_with("HloModule"), "{f} is not HLO text");
        assert!(text.contains("ENTRY"), "{f} lacks an entry computation");
    }
}
