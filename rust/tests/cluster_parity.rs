//! Cluster scale-out correctness (ISSUE 9 acceptance):
//!
//! 1. **Pipeline-parallel execution is bit-identical to the single-engine
//!    walk** — same logits over the quantized container — at stage counts
//!    {1, 2, 4} × micro-batch sizes {1, 2}, over both Fixed and Rans
//!    payloads. Stages execute the same layer ops in the same order on
//!    the same values, so pipelining is a pure overlap, never an
//!    approximation.
//! 2. **Pipelining composes with tensor parallelism**: a 2-stage × 2-shard
//!    grid produces the same logits again, and greedy generation through
//!    a [`PipelinedBackend`] matches the streaming backend byte for byte.
//! 3. **The router adds scale-out, never semantics**: responses from a
//!    round-robin cluster of continuous replicas match the single-engine
//!    answers request for request, and draining a replica finishes its
//!    in-flight work while new traffic re-routes — admitted requests are
//!    never dropped.

use std::sync::Arc;

use glvq::baselines::rtn::RtnQuantizer;
use glvq::cluster::{
    PipeOpts, PipelineExec, PipelinePlan, PipelineWeights, PipelinedBackend, RoutePolicy, Router,
    RouterOpts,
};
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::coordinator::server::{
    start, start_continuous, CachedNativeBackend, LmBackend, Request, Response, ServerHandle,
    ServerOpts, StreamingNativeBackend,
};
use glvq::eval::native_fwd::{self, CalibCapture, StreamedLinear};
use glvq::eval::plan::ModelPlan;
use glvq::glvq::pipeline::{quantize_model, PipelineOpts};
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::quant::format::QuantizedModel;
use glvq::serving::ContinuousOpts;
use glvq::shard::ShardOpts;
use glvq::tensor::TensorStore;
use glvq::util::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 48,
        batch_train: 2,
        batch_eval: 2,
    }
}

/// Quantize the tiny model once (3-bit RTN), optionally with rANS
/// entropy payloads — the same recipe `tests/shard_parity.rs` uses, so
/// shard-level and pipeline-level parity cover the same container.
fn quantized(cfg: &ModelConfig, entropy: bool) -> (TensorStore, QuantizedModel) {
    let store = init_params(cfg, 0);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
    let mut cap = CalibCapture::new(16, 0);
    native_fwd::forward(cfg, &store, &toks, 2, Some(&mut cap)).expect("calibration forward");
    let calib = cap.into_calib_set();
    let opts = PipelineOpts {
        target_bits: 3.0,
        bit_allocation: false,
        entropy,
        // 8-wide column groups → every tensor has ≥4 group-aligned cells,
        // so 2-way shard plans genuinely partition each stage's linears
        group_size: 8,
        ..PipelineOpts::default()
    };
    let (qm, _) =
        quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).expect("quantize");
    (store, qm)
}

fn shard_opts(shards: usize) -> ShardOpts {
    ShardOpts { shards, panel_rows: 8, threads_per_shard: 1 }
}

#[test]
fn pipelined_forward_matches_streaming_logits_bitwise() {
    let cfg = tiny_cfg();
    for entropy in [false, true] {
        let (store, qm) = quantized(&cfg, entropy);
        let mut rng = Rng::new(17);
        let toks: Vec<i32> = (0..3 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();

        let engine = StreamingMatmul::new(8, 2);
        let mut lin = StreamedLinear {
            qm: &qm,
            store: &store,
            engine: &engine,
            stats: DecodeStats::default(),
        };
        let want = native_fwd::forward_with(&cfg, &store, &mut lin, &toks, 3, None).unwrap();

        let qm = Arc::new(qm);
        for stages in [1usize, 2, 4] {
            for micro_batch in [1usize, 2] {
                let pplan = PipelinePlan::build(&ModelPlan::of(&cfg), &qm, stages);
                let exec = PipelineExec::new(
                    cfg,
                    store.clone(),
                    pplan,
                    PipelineWeights::Sharded { qm: Arc::clone(&qm), opts: shard_opts(1) },
                    PipeOpts { micro_batch, channel_depth: 2 },
                );
                let got = exec.forward(&toks, 3).unwrap();
                assert_eq!((got.rows, got.cols), (want.rows, want.cols));
                assert_eq!(
                    got.data, want.data,
                    "entropy={entropy} stages={stages} mb={micro_batch}: pipeline diverged"
                );
                let st = exec.stage_stats();
                assert_eq!(st.len(), stages);
                assert!(st.iter().all(|s| s.micro_batches == 3usize.div_ceil(micro_batch)));
                assert!(exec.decode_stats().is_some(), "sharded stages report decode traffic");
            }
        }
    }
}

#[test]
fn pipeline_composes_with_tensor_parallel_shards() {
    // the 2-stage × 2-shard grid: each stage spreads its linears over two
    // shard workers, and the grid still matches the reference bitwise
    let cfg = tiny_cfg();
    let (store, qm) = quantized(&cfg, true);
    let mut rng = Rng::new(23);
    let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();

    let engine = StreamingMatmul::new(8, 2);
    let mut lin =
        StreamedLinear { qm: &qm, store: &store, engine: &engine, stats: DecodeStats::default() };
    let want = native_fwd::forward_with(&cfg, &store, &mut lin, &toks, 2, None).unwrap();

    let qm = Arc::new(qm);
    let exec = PipelineExec::new(
        cfg,
        store.clone(),
        PipelinePlan::build(&ModelPlan::of(&cfg), &qm, 2),
        PipelineWeights::Sharded { qm: Arc::clone(&qm), opts: shard_opts(2) },
        PipeOpts::default(),
    );
    let got = exec.forward(&toks, 2).unwrap();
    assert_eq!(got.data, want.data, "2×2 grid diverged from the single engine");
    let per = exec.shard_stats().expect("sharded stages");
    assert_eq!(per.len(), 2, "one shard-stat row per stage");
    assert!(per.iter().all(|stage| stage.len() == 2), "two shards per stage");
}

/// Greedy-generate `max_new` tokens with any backend, returning the bytes.
fn generate(backend: &mut dyn LmBackend, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut toks: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
    let start = toks.len();
    for _ in 0..max_new {
        let logits = backend.logits_last(&toks).expect("forward failed");
        toks.push(native_fwd::argmax_logit(&logits));
    }
    toks[start..].iter().map(|&t| t.clamp(0, 255) as u8).collect()
}

#[test]
fn pipelined_backend_generation_matches_streaming() {
    let cfg = tiny_cfg();
    let (store, qm) = quantized(&cfg, false);
    let mut streaming = StreamingNativeBackend {
        cfg,
        store: store.clone(),
        qm: qm.clone(),
        engine: StreamingMatmul::new(8, 2),
        stats: DecodeStats::default(),
    };
    let want = generate(&mut streaming, b"the kama ", 8);

    let qm = Arc::new(qm);
    let exec = PipelineExec::new(
        cfg,
        store,
        PipelinePlan::build(&ModelPlan::of(&cfg), &qm, 2),
        PipelineWeights::Sharded { qm: Arc::clone(&qm), opts: shard_opts(1) },
        PipeOpts::default(),
    );
    let mut pipelined = PipelinedBackend { exec };
    let got = generate(&mut pipelined, b"the kama ", 8);
    assert_eq!(got, want, "pipelined generation diverged from streaming");
}

/// One continuous replica serving the compressed container — a complete
/// engine (scheduler + paged KV cache + streaming decode), interchangeable
/// behind the router.
fn continuous_replica(cfg: ModelConfig, store: TensorStore, qm: QuantizedModel) -> ServerHandle {
    let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
    let copts = ContinuousOpts { max_batch: 8, prefill_chunk: 6, ..Default::default() };
    start_continuous(
        move || {
            let engine = StreamingMatmul::new(8, 1);
            Ok(CachedNativeBackend::streaming(cfg, store, qm, engine, kv))
        },
        copts,
    )
}

fn assert_same(a: &Response, b: &Response, what: &str) {
    match (a, b) {
        (Response::Generated { text: ta }, Response::Generated { text: tb }) => {
            assert_eq!(ta, tb, "{what}: generation diverged")
        }
        (Response::Scored { logprob: la }, Response::Scored { logprob: lb }) => {
            assert!((la - lb).abs() < 1e-12, "{what}: {la} vs {lb}")
        }
        other => panic!("{what}: mismatched kinds {other:?}"),
    }
}

#[test]
fn routed_continuous_replicas_match_the_single_engine() {
    // scale-out never changes semantics: every response from a 2-replica
    // round-robin cluster equals the single-engine answer
    let cfg = tiny_cfg();
    let (store, qm) = quantized(&cfg, true);
    let requests = vec![
        Request::Generate { prompt: vec![7; 14], max_new: 10 },
        Request::Generate { prompt: b"hi ".to_vec(), max_new: 4 },
        Request::Score { prompt: b"the ".to_vec(), continuation: b"kam".to_vec() },
        Request::Generate { prompt: b"mid-flight ".to_vec(), max_new: 5 },
    ];

    let reference = continuous_replica(cfg, store.clone(), qm.clone());
    let want: Vec<Response> =
        requests.iter().map(|r| reference.call(r.clone()).expect("reference reply")).collect();
    reference.shutdown();

    let replicas = vec![
        continuous_replica(cfg, store.clone(), qm.clone()),
        continuous_replica(cfg, store, qm),
    ];
    let opts = RouterOpts { policy: RoutePolicy::RoundRobin, ..RouterOpts::default() };
    let router = Router::new(replicas, opts);
    let rxs: Vec<_> = requests.iter().map(|r| router.submit(r.clone())).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let got = rx.recv().expect("routed reply");
        assert_same(&got, &want[i], &format!("request {i}"));
    }
    let metrics = router.shutdown();
    assert_eq!(metrics.routed, vec![2, 2], "round robin spreads evenly");
    assert_eq!(metrics.requests(), 4);
    assert_eq!(metrics.router_rejections, 0);
}

#[test]
fn draining_finishes_in_flight_work_and_reroutes_new_traffic() {
    // pipelined replicas behind the router — the two cluster axes
    // composed end to end. Drain replica 0 mid-stream: its in-flight
    // requests still answer, later traffic lands on replica 1 only.
    let cfg = tiny_cfg();
    let (store, qm) = quantized(&cfg, false);
    let qm = Arc::new(qm);
    let pipelined_replica = |store: TensorStore, qm: Arc<QuantizedModel>| {
        start(
            move || {
                let pplan = PipelinePlan::build(&ModelPlan::of(&cfg), &qm, 2);
                let weights = PipelineWeights::Sharded { qm, opts: shard_opts(1) };
                let exec = PipelineExec::new(cfg, store, pplan, weights, PipeOpts::default());
                Ok(Box::new(PipelinedBackend { exec }) as Box<dyn LmBackend>)
            },
            ServerOpts::default(),
        )
    };
    let replicas = vec![
        pipelined_replica(store.clone(), Arc::clone(&qm)),
        pipelined_replica(store, Arc::clone(&qm)),
    ];
    let opts = RouterOpts { policy: RoutePolicy::RoundRobin, ..RouterOpts::default() };
    let router = Router::new(replicas, opts);

    let gen = |fill: u8| Request::Generate { prompt: vec![fill; 6], max_new: 2 };
    let first: Vec<_> = (0..4).map(|_| router.submit(gen(7))).collect();
    router.drain(0);
    let second: Vec<_> = (0..3).map(|_| router.submit(gen(9))).collect();
    for rx in first.into_iter().chain(second) {
        let resp = rx.recv().expect("admitted requests are never dropped");
        assert!(matches!(resp, Response::Generated { .. }), "unexpected {resp:?}");
    }
    router.wait_drained(0);
    let metrics = router.shutdown();
    assert_eq!(metrics.router_rejections, 0, "draining re-routes, it does not refuse");
    assert_eq!(metrics.routed, vec![2, 5], "post-drain traffic lands on replica 1 only");
    assert_eq!(metrics.requests(), 7);
}
