//! FORMAT.md cross-check: parse a `.glvq` container **by hand**, using
//! only byte offsets and field layouts taken from the spec, and verify
//! both that the fields hold the expected values and that the library
//! round-trips the same file. If `quant/format.rs` and FORMAT.md ever
//! disagree, this test fails.

use std::path::PathBuf;

use glvq::quant::format::{QuantizedModel, QuantizedTensor, VERSION_V1, VERSION_V2};
use glvq::quant::pack::PackedCodes;
use glvq::quant::traits::{QuantizedGroup, SideInfo};
use glvq::tensor::crc32;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("glvq_spec_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("m.glvq")
}

fn u32_at(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

fn f32_at(b: &[u8], off: usize) -> f32 {
    f32::from_bits(u32_at(b, off))
}

/// The FORMAT.md "worked example": one tensor "t", one 4×4 2-bit RTN
/// group with uniform side info.
fn worked_example() -> QuantizedModel {
    let codes: Vec<i32> = (0..16).map(|i| (i % 4) - 2).collect(); // 2-bit range
    QuantizedModel {
        tensors: vec![QuantizedTensor {
            name: "t".into(),
            rows: 4,
            cols: 4,
            groups: vec![(
                0,
                0,
                QuantizedGroup {
                    method: "rtn",
                    bits: 2,
                    rows: 4,
                    cols: 4,
                    codes: PackedCodes::pack(&codes, 2).into(),
                    side: SideInfo::Uniform { scale: 0.5, zero: 0.125 },
                },
            )],
        }],
    }
}

#[test]
fn v1_worked_example_offsets_match_format_md() {
    let m = worked_example();
    assert_eq!(m.container_version(), VERSION_V1);
    let path = tmp("v1");
    m.save(&path).unwrap();
    let b = std::fs::read(&path).unwrap();

    // top-level layout
    assert_eq!(&b[0..4], b"GLVQ", "magic at offset 0");
    assert_eq!(u32_at(&b, 4), VERSION_V1, "version at offset 4");
    assert_eq!(u32_at(&b, 8), 1, "n_tensors at offset 8");

    // tensor record at offset 12 (0x0C)
    assert_eq!(u32_at(&b, 0x0C), 1, "name_len");
    assert_eq!(b[0x10], b't', "name byte");
    assert_eq!(u32_at(&b, 0x11), 4, "tensor rows");
    assert_eq!(u32_at(&b, 0x15), 4, "tensor cols");
    assert_eq!(u32_at(&b, 0x19), 1, "n_groups");

    // group record at 0x1D
    assert_eq!(b[0x1D], 2, "method_tag rtn");
    assert_eq!(b[0x1E], 2, "group bits");
    assert_eq!(u32_at(&b, 0x1F), 4, "group rows");
    assert_eq!(u32_at(&b, 0x23), 4, "group cols");
    assert_eq!(u32_at(&b, 0x27), 0, "row_offset");
    assert_eq!(u32_at(&b, 0x2B), 0, "col_offset");

    // v1 fixed payload (no tag byte) at 0x2F
    assert_eq!(b[0x2F], 2, "payload bits");
    assert_eq!(u32_at(&b, 0x30), 16, "payload n");
    assert_eq!(u32_at(&b, 0x34), 4, "payload byte_len = ceil(16*2/8)");
    // 4 packed-code bytes at 0x38..0x3C

    // side info at 0x3C
    assert_eq!(b[0x3C], 1, "side_tag uniform");
    assert_eq!(f32_at(&b, 0x3D), 0.5, "uniform scale");
    assert_eq!(f32_at(&b, 0x41), 0.125, "uniform zero");

    // trailing CRC over [4, EOF-4)
    assert_eq!(b.len(), 0x49, "total size from the spec");
    let stored = u32_at(&b, b.len() - 4);
    assert_eq!(stored, crc32(&b[4..b.len() - 4]), "CRC-32 coverage");

    // and the library agrees with the hand parse
    assert_eq!(QuantizedModel::load(&path).unwrap(), m);
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn v2_payload_tags_match_format_md() {
    // same model, rANS-coded → v2; the group header layout is unchanged,
    // the payload gains a tag byte and the rANS body of the spec
    let mut m = worked_example();
    let g = &mut m.tensors[0].groups[0].2;
    g.codes = g.codes.to_entropy(8, 2); // chunk_len 8, 2 lanes → 2 chunks
    assert_eq!(m.container_version(), VERSION_V2);
    let path = tmp("v2");
    m.save(&path).unwrap();
    let b = std::fs::read(&path).unwrap();

    assert_eq!(&b[0..4], b"GLVQ");
    assert_eq!(u32_at(&b, 4), VERSION_V2, "version 2");
    // header fields identical to v1 up to the payload...
    assert_eq!(b[0x1D], 2, "method_tag");
    assert_eq!(u32_at(&b, 0x27), 0, "row_offset");
    // ...then the v2 payload tag byte
    assert_eq!(b[0x2F], 1, "payload_tag = rans");
    let mut off = 0x30;
    assert_eq!(b[off], 2, "rans bits");
    off += 1;
    assert_eq!(u32_at(&b, off), 16, "rans n");
    off += 4;
    assert_eq!(u32_at(&b, off), 8, "rans chunk_len");
    off += 4;
    let lanes = b[off] as usize;
    assert_eq!(lanes, 2, "rans lanes");
    off += 1;
    let n_syms = u32_at(&b, off) as usize;
    off += 4;
    assert_eq!(n_syms, (1 << 2) + 1, "alphabet = 2^bits + escape");
    // 12-bit table: entries sum to 4096, all nonzero
    let mut sum = 0u32;
    for s in 0..n_syms {
        let f = u16::from_le_bytes(b[off + 2 * s..off + 2 * s + 2].try_into().unwrap());
        assert!(f > 0, "freq[{s}] must be >= 1");
        sum += f as u32;
    }
    assert_eq!(sum, 4096, "freq table sums to PROB_SCALE");
    off += 2 * n_syms;
    let n_chunks = u32_at(&b, off) as usize;
    off += 4;
    assert_eq!(n_chunks, 2, "ceil(16/8) chunks");
    for ci in 0..n_chunks {
        off += 4 * lanes; // final rANS states
        let stream_len = u32_at(&b, off) as usize;
        off += 4 + stream_len;
        let n_escapes = u32_at(&b, off) as usize;
        off += 4 + 4 * n_escapes;
        assert!(n_escapes <= 8, "chunk {ci} escape bound");
    }
    // side info follows immediately, then the CRC closes the file
    assert_eq!(b[off], 1, "side_tag after last chunk");
    assert_eq!(f32_at(&b, off + 1), 0.5, "uniform scale");
    assert_eq!(off + 1 + 8 + 4, b.len(), "side body + CRC reach EOF");
    let stored = u32_at(&b, b.len() - 4);
    assert_eq!(stored, crc32(&b[4..b.len() - 4]), "CRC-32 coverage");

    let loaded = QuantizedModel::load(&path).unwrap();
    assert_eq!(loaded, m);
    // v1→v2 re-encode is lossless: both decode to identical weights
    assert_eq!(
        loaded.tensors[0].dequantize().data,
        worked_example().tensors[0].dequantize().data
    );
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}
