//! Integration: the rust-native implementations must agree with the AOT
//! JAX/Pallas graphs executed through PJRT —
//!   (a) native transformer forward ≡ ForwardLoss HLO,
//!   (b) native GLVQ analytic gradients ≡ glvq_step HLO (JAX autodiff),
//!   (c) native encode/decode ≡ Pallas encode/decode kernels.
//! These are the tests that pin the three layers together.

use glvq::compand::MuLaw;
use glvq::eval::native_fwd;
use glvq::glvq::group::as_blocks;
use glvq::lattice::babai::babai_batch_shifted;
use glvq::lattice::GenLattice;
use glvq::linalg::decomp::inverse;
use glvq::linalg::Mat;
use glvq::model::{init_params, ModelConfig};
use glvq::runtime::exec::{ForwardLossExec, GlvqStepExec};
use glvq::runtime::Engine;
use glvq::util::rng::Rng;

fn engine() -> Option<Engine> {
    match Engine::new(std::path::Path::new("artifacts")) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            None
        }
    }
}

#[test]
fn native_forward_matches_forward_loss_hlo() {
    let Some(engine) = engine() else { return };
    let cfg = ModelConfig::by_name("s").unwrap();
    let store = init_params(&cfg, 3);
    let exec = ForwardLossExec::new(&engine, "s").unwrap();
    let params = exec.stage_params(&store).unwrap();

    let mut rng = Rng::new(11);
    let n = exec.batch * exec.seq;
    let x: Vec<i32> = (0..n).map(|_| rng.below(256) as i32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(256) as i32).collect();

    let pjrt = exec.nll_sum(&params, &x, &y).unwrap();
    let native = native_fwd::nll_sum(&cfg, &store, &x, &y, exec.batch).unwrap();
    let rel = (pjrt - native).abs() / native.abs().max(1e-9);
    assert!(rel < 2e-3, "pjrt {pjrt} vs native {native} (rel {rel})");
}

#[test]
fn native_glvq_gradients_match_jax_autodiff() {
    let Some(engine) = engine() else { return };
    let exec = GlvqStepExec::new(&engine, 8).unwrap();
    let (d, r, n, ncal) = (exec.d, exec.r, exec.n, exec.ncal);

    let mut rng = Rng::new(5);
    let w = Mat::random_normal(r, n, 0.05, &mut rng);
    let x = Mat::random_normal(n, ncal, 1.0, &mut rng);
    let mut g = Mat::eye(d).scale(0.04);
    for v in g.data.iter_mut() {
        *v += rng.normal_f32() * 0.003;
    }
    let ginv = inverse(&g).unwrap();
    let mu = 80.0f32;
    let g0 = g.clone();

    // --- PJRT glvq_step (JAX value_and_grad through the decode chain) ---
    let (loss_pjrt, dg_pjrt, dmu_pjrt) = exec.step(&w, &x, &g, &ginv, mu, &g0).unwrap();

    // --- native analytic replication of the same observation ---
    let comp = MuLaw::new(mu);
    let lat = GenLattice::new(g.clone()).unwrap();
    let mut wt = w.clone();
    comp.forward_slice(&mut wt.data);
    let y = as_blocks(&wt, d);
    let mut z = babai_batch_shifted(&lat, &y); // NOTE: no clamping — matches the graph
    for c in z.data.iter_mut() {
        *c += 0.5; // half-integer grid decode
    }
    let v = z.matmul(&g.transpose());
    let mut w_hat = Mat::from_vec(r, n, v.data.clone());
    comp.inverse_slice(&mut w_hat.data);
    let err = w.sub(&w_hat).matmul(&x);
    let loss_native: f64 = err.data.iter().map(|e| (*e as f64).powi(2)).sum();

    let rel = (loss_pjrt - loss_native).abs() / loss_native.max(1e-9);
    assert!(rel < 5e-3, "loss pjrt {loss_pjrt} vs native {loss_native}");

    // native gradients (same math as glvq::optimizer)
    let xt = x.transpose();
    let mut dldw = err.matmul(&xt);
    for gv in dldw.data.iter_mut() {
        *gv *= -2.0;
    }
    let log1p_mu = (1.0 + mu).ln();
    let mut dmu_native = 0.0f64;
    let mut dldv = Mat::zeros(v.rows, v.cols);
    for i in 0..v.data.len() {
        let vv = v.data[i];
        let t = vv.abs();
        let a = (t * log1p_mu).exp();
        let dfdv = a * log1p_mu / mu;
        let dfdmu = vv.signum() * (a * t * mu / (1.0 + mu) - (a - 1.0)) / (mu * mu);
        dmu_native += (dldw.data[i] * dfdmu) as f64;
        dldv.data[i] = dldw.data[i] * dfdv;
    }
    let dg_native = dldv.transpose().matmul(&z); // λ reg term is zero at G=G0

    let denom = dg_pjrt.frob_norm().max(1e-6);
    let dg_rel = dg_pjrt.frob_dist(&dg_native) / denom;
    assert!(dg_rel < 2e-2, "dG mismatch rel {dg_rel}");
    let dmu_rel = (dmu_pjrt as f64 - dmu_native).abs() / dmu_native.abs().max(1e-6);
    assert!(dmu_rel < 2e-2, "dmu pjrt {dmu_pjrt} vs native {dmu_native}");
}

#[test]
fn native_encode_decode_match_pallas_kernels() {
    let Some(engine) = engine() else { return };
    for d in [8usize, 16, 32] {
        let exec = GlvqStepExec::new(&engine, d).unwrap();
        let (r, n) = (exec.r, exec.n);
        let mut rng = Rng::new(d as u64);
        let w = Mat::random_normal(r, n, 0.05, &mut rng);
        let mut g = Mat::eye(d).scale(0.05);
        for v in g.data.iter_mut() {
            *v += rng.normal_f32() * 0.004;
        }
        let ginv = inverse(&g).unwrap();
        let mu = 42.0f32;

        // Pallas fused compand+babai kernel (through HLO)
        let z_pjrt = exec.encode(&w, &ginv, mu).unwrap();

        // native equivalent
        let comp = MuLaw::new(mu);
        let mut wt = w.clone();
        comp.forward_slice(&mut wt.data);
        let lat = GenLattice::new(g.clone()).unwrap();
        let z_native = babai_batch_shifted(&lat, &as_blocks(&wt, d));
        assert_eq!(z_pjrt.len(), z_native.data.len(), "d={d}");
        let mismatches = z_pjrt
            .iter()
            .zip(&z_native.data)
            .filter(|(a, b)| (**a - **b).abs() > 0.5)
            .count();
        // rounding ties at exactly .5 may differ in float order-of-ops;
        // must be a vanishing fraction
        assert!(
            mismatches * 1000 <= z_pjrt.len(),
            "d={d}: {mismatches}/{} code mismatches",
            z_pjrt.len()
        );

        // decode parity on the pjrt codes
        let w_hat_pjrt = exec.decode(&z_pjrt, &g, mu).unwrap();
        let zs: Vec<f32> = z_pjrt.iter().map(|v| v + 0.5).collect();
        let z_mat = Mat::from_vec(r * n / d, d, zs);
        let v = z_mat.matmul(&g.transpose());
        let mut w_hat_native = Mat::from_vec(r, n, v.data.clone());
        comp.inverse_slice(&mut w_hat_native.data);
        let rel = w_hat_pjrt.frob_dist(&w_hat_native) / w_hat_native.frob_norm().max(1e-9);
        assert!(rel < 1e-4, "d={d}: decode mismatch rel {rel}");
    }
}
