//! Golden-shape tests for the observability exports: the Chrome
//! trace-event JSON, the Prometheus text exposition, and the structured
//! metrics JSON must keep the exact shapes external tooling depends on
//! (Perfetto / chrome://tracing for traces, any Prometheus scraper for
//! metrics). These tests pin the contract end to end: real spans recorded
//! across real threads, a real scheduler-shaped metrics snapshot, every
//! export parsed back through `util::json` and the Prometheus validator.

use std::sync::Mutex;

use glvq::coordinator::metrics::ServerMetrics;
use glvq::obs::span;
use glvq::obs::{chrome_trace_json, Mark, RequestTimeline};
use glvq::util::json::Json;

/// Span state is process-global; serialize the tests that enable/drain.
fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn spin_ns(ns: u64) {
    let t0 = std::time::Instant::now();
    while (t0.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

/// Record a small multi-thread span forest: a nested stack on the main
/// thread plus one worker thread with its own root.
fn record_spans() -> Vec<span::FinishedSpan> {
    let _ = span::drain();
    span::set_enabled(true);
    {
        let _root = glvq::span!("golden_root");
        spin_ns(50_000);
        {
            let _child = glvq::span!("golden_child");
            spin_ns(50_000);
        }
        {
            let _child = glvq::span!("golden_child");
            spin_ns(50_000);
        }
    }
    std::thread::spawn(|| {
        let _w = glvq::span!("golden_worker");
        spin_ns(50_000);
    })
    .join()
    .expect("worker thread");
    span::set_enabled(false);
    span::drain()
}

fn sample_timeline() -> RequestTimeline {
    // spins keep every phase strictly positive so trace_events cannot
    // legitimately drop a zero-duration bar
    let mut t = RequestTimeline::with_base(3, 1_000);
    t.mark(Mark::Admit);
    spin_ns(10_000);
    t.mark(Mark::PrefillChunk);
    t.mark(Mark::FirstToken);
    spin_ns(10_000);
    t.mark(Mark::DecodeStep);
    t.mark(Mark::Finish);
    t
}

/// A metrics value shaped like a real continuous-mode run.
fn sample_metrics() -> ServerMetrics {
    let mut m = ServerMetrics::default();
    m.requests = 4;
    m.tokens_out = 40;
    m.batches = 2;
    m.sched_steps = 12;
    m.prefill_chunks = 5;
    for v in [1.5, 2.5, 9.0, 4.0] {
        m.latency.record(v);
        m.ttft.record(v * 0.5);
        m.queue_wait.record(v * 0.25);
    }
    m.timelines.push(sample_timeline());
    m
}

#[test]
fn chrome_trace_export_has_the_golden_shape() {
    let _l = test_lock();
    let spans = record_spans();
    assert!(spans.len() >= 4, "expected 4 recorded spans, got {}", spans.len());
    span::validate_nesting(&spans).expect("recorded spans are well-nested");

    let trace = chrome_trace_json(&spans, &[sample_timeline()]);
    let parsed = Json::parse(&trace.to_string()).expect("trace JSON parses");

    // golden top-level shape
    assert_eq!(parsed.get("displayTimeUnit").as_str(), Some("ms"));
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());

    // every event carries the mandatory trace-event fields
    for e in events {
        assert!(e.get("name").as_str().is_some(), "event without name: {}", e.to_string());
        let ph = e.get("ph").as_str().expect("event phase");
        assert!(["X", "M", "i"].contains(&ph), "unexpected phase {ph}");
        assert_eq!(e.get("pid").as_f64(), Some(1.0));
        assert!(e.get("tid").as_f64().is_some());
        match ph {
            "X" => {
                assert!(e.get("ts").as_f64().is_some());
                assert!(e.get("dur").as_f64().unwrap_or(-1.0) >= 0.0);
            }
            "i" => assert_eq!(e.get("s").as_str(), Some("t")),
            _ => {}
        }
    }

    // span events and timeline phases both made it in
    let names: Vec<&str> = events.iter().filter_map(|e| e.get("name").as_str()).collect();
    for want in ["golden_root", "golden_child", "golden_worker", "queue", "prefill", "decode"] {
        assert!(names.contains(&want), "missing event {want}");
    }

    // the worker span sits on a different track than the main-thread stack
    let tid_of = |name: &str| {
        events
            .iter()
            .find(|e| e.get("name").as_str() == Some(name))
            .and_then(|e| e.get("tid").as_f64())
            .expect("tid")
    };
    assert_ne!(tid_of("golden_root"), tid_of("golden_worker"));
}

#[test]
fn prometheus_export_has_the_golden_shape() {
    let _l = test_lock();
    let m = sample_metrics();
    let snap = m.snapshot();
    let prom = snap.to_prometheus();
    glvq::obs::registry::validate_prometheus(&prom).expect("valid exposition");

    // golden structural facts scrapers rely on
    assert!(prom.contains("# TYPE glvq_requests_total counter"), "{prom}");
    assert!(prom.contains("glvq_requests_total 4"), "{prom}");
    assert!(prom.contains("# TYPE glvq_request_latency_ms summary"), "{prom}");
    assert!(prom.contains("glvq_request_latency_ms{quantile=\"0.5\"}"), "{prom}");
    assert!(prom.contains("glvq_request_latency_ms_count 4"), "{prom}");
    assert!(prom.contains("glvq_request_latency_ms_sum"), "{prom}");
    assert!(prom.contains("# TYPE glvq_uptime_seconds gauge"), "{prom}");
    // timelines flow into the queue/prefill/decode attribution summaries
    assert!(prom.contains("glvq_timelines_recorded_total 1"), "{prom}");
    assert!(prom.contains("glvq_request_prefill_ms"), "{prom}");

    // tampered text must be rejected
    let broken = prom.replace("# TYPE glvq_requests_total counter", "# TYPE glvq_requests_total");
    assert!(glvq::obs::registry::validate_prometheus(&broken).is_err());
}

#[test]
fn metrics_json_round_trips_through_util_json() {
    let _l = test_lock();
    let m = sample_metrics();
    let snap = m.snapshot();
    let j = snap.to_json();
    let text = j.to_string();
    let parsed = Json::parse(&text).expect("snapshot JSON parses");
    assert_eq!(parsed, j, "snapshot JSON must round-trip bit-exactly");

    // counters surface as plain numbers, summaries as q50/q95/q99 objects
    assert_eq!(parsed.get("requests_total").as_f64(), Some(4.0));
    assert_eq!(parsed.get("tokens_out_total").as_f64(), Some(40.0));
    let lat = parsed.get("request_latency_ms");
    assert_eq!(lat.get("count").as_f64(), Some(4.0));
    assert!(lat.get("q50").as_f64().is_some());
    assert!(lat.get("q95").as_f64().is_some());
    assert_eq!(lat.get("sum").as_f64(), Some(17.0));

    // the human report line and the snapshot agree on the headline counters
    let line = glvq::coordinator::metrics::human_line(&snap);
    assert!(line.starts_with("requests=4 tokens=40 batches=2"), "{line}");
    assert!(line.contains("steps=12"), "{line}");
}
