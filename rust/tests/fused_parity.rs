//! Integration: fused decode-GEMM kernel parity — the fused execution
//! mode must be **bit-identical** (outputs and DecodeStats) to the
//! classic decode-then-FMA slab path for every side-info family, both
//! payload encodings, every thread count and batch size, before and
//! after the engine's LUT cache warms. The `simd` feature additionally
//! gets a documented-tolerance + token-identity check (SIMD lane
//! reduction reorders the dot-product sum, so bitwise equality is not
//! promised there).

use glvq::baselines;
use glvq::config::GlvqConfig;
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::glvq::optimizer::GlvqGroupQuantizer;
use glvq::kernels::{ExecMode, LUT_WARM_CALLS};
use glvq::linalg::Mat;
use glvq::quant::format::QuantizedTensor;
use glvq::quant::traits::GroupQuantizer;
use glvq::util::rng::Rng;

/// Quantize a 32×64 weight tensor (two 32-col groups) with the given
/// method, covering one side-info family per method name.
fn build(method: &str, bits: u8, seed: u64) -> QuantizedTensor {
    let mut rng = Rng::new(seed);
    let wt = Mat::random_normal(32, 64, 0.05, &mut rng);
    let mut groups = Vec::new();
    for gi in 0..2 {
        let panel = wt.slice(0, 32, gi * 32, (gi + 1) * 32);
        let xc = Mat::random_normal(32, 16, 1.0, &mut rng);
        let qg = match method {
            "glvq-8d" => {
                let mut cfg = GlvqConfig::default();
                cfg.lattice_dim = 8;
                cfg.group_size = 32;
                cfg.iters = 4;
                GlvqGroupQuantizer::new(cfg).quantize(&panel, &xc, bits)
            }
            _ => baselines::by_name(method).expect(method).quantize(&panel, &xc, bits),
        };
        groups.push((0usize, gi * 32, qg));
    }
    QuantizedTensor { name: format!("{method}_b{bits}"), rows: 32, cols: 64, groups }
}

/// Losslessly re-encode every group payload with rANS (5 rows per chunk
/// — deliberately misaligned with the 5-row panels' ragged tail).
fn to_entropy(qt: &QuantizedTensor) -> QuantizedTensor {
    let mut out = qt.clone();
    for (_, _, g) in &mut out.groups {
        g.codes = g.codes.to_entropy(g.cols * 5, 4);
    }
    out
}

#[test]
fn fused_mode_is_bitwise_identical_to_slab_across_families() {
    // (method → side-info family, bits). glvq-8d@2 is LUT-eligible
    // (8·2 = 16 index bits); glvq-8d@4 exercises the fused non-LUT path;
    // kmeans_vq / tcq / binary cannot stream and must take the identical
    // whole-group fallback in both modes.
    let cases: &[(&str, u8)] = &[
        ("rtn", 2),
        ("glvq-8d", 2),
        ("glvq-8d", 4),
        ("quip_lite", 2),
        ("kmeans_vq", 2),
        ("tcq", 2),
        ("binary", 1),
    ];
    for &(method, bits) in cases {
        let qt_fixed = build(method, bits, 7);
        for payload in ["fixed", "rans"] {
            let qt = if payload == "rans" { to_entropy(&qt_fixed) } else { qt_fixed.clone() };
            for &threads in &[1usize, 2, 4] {
                for &batch in &[1usize, 4, 16] {
                    let mut rng = Rng::new(9);
                    let x = Mat::random_normal(batch, 64, 1.0, &mut rng);

                    let slab = StreamingMatmul::new(5, threads).with_mode(ExecMode::Slab);
                    let mut ys = Mat::zeros(batch, 32);
                    let mut ss = DecodeStats::default();
                    slab.matmul(&qt, &x, &mut ys, &mut ss);

                    // one engine called past its LUT warm threshold:
                    // pre-warm calls decode directly, post-warm through
                    // the code→vector table — every call must match
                    let fused = StreamingMatmul::new(5, threads).with_mode(ExecMode::Fused);
                    for call in 0..LUT_WARM_CALLS + 1 {
                        let mut yf = Mat::zeros(batch, 32);
                        let mut sf = DecodeStats::default();
                        fused.matmul(&qt, &x, &mut yf, &mut sf);
                        let ctx = format!(
                            "{method}/b{bits}/{payload} threads={threads} batch={batch} call={call}"
                        );
                        assert_eq!(yf.data, ys.data, "{ctx}: fused output != slab output");
                        assert_eq!(sf, ss, "{ctx}: fused stats != slab stats");
                    }
                }
            }
        }
    }
}

#[test]
fn matvec_into_is_the_exact_batch1_matmul() {
    // the allocation-free matvec path (borrowed x, caller-owned y) must
    // be bit-identical to a 1-row matmul in both modes, with a reused
    // output buffer across calls
    for payload in ["fixed", "rans"] {
        let qt = build("glvq-8d", 2, 13);
        let qt = if payload == "rans" { to_entropy(&qt) } else { qt };
        let mut rng = Rng::new(14);
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        for mode in [ExecMode::Fused, ExecMode::Slab] {
            let engine = StreamingMatmul::new(5, 2).with_mode(mode);
            let xm = Mat::from_vec(1, 64, x.clone());
            let mut ym = Mat::zeros(1, 32);
            let mut sm = DecodeStats::default();
            engine.matmul(&qt, &xm, &mut ym, &mut sm);

            let mut y = vec![999.0f32; 32]; // stale contents must be overwritten
            let mut sv = DecodeStats::default();
            engine.matvec_into(&qt, &x, &mut y, &mut sv);
            assert_eq!(y, ym.data, "{payload}/{}: matvec_into != batch-1 matmul", mode.name());
            assert_eq!(sv, sm, "{payload}/{}: stats drifted", mode.name());
        }
    }
}

/// SIMD contract: |fused_simd − slab| ≤ 1e-4 · (1 + |slab|) elementwise
/// (reduction reorder only — documented in `kernels`), and greedy token
/// decisions (argmax over the output rows) are identical.
#[cfg(feature = "simd")]
mod simd {
    use super::*;

    fn argmax(row: &[f32]) -> usize {
        let mut best = 0;
        for (i, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = i;
            }
        }
        best
    }

    #[test]
    fn simd_fused_within_tolerance_and_token_identical() {
        for payload in ["fixed", "rans"] {
            let qt = build("glvq-8d", 2, 21);
            let qt = if payload == "rans" { to_entropy(&qt) } else { qt };
            let mut rng = Rng::new(22);
            let x = Mat::random_normal(8, 64, 1.0, &mut rng);

            let scalar = StreamingMatmul::new(5, 2).with_mode(ExecMode::Slab);
            let mut ys = Mat::zeros(8, 32);
            let mut ss = DecodeStats::default();
            scalar.matmul(&qt, &x, &mut ys, &mut ss);

            let simd = StreamingMatmul::new(5, 2).with_mode(ExecMode::Fused).with_simd(true);
            let mut yv = Mat::zeros(8, 32);
            let mut sv = DecodeStats::default();
            simd.matmul(&qt, &x, &mut yv, &mut sv);

            for (a, b) in yv.data.iter().zip(&ys.data) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{payload}: simd {a} vs scalar {b} outside documented tolerance"
                );
            }
            for b in 0..8 {
                assert_eq!(
                    argmax(yv.row(b)),
                    argmax(ys.row(b)),
                    "{payload}: greedy token decision diverged on row {b}"
                );
            }
            // stats accounting is mode-independent
            assert_eq!(sv, ss, "{payload}: simd stats drifted");
        }
    }
}
