//! Prefix-sharing cache runtime: property + differential layer (ISSUE 7).
//!
//! 1. **Refcount soundness under arbitrary interleavings** — random
//!    sequences of claim / append / publish / evict / spill / restore /
//!    cold-flush ops against a small arena, with the cache's structural
//!    audit ([`PagedKvCache::check_invariants`]) run after every op:
//!    every arena refcount equals the number of live references, no
//!    refcount-zero page is reachable, the free list is exact.
//! 2. **Evict-then-reinsert round-trips** — a dropped cold prefix is no
//!    longer claimable, republishing the same tokens rebuilds it, and a
//!    later claim matches it fully again.
//! 3. **Copy-on-write never mutates a shared page** — a claim that
//!    diverges mid-page leaves the publisher's rows bit-identical, and
//!    the claimer's shared rows equal the publisher's exactly.
//! 4. **Differential serving** — a seeded multi-turn chat workload
//!    (interleaved begin / continue / finish plus concurrent bursts that
//!    put preemption pressure on a bounded arena) produces byte-identical
//!    responses with prefix sharing off and on, while the sharing run
//!    prefills strictly fewer tokens; the saved tokens are exactly the
//!    claimed ones.

use glvq::coordinator::metrics::ServerMetrics;
use glvq::coordinator::server::{start_continuous, CachedNativeBackend, Request, Response};
use glvq::kvcache::{Kv, KvCacheOpts, PagedKvCache, SeqId, SpilledSeq};
use glvq::model::{init_params, ModelConfig};
use glvq::serving::ContinuousOpts;
use glvq::util::proptest::proptest;
use glvq::util::rng::Rng;

fn share_opts(page_rows: usize, max_pages: usize) -> KvCacheOpts {
    KvCacheOpts { page_rows, prefix_share: true, max_pages, ..Default::default() }
}

/// Append rows for `tokens[start..]` to every (layer, K|V) stream. Row
/// content is a pure function of (token, position, stream), so two
/// sequences that agree on a token prefix hold bit-identical rows there —
/// the same determinism the real forward provides.
fn fill_rows(c: &mut PagedKvCache, s: SeqId, n_layer: usize, tokens: &[i32], start: usize) {
    let w = c.width();
    for (p, &t) in tokens.iter().enumerate().skip(start) {
        for l in 0..n_layer {
            for which in [Kv::K, Kv::V] {
                let stream = (2 * l + usize::from(matches!(which, Kv::V))) as f32;
                let row: Vec<f32> = (0..w)
                    .map(|j| t as f32 + 0.25 * stream + 0.01 * p as f32 + 0.001 * j as f32)
                    .collect();
                c.append(s, l, which, &row).unwrap();
            }
        }
    }
}

/// Concatenated contents of rows `[0, rows)` of every stream of `s`.
fn snap(c: &mut PagedKvCache, s: SeqId, n_layer: usize, rows: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for l in 0..n_layer {
        for which in [Kv::K, Kv::V] {
            let mut v = Vec::new();
            c.visit(s, l, which, rows, |_, chunk| v.extend_from_slice(chunk));
            out.push(v);
        }
    }
    out
}

#[test]
fn refcounts_and_free_lists_survive_random_op_interleavings() {
    proptest(256, |rig| {
        let pr = *rig.choice(&[2usize, 3, 4]);
        let n_layer = rig.usize_in(1, 2);
        let max_pages = *rig.choice(&[0usize, 24, 48]);
        let opts = KvCacheOpts {
            quantize: rig.bool(),
            quantize_shared: rig.bool(),
            ..share_opts(pr, max_pages)
        };
        let mut c = PagedKvCache::new(n_layer, 4, opts);
        // two prompt families with a shared head, so claims, mid-page
        // divergences and dedup publishes all actually occur
        let base: Vec<i32> = (0..16).map(|i| (i % 5) as i32).collect();
        let alt: Vec<i32> = {
            let mut v = base.clone();
            for (i, t) in v.iter_mut().enumerate().skip(6) {
                *t = (i % 3 + 5) as i32;
            }
            v
        };
        let mut live: Vec<(SeqId, Vec<i32>)> = Vec::new();
        let mut parked: Vec<(SpilledSeq, Vec<i32>)> = Vec::new();
        for op in 0..10 {
            match rig.usize_in(0, 6) {
                0 | 1 => {
                    // begin: claim the longest shared prefix, prefill the
                    // rest — shedding the admission when the arena is full
                    let src = if rig.bool() { &base } else { &alt };
                    let len = rig.usize_in(1, 16);
                    let mut tokens = src[..len].to_vec();
                    if rig.bool() {
                        let i = rig.usize_in(0, len - 1);
                        tokens[i] += 11;
                    }
                    let cap = if rig.bool() { len } else { len - 1 };
                    let (sid, claimed) = c.new_seq_shared(&tokens, cap);
                    let need = c.pages_needed(claimed, len - claimed);
                    if c.free_pages().is_some_and(|f| f < need) {
                        c.evict(sid);
                    } else {
                        fill_rows(&mut c, sid, n_layer, &tokens, claimed);
                        live.push((sid, tokens));
                    }
                }
                2 => {
                    // publish mid-flight (idempotent; dedups duplicates)
                    if !live.is_empty() {
                        let i = rig.usize_in(0, live.len() - 1);
                        let (sid, tokens) = (live[i].0, live[i].1.clone());
                        c.publish_prefix(sid, &tokens);
                    }
                }
                3 => {
                    // finish: usually publish, then drop
                    if !live.is_empty() {
                        let i = rig.usize_in(0, live.len() - 1);
                        let (sid, tokens) = live.swap_remove(i);
                        if rig.bool() {
                            c.publish_prefix(sid, &tokens);
                        }
                        c.evict(sid);
                    }
                }
                4 => {
                    // preempt: park the sequence outside the arena
                    if !live.is_empty() {
                        let i = rig.usize_in(0, live.len() - 1);
                        let (sid, tokens) = live.swap_remove(i);
                        let sp = c.spill(sid, rig.bool()).unwrap();
                        parked.push((sp, tokens));
                    }
                }
                5 => {
                    // truncate: the speculative decoder's rejection path —
                    // roll a live sequence back to an arbitrary length,
                    // possibly into its claimed shared prefix. A shared
                    // partial tail CoW-splits (one fresh page per stream),
                    // so skip when the bounded arena can't cover that.
                    let room = !c.free_pages().is_some_and(|f| f < 2 * n_layer);
                    if !live.is_empty() && room {
                        let i = rig.usize_in(0, live.len() - 1);
                        let keep = rig.usize_in(0, live[i].1.len());
                        c.truncate_seq(live[i].0, keep).unwrap();
                        // mirror the trim so later publishes stay honest
                        live[i].1.truncate(keep);
                    }
                }
                _ => {
                    // resume a parked sequence, or flush the cold set
                    if let Some((sp, tokens)) = parked.pop() {
                        match c.restore(sp) {
                            Ok(sid) => live.push((sid, tokens)),
                            // capacity-refused: parked state comes back
                            Err(sp) => parked.push((sp, tokens)),
                        }
                    } else {
                        c.drop_cold_prefixes();
                    }
                }
            }
            if let Err(e) = c.check_invariants() {
                panic!("case {}: after op {op}: {e}", rig.case);
            }
        }
        for (sid, _) in live.drain(..) {
            c.evict(sid);
        }
        parked.clear();
        c.drop_cold_prefixes();
        c.check_invariants().unwrap();
        assert_eq!(c.stats().pages_in_use, 0, "case {}: pages leaked", rig.case);
    });
}

#[test]
fn evicted_prefixes_reinsert_and_claim_cleanly() {
    proptest(256, |rig| {
        let pr = rig.usize_in(2, 4);
        let n_layer = rig.usize_in(1, 2);
        let mut c = PagedKvCache::new(n_layer, 4, share_opts(pr, 0));
        let len = pr * rig.usize_in(1, 3);
        let tokens: Vec<i32> = (0..len).map(|_| rig.usize_in(0, 7) as i32).collect();
        let (a, ca) = c.new_seq_shared(&tokens, len);
        assert_eq!(ca, 0, "case {}: empty index cannot match", rig.case);
        fill_rows(&mut c, a, n_layer, &tokens, 0);
        c.publish_prefix(a, &tokens);
        c.evict(a);
        c.check_invariants().unwrap();
        assert!(c.stats().shared_nodes > 0);
        let freed = c.drop_cold_prefixes();
        assert_eq!(freed, 2 * n_layer * (len / pr), "case {}: cold flush size", rig.case);
        assert_eq!(c.stats().pages_in_use, 0);
        assert_eq!(c.stats().shared_nodes, 0);
        c.check_invariants().unwrap();
        // reinsert: the evicted prefix is gone, republishing the same
        // tokens rebuilds it, and a later claim matches it fully
        let (b, cb) = c.new_seq_shared(&tokens, len);
        assert_eq!(cb, 0, "case {}: evicted prefix must not be claimable", rig.case);
        fill_rows(&mut c, b, n_layer, &tokens, 0);
        c.publish_prefix(b, &tokens);
        c.check_invariants().unwrap();
        let (d, cd) = c.new_seq_shared(&tokens, len);
        assert_eq!(cd, len, "case {}: reinserted prefix claims fully", rig.case);
        c.check_invariants().unwrap();
        c.evict(d);
        c.evict(b);
        c.drop_cold_prefixes();
        assert_eq!(c.stats().pages_in_use, 0);
        c.check_invariants().unwrap();
    });
}

#[test]
fn cow_split_never_mutates_the_shared_pages() {
    proptest(256, |rig| {
        let pr = rig.usize_in(2, 4);
        let n_layer = rig.usize_in(1, 2);
        let mut c = PagedKvCache::new(n_layer, 4, share_opts(pr, 0));
        let la = 3 * pr;
        let ta: Vec<i32> = (0..la).map(|_| rig.usize_in(0, 7) as i32).collect();
        let (a, _) = c.new_seq_shared(&ta, la);
        fill_rows(&mut c, a, n_layer, &ta, 0);
        c.publish_prefix(a, &ta);
        let before = snap(&mut c, a, n_layer, la);
        // a prompt that diverges mid-page: inside full page k, offset off
        let k = rig.usize_in(0, 2);
        let off = rig.usize_in(1, pr - 1);
        let d = k * pr + off;
        let mut tb = ta[..d].to_vec();
        tb.push(ta[d] + 8);
        for _ in 0..rig.usize_in(0, pr) {
            tb.push(rig.usize_in(0, 7) as i32);
        }
        let (b, claimed) = c.new_seq_shared(&tb, tb.len());
        assert_eq!(claimed, d, "case {}: claim stops exactly at the divergence", rig.case);
        assert_eq!(c.stats().cow_splits, 1, "case {}: divergence CoW-splits", rig.case);
        c.check_invariants().unwrap();
        fill_rows(&mut c, b, n_layer, &tb, claimed);
        // the shared pages were read, never written
        assert_eq!(snap(&mut c, a, n_layer, la), before, "case {}: A mutated", rig.case);
        // and the claimer's shared rows equal the publisher's exactly
        assert_eq!(
            snap(&mut c, b, n_layer, d),
            snap(&mut c, a, n_layer, d),
            "case {}: claimed rows diverge from the publisher",
            rig.case
        );
        c.evict(b);
        assert_eq!(snap(&mut c, a, n_layer, la), before, "case {}: evict(B) hit A", rig.case);
        c.check_invariants().unwrap();
        c.evict(a);
        c.drop_cold_prefixes();
        assert_eq!(c.stats().pages_in_use, 0);
        c.check_invariants().unwrap();
    });
}

#[test]
fn truncate_rolls_back_without_touching_shared_pages() {
    proptest(256, |rig| {
        let pr = rig.usize_in(2, 4);
        let n_layer = rig.usize_in(1, 2);
        let mut c = PagedKvCache::new(n_layer, 4, share_opts(pr, 0));
        let la = 3 * pr;
        let ta: Vec<i32> = (0..la).map(|_| rig.usize_in(0, 7) as i32).collect();
        let (a, _) = c.new_seq_shared(&ta, la);
        fill_rows(&mut c, a, n_layer, &ta, 0);
        c.publish_prefix(a, &ta);
        let before = snap(&mut c, a, n_layer, la);
        // B claims the whole published prefix, drafts a few speculative
        // rows past it, then a rejection rolls it back to `keep` — which
        // may land anywhere, including mid-page inside the shared claim
        let mut tb = ta.clone();
        for _ in 0..rig.usize_in(1, pr) {
            tb.push(rig.usize_in(0, 7) as i32);
        }
        let (b, claimed) = c.new_seq_shared(&tb, la);
        assert_eq!(claimed, la, "case {}: full prefix must claim", rig.case);
        fill_rows(&mut c, b, n_layer, &tb, claimed);
        let keep = rig.usize_in(0, tb.len());
        c.truncate_seq(b, keep).unwrap();
        c.check_invariants().unwrap();
        // the publisher's rows are bit-identical no matter where the cut
        // landed: rollback drops references, it never writes shared pages
        assert_eq!(snap(&mut c, a, n_layer, la), before, "case {}: truncate(B) hit A", rig.case);
        // B's surviving shared rows still equal the publisher's
        let shared_keep = keep.min(la);
        assert_eq!(
            snap(&mut c, b, n_layer, shared_keep),
            snap(&mut c, a, n_layer, shared_keep),
            "case {}: B's kept rows diverged",
            rig.case
        );
        // the truncated tail page is appendable again: regrow B to full
        // length and it matches a from-scratch fill exactly
        fill_rows(&mut c, b, n_layer, &tb, keep);
        assert_eq!(
            snap(&mut c, b, n_layer, la),
            before,
            "case {}: regrown rows diverge from the publisher",
            rig.case
        );
        assert_eq!(snap(&mut c, a, n_layer, la), before, "case {}: regrow hit A", rig.case);
        c.evict(b);
        c.evict(a);
        c.drop_cold_prefixes();
        assert_eq!(c.stats().pages_in_use, 0, "case {}: pages leaked", rig.case);
        c.check_invariants().unwrap();
    });
}

// ---------------------------------------------------------------------
// differential serving: shared vs unshared must be byte-identical
// ---------------------------------------------------------------------

fn chat_cfg() -> ModelConfig {
    ModelConfig {
        name: "t",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 96,
        batch_train: 2,
        batch_eval: 2,
    }
}

const SYSTEM: &[u8] = b"system: answer briefly. ";

/// Drive the seeded chat workload against a continuous server built over
/// `kv`: three session slots with interleaved begin / continue / finish,
/// plus concurrent generate bursts whose footprint exceeds the bounded
/// arena (preemption pressure). Returns every response body, every closed
/// transcript, the server metrics, and the number of warm turns (turn ≥ 2
/// of a session — a continue whose full previous transcript was already
/// published, so the sharing run must claim it).
fn run_chat(kv: KvCacheOpts) -> (Vec<Vec<u8>>, Vec<Vec<u8>>, ServerMetrics, usize) {
    let cfg = chat_cfg();
    let handle = start_continuous(
        move || Ok(CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv)),
        ContinuousOpts { max_batch: 8, prefill_chunk: 8, ..Default::default() },
    );
    let mut rng = Rng::new(20260808);
    // (session id, transcript length, turns taken)
    let mut slots: Vec<Option<(u64, usize, usize)>> = vec![None; 3];
    let mut texts: Vec<Vec<u8>> = Vec::new();
    let mut transcripts: Vec<Vec<u8>> = Vec::new();
    let mut warm_turns = 0usize;
    for _ in 0..24 {
        let si = rng.below(slots.len());
        match slots[si] {
            None => {
                let sid = handle.begin_session(SYSTEM);
                slots[si] = Some((sid, SYSTEM.len(), 0));
            }
            Some((sid, tlen, turns)) => {
                // keep prompt + max_new inside the model context
                if tlen > 80 || rng.below(5) == 0 {
                    transcripts.push(handle.end_session(sid).expect("open session"));
                    slots[si] = None;
                } else if rng.below(4) == 0 {
                    // concurrent burst sharing the system prompt: enough
                    // in-flight pages to force preemption on the bounded
                    // arena, answered deterministically regardless
                    let mut rxs = Vec::new();
                    for _ in 0..5 {
                        let mut prompt = SYSTEM.to_vec();
                        for _ in 0..3 {
                            prompt.push(rng.below(256) as u8);
                        }
                        rxs.push(handle.submit(Request::Generate { prompt, max_new: 3 }));
                    }
                    for rx in rxs {
                        match rx.recv().unwrap() {
                            Response::Generated { text } => texts.push(text),
                            other => panic!("burst refused: {other:?}"),
                        }
                    }
                } else {
                    let user: Vec<u8> = (0..2).map(|_| rng.below(256) as u8).collect();
                    let max_new = 1 + rng.below(3);
                    match handle.continue_session(sid, &user, max_new).unwrap() {
                        Response::Generated { text } => {
                            slots[si] = Some((sid, tlen + user.len() + text.len(), turns + 1));
                            if turns >= 1 {
                                warm_turns += 1;
                            }
                            texts.push(text);
                        }
                        other => panic!("turn refused: {other:?}"),
                    }
                }
            }
        }
    }
    for slot in slots.iter_mut() {
        if let Some((sid, _, _)) = slot.take() {
            transcripts.push(handle.end_session(sid).expect("open session"));
        }
    }
    (texts, transcripts, handle.shutdown(), warm_turns)
}

#[test]
fn shared_serving_is_byte_identical_and_prefills_strictly_less() {
    let kv = KvCacheOpts { page_rows: 4, max_pages: 96, ..Default::default() };
    let (t_off, tr_off, m_off, _) = run_chat(kv);
    let (t_on, tr_on, m_on, warm) = run_chat(KvCacheOpts { prefix_share: true, ..kv });

    assert_eq!(t_off, t_on, "prefix sharing must not change any response byte");
    assert_eq!(tr_off, tr_on, "prefix sharing must not change any transcript");
    assert!(!t_on.is_empty() && !tr_on.is_empty(), "workload degenerated");

    // sharing off: the counters stay dark
    assert_eq!(m_off.prefix_hits, 0);
    assert_eq!(m_off.prefix_tokens, 0);

    // sharing on: every warm turn claims its published transcript, the
    // prefill path feeds strictly fewer tokens, and the books balance —
    // saved prefill tokens are exactly the claimed ones
    assert!(warm >= 2, "seed produced too few warm turns ({warm})");
    assert!(m_on.prefix_hits >= warm, "hits {} < warm turns {warm}", m_on.prefix_hits);
    assert!(
        m_on.prefill_tokens < m_off.prefill_tokens,
        "sharing prefilled {} tokens, unshared {}",
        m_on.prefill_tokens,
        m_off.prefill_tokens
    );
    // the books balance: the prefill gap is the claimed tokens, up to
    // one token of chunk-accounting slack per request (a feed with a
    // single pending token is a decode step, not a prefill chunk, and
    // where that boundary lands differs between the two runs)
    let gap = m_off.prefill_tokens - m_on.prefill_tokens;
    let slack = t_on.len();
    assert!(
        gap + slack >= m_on.prefix_tokens && gap <= m_on.prefix_tokens + slack,
        "prefill gap {gap} vs claimed {} (slack {slack})",
        m_on.prefix_tokens
    );

    let stats = m_on.kv_cache.expect("cached backend reports kv stats");
    assert!(stats.prefix_hits >= warm);
    assert!(stats.prefix_hit_rows >= m_on.prefix_tokens);
    assert!(stats.shared_nodes >= 1, "published prefixes stay resident");
}
