//! Continuous-batching correctness (ISSUE 4 acceptance):
//!
//! 1. **Per-request outputs under continuous batching are identical to
//!    sequential execution** on f32 KV pages — across mixed
//!    generate/score traffic, chunked prefill, mid-flight admission, and
//!    **forced preemption + resume**. The scheduler may reorder *work*,
//!    never *results*: every per-row op of the ragged forward is
//!    independent of batch composition, and an f32 spill/restore is
//!    bit-exact.
//! 2. **Quantize-to-spill stays within the documented NLL tolerance**:
//!    when a preempted sequence's pages go through the 8-bit KV
//!    quantizer, its scores drift ≤ 0.15 nats/token from the exact f32
//!    path (the same contract as `tests/kvcache_parity.rs`).

use std::time::Instant;

use glvq::coordinator::server::{CachedNativeBackend, LmBackend, NativeBackend, Request, Response};
use glvq::eval::native_fwd::argmax_logit;
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::serving::{ContinuousOpts, ContinuousScheduler};

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 48,
        batch_train: 2,
        batch_eval: 2,
    }
}

/// Ground truth: serve one request alone against the cacheless backend
/// (full recompute every step — the seed semantics everything else is
/// measured against).
fn sequential_answer(cfg: &ModelConfig, seed: u64, request: &Request) -> Response {
    let mut backend = NativeBackend { cfg: *cfg, store: init_params(cfg, seed) };
    match request {
        Request::Generate { prompt, max_new } => {
            let mut toks: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
            let start = toks.len();
            for _ in 0..*max_new {
                let logits = backend.logits_last(&toks).expect("forward failed");
                toks.push(argmax_logit(&logits));
            }
            Response::Generated {
                text: toks[start..].iter().map(|&t| t.clamp(0, 255) as u8).collect(),
            }
        }
        Request::Score { prompt, continuation } => {
            let mut toks: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
            let mut logprob = 0.0f64;
            for &b in continuation {
                let row = backend.logits_last(&toks).expect("forward failed");
                let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                let lse: f32 = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
                logprob += (row[b as usize] - lse) as f64;
                toks.push(b as i32);
            }
            Response::Scored { logprob }
        }
    }
}

/// Drive a continuous scheduler to completion over `requests`, with
/// request `i` submitted after `arrive_after[i]` scheduler steps (0 =
/// up-front). Returns responses in submission order.
fn continuous_answers(
    cfg: &ModelConfig,
    seed: u64,
    kv: KvCacheOpts,
    opts: ContinuousOpts,
    requests: &[Request],
    arrive_after: &[usize],
) -> (Vec<Response>, glvq::coordinator::metrics::ServerMetrics) {
    assert_eq!(requests.len(), arrive_after.len());
    let backend = CachedNativeBackend::dense(*cfg, init_params(cfg, seed), kv);
    let mut sched = ContinuousScheduler::new(backend, opts);
    let mut ids: Vec<Option<u64>> = vec![None; requests.len()];
    let mut responses: Vec<Option<Response>> = vec![None; requests.len()];
    let mut steps = 0usize;
    loop {
        for (i, req) in requests.iter().enumerate() {
            if ids[i].is_none() && arrive_after[i] <= steps {
                ids[i] = Some(sched.submit(req.clone(), Instant::now()).expect("admission"));
            }
        }
        sched.step();
        steps += 1;
        for (rid, resp) in sched.drain_finished() {
            let slot = ids
                .iter()
                .position(|id| *id == Some(rid))
                .expect("response for unknown request");
            responses[slot] = Some(resp);
        }
        if ids.iter().all(|id| id.is_some()) && !sched.has_work() {
            break;
        }
        assert!(steps < 2000, "scheduler did not converge");
    }
    let metrics = sched.into_metrics();
    (responses.into_iter().map(|r| r.expect("all answered")).collect(), metrics)
}

fn assert_same(a: &Response, b: &Response, what: &str) {
    match (a, b) {
        (Response::Generated { text: ta }, Response::Generated { text: tb }) => {
            assert_eq!(ta, tb, "{what}: generation diverged")
        }
        (Response::Scored { logprob: la }, Response::Scored { logprob: lb }) => {
            assert!((la - lb).abs() < 1e-12, "{what}: {la} vs {lb}")
        }
        other => panic!("{what}: mismatched kinds {other:?}"),
    }
}

#[test]
fn continuous_batching_matches_sequential_execution_exactly() {
    // mixed lengths, chunked prefill (chunk 4 « prompt 20), staggered
    // arrivals joining mid-flight — every output must equal serving the
    // request alone on the cacheless backend
    let cfg = tiny_cfg();
    let requests = vec![
        Request::Generate { prompt: b"the kama ".to_vec(), max_new: 12 },
        Request::Generate { prompt: b"a much longer prompt".to_vec(), max_new: 4 },
        Request::Score { prompt: b"the ".to_vec(), continuation: b"kam".to_vec() },
        Request::Generate { prompt: b"Boku ".to_vec(), max_new: 2 },
        Request::Score { prompt: b"a longer scoring p".to_vec(), continuation: b"rompt".to_vec() },
    ];
    let arrive = vec![0, 0, 2, 5, 9];
    let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
    let opts = ContinuousOpts { prefill_chunk: 4, ..Default::default() };
    let (got, metrics) = continuous_answers(&cfg, 0, kv, opts, &requests, &arrive);
    for (i, (req, resp)) in requests.iter().zip(&got).enumerate() {
        let want = sequential_answer(&cfg, 0, req);
        assert_same(resp, &want, &format!("request {i}"));
    }
    assert_eq!(metrics.requests, requests.len());
    assert!(metrics.prefill_chunks >= 5, "long prompts must be chunked");
    assert!(metrics.seqs_per_step.quantile(1.0) >= 2.0, "requests must share step batches");
    assert_eq!(metrics.preemptions, 0, "unbounded arena never preempts");
    let kv_stats = metrics.kv_cache.expect("cache-aware backend reports kv stats");
    assert_eq!(kv_stats.pages_in_use, 0, "retirement frees every page");
}

#[test]
fn forced_preemption_and_resume_stay_bit_identical_on_f32_pages() {
    // arena of 24 pages; each request peaks at 20 (2 layers × 2 streams ×
    // 5 pages), so two concurrent requests must preempt — and the f32
    // spill/restore must leave every output untouched
    let cfg = tiny_cfg();
    let requests = vec![
        Request::Generate { prompt: b"first in".to_vec(), max_new: 12 },
        Request::Generate { prompt: b"second i".to_vec(), max_new: 12 },
    ];
    let arrive = vec![0, 0];
    let kv = KvCacheOpts { page_rows: 4, max_pages: 24, ..Default::default() };
    let opts = ContinuousOpts { prefill_chunk: 4, ..Default::default() };
    let (got, metrics) = continuous_answers(&cfg, 1, kv, opts, &requests, &arrive);
    for (i, (req, resp)) in requests.iter().zip(&got).enumerate() {
        let want = sequential_answer(&cfg, 1, req);
        assert_same(resp, &want, &format!("request {i}"));
    }
    assert!(metrics.preemptions >= 1, "24-page arena must force a preemption");
    assert!(metrics.resumes >= 1, "the preempted sequence must resume");
    let kv_stats = metrics.kv_cache.expect("kv stats");
    assert!(kv_stats.pages_spilled > 0 && kv_stats.pages_restored > 0);
    assert_eq!(kv_stats.pages_quantized, 0, "f32 spill never quantizes");
    assert_eq!(kv_stats.pages_in_use, 0);
}

#[test]
fn quantize_to_spill_stays_within_documented_nll_tolerance() {
    // same forced-preemption shape, but spilled pages go through the
    // 8-bit KV quantizer: the preempted score may drift, bounded by the
    // documented 0.15 nats/token contract
    const NLL_TOL_PER_TOKEN: f64 = 0.15;
    let cfg = tiny_cfg();
    let requests = vec![
        Request::Generate { prompt: b"first in".to_vec(), max_new: 12 },
        Request::Score { prompt: b"second i".to_vec(), continuation: b"n line, sure".to_vec() },
    ];
    let arrive = vec![0, 0];
    let kv = KvCacheOpts { page_rows: 4, max_pages: 24, kv_bits: 8, ..Default::default() };
    let opts = ContinuousOpts { prefill_chunk: 4, quantize_spill: true, ..Default::default() };
    let (got, metrics) = continuous_answers(&cfg, 2, kv, opts, &requests, &arrive);
    assert!(metrics.preemptions >= 1, "preemption must actually happen");
    let kv_stats = metrics.kv_cache.expect("kv stats");
    assert!(kv_stats.pages_quantized > 0, "quantize-to-spill compresses spilled pages");

    // the never-preempted generation stays exact
    let want0 = sequential_answer(&cfg, 2, &requests[0]);
    assert_same(&got[0], &want0, "unpreempted request");

    // the preempted score stays within the documented tolerance
    let got_lp = match &got[1] {
        Response::Scored { logprob } => *logprob,
        other => panic!("expected score, got {other:?}"),
    };
    let want_lp = match sequential_answer(&cfg, 2, &requests[1]) {
        Response::Scored { logprob } => logprob,
        other => panic!("sequential reference must score, got {other:?}"),
    };
    let cont_len = match &requests[1] {
        Request::Score { continuation, .. } => continuation.len(),
        _ => unreachable!(),
    };
    let per_tok = (got_lp - want_lp).abs() / cont_len as f64;
    assert!(
        per_tok < NLL_TOL_PER_TOKEN,
        "quantized spill drifted {per_tok:.4} nats/token (tolerance {NLL_TOL_PER_TOKEN})"
    );
    assert!(got_lp.is_finite() && want_lp.is_finite());
}

#[test]
fn continuous_backpressure_is_structured_and_recoverable() {
    // overflowing requests are refused with reasons; the queue bound
    // sheds load; feasible traffic keeps flowing on the same scheduler
    let cfg = tiny_cfg(); // seq_len 48
    let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
    let opts = ContinuousOpts { max_queue: 2, ..Default::default() };
    let backend = CachedNativeBackend::dense(cfg, init_params(&cfg, 3), kv);
    let mut sched = ContinuousScheduler::new(backend, opts);
    let now = Instant::now();
    let err = sched
        .submit(Request::Generate { prompt: vec![b'x'; 40], max_new: 20 }, now)
        .unwrap_err();
    assert!(err.to_string().contains("context"), "{err}");
    // fill the bounded queue
    let a = sched.submit(Request::Generate { prompt: b"aa".to_vec(), max_new: 2 }, now).unwrap();
    let b = sched.submit(Request::Generate { prompt: b"bb".to_vec(), max_new: 2 }, now).unwrap();
    let err = sched
        .submit(Request::Generate { prompt: b"cc".to_vec(), max_new: 2 }, now)
        .unwrap_err();
    assert!(err.to_string().contains("queue full"), "{err}");
    // queued work still completes
    let mut done = Vec::new();
    for _ in 0..100 {
        if !sched.has_work() {
            break;
        }
        sched.step();
        done.extend(sched.drain_finished());
    }
    assert_eq!(done.len(), 2);
    assert!(done.iter().any(|d| d.0 == a) && done.iter().any(|d| d.0 == b));
    assert_eq!(sched.metrics().rejections.total(), 2);
}
