//! Host-side stub of the `xla` PJRT binding API.
//!
//! The build environment is fully offline and does not ship the native
//! `xla_extension` runtime, so this crate provides the exact API surface
//! `glvq::runtime` uses with two behaviours:
//!
//! - **Host-side literal math is real**: [`Literal`] construction, reshape,
//!   element access and conversion round-trip exactly (unit-testable
//!   without any native library).
//! - **Device paths return a structured error**: compiling an HLO module,
//!   uploading buffers, and executing all fail with a clear
//!   "PJRT runtime unavailable" [`Error`]. Callers that probe for
//!   artifacts (`Engine::new` + `engine.load(..)`) degrade gracefully —
//!   integration tests print their SKIP message exactly as they do when
//!   the artifacts directory is absent.
//!
//! Swapping this path dependency for the real bindings re-enables the
//! AOT-artifact execution paths with no source changes in `glvq`.

use std::fmt;

/// Error type for all stubbed operations.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error {
        msg: format!(
            "{what}: PJRT/XLA native runtime unavailable (vendored stub `xla` crate; \
             link the real xla bindings to enable device execution)"
        ),
    }
}

fn err(msg: String) -> Error {
    Error { msg }
}

// ---------------------------------------------------------------------------
// Literals (fully functional host-side)
// ---------------------------------------------------------------------------

/// Element payload: the two dtypes the workspace uses. Public only because
/// the [`NativeType`] trait methods mention it; not part of the stable API.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Sealed-ish dtype trait for generic literal accessors.
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Payload;
    fn slice(p: &Payload) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Payload {
        Payload::F32(v)
    }
    fn slice(p: &Payload) -> Option<&[f32]> {
        match p {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Payload {
        Payload::I32(v)
    }
    fn slice(p: &Payload) -> Option<&[i32]> {
        match p {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host literal: typed buffer + dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { payload: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.payload.len() {
            return Err(err(format!(
                "reshape: {} elements into shape {:?}",
                self.payload.len(),
                dims
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.payload.len()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice(&self.payload)
            .map(|s| s.to_vec())
            .ok_or_else(|| err("to_vec: dtype mismatch".to_string()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::slice(&self.payload)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| err("get_first_element: empty or dtype mismatch".to_string()))
    }

    /// Decompose a tuple literal — only device executions produce tuples,
    /// so in the stub this is unreachable through working code paths.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::decompose_tuple"))
    }
}

impl From<f32> for Literal {
    fn from(v: f32) -> Literal {
        Literal { payload: Payload::F32(vec![v]), dims: vec![] }
    }
}

// ---------------------------------------------------------------------------
// PJRT objects (stubbed device paths)
// ---------------------------------------------------------------------------

/// Stub PJRT client. Construction succeeds (so manifest parsing and
/// inventory paths work); any device operation errors.
#[derive(Clone, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

/// Stub device buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Stub HLO module proto. Parsing always reports the runtime unavailable —
/// callers treat it exactly like a missing artifact.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn i32_literals_and_scalars() {
        let l = Literal::vec1(&[5i32, 6]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, 6]);
        let s = Literal::from(2.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        assert_eq!(s.element_count(), 1);
    }

    #[test]
    fn device_paths_report_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let e = c.buffer_from_host_literal(None, &Literal::from(0.0)).unwrap_err();
        assert!(e.to_string().contains("unavailable"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
