//! Vendored minimal reimplementation of the `anyhow` error-handling API.
//!
//! The build environment for this repository is fully offline, so the real
//! crates.io `anyhow` cannot be fetched. This crate provides the exact
//! subset the workspace uses, with the same semantics:
//!
//! - [`Error`]: an opaque, `Send + Sync` error value wrapping any
//!   `std::error::Error`, with a source chain and chain-walking
//!   [`Error::downcast_ref`].
//! - [`Result`]: `std::result::Result` defaulted to [`Error`].
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, attaching a message while preserving the source chain.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros (format-string forms).
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` itself — that is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// An opaque error value with a source chain.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// A plain-message error (what `anyhow!`/`bail!` produce).
struct MessageError(String);

impl Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context layer wrapping an underlying error.
struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.context, self.source)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        let src: &(dyn StdError + 'static) = &*self.source;
        Some(src)
    }
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Wrap a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }

    /// Attach a context message, keeping `self` as the source.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error {
            inner: Box::new(ContextError { context: context.to_string(), source: self.inner }),
        }
    }

    /// Find the first error of type `E` anywhere in the source chain
    /// (the outermost context layer first).
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        let first: &(dyn StdError + 'static) = &*self.inner;
        let mut cur: Option<&(dyn StdError + 'static)> = Some(first);
        while let Some(err) = cur {
            if let Some(hit) = err.downcast_ref::<E>() {
                return Some(hit);
            }
            cur = err.source();
        }
        None
    }

    /// The outermost message (without the source chain).
    pub fn to_string_outer(&self) -> String {
        self.inner.to_string()
    }

    /// Iterate over the source chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        let first: &(dyn StdError + 'static) = &*self.inner;
        Chain { next: Some(first) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur: &(dyn StdError + 'static) = &*self.inner;
        while let Some(src) = cur.source() {
            cur = src;
        }
        cur
    }
}

/// Iterator over an error's source chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.inner, f)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(err) = source {
            write!(f, "\n    {err}")?;
            source = err.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `std::result::Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` extension for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Leaf(u32);

    impl Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "leaf {}", self.0)
        }
    }

    impl StdError for Leaf {}

    #[test]
    fn from_and_downcast_through_context() {
        let err: Error = Error::new(Leaf(7)).context("outer").context("outermost");
        assert_eq!(err.to_string(), "outermost");
        assert_eq!(err.downcast_ref::<Leaf>(), Some(&Leaf(7)));
        assert_eq!(err.root_cause().to_string(), "leaf 7");
        assert_eq!(err.chain().count(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let v = String::from_utf8(vec![0xff])?;
            Ok(v)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context_and_macros() {
        let missing: Option<u32> = None;
        let e = missing.context("nothing here").unwrap_err();
        assert_eq!(e.to_string(), "nothing here");

        fn failing(x: u32) -> Result<u32> {
            ensure!(x > 2, "x too small: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(failing(2).unwrap_err().to_string(), "x too small: 2");
        assert_eq!(failing(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(failing(4).unwrap(), 4);
    }

    #[test]
    fn debug_prints_cause_chain() {
        let err = Error::new(Leaf(1)).context("ctx");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("ctx") && dbg.contains("Caused by") && dbg.contains("leaf 1"), "{dbg}");
    }
}
