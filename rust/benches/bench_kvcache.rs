//! Bench: KV-cache serving — prefill vs decode tokens/s and cache
//! bytes/token over the {uncached, cached-f32, cached-q4} × batch
//! {1, 4, 16} grid, all on the same tiny model and prompts.
//!
//! The uncached backend re-runs the full padded forward for every
//! generated token (O(T²) per sequence); the cache-aware backend prefills
//! once and then takes O(T) one-token lockstep steps. "cached-q4" retires
//! full KV pages through the grouped lattice quantizer at 4 bits.
//!
//! Asserted acceptance (ISSUE 3): at batch 4 on a 256-token generation,
//! cached-f32 decode reaches ≥ 3× the uncached tokens/s *and* generates
//! bit-identical tokens. Off-assert cells use a shorter generation to
//! keep the bench quick; each JSON record carries its `gen` length.
//!
//! Results are appended to `runs/bench/kvcache.json` so successive runs
//! form a trajectory (`{"runs": [...]}`).
//!
//! Run: `cargo bench --bench bench_kvcache`

use std::time::Instant;

use glvq::coordinator::server::{CachedNativeBackend, LmBackend, NativeBackend};
use glvq::eval::native_fwd::argmax_logit;
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::bench_support::append_trajectory;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

const PROMPT: usize = 8;
const GEN_ASSERT: usize = 256; // batch-4 cells (the asserted ≥256-token run)
const GEN_QUICK: usize = 64; // other cells

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "kvbench",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 288,
        batch_train: 4,
        batch_eval: 4,
    }
}

struct Cell {
    prefill_ms: f64,
    decode_tok_s: f64,
    cache_bytes_per_tok: f64,
    generated: Vec<Vec<i32>>,
}

/// Lockstep-generate `gen` tokens per sequence; the first call is the
/// prefill (timed separately), the remaining `gen − 1` are decode steps.
fn run_cell(backend: &mut dyn LmBackend, prompts: &[Vec<i32>], gen: usize) -> Cell {
    let mut prefixes = prompts.to_vec();
    let t0 = Instant::now();
    let views: Vec<&[i32]> = prefixes.iter().map(|p| p.as_slice()).collect();
    let first = backend.logits_last_batch(&views).expect("prefill failed");
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
    for (p, l) in prefixes.iter_mut().zip(&first) {
        p.push(argmax_logit(l));
    }
    let t1 = Instant::now();
    for _ in 1..gen {
        let views: Vec<&[i32]> = prefixes.iter().map(|p| p.as_slice()).collect();
        let logits = backend.logits_last_batch(&views).expect("decode step failed");
        for (p, l) in prefixes.iter_mut().zip(&logits) {
            p.push(argmax_logit(l));
        }
    }
    let decode_secs = t1.elapsed().as_secs_f64().max(1e-9);
    let cached_tokens: usize = prefixes.iter().map(|p| p.len()).sum();
    let cache_bytes_per_tok = backend
        .cache_stats()
        .map(|s| s.bytes_in_use as f64 / cached_tokens as f64)
        .unwrap_or(0.0);
    backend.end_batch();
    Cell {
        prefill_ms,
        decode_tok_s: (prompts.len() * (gen - 1)) as f64 / decode_secs,
        cache_bytes_per_tok,
        generated: prefixes
            .iter()
            .zip(prompts)
            .map(|(p, q)| p[q.len()..].to_vec())
            .collect(),
    }
}

fn main() {
    let cfg = bench_cfg();
    let store = init_params(&cfg, 0);
    println!(
        "# kv-cache serving: d={} L={} seq={} — mode x batch grid, prompt {PROMPT}",
        cfg.d_model, cfg.n_layer, cfg.seq_len
    );
    let kv_f32 = KvCacheOpts { page_rows: 16, ..Default::default() };
    let kv_q4 =
        KvCacheOpts { page_rows: 16, quantize: true, kv_bits: 4, ..Default::default() };
    let mut entries: Vec<Json> = Vec::new();
    let mut assert_cells: Vec<(String, f64, Vec<Vec<i32>>)> = Vec::new();

    for &batch in &[1usize, 4, 16] {
        let gen = if batch == 4 { GEN_ASSERT } else { GEN_QUICK };
        let mut rng = Rng::new(100 + batch as u64);
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|_| (0..PROMPT).map(|_| rng.below(256) as i32).collect())
            .collect();
        for mode in ["uncached", "cached-f32", "cached-q4"] {
            let mut backend: Box<dyn LmBackend> = match mode {
                "uncached" => Box::new(NativeBackend { cfg, store: store.clone() }),
                "cached-f32" => Box::new(CachedNativeBackend::dense(cfg, store.clone(), kv_f32)),
                _ => Box::new(CachedNativeBackend::dense(cfg, store.clone(), kv_q4)),
            };
            let cell = run_cell(&mut *backend, &prompts, gen);
            println!(
                "{mode:<11} b{batch:<3} gen {gen:<4} prefill {:>8.1} ms  decode {:>9.1} tok/s  kv {:>7.1} B/tok",
                cell.prefill_ms, cell.decode_tok_s, cell.cache_bytes_per_tok
            );
            entries.push(Json::obj(vec![
                ("mode", Json::str(mode)),
                ("batch", Json::num(batch as f64)),
                ("gen", Json::num(gen as f64)),
                ("prefill_ms", Json::num(cell.prefill_ms)),
                ("decode_tok_s", Json::num(cell.decode_tok_s)),
                ("cache_bytes_per_tok", Json::num(cell.cache_bytes_per_tok)),
            ]));
            if batch == 4 {
                assert_cells.push((mode.to_string(), cell.decode_tok_s, cell.generated));
            }
        }
    }

    // ---- acceptance: ≥ 3× decode speedup at batch 4, identical tokens ----
    let uncached = assert_cells.iter().find(|c| c.0 == "uncached").expect("uncached cell");
    let cached = assert_cells.iter().find(|c| c.0 == "cached-f32").expect("cached cell");
    let speedup = cached.1 / uncached.1.max(1e-9);
    println!("  cached-f32 vs uncached decode at batch 4: {speedup:.2}x tokens/s");
    assert!(
        cached.2 == uncached.2,
        "f32-cached generation diverged from the uncached path"
    );
    assert!(
        speedup >= 3.0,
        "kv cache only {speedup:.2}x over full recompute at batch 4 (need >= 3x)"
    );

    append_trajectory("kvcache", vec![("measurements", Json::Arr(entries))]);
}
