//! Bench: self-speculative decoding from the GLVQ container (ISSUE 8
//! acceptance).
//!
//! All cells serve the same entropy-coded 3-bit streaming container (the
//! regime where every target step pays a real rANS panel-decode), and
//! the speculative cells draft through the in-memory fixed-rate 2-bit
//! view of the same weights:
//!
//! - **batch-1** — a sequential greedy decode loop driven straight over
//!   the [`SeqBackend`] surface: `target-only` vs `speculate-{2,4,8}`.
//!   The speculative speedup lives or dies here: one ragged target
//!   forward verifies k drafted tokens, and every accepted token is a
//!   target forward that never ran.
//! - **continuous** — the same comparison through the continuous
//!   scheduler under a concurrent request burst (`target-only` vs
//!   `speculate-4`), where verify batching across sequences shares the
//!   per-step whole-model decode.
//!
//! Asserted acceptance: every speculative cell's outputs are
//! **bit-identical** to target-only decode (greedy acceptance is exact —
//! asserted in smoke mode too), and in full mode the best batch-1
//! speculative cell reaches **≥ 1.2×** target-only tokens/s. The
//! per-cell accept rate is the paper tie-in: it measures how faithfully
//! the 2-bit lattice view tracks the variable-rate target, so the
//! `accept_rate` trajectory key doubles as a draft-quality metric.
//!
//! Results append to `runs/bench/spec.json` (`{"runs": [...]}`), with
//! headline `accept_rate` and `spec_decode_speedup` keys plus a
//! per-cell measurement array. `GLVQ_BENCH_SMOKE=1` runs a miniature
//! workload for CI: parity and counter checks, speedup reported but not
//! asserted.
//!
//! Run: `cargo bench --bench bench_spec`

use std::time::Instant;

use glvq::baselines::rtn::RtnQuantizer;
use glvq::bench_support::append_trajectory;
use glvq::coordinator::decode_stream::StreamingMatmul;
use glvq::coordinator::server::{self, CachedNativeBackend, Request, Response, ServerHandle};
use glvq::eval::native_fwd::{self, CalibCapture};
use glvq::glvq::pipeline::{quantize_model, PipelineOpts};
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::quant::format::QuantizedModel;
use glvq::serving::{ContinuousOpts, SeqBackend};
use glvq::spec::SpeculativeBackend;
use glvq::tensor::TensorStore;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

fn smoke() -> bool {
    std::env::var("GLVQ_BENCH_SMOKE").is_ok()
}

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "specbench",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 160,
        batch_train: 2,
        batch_eval: 2,
    }
}

/// Quantize the bench model once into an entropy-coded container; every
/// cell serves from clones of the same parts.
fn quantized_parts(cfg: &ModelConfig) -> (TensorStore, QuantizedModel) {
    let store = init_params(cfg, 0);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
    let mut cap = CalibCapture::new(16, 0);
    native_fwd::forward(cfg, &store, &toks, 2, Some(&mut cap)).expect("calibration forward");
    let calib = cap.into_calib_set();
    let mut opts = PipelineOpts::default();
    opts.target_bits = 3.0;
    opts.bit_allocation = false;
    opts.entropy = true;
    let (qm, _) =
        quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).expect("quantize");
    (store, qm)
}

/// Last-maximal argmax, matching the serving loops' tie-breaking.
fn argmax(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Sequential batch-1 greedy decode over the raw [`SeqBackend`] surface:
/// `n_new` tokens per prompt, timed over the whole loop.
fn greedy_cell<B: SeqBackend>(
    b: &mut B,
    prompts: &[Vec<i32>],
    n_new: usize,
) -> (Vec<Vec<i32>>, f64) {
    let t0 = Instant::now();
    let mut outs = Vec::with_capacity(prompts.len());
    for p in prompts {
        let sid = b.begin_seq();
        let m = b.step_ragged(&[(sid, &p[..])]).expect("prefill");
        let mut last = argmax(m.row(m.rows - 1));
        let mut out = vec![last];
        for _ in 1..n_new {
            let m = b.step_ragged(&[(sid, std::slice::from_ref(&last))]).expect("decode step");
            last = argmax(m.row(m.rows - 1));
            out.push(last);
        }
        b.retire_seq(sid);
        outs.push(out);
    }
    (outs, t0.elapsed().as_secs_f64())
}

/// Submit the concurrent burst, wait for every reply, return the
/// response bytes, the wall time, and the final server metrics.
fn continuous_cell(
    handle: ServerHandle,
    prompts: &[Vec<u8>],
    n_new: usize,
) -> (Vec<Vec<u8>>, f64, glvq::coordinator::metrics::ServerMetrics) {
    let t0 = Instant::now();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| handle.submit(Request::Generate { prompt: p.clone(), max_new: n_new }))
        .collect();
    let mut outs = Vec::with_capacity(rxs.len());
    for rx in rxs {
        match rx.recv().expect("server dropped reply") {
            Response::Generated { text } => outs.push(text),
            other => panic!("unexpected response {other:?}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    (outs, wall, handle.shutdown())
}

fn main() {
    let cfg = bench_cfg();
    let (n_prompts, n_new, burst, burst_new) =
        if smoke() { (2, 8, 4, 6) } else { (4, 64, 8, 32) };
    let (store, qm) = quantized_parts(&cfg);
    let kv = KvCacheOpts { page_rows: 16, ..Default::default() };
    let mk = || {
        let engine = StreamingMatmul::new(16, 1);
        CachedNativeBackend::streaming(cfg, store.clone(), qm.clone(), engine, kv)
    };
    println!(
        "# spec: d={} L={} seq={} — {} prompts x {} tok batch-1, burst {} x {} tok, {}",
        cfg.d_model,
        cfg.n_layer,
        cfg.seq_len,
        n_prompts,
        n_new,
        burst,
        burst_new,
        if smoke() { "smoke" } else { "full" },
    );

    let prompts: Vec<Vec<i32>> = (0..n_prompts)
        .map(|p| (0..12).map(|i| ((p * 37 + i * 11) % 251) as i32).collect())
        .collect();
    let total = (n_prompts * n_new) as f64;

    // ---- batch-1 cells ----
    let mut base = mk();
    let (ref_outs, ref_wall) = greedy_cell(&mut base, &prompts, n_new);
    let base_tok_s = total / ref_wall.max(1e-9);
    println!("target-only         {base_tok_s:>8.1} tok/s  wall {:>8.1} ms", ref_wall * 1e3);

    let mut entries: Vec<Json> = Vec::new();
    entries.push(Json::obj(vec![
        ("mode", Json::str("target-only")),
        ("tokens", Json::num(total)),
        ("tok_s", Json::num(base_tok_s)),
        ("wall_ms", Json::num(ref_wall * 1e3)),
    ]));

    let mut best_speedup = 0.0f64;
    let mut headline_accept = 0.0f64;
    let mut headline_speedup = 0.0f64;
    for k in [2usize, 4, 8] {
        let mut spec = SpeculativeBackend::new(mk(), k).expect("draft view builds");
        let draft_bytes = spec.draft_view().total_bytes();
        let (outs, wall) = greedy_cell(&mut spec, &prompts, n_new);
        assert_eq!(outs, ref_outs, "speculate-{k}: outputs diverged from target-only");
        let s = spec.spec_counters();
        assert!(s.rounds > 0 && s.drafted > 0, "speculate-{k}: no drafting happened");
        let tok_s = total / wall.max(1e-9);
        let speedup = tok_s / base_tok_s.max(1e-9);
        let accept = s.accept_rate();
        println!(
            "speculate-{k}         {tok_s:>8.1} tok/s  wall {:>8.1} ms  {speedup:.2}x  accept {accept:.2}  ({} drafted, {} rollback rows, draft view {} B)",
            wall * 1e3,
            s.drafted,
            s.rollback_rows,
            draft_bytes,
        );
        entries.push(Json::obj(vec![
            ("mode", Json::str(&format!("speculate-{k}"))),
            ("k", Json::num(k as f64)),
            ("tokens", Json::num(total)),
            ("tok_s", Json::num(tok_s)),
            ("wall_ms", Json::num(wall * 1e3)),
            ("speedup", Json::num(speedup)),
            ("accept_rate", Json::num(accept)),
            ("drafted", Json::num(s.drafted as f64)),
            ("accepted", Json::num(s.accepted as f64)),
            ("rounds", Json::num(s.rounds as f64)),
            ("verify_calls", Json::num(s.verify_calls as f64)),
            ("rollback_rows", Json::num(s.rollback_rows as f64)),
            ("draft_bytes", Json::num(draft_bytes as f64)),
        ]));
        best_speedup = best_speedup.max(speedup);
        if k == 4 {
            headline_accept = accept;
            headline_speedup = speedup;
        }
    }
    println!("  best batch-1 speculative speedup: {best_speedup:.2}x");
    if smoke() {
        println!("  (smoke mode: speedup not asserted)");
    } else {
        assert!(
            best_speedup >= 1.2,
            "speculative decode only {best_speedup:.2}x over target-only at batch 1 (need >= 1.2x)"
        );
    }

    // ---- continuous cells ----
    let burst_prompts: Vec<Vec<u8>> = (0..burst)
        .map(|p| (0..10).map(|i| ((p * 53 + i * 17) % 251) as u8).collect())
        .collect();
    let copts = ContinuousOpts { max_batch: 8, prefill_chunk: 16, ..Default::default() };
    let burst_total = (burst * burst_new) as f64;
    let mk_plain = {
        let (cfg, store, qm) = (cfg, store.clone(), qm.clone());
        move || {
            let engine = StreamingMatmul::new(16, 1);
            Ok(CachedNativeBackend::streaming(cfg, store, qm, engine, kv))
        }
    };
    let mk_spec = {
        let (cfg, store, qm) = (cfg, store.clone(), qm.clone());
        move || {
            let engine = StreamingMatmul::new(16, 1);
            SpeculativeBackend::new(
                CachedNativeBackend::streaming(cfg, store, qm, engine, kv),
                4,
            )
        }
    };
    let (cont_ref, wall_plain, m_plain) =
        continuous_cell(server::start_continuous(mk_plain, copts), &burst_prompts, burst_new);
    let (cont_spec, wall_spec, m_spec) =
        continuous_cell(server::start_continuous(mk_spec, copts), &burst_prompts, burst_new);
    assert_eq!(cont_spec, cont_ref, "continuous speculate-4: outputs diverged");
    assert!(m_plain.spec.is_none(), "plain continuous cell must not report spec counters");
    let cs = m_spec.spec.expect("speculative continuous cell reports counters");
    assert!(cs.rounds > 0 && cs.accepted <= cs.drafted);
    let cont_plain_tok_s = burst_total / wall_plain.max(1e-9);
    let cont_spec_tok_s = burst_total / wall_spec.max(1e-9);
    let cont_speedup = cont_spec_tok_s / cont_plain_tok_s.max(1e-9);
    println!(
        "continuous          {cont_plain_tok_s:>8.1} tok/s  wall {:>8.1} ms",
        wall_plain * 1e3
    );
    println!(
        "continuous-spec-4   {cont_spec_tok_s:>8.1} tok/s  wall {:>8.1} ms  {cont_speedup:.2}x  accept {:.2}",
        wall_spec * 1e3,
        cs.accept_rate(),
    );
    entries.push(Json::obj(vec![
        ("mode", Json::str("continuous")),
        ("tokens", Json::num(burst_total)),
        ("tok_s", Json::num(cont_plain_tok_s)),
        ("wall_ms", Json::num(wall_plain * 1e3)),
    ]));
    entries.push(Json::obj(vec![
        ("mode", Json::str("continuous-spec-4")),
        ("k", Json::num(4.0)),
        ("tokens", Json::num(burst_total)),
        ("tok_s", Json::num(cont_spec_tok_s)),
        ("wall_ms", Json::num(wall_spec * 1e3)),
        ("speedup", Json::num(cont_speedup)),
        ("accept_rate", Json::num(cs.accept_rate())),
        ("drafted", Json::num(cs.drafted as f64)),
        ("accepted", Json::num(cs.accepted as f64)),
    ]));

    append_trajectory(
        "spec",
        vec![
            ("smoke", Json::num(if smoke() { 1.0 } else { 0.0 })),
            ("accept_rate", Json::num(headline_accept)),
            ("spec_decode_speedup", Json::num(headline_speedup)),
            ("best_batch1_speedup", Json::num(best_speedup)),
            ("continuous_speedup", Json::num(cont_speedup)),
            ("measurements", Json::Arr(entries)),
        ],
    );
}
