//! Bench: streaming dequant-matvec throughput per method — Table 4's TOK/s
//! and MEM-BW columns at micro scale. One iteration = one "token" through a
//! quantized (1024×1024) layer (8 column groups of 128), driven as the
//! batch-1 case of the shared `StreamingMatmul` serving engine.
//!
//! Run: `cargo bench --bench bench_table4_decode`

use glvq::baselines;
use glvq::bench_support::Bencher;
use glvq::config::GlvqConfig;
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::glvq::optimizer::GlvqGroupQuantizer;
use glvq::linalg::Mat;
use glvq::quant::format::QuantizedTensor;
use glvq::quant::traits::GroupQuantizer;
use glvq::util::rng::Rng;

fn build(method: &str, bits: u8) -> QuantizedTensor {
    let mut rng = Rng::new(2);
    let wt = Mat::random_normal(1024, 1024, 0.02, &mut rng);
    let x = Mat::random_normal(128, 64, 1.0, &mut rng);
    let mut groups = Vec::new();
    for gi in 0..8 {
        let panel = wt.slice(0, 1024, gi * 128, (gi + 1) * 128);
        let qg = if let Some(q) = baselines::by_name(method) {
            q.quantize(&panel, &x, bits)
        } else {
            let mut cfg = GlvqConfig::default();
            cfg.lattice_dim = if method.contains("32") { 32 } else { 8 };
            cfg.iters = 4;
            GlvqGroupQuantizer::new(cfg).quantize(&panel, &x, bits)
        };
        groups.push((0usize, gi * 128, qg));
    }
    QuantizedTensor { name: method.into(), rows: 1024, cols: 1024, groups }
}

fn main() {
    let b = Bencher::default();
    println!("# Table 4 work unit: streaming dequant-matvec of a 1024x1024 layer (2-bit)");
    let x: Vec<f32> = {
        let mut rng = Rng::new(3);
        (0..1024).map(|_| rng.normal_f32()).collect()
    };
    for method in ["rtn", "gptq", "kmeans_vq", "quip_lite", "tcq", "glvq-8d", "glvq-32d"] {
        let qt = build(method, 2);
        let sm = StreamingMatmul::new(16, 1);
        let mut stats = DecodeStats::default();
        let mut y = vec![0.0f32; qt.rows];
        sm.matvec_into(&qt, &x, &mut y, &mut stats); // prime + capture stats
        let bytes = stats.total_bytes() as f64;
        // steady state is allocation-free: one caller-owned output buffer
        // reused across iterations, x borrowed (never cloned into a batch)
        let r = b.run(&format!("decode-matvec/{method}"), bytes, || {
            let mut s = DecodeStats::default();
            sm.matvec_into(&qt, &x, &mut y, &mut s);
            std::hint::black_box(&y);
        });
        println!("{}   ({:.3} MB/token)", r.report(), bytes / 1e6);
    }
}
