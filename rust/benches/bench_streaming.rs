//! Bench: the batched multi-threaded streaming serving engine
//! (`StreamingMatmul`) — tokens/s and bytes-moved across the
//! {1,2,4} threads × {1,4,16} batch grid, on the same quantized model.
//!
//! One "token" is one activation row pushed through a quantized
//! 512×512 layer (4 column groups of 128); a batch-B call therefore
//! scores B tokens while decoding every group-panel exactly once. The
//! 4-thread batch-16 cell must beat the 1-thread batch-1 baseline by
//! ≥ 2× tokens/s (asserted for the decode-heavy GLVQ methods — that is
//! the amortization the engine exists for). Each method also measures
//! the classic slab path (`ExecMode::Slab`) at the corner cells, so the
//! trajectory tracks fused-vs-slab end to end.
//!
//! Results are appended to `runs/bench/streaming.json` so successive
//! runs form a trajectory (`{"runs": [...]}`). `GLVQ_BENCH_SMOKE=1`
//! runs a miniature grid for CI: parity-relevant structure intact,
//! perf assertions skipped.
//!
//! Run: `cargo bench --bench bench_streaming`

use glvq::baselines;
use glvq::bench_support::{append_trajectory, Bencher};
use glvq::config::GlvqConfig;
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::glvq::optimizer::GlvqGroupQuantizer;
use glvq::kernels::ExecMode;
use glvq::linalg::Mat;
use glvq::quant::format::QuantizedTensor;
use glvq::quant::traits::GroupQuantizer;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

fn smoke() -> bool {
    std::env::var("GLVQ_BENCH_SMOKE").is_ok()
}

fn dim() -> usize {
    if smoke() {
        128
    } else {
        512
    }
}

fn group() -> usize {
    if smoke() {
        64
    } else {
        128
    }
}

fn build(method: &str, bits: u8) -> QuantizedTensor {
    let (dim, group) = (dim(), group());
    let mut rng = Rng::new(2);
    let wt = Mat::random_normal(dim, dim, 0.02, &mut rng);
    let x = Mat::random_normal(group, 64, 1.0, &mut rng);
    let mut groups = Vec::new();
    for gi in 0..dim / group {
        let panel = wt.slice(0, dim, gi * group, (gi + 1) * group);
        let qg = if let Some(q) = baselines::by_name(method) {
            q.quantize(&panel, &x, bits)
        } else {
            let mut cfg = GlvqConfig::default();
            cfg.lattice_dim = 8;
            cfg.group_size = group;
            cfg.iters = 4;
            GlvqGroupQuantizer::new(cfg).quantize(&panel, &x, bits)
        };
        groups.push((0usize, gi * group, qg));
    }
    QuantizedTensor { name: method.into(), rows: dim, cols: dim, groups }
}

/// Losslessly re-encode every group with the rANS backend (chunk = 8 rows).
fn to_entropy(qt: &QuantizedTensor) -> QuantizedTensor {
    let mut out = qt.clone();
    for (_, _, g) in &mut out.groups {
        g.codes = g.codes.to_entropy(g.cols * 8, 4);
    }
    out
}

fn main() {
    let b = if smoke() {
        Bencher::quick()
    } else {
        Bencher { warmup_iters: 1, min_iters: 3, budget_ms: 200.0 }
    };
    let dim = dim();
    println!("# streaming serving engine: {dim}x{dim} layer, 2-bit, threads x batch grid");
    let mut entries: Vec<Json> = Vec::new();
    let mut fused_vs_slab = 1.0f64;

    let variants: Vec<(String, QuantizedTensor)> = {
        let glvq = build("glvq-8d", 2);
        let rans = to_entropy(&glvq);
        vec![
            ("rtn".to_string(), build("rtn", 2)),
            ("glvq-8d".to_string(), glvq),
            ("glvq-8d+rans".to_string(), rans),
        ]
    };

    for (method, qt) in &variants {
        let mut rng = Rng::new(3);
        let mut baseline_tok_s = 0.0f64;
        let mut best_tok_s = 0.0f64;
        let mut slab_best_tok_s = 0.0f64;
        for &threads in &[1usize, 2, 4] {
            for &batch in &[1usize, 4, 16] {
                // fused (engine default resolution = Auto) and, at the
                // corner cells, the classic slab path for comparison
                let corner = (threads, batch) == (1, 1) || (threads, batch) == (4, 16);
                let modes: &[ExecMode] =
                    if corner { &[ExecMode::Auto, ExecMode::Slab] } else { &[ExecMode::Auto] };
                for &mode in modes {
                    let engine = StreamingMatmul::new(16, threads).with_mode(mode);
                    let x = Mat::random_normal(batch, dim, 1.0, &mut rng);
                    let mut y = Mat::zeros(batch, dim);
                    // primed calls: capture per-call byte traffic and warm
                    // the fused engine past its LUT threshold
                    let mut stats = DecodeStats::default();
                    engine.matmul(qt, &x, &mut y, &mut stats);
                    engine.matmul(qt, &x, &mut y, &mut stats);
                    let bytes_per_tok = stats.total_bytes() as f64 / (2 * batch) as f64;
                    let bytes_per_mac = stats.total_bytes() as f64 / stats.macs.max(1) as f64;

                    let label = format!("{method}/t{threads}/b{batch}/{}", mode.name());
                    let r = b.run(&label, batch as f64, || {
                        let mut s = DecodeStats::default();
                        engine.matmul(qt, &x, &mut y, &mut s);
                        std::hint::black_box(&y);
                    });
                    let tok_s = r.throughput();
                    println!("{}   ({:.3} MB/token)", r.report(), bytes_per_tok / 1e6);
                    if mode == ExecMode::Auto {
                        if threads == 1 && batch == 1 {
                            baseline_tok_s = tok_s;
                        }
                        if threads == 4 && batch == 16 {
                            best_tok_s = tok_s;
                        }
                    } else if threads == 4 && batch == 16 {
                        slab_best_tok_s = tok_s;
                    }
                    entries.push(Json::obj(vec![
                        ("method", Json::str(method)),
                        ("mode", Json::str(mode.name())),
                        ("threads", Json::num(threads as f64)),
                        ("batch", Json::num(batch as f64)),
                        ("tok_s", Json::num(tok_s)),
                        ("bytes_per_tok", Json::num(bytes_per_tok)),
                        ("bytes_per_mac", Json::num(bytes_per_mac)),
                        ("peak_panel_elems", Json::num(engine.peak_panel_elems(qt) as f64)),
                    ]));
                }
            }
        }
        let speedup = best_tok_s / baseline_tok_s.max(1e-12);
        println!("  {method}: 4-thread batch-16 vs 1-thread batch-1 = {speedup:.2}x tokens/s");
        if method.starts_with("glvq") {
            if !smoke() {
                assert!(
                    speedup >= 2.0,
                    "{method}: batched multi-threaded engine only {speedup:.2}x over baseline"
                );
            }
            let ratio = best_tok_s / slab_best_tok_s.max(1e-12);
            println!("  {method}: fused vs slab at t4/b16 = {ratio:.2}x");
            fused_vs_slab = fused_vs_slab.max(ratio);
        }
    }

    append_trajectory(
        "streaming",
        vec![
            ("fused_vs_slab", Json::num(fused_vs_slab)),
            ("measurements", Json::Arr(entries)),
        ],
    );
}
