//! Bench: the batched multi-threaded streaming serving engine
//! (`StreamingMatmul`) — tokens/s and bytes-moved across the
//! {1,2,4} threads × {1,4,16} batch grid, on the same quantized model.
//!
//! One "token" is one activation row pushed through a quantized
//! 512×512 layer (4 column groups of 128); a batch-B call therefore
//! scores B tokens while decoding every group-panel exactly once. The
//! 4-thread batch-16 cell must beat the 1-thread batch-1 baseline by
//! ≥ 2× tokens/s (asserted for the decode-heavy GLVQ methods — that is
//! the amortization the engine exists for).
//!
//! Results are appended to `runs/bench/streaming.json` so successive
//! runs form a trajectory (`{"runs": [...]}`).
//!
//! Run: `cargo bench --bench bench_streaming`

use glvq::baselines;
use glvq::bench_support::{append_trajectory, Bencher};
use glvq::config::GlvqConfig;
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::glvq::optimizer::GlvqGroupQuantizer;
use glvq::linalg::Mat;
use glvq::quant::format::QuantizedTensor;
use glvq::quant::traits::GroupQuantizer;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

const DIM: usize = 512;
const GROUP: usize = 128;

fn build(method: &str, bits: u8) -> QuantizedTensor {
    let mut rng = Rng::new(2);
    let wt = Mat::random_normal(DIM, DIM, 0.02, &mut rng);
    let x = Mat::random_normal(GROUP, 64, 1.0, &mut rng);
    let mut groups = Vec::new();
    for gi in 0..DIM / GROUP {
        let panel = wt.slice(0, DIM, gi * GROUP, (gi + 1) * GROUP);
        let qg = if let Some(q) = baselines::by_name(method) {
            q.quantize(&panel, &x, bits)
        } else {
            let mut cfg = GlvqConfig::default();
            cfg.lattice_dim = 8;
            cfg.iters = 4;
            GlvqGroupQuantizer::new(cfg).quantize(&panel, &x, bits)
        };
        groups.push((0usize, gi * GROUP, qg));
    }
    QuantizedTensor { name: method.into(), rows: DIM, cols: DIM, groups }
}

/// Losslessly re-encode every group with the rANS backend (chunk = 8 rows).
fn to_entropy(qt: &QuantizedTensor) -> QuantizedTensor {
    let mut out = qt.clone();
    for (_, _, g) in &mut out.groups {
        g.codes = g.codes.to_entropy(g.cols * 8, 4);
    }
    out
}

fn main() {
    let b = Bencher { warmup_iters: 1, min_iters: 3, budget_ms: 200.0 };
    println!("# streaming serving engine: {DIM}x{DIM} layer, 2-bit, threads x batch grid");
    let mut entries: Vec<Json> = Vec::new();

    let variants: Vec<(String, QuantizedTensor)> = {
        let glvq = build("glvq-8d", 2);
        let rans = to_entropy(&glvq);
        vec![
            ("rtn".to_string(), build("rtn", 2)),
            ("glvq-8d".to_string(), glvq),
            ("glvq-8d+rans".to_string(), rans),
        ]
    };

    for (method, qt) in &variants {
        let mut rng = Rng::new(3);
        let mut baseline_tok_s = 0.0f64;
        let mut best_tok_s = 0.0f64;
        for &threads in &[1usize, 2, 4] {
            for &batch in &[1usize, 4, 16] {
                let engine = StreamingMatmul::new(16, threads);
                let x = Mat::random_normal(batch, DIM, 1.0, &mut rng);
                let mut y = Mat::zeros(batch, DIM);
                // one primed call to capture the per-call byte traffic
                let mut stats = DecodeStats::default();
                engine.matmul(qt, &x, &mut y, &mut stats);
                let bytes_per_tok = stats.total_bytes() as f64 / batch as f64;

                let r = b.run(&format!("{method}/t{threads}/b{batch}"), batch as f64, || {
                    let mut s = DecodeStats::default();
                    engine.matmul(qt, &x, &mut y, &mut s);
                    std::hint::black_box(&y);
                });
                let tok_s = r.throughput();
                println!("{}   ({:.3} MB/token)", r.report(), bytes_per_tok / 1e6);
                if threads == 1 && batch == 1 {
                    baseline_tok_s = tok_s;
                }
                if threads == 4 && batch == 16 {
                    best_tok_s = tok_s;
                }
                entries.push(Json::obj(vec![
                    ("method", Json::str(method)),
                    ("threads", Json::num(threads as f64)),
                    ("batch", Json::num(batch as f64)),
                    ("tok_s", Json::num(tok_s)),
                    ("bytes_per_tok", Json::num(bytes_per_tok)),
                    ("peak_panel_elems", Json::num(engine.peak_panel_elems(qt) as f64)),
                ]));
            }
        }
        let speedup = best_tok_s / baseline_tok_s.max(1e-12);
        println!("  {method}: 4-thread batch-16 vs 1-thread batch-1 = {speedup:.2}x tokens/s");
        if method.starts_with("glvq") {
            assert!(
                speedup >= 2.0,
                "{method}: batched multi-threaded engine only {speedup:.2}x over baseline"
            );
        }
    }

    append_trajectory("streaming", vec![("measurements", Json::Arr(entries))]);
}
