//! Bench: cluster scale-out on the mixed Poisson-arrival serving
//! workload (ISSUE 9 acceptance).
//!
//! Every cell serves the identical request stream from clones of the same
//! 3-bit rANS container, through the [`Router`] front end, at a matched
//! total decode-thread budget of 2 cores — so the cells compare *where*
//! the parallelism goes, not how much hardware it gets:
//!
//! - `replicas-1`          — one continuous streaming replica, 2 decode
//!                           threads (the single-engine baseline)
//! - `replicas-2`          — two continuous replicas × 1 thread behind
//!                           least-outstanding placement: true engine-level
//!                           concurrency, scheduler and all
//! - `replicas-1-shards-2` — one continuous replica whose decode runs
//!                           tensor-parallel over 2 shard workers
//! - `pipeline-2`          — one lockstep engine whose layer walk runs as
//!                           2 pipeline stages ([`PipelinedBackend`])
//!
//! Asserted acceptance: every cell's per-request outputs are
//! **bit-identical** to the single-replica cell (scale-out never changes
//! semantics), and in full mode `replicas-2` reaches **≥ 1.5× aggregate
//! tokens/s** over `replicas-1` — replica concurrency beats decode-thread
//! concurrency on this scheduler-bound workload. p95 time-to-first-token
//! comes from the per-request timelines the router relays back.
//!
//! Results append to `runs/bench/cluster.json` (`{"runs": [...]}`) with
//! trajectory keys `cluster_agg_toks`, `cluster_p95_ttft_ms` and
//! `cluster_scaleup`. `GLVQ_BENCH_SMOKE=1` runs a miniature workload for
//! CI: same parity checks, scaleup reported but not asserted.
//!
//! Run: `cargo bench --bench bench_cluster`

use std::sync::Arc;
use std::time::{Duration, Instant};

use glvq::baselines::rtn::RtnQuantizer;
use glvq::cluster::{
    PipeOpts, PipelineExec, PipelinePlan, PipelineWeights, PipelinedBackend, Router, RouterOpts,
};
use glvq::coordinator::decode_stream::StreamingMatmul;
use glvq::coordinator::server::{self, CachedNativeBackend, Request, Response, ServerOpts};
use glvq::eval::native_fwd::{self, CalibCapture};
use glvq::eval::plan::ModelPlan;
use glvq::glvq::pipeline::{quantize_model, PipelineOpts};
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::obs::Mark;
use glvq::quant::format::QuantizedModel;
use glvq::shard::ShardOpts;
use glvq::tensor::TensorStore;
use glvq::bench_support::append_trajectory;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "clusterbench",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 160,
        batch_train: 2,
        batch_eval: 2,
    }
}

struct Workload {
    requests: Vec<Request>,
    /// inter-arrival gap before each request, microseconds
    gaps_us: Vec<u64>,
    total_new: usize,
}

/// Interleaved long/short request stream with seeded Poisson arrivals —
/// the `bench_serving` workload shape, reused so cluster numbers sit next
/// to the single-engine serving numbers.
fn build_workload(groups: usize, shorts: usize, long_gen: usize, short_gen: usize) -> Workload {
    let long_prompt = long_gen / 2;
    let mut rng = Rng::new(4242);
    let mut requests = Vec::new();
    let mut gaps_us = Vec::new();
    let mut total_new = 0usize;
    let mean_us = if smoke() { 0.0 } else { 300.0 };
    for g in 0..groups {
        let mut push = |req: Request, rng: &mut Rng| {
            let u = (rng.below(1_000_000) as f64 + 1.0) / 1_000_001.0;
            gaps_us.push((-u.ln() * mean_us) as u64);
            requests.push(req);
        };
        let lp: Vec<u8> = (0..long_prompt).map(|i| ((g * 37 + i * 11) % 251) as u8).collect();
        push(Request::Generate { prompt: lp, max_new: long_gen }, &mut rng);
        total_new += long_gen;
        for s in 0..shorts {
            let sp: Vec<u8> = (0..6).map(|i| ((g * 53 + s * 17 + i * 7) % 251) as u8).collect();
            push(Request::Generate { prompt: sp, max_new: short_gen }, &mut rng);
            total_new += short_gen;
        }
    }
    Workload { requests, gaps_us, total_new }
}

fn smoke() -> bool {
    std::env::var("GLVQ_BENCH_SMOKE").is_ok()
}

/// Quantize the bench model once; every replica in every cell serves from
/// clones of the same container, so routing is transparent by
/// construction.
fn quantized_parts(cfg: &ModelConfig) -> (TensorStore, QuantizedModel) {
    let store = init_params(cfg, 0);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
    let mut cap = CalibCapture::new(16, 0);
    native_fwd::forward(cfg, &store, &toks, 2, Some(&mut cap)).expect("calibration forward");
    let calib = cap.into_calib_set();
    let opts = PipelineOpts {
        target_bits: 3.0,
        bit_allocation: false,
        entropy: true,
        ..PipelineOpts::default()
    };
    let (qm, _) =
        quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).expect("quantize");
    (store, qm)
}

struct CellResult {
    tok_s: f64,
    wall_ms: f64,
    ttft_p95_ms: f64,
    outputs: Vec<Vec<u8>>,
    routed: Vec<usize>,
    report: String,
}

/// Submit the workload with its arrival gaps through the router, wait for
/// every response, and fold in the relayed per-request timelines.
fn run_cell(router: Router, wl: &Workload) -> CellResult {
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(wl.requests.len());
    for (req, &gap) in wl.requests.iter().zip(&wl.gaps_us) {
        if gap > 0 {
            std::thread::sleep(Duration::from_micros(gap));
        }
        rxs.push(router.submit_timed(req.clone()));
    }
    let mut outputs = Vec::with_capacity(rxs.len());
    let mut ttfts: Vec<f64> = Vec::new();
    for (rx, trx) in rxs {
        match rx.recv().expect("cluster dropped reply") {
            Response::Generated { text } => outputs.push(text),
            other => panic!("unexpected response {other:?}"),
        }
        // the relay forwards the timeline before the response, so it is
        // already here; continuous replicas mark FirstToken, the lockstep
        // pipeline cell only Finish — use that as its TTFT stand-in
        if let Ok(t) = trx.try_recv() {
            if let Some(ns) = t.first(Mark::FirstToken).or_else(|| t.first(Mark::Finish)) {
                ttfts.push(ns as f64 / 1e6);
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = router.shutdown();
    ttfts.sort_by(|a, b| a.partial_cmp(b).expect("finite ttft"));
    let p95 = if ttfts.is_empty() { 0.0 } else { ttfts[(ttfts.len() - 1) * 95 / 100] };
    CellResult {
        tok_s: wl.total_new as f64 / wall.max(1e-9),
        wall_ms: wall * 1e3,
        ttft_p95_ms: p95,
        outputs,
        routed: metrics.routed.clone(),
        report: metrics.report(),
    }
}

fn main() {
    let cfg = bench_cfg();
    let (groups, shorts, long_gen, short_gen) =
        if smoke() { (2, 7, 24, 4) } else { (4, 15, 96, 8) };
    let wl = build_workload(groups, shorts, long_gen, short_gen);
    let (store, qm) = quantized_parts(&cfg);
    println!(
        "# cluster: d={} L={} seq={} — {} requests, {} tokens, {}",
        cfg.d_model,
        cfg.n_layer,
        cfg.seq_len,
        wl.requests.len(),
        wl.total_new,
        if smoke() { "smoke" } else { "full" },
    );

    let kv = KvCacheOpts { page_rows: 16, ..Default::default() };
    let copts = glvq::serving::ContinuousOpts {
        max_batch: 16,
        prefill_chunk: 16,
        ..Default::default()
    };
    // one continuous streaming replica with `threads` decode threads
    let streaming_replica = |threads: usize| {
        let store = store.clone();
        let qm = qm.clone();
        server::start_continuous(
            move || -> anyhow::Result<CachedNativeBackend> {
                let engine = StreamingMatmul::new(16, threads);
                Ok(CachedNativeBackend::streaming(cfg, store, qm, engine, kv))
            },
            copts,
        )
    };
    // one continuous replica whose decode is tensor-parallel (2 shards)
    let sharded_replica = || {
        let store = store.clone();
        let qm = qm.clone();
        let sopts = ShardOpts { shards: 2, panel_rows: 16, threads_per_shard: 1 };
        server::start_continuous(
            move || -> anyhow::Result<CachedNativeBackend> {
                Ok(CachedNativeBackend::sharded(cfg, store, qm, sopts, kv))
            },
            copts,
        )
    };
    // one lockstep engine running the layer walk as 2 pipeline stages
    let qm_arc = Arc::new(qm.clone());
    let pipelined_replica = || {
        let store = store.clone();
        let qm = Arc::clone(&qm_arc);
        server::start(
            move || {
                let pplan = PipelinePlan::build(&ModelPlan::of(&cfg), &qm, 2);
                let sopts = ShardOpts { shards: 1, panel_rows: 16, threads_per_shard: 1 };
                let weights = PipelineWeights::Sharded { qm, opts: sopts };
                let exec = PipelineExec::new(cfg, store, pplan, weights, PipeOpts::default());
                Ok(Box::new(PipelinedBackend { exec }) as Box<dyn server::LmBackend>)
            },
            ServerOpts { max_batch: 16 },
        )
    };

    let cells: Vec<(&str, CellResult)> = vec![
        (
            "replicas-1",
            run_cell(Router::new(vec![streaming_replica(2)], RouterOpts::default()), &wl),
        ),
        (
            "replicas-2",
            run_cell(
                Router::new(
                    vec![streaming_replica(1), streaming_replica(1)],
                    RouterOpts::default(),
                ),
                &wl,
            ),
        ),
        (
            "replicas-1-shards-2",
            run_cell(Router::new(vec![sharded_replica()], RouterOpts::default()), &wl),
        ),
        (
            "pipeline-2",
            run_cell(Router::new(vec![pipelined_replica()], RouterOpts::default()), &wl),
        ),
    ];

    let mut entries: Vec<Json> = Vec::new();
    for (mode, cell) in &cells {
        println!(
            "{mode:<19} {:>8.1} tok/s  wall {:>8.1} ms  ttft p95 {:>7.2} ms  routed {:?}",
            cell.tok_s, cell.wall_ms, cell.ttft_p95_ms, cell.routed,
        );
        println!("    {}", cell.report.replace('\n', "\n    "));
        entries.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("requests", Json::num(wl.requests.len() as f64)),
            ("tokens", Json::num(wl.total_new as f64)),
            ("tok_s", Json::num(cell.tok_s)),
            ("wall_ms", Json::num(cell.wall_ms)),
            ("ttft_p95_ms", Json::num(cell.ttft_p95_ms)),
            ("replicas", Json::num(cell.routed.len() as f64)),
        ]));
    }

    // ---- acceptance ----
    let by = |m: &str| &cells.iter().find(|c| c.0 == m).expect("cell").1;
    let reference = &by("replicas-1").outputs;
    for (mode, cell) in &cells {
        assert_eq!(&cell.outputs, reference, "{mode}: outputs diverged");
    }
    let scaleup = by("replicas-2").tok_s / by("replicas-1").tok_s.max(1e-9);
    println!("  2 replicas vs 1 at matched cores: {scaleup:.2}x aggregate tok/s");
    if smoke() {
        println!("  (smoke mode: scaleup not asserted)");
    } else {
        assert!(scaleup >= 1.5, "2 replicas only {scaleup:.2}x over 1 (need >= 1.5x)");
    }

    let r2 = by("replicas-2");
    append_trajectory(
        "cluster",
        vec![
            ("smoke", Json::num(if smoke() { 1.0 } else { 0.0 })),
            ("cluster_agg_toks", Json::num(r2.tok_s)),
            ("cluster_p95_ttft_ms", Json::num(r2.ttft_p95_ms)),
            ("cluster_scaleup", Json::num(scaleup)),
            ("measurements", Json::Arr(entries)),
        ],
    );
}
