//! Bench: rANS entropy-coding backend — encode/decode throughput (MB/s of
//! equivalent fixed-width payload) and compressed-vs-fixed payload ratio on
//! synthetic discrete-Gaussian codes (the post-Babai code distribution).
//!
//! Results are appended to `runs/bench/entropy.json` so successive runs
//! form a trajectory (`{"runs": [...]}`).
//!
//! Run: `cargo bench --bench bench_entropy`

use glvq::bench_support::{append_trajectory, Bencher};
use glvq::entropy::{RansCodes, DEFAULT_CHUNK, DEFAULT_LANES};
use glvq::quant::pack::clamp_code;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

/// Discrete-Gaussian codes at σ = range/8 — Babai codes concentrate well
/// inside the clamp range.
fn gaussian_codes(rng: &mut Rng, bits: u8, n: usize) -> Vec<i32> {
    let sigma = (1 << (bits - 1)) as f32 / 8.0;
    (0..n).map(|_| clamp_code(rng.normal_f32() * sigma, bits)).collect()
}

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(11);
    let n = 1 << 18; // 256k codes per measurement

    println!("# entropy backend: rANS encode/decode on discrete-Gaussian codes ({n} codes)");
    let mut entries: Vec<Json> = Vec::new();

    for bits in [2u8, 3, 4, 6, 8] {
        let codes = gaussian_codes(&mut rng, bits, n);
        let fixed_bytes = (n * bits as usize).div_ceil(8) as f64;

        let enc = b.run(&format!("rans_encode/b{bits}"), fixed_bytes, || {
            std::hint::black_box(RansCodes::encode(&codes, bits, DEFAULT_CHUNK, DEFAULT_LANES));
        });
        println!("{}", enc.report());

        let rc = RansCodes::encode(&codes, bits, DEFAULT_CHUNK, DEFAULT_LANES);
        let mut out = vec![0i32; n];
        let dec = b.run(&format!("rans_decode/b{bits}"), fixed_bytes, || {
            rc.decode_into(&mut out);
            std::hint::black_box(&out);
        });
        println!("{}", dec.report());
        assert_eq!(out, codes, "decode must be bit-exact");

        let ratio = rc.payload_bytes() as f64 / fixed_bytes;
        println!(
            "  payload: {} B vs {} B fixed  (ratio {:.3}, {:.1}% saved, H≈{:.2} bits)",
            rc.payload_bytes(),
            fixed_bytes as usize,
            ratio,
            100.0 * (1.0 - ratio),
            rc.hist.entropy_bits()
        );

        entries.push(Json::obj(vec![
            ("bits", Json::num(bits as f64)),
            ("codes", Json::num(n as f64)),
            ("encode_mb_s", Json::num(enc.throughput() / 1e6)),
            ("decode_mb_s", Json::num(dec.throughput() / 1e6)),
            ("payload_bytes", Json::num(rc.payload_bytes() as f64)),
            ("fixed_bytes", Json::num(fixed_bytes)),
            ("ratio", Json::num(ratio)),
            ("entropy_bits", Json::num(rc.hist.entropy_bits())),
        ]));
    }

    append_trajectory("entropy", vec![("measurements", Json::Arr(entries))]);
}
