//! Bench: continuous batching vs lockstep on a mixed-length
//! Poisson-arrival workload (ISSUE 4 acceptance).
//!
//! Workload: interleaved groups of one *long* request (long prompt, long
//! generation) and many *short* requests, submitted with seeded
//! exponential inter-arrival gaps. All cells serve the identical request
//! stream through the same cache-aware **streaming** backend (compressed
//! weights: every step call pays one whole-model panel-decode, so
//! scheduling efficiency — fewer, fuller step batches — is what moves
//! aggregate tokens/s):
//!
//! - `lockstep-b1`    — sequential reference (one request per batch)
//! - `lockstep-b16`   — the old drain-and-run loop at batch budget 16:
//!                      every drained batch convoys behind its longest
//!                      member while later arrivals wait
//! - `continuous-b16` — the `serving::ContinuousScheduler` at the same
//!                      budget: shorts join/leave mid-flight, longs
//!                      overlap each other
//! - `continuous-preempt` — continuous over a page-capped KV arena that
//!                      forces spill/resume mid-run
//!
//! Asserted acceptance: `continuous-b16` reaches **≥ 1.5× aggregate
//! tokens/s** over `lockstep-b16` (full mode), every cell's per-request
//! outputs are **bit-identical** to the sequential reference (f32 KV +
//! batch-invariant streaming decode), and the preemption-forced cell
//! completes with correct resumes. p50/p95 time-to-first-token and
//! queue-wait come from the server-side histograms.
//!
//! A fifth cell (`continuous-traced`) reruns the continuous workload with
//! span tracing on, and writes two observability artifacts:
//! `runs/bench/serving_trace.json` (Chrome trace-event JSON — scheduler
//! phases, panel decodes and per-request timeline tracks, loadable in
//! Perfetto) and `runs/bench/serving_metrics.prom` (Prometheus text of
//! the final metrics snapshot). Full-mode observability acceptance: the
//! scheduler phase spans account for **≥ 90%** of `sched_step` wall time,
//! and the measured cost of *disabled* span guards stays **< 2%** of the
//! per-token serving cost.
//!
//! A final **chat** pair (`chat-unshared` / `chat-shared`) drives
//! multi-turn sessions that all share one long system prompt through the
//! session API, with and without radix prefix sharing: outputs must be
//! bit-identical (f32 sharing is exact), the shared cell must prefill
//! strictly fewer tokens, and in full mode its prefill tok/s must beat
//! the unshared cell measurably. The shared cell's prefix hit rate, CoW
//! splits and shared-page counts land in the JSON trajectory.
//!
//! Results append to `runs/bench/serving.json` (`{"runs": [...]}`),
//! including the full structured metrics snapshot of the traced cell.
//! `GLVQ_BENCH_SMOKE=1` runs a miniature workload for CI: same parity
//! and preemption checks, speedup reported but not asserted.
//!
//! Run: `cargo bench --bench bench_serving`

use std::time::{Duration, Instant};

use glvq::baselines::rtn::RtnQuantizer;
use glvq::coordinator::decode_stream::StreamingMatmul;
use glvq::coordinator::server::{
    self, CachedNativeBackend, Request, Response, ServerHandle, ServerOpts,
};
use glvq::eval::native_fwd::{self, CalibCapture};
use glvq::glvq::pipeline::{quantize_model, PipelineOpts};
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::obs::{self, span, MetricsSnapshot, RequestTimeline};
use glvq::quant::format::QuantizedModel;
use glvq::tensor::TensorStore;
use glvq::bench_support::append_trajectory;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "servbench",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 160,
        batch_train: 2,
        batch_eval: 2,
    }
}

struct Workload {
    requests: Vec<Request>,
    /// inter-arrival gap before each request, microseconds
    gaps_us: Vec<u64>,
    total_new: usize,
}

/// Interleaved long/short request stream with seeded Poisson arrivals.
fn build_workload(groups: usize, shorts: usize, long_gen: usize, short_gen: usize) -> Workload {
    let long_prompt = long_gen / 2;
    let mut rng = Rng::new(4242);
    let mut requests = Vec::new();
    let mut gaps_us = Vec::new();
    let mut total_new = 0usize;
    let mean_us = if smoke() { 0.0 } else { 300.0 };
    for g in 0..groups {
        let mut push = |req: Request, rng: &mut Rng| {
            let u = (rng.below(1_000_000) as f64 + 1.0) / 1_000_001.0;
            gaps_us.push((-u.ln() * mean_us) as u64);
            requests.push(req);
        };
        let lp: Vec<u8> = (0..long_prompt).map(|i| ((g * 37 + i * 11) % 251) as u8).collect();
        push(Request::Generate { prompt: lp, max_new: long_gen }, &mut rng);
        total_new += long_gen;
        for s in 0..shorts {
            let sp: Vec<u8> = (0..6).map(|i| ((g * 53 + s * 17 + i * 7) % 251) as u8).collect();
            push(Request::Generate { prompt: sp, max_new: short_gen }, &mut rng);
            total_new += short_gen;
        }
    }
    Workload { requests, gaps_us, total_new }
}

fn smoke() -> bool {
    std::env::var("GLVQ_BENCH_SMOKE").is_ok()
}

/// Quantize the bench model once; every cell serves from clones of the
/// same container. rANS-entropy payloads make every step call pay a real
/// panel-decode cost — the regime where scheduling efficiency (fewer,
/// fuller step batches) dominates aggregate throughput.
fn quantized_parts(cfg: &ModelConfig) -> (TensorStore, QuantizedModel) {
    let store = init_params(cfg, 0);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
    let mut cap = CalibCapture::new(16, 0);
    native_fwd::forward(cfg, &store, &toks, 2, Some(&mut cap)).expect("calibration forward");
    let calib = cap.into_calib_set();
    let mut opts = PipelineOpts::default();
    opts.target_bits = 3.0;
    opts.bit_allocation = false;
    opts.entropy = true;
    let (qm, _) =
        quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).expect("quantize");
    (store, qm)
}

struct CellResult {
    tok_s: f64,
    wall_ms: f64,
    ttft_p50: f64,
    ttft_p95: f64,
    queue_p50: f64,
    preemptions: usize,
    resumes: usize,
    sched_steps: usize,
    outputs: Vec<Vec<u8>>,
    snapshot: MetricsSnapshot,
    timelines: Vec<RequestTimeline>,
}

/// Submit the workload with its arrival gaps, wait for every response,
/// and fold in the server-side histograms.
fn run_cell(handle: ServerHandle, wl: &Workload) -> CellResult {
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(wl.requests.len());
    for (req, &gap) in wl.requests.iter().zip(&wl.gaps_us) {
        if gap > 0 {
            std::thread::sleep(Duration::from_micros(gap));
        }
        rxs.push(handle.submit(req.clone()));
    }
    let mut outputs = Vec::with_capacity(rxs.len());
    for rx in rxs {
        match rx.recv().expect("server dropped reply") {
            Response::Generated { text } => outputs.push(text),
            other => panic!("unexpected response {other:?}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = handle.shutdown();
    let snapshot = metrics.snapshot();
    CellResult {
        tok_s: wl.total_new as f64 / wall.max(1e-9),
        wall_ms: wall * 1e3,
        ttft_p50: metrics.ttft.quantile(0.5),
        ttft_p95: metrics.ttft.quantile(0.95),
        queue_p50: metrics.queue_wait.quantile(0.5),
        preemptions: metrics.preemptions,
        resumes: metrics.resumes,
        sched_steps: metrics.sched_steps,
        outputs,
        snapshot,
        timelines: metrics.timelines,
    }
}

fn main() {
    let cfg = bench_cfg();
    let (groups, shorts, long_gen, short_gen) =
        if smoke() { (2, 7, 24, 4) } else { (4, 15, 96, 8) };
    let wl = build_workload(groups, shorts, long_gen, short_gen);
    let (store, qm) = quantized_parts(&cfg);
    println!(
        "# serving: d={} L={} seq={} — {} requests ({} long × {} tok, {} short × {} tok), {}",
        cfg.d_model,
        cfg.n_layer,
        cfg.seq_len,
        wl.requests.len(),
        groups,
        long_gen,
        groups * shorts,
        short_gen,
        if smoke() { "smoke" } else { "full" },
    );

    let kv = KvCacheOpts { page_rows: 16, ..Default::default() };
    // page-capped arena for the preemption cell: one long sequence fits,
    // two cannot coexist with the short traffic
    let long_rows = long_gen / 2 + long_gen - 1;
    let per_long = 2 * cfg.n_layer * long_rows.div_ceil(kv.page_rows);
    let kv_capped = KvCacheOpts { max_pages: per_long + per_long / 2, ..kv };
    let mk = |kv: KvCacheOpts| {
        let cfg = cfg;
        let store = store.clone();
        let qm = qm.clone();
        move || -> anyhow::Result<CachedNativeBackend> {
            // single decode thread: deterministic cost per call, and the
            // whole-model decode price is paid once per *step batch* —
            // exactly what the lockstep/continuous comparison measures
            let engine = StreamingMatmul::new(16, 1);
            Ok(CachedNativeBackend::streaming(cfg, store, qm, engine, kv))
        }
    };
    let mk_box = |kv: KvCacheOpts| {
        let f = mk(kv);
        move || f().map(|b| Box::new(b) as Box<dyn server::LmBackend>)
    };

    let copts = glvq::serving::ContinuousOpts {
        max_batch: 16,
        prefill_chunk: 16,
        ..Default::default()
    };
    let cells: Vec<(&str, CellResult)> = vec![
        (
            "lockstep-b1",
            run_cell(server::start(mk_box(kv), ServerOpts { max_batch: 1 }), &wl),
        ),
        (
            "lockstep-b16",
            run_cell(server::start(mk_box(kv), ServerOpts { max_batch: 16 }), &wl),
        ),
        ("continuous-b16", run_cell(server::start_continuous(mk(kv), copts), &wl)),
        (
            "continuous-preempt",
            run_cell(server::start_continuous(mk(kv_capped), copts), &wl),
        ),
    ];

    let mut entries: Vec<Json> = Vec::new();
    for (mode, cell) in &cells {
        println!(
            "{mode:<19} {:>8.1} tok/s  wall {:>8.1} ms  ttft p50 {:>7.2} ms  p95 {:>7.2} ms  queue p50 {:>7.2} ms  steps {:>5}  preempt {}/{}",
            cell.tok_s,
            cell.wall_ms,
            cell.ttft_p50,
            cell.ttft_p95,
            cell.queue_p50,
            cell.sched_steps,
            cell.preemptions,
            cell.resumes,
        );
        entries.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("requests", Json::num(wl.requests.len() as f64)),
            ("tokens", Json::num(wl.total_new as f64)),
            ("tok_s", Json::num(cell.tok_s)),
            ("wall_ms", Json::num(cell.wall_ms)),
            ("ttft_p50_ms", Json::num(cell.ttft_p50)),
            ("ttft_p95_ms", Json::num(cell.ttft_p95)),
            ("queue_p50_ms", Json::num(cell.queue_p50)),
            ("sched_steps", Json::num(cell.sched_steps as f64)),
            ("preemptions", Json::num(cell.preemptions as f64)),
            ("resumes", Json::num(cell.resumes as f64)),
        ]));
    }

    // ---- acceptance ----
    let by = |m: &str| &cells.iter().find(|c| c.0 == m).expect("cell").1;
    let sequential = by("lockstep-b1");
    for (mode, cell) in &cells {
        assert_eq!(
            cell.outputs, sequential.outputs,
            "{mode}: outputs diverged from sequential execution"
        );
    }
    let preempt = by("continuous-preempt");
    assert!(
        preempt.preemptions >= 1 && preempt.resumes >= 1,
        "page-capped cell must preempt and resume (got {}/{})",
        preempt.preemptions,
        preempt.resumes
    );
    let speedup = by("continuous-b16").tok_s / by("lockstep-b16").tok_s.max(1e-9);
    println!("  continuous vs lockstep at batch budget 16: {speedup:.2}x aggregate tok/s");
    if smoke() {
        println!("  (smoke mode: speedup not asserted)");
    } else {
        assert!(
            speedup >= 1.5,
            "continuous batching only {speedup:.2}x over lockstep (need >= 1.5x)"
        );
    }

    // ---- traced cell: the observability acceptance ----
    // rerun the continuous workload with span tracing on, then turn the
    // collected spans into the two exported artifacts
    span::set_enabled(true);
    let traced = run_cell(server::start_continuous(mk(kv), copts), &wl);
    span::set_enabled(false);
    let spans = span::drain();
    assert_eq!(
        traced.outputs, sequential.outputs,
        "continuous-traced: outputs diverged from sequential execution"
    );
    span::validate_nesting(&spans).expect("span tree is well-nested");

    let stages = span::summarize(&spans);
    let total_of = |name: &str| {
        stages.iter().find(|s| s.name == name).map(|s| s.total_ms).unwrap_or(0.0)
    };
    let sched_total = total_of("sched_step");
    let phases =
        ["sweep", "resume", "admit", "plan", "preempt", "exec", "apply_logits", "refresh"];
    let attributed: f64 = phases.iter().map(|n| total_of(n)).sum();
    let frac = attributed / sched_total.max(1e-9);
    println!(
        "continuous-traced   {:>8.1} tok/s  {} spans; sched_step {:.1} ms, phases {:.1} ms ({:.0}% attributed)",
        traced.tok_s,
        spans.len(),
        sched_total,
        attributed,
        frac * 100.0
    );
    println!("{}", span::render_summary(&stages));
    assert!(sched_total > 0.0, "traced run recorded no sched_step spans");
    if !smoke() {
        assert!(
            frac >= 0.90,
            "phase spans attribute only {:.1}% of sched_step wall time (need >= 90%)",
            frac * 100.0
        );
    }

    // the snapshot carries every counter the report line exposes
    for name in [
        "requests_total",
        "tokens_out_total",
        "batches_total",
        "tokens_per_sec",
        "request_latency_ms",
        "ttft_ms",
        "queue_wait_ms",
        "sched_steps_total",
        "prefill_chunks_total",
        "decoded_bytes_total",
        "kv_pages_in_use",
    ] {
        assert!(traced.snapshot.has(name), "snapshot missing metric {name}");
    }

    std::fs::create_dir_all("runs/bench").expect("create runs/bench");
    let trace = obs::chrome_trace_json(&spans, &traced.timelines);
    let trace_text = trace.to_string();
    // self-check both artifacts before writing: the trace round-trips
    // through the JSON parser, the Prometheus text through the validator
    let parsed = Json::parse(&trace_text).expect("trace JSON parses");
    let n_events = parsed.get("traceEvents").as_arr().map_or(0, |a| a.len());
    assert!(n_events > 0, "empty trace export");
    let prom = traced.snapshot.to_prometheus();
    glvq::obs::registry::validate_prometheus(&prom).expect("prometheus exposition valid");
    std::fs::write("runs/bench/serving_trace.json", &trace_text).expect("write trace");
    std::fs::write("runs/bench/serving_metrics.prom", &prom).expect("write metrics");
    println!(
        "  wrote runs/bench/serving_trace.json ({n_events} events) and runs/bench/serving_metrics.prom"
    );

    // ---- disabled-guard overhead: tracing off must be ~free ----
    let reps: u64 = if smoke() { 200_000 } else { 2_000_000 };
    let t0 = Instant::now();
    for _ in 0..reps {
        let _g = glvq::span!("overhead_probe");
    }
    let ns_per_guard = t0.elapsed().as_nanos() as f64 / reps as f64;
    // guards fired per generated token, measured on the traced run itself
    let guards_per_token = spans.len() as f64 / wl.total_new.max(1) as f64;
    let per_token_ns = 1e9 / by("continuous-b16").tok_s.max(1e-9);
    let overhead = ns_per_guard * guards_per_token / per_token_ns;
    println!(
        "  disabled guards: {ns_per_guard:.1} ns/guard x {guards_per_token:.1} guards/token = {:.3}% of per-token cost",
        overhead * 100.0
    );
    if !smoke() {
        assert!(
            overhead < 0.02,
            "disabled tracing costs {:.2}% of per-token time (need < 2%)",
            overhead * 100.0
        );
    }

    // ---- chat cells: multi-turn sessions over one shared system prompt ----
    // every session replays the same long system prompt; with prefix
    // sharing the backend claims it (and each session's own transcript)
    // from the radix index instead of re-prefilling, so the same logical
    // prompt volume is served in less wall time
    let (n_sessions, n_turns, sys_len, turn_gen) =
        if smoke() { (3, 2, 48, 4) } else { (8, 3, 96, 8) };
    let system: Vec<u8> = (0..sys_len).map(|i| ((i * 13 + 7) % 251) as u8).collect();
    let run_chat = |handle: ServerHandle| {
        let t0 = Instant::now();
        let mut outputs = Vec::new();
        let mut logical = 0usize;
        for s in 0..n_sessions {
            let sid = handle.begin_session(&system);
            let mut transcript = sys_len;
            for t in 0..n_turns {
                let user: Vec<u8> =
                    (0..4).map(|i| ((s * 31 + t * 17 + i * 5) % 251) as u8).collect();
                transcript += user.len();
                logical += transcript; // the turn's full prompt length
                match handle.continue_session(sid, &user, turn_gen).expect("session turn") {
                    Response::Generated { text } => {
                        transcript += text.len();
                        outputs.push(text);
                    }
                    other => panic!("unexpected response {other:?}"),
                }
            }
            handle.end_session(sid);
        }
        let wall = t0.elapsed().as_secs_f64();
        (outputs, wall, handle.shutdown(), logical)
    };
    let kv_share = KvCacheOpts { prefix_share: true, ..kv };
    let (chat_plain, wall_plain, m_plain, logical) =
        run_chat(server::start_continuous(mk(kv), copts));
    let (chat_shared, wall_shared, m_shared, _) =
        run_chat(server::start_continuous(mk(kv_share), copts));
    assert_eq!(chat_plain, chat_shared, "prefix sharing changed chat outputs");
    assert!(
        m_shared.prefix_hits + 1 >= n_sessions * n_turns,
        "every turn after the first must claim a shared prefix (hits {})",
        m_shared.prefix_hits
    );
    assert!(
        m_shared.prefill_tokens < m_plain.prefill_tokens,
        "sharing must prefill strictly fewer tokens ({} vs {})",
        m_shared.prefill_tokens,
        m_plain.prefill_tokens
    );
    let chat_kv = m_shared.kv_cache.expect("shared chat cell reports kv stats");
    let hit_rate = chat_kv.prefix_hits as f64 / chat_kv.prefix_lookups.max(1) as f64;
    let prefill_plain_tok_s = logical as f64 / wall_plain.max(1e-9);
    let prefill_shared_tok_s = logical as f64 / wall_shared.max(1e-9);
    let chat_speedup = prefill_shared_tok_s / prefill_plain_tok_s.max(1e-9);
    println!(
        "chat-unshared       {:>8.1} prefill tok/s  wall {:>8.1} ms  prefilled {} of {} prompt tokens",
        prefill_plain_tok_s,
        wall_plain * 1e3,
        m_plain.prefill_tokens,
        logical,
    );
    println!(
        "chat-shared         {:>8.1} prefill tok/s  wall {:>8.1} ms  prefilled {} of {} prompt tokens  hit rate {:.2}  claimed {} tok  cow {}  shared pages {}",
        prefill_shared_tok_s,
        wall_shared * 1e3,
        m_shared.prefill_tokens,
        logical,
        hit_rate,
        m_shared.prefix_tokens,
        chat_kv.cow_splits,
        chat_kv.shared_pages,
    );
    println!("  chat prefill speedup with prefix sharing: {chat_speedup:.2}x");
    if !smoke() {
        assert!(
            chat_speedup >= 1.2,
            "prefix sharing only {chat_speedup:.2}x prefill tok/s (need >= 1.2x)"
        );
    }
    entries.push(Json::obj(vec![
        ("mode", Json::str("chat-unshared")),
        ("sessions", Json::num(n_sessions as f64)),
        ("turns", Json::num(n_turns as f64)),
        ("prompt_tokens", Json::num(logical as f64)),
        ("prefilled_tokens", Json::num(m_plain.prefill_tokens as f64)),
        ("prefill_tok_s", Json::num(prefill_plain_tok_s)),
        ("wall_ms", Json::num(wall_plain * 1e3)),
    ]));
    entries.push(Json::obj(vec![
        ("mode", Json::str("chat-shared")),
        ("sessions", Json::num(n_sessions as f64)),
        ("turns", Json::num(n_turns as f64)),
        ("prompt_tokens", Json::num(logical as f64)),
        ("prefilled_tokens", Json::num(m_shared.prefill_tokens as f64)),
        ("prefill_tok_s", Json::num(prefill_shared_tok_s)),
        ("wall_ms", Json::num(wall_shared * 1e3)),
        ("prefix_hit_rate", Json::num(hit_rate)),
        ("prefix_hits", Json::num(m_shared.prefix_hits as f64)),
        ("prefix_tokens", Json::num(m_shared.prefix_tokens as f64)),
        ("cow_splits", Json::num(chat_kv.cow_splits as f64)),
        ("shared_pages", Json::num(chat_kv.shared_pages as f64)),
    ]));

    entries.push(Json::obj(vec![
        ("mode", Json::str("continuous-traced")),
        ("tok_s", Json::num(traced.tok_s)),
        ("wall_ms", Json::num(traced.wall_ms)),
        ("spans", Json::num(spans.len() as f64)),
        ("sched_step_ms", Json::num(sched_total)),
        ("phase_attribution", Json::num(frac)),
        ("metrics", traced.snapshot.to_json()),
    ]));

    append_trajectory(
        "serving",
        vec![
            ("smoke", Json::num(if smoke() { 1.0 } else { 0.0 })),
            ("speedup_vs_lockstep", Json::num(speedup)),
            ("chat_prefill_speedup", Json::num(chat_speedup)),
            ("chat_prefix_hit_rate", Json::num(hit_rate)),
            ("span_attribution", Json::num(frac)),
            ("disabled_guard_overhead", Json::num(overhead)),
            ("measurements", Json::Arr(entries)),
        ],
    );
}
