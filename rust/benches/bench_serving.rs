//! Bench: continuous batching vs lockstep on a mixed-length
//! Poisson-arrival workload (ISSUE 4 acceptance).
//!
//! Workload: interleaved groups of one *long* request (long prompt, long
//! generation) and many *short* requests, submitted with seeded
//! exponential inter-arrival gaps. All cells serve the identical request
//! stream through the same cache-aware **streaming** backend (compressed
//! weights: every step call pays one whole-model panel-decode, so
//! scheduling efficiency — fewer, fuller step batches — is what moves
//! aggregate tokens/s):
//!
//! - `lockstep-b1`    — sequential reference (one request per batch)
//! - `lockstep-b16`   — the old drain-and-run loop at batch budget 16:
//!                      every drained batch convoys behind its longest
//!                      member while later arrivals wait
//! - `continuous-b16` — the `serving::ContinuousScheduler` at the same
//!                      budget: shorts join/leave mid-flight, longs
//!                      overlap each other
//! - `continuous-preempt` — continuous over a page-capped KV arena that
//!                      forces spill/resume mid-run
//!
//! Asserted acceptance: `continuous-b16` reaches **≥ 1.5× aggregate
//! tokens/s** over `lockstep-b16` (full mode), every cell's per-request
//! outputs are **bit-identical** to the sequential reference (f32 KV +
//! batch-invariant streaming decode), and the preemption-forced cell
//! completes with correct resumes. p50/p95 time-to-first-token and
//! queue-wait come from the server-side histograms.
//!
//! Results append to `runs/bench/serving.json` (`{"runs": [...]}`).
//! `GLVQ_BENCH_SMOKE=1` runs a miniature workload for CI: same parity
//! and preemption checks, speedup reported but not asserted.
//!
//! Run: `cargo bench --bench bench_serving`

use std::time::{Duration, Instant};

use glvq::baselines::rtn::RtnQuantizer;
use glvq::coordinator::decode_stream::StreamingMatmul;
use glvq::coordinator::server::{
    self, CachedNativeBackend, Request, Response, ServerHandle, ServerOpts,
};
use glvq::eval::native_fwd::{self, CalibCapture};
use glvq::glvq::pipeline::{quantize_model, PipelineOpts};
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::quant::format::QuantizedModel;
use glvq::tensor::TensorStore;
use glvq::bench_support::append_trajectory;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "servbench",
        vocab: 256,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
        seq_len: 160,
        batch_train: 2,
        batch_eval: 2,
    }
}

struct Workload {
    requests: Vec<Request>,
    /// inter-arrival gap before each request, microseconds
    gaps_us: Vec<u64>,
    total_new: usize,
}

/// Interleaved long/short request stream with seeded Poisson arrivals.
fn build_workload(groups: usize, shorts: usize, long_gen: usize, short_gen: usize) -> Workload {
    let long_prompt = long_gen / 2;
    let mut rng = Rng::new(4242);
    let mut requests = Vec::new();
    let mut gaps_us = Vec::new();
    let mut total_new = 0usize;
    let mean_us = if smoke() { 0.0 } else { 300.0 };
    for g in 0..groups {
        let mut push = |req: Request, rng: &mut Rng| {
            let u = (rng.below(1_000_000) as f64 + 1.0) / 1_000_001.0;
            gaps_us.push((-u.ln() * mean_us) as u64);
            requests.push(req);
        };
        let lp: Vec<u8> = (0..long_prompt).map(|i| ((g * 37 + i * 11) % 251) as u8).collect();
        push(Request::Generate { prompt: lp, max_new: long_gen }, &mut rng);
        total_new += long_gen;
        for s in 0..shorts {
            let sp: Vec<u8> = (0..6).map(|i| ((g * 53 + s * 17 + i * 7) % 251) as u8).collect();
            push(Request::Generate { prompt: sp, max_new: short_gen }, &mut rng);
            total_new += short_gen;
        }
    }
    Workload { requests, gaps_us, total_new }
}

fn smoke() -> bool {
    std::env::var("GLVQ_BENCH_SMOKE").is_ok()
}

/// Quantize the bench model once; every cell serves from clones of the
/// same container. rANS-entropy payloads make every step call pay a real
/// panel-decode cost — the regime where scheduling efficiency (fewer,
/// fuller step batches) dominates aggregate throughput.
fn quantized_parts(cfg: &ModelConfig) -> (TensorStore, QuantizedModel) {
    let store = init_params(cfg, 0);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
    let mut cap = CalibCapture::new(16, 0);
    native_fwd::forward(cfg, &store, &toks, 2, Some(&mut cap)).expect("calibration forward");
    let calib = cap.into_calib_set();
    let mut opts = PipelineOpts::default();
    opts.target_bits = 3.0;
    opts.bit_allocation = false;
    opts.entropy = true;
    let (qm, _) =
        quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).expect("quantize");
    (store, qm)
}

struct CellResult {
    tok_s: f64,
    wall_ms: f64,
    ttft_p50: f64,
    ttft_p95: f64,
    queue_p50: f64,
    preemptions: usize,
    resumes: usize,
    sched_steps: usize,
    outputs: Vec<Vec<u8>>,
}

/// Submit the workload with its arrival gaps, wait for every response,
/// and fold in the server-side histograms.
fn run_cell(handle: ServerHandle, wl: &Workload) -> CellResult {
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(wl.requests.len());
    for (req, &gap) in wl.requests.iter().zip(&wl.gaps_us) {
        if gap > 0 {
            std::thread::sleep(Duration::from_micros(gap));
        }
        rxs.push(handle.submit(req.clone()));
    }
    let mut outputs = Vec::with_capacity(rxs.len());
    for rx in rxs {
        match rx.recv().expect("server dropped reply") {
            Response::Generated { text } => outputs.push(text),
            other => panic!("unexpected response {other:?}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = handle.shutdown();
    CellResult {
        tok_s: wl.total_new as f64 / wall.max(1e-9),
        wall_ms: wall * 1e3,
        ttft_p50: metrics.ttft.quantile(0.5),
        ttft_p95: metrics.ttft.quantile(0.95),
        queue_p50: metrics.queue_wait.quantile(0.5),
        preemptions: metrics.preemptions,
        resumes: metrics.resumes,
        sched_steps: metrics.sched_steps,
        outputs,
    }
}

fn main() {
    let cfg = bench_cfg();
    let (groups, shorts, long_gen, short_gen) =
        if smoke() { (2, 7, 24, 4) } else { (4, 15, 96, 8) };
    let wl = build_workload(groups, shorts, long_gen, short_gen);
    let (store, qm) = quantized_parts(&cfg);
    println!(
        "# serving: d={} L={} seq={} — {} requests ({} long × {} tok, {} short × {} tok), {}",
        cfg.d_model,
        cfg.n_layer,
        cfg.seq_len,
        wl.requests.len(),
        groups,
        long_gen,
        groups * shorts,
        short_gen,
        if smoke() { "smoke" } else { "full" },
    );

    let kv = KvCacheOpts { page_rows: 16, ..Default::default() };
    // page-capped arena for the preemption cell: one long sequence fits,
    // two cannot coexist with the short traffic
    let long_rows = long_gen / 2 + long_gen - 1;
    let per_long = 2 * cfg.n_layer * long_rows.div_ceil(kv.page_rows);
    let kv_capped = KvCacheOpts { max_pages: per_long + per_long / 2, ..kv };
    let mk = |kv: KvCacheOpts| {
        let cfg = cfg;
        let store = store.clone();
        let qm = qm.clone();
        move || -> anyhow::Result<CachedNativeBackend> {
            // single decode thread: deterministic cost per call, and the
            // whole-model decode price is paid once per *step batch* —
            // exactly what the lockstep/continuous comparison measures
            let engine = StreamingMatmul::new(16, 1);
            Ok(CachedNativeBackend::streaming(cfg, store, qm, engine, kv))
        }
    };
    let mk_box = |kv: KvCacheOpts| {
        let f = mk(kv);
        move || f().map(|b| Box::new(b) as Box<dyn server::LmBackend>)
    };

    let copts = glvq::serving::ContinuousOpts {
        max_batch: 16,
        prefill_chunk: 16,
        ..Default::default()
    };
    let cells: Vec<(&str, CellResult)> = vec![
        (
            "lockstep-b1",
            run_cell(server::start(mk_box(kv), ServerOpts { max_batch: 1 }), &wl),
        ),
        (
            "lockstep-b16",
            run_cell(server::start(mk_box(kv), ServerOpts { max_batch: 16 }), &wl),
        ),
        ("continuous-b16", run_cell(server::start_continuous(mk(kv), copts), &wl)),
        (
            "continuous-preempt",
            run_cell(server::start_continuous(mk(kv_capped), copts), &wl),
        ),
    ];

    let mut entries: Vec<Json> = Vec::new();
    for (mode, cell) in &cells {
        println!(
            "{mode:<19} {:>8.1} tok/s  wall {:>8.1} ms  ttft p50 {:>7.2} ms  p95 {:>7.2} ms  queue p50 {:>7.2} ms  steps {:>5}  preempt {}/{}",
            cell.tok_s,
            cell.wall_ms,
            cell.ttft_p50,
            cell.ttft_p95,
            cell.queue_p50,
            cell.sched_steps,
            cell.preemptions,
            cell.resumes,
        );
        entries.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("requests", Json::num(wl.requests.len() as f64)),
            ("tokens", Json::num(wl.total_new as f64)),
            ("tok_s", Json::num(cell.tok_s)),
            ("wall_ms", Json::num(cell.wall_ms)),
            ("ttft_p50_ms", Json::num(cell.ttft_p50)),
            ("ttft_p95_ms", Json::num(cell.ttft_p95)),
            ("queue_p50_ms", Json::num(cell.queue_p50)),
            ("sched_steps", Json::num(cell.sched_steps as f64)),
            ("preemptions", Json::num(cell.preemptions as f64)),
            ("resumes", Json::num(cell.resumes as f64)),
        ]));
    }

    // ---- acceptance ----
    let by = |m: &str| &cells.iter().find(|c| c.0 == m).expect("cell").1;
    let sequential = by("lockstep-b1");
    for (mode, cell) in &cells {
        assert_eq!(
            cell.outputs, sequential.outputs,
            "{mode}: outputs diverged from sequential execution"
        );
    }
    let preempt = by("continuous-preempt");
    assert!(
        preempt.preemptions >= 1 && preempt.resumes >= 1,
        "page-capped cell must preempt and resume (got {}/{})",
        preempt.preemptions,
        preempt.resumes
    );
    let speedup = by("continuous-b16").tok_s / by("lockstep-b16").tok_s.max(1e-9);
    println!("  continuous vs lockstep at batch budget 16: {speedup:.2}x aggregate tok/s");
    if smoke() {
        println!("  (smoke mode: speedup not asserted)");
    } else {
        assert!(
            speedup >= 1.5,
            "continuous batching only {speedup:.2}x over lockstep (need >= 1.5x)"
        );
    }

    append_trajectory(
        "serving",
        vec![
            ("smoke", Json::num(if smoke() { 1.0 } else { 0.0 })),
            ("speedup_vs_lockstep", Json::num(speedup)),
            ("measurements", Json::Arr(entries)),
        ],
    );
}
