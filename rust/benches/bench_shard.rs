//! Bench: tensor-parallel sharded serving vs the PR-2 single-shard
//! streaming path, at **equal total core count** (ISSUE 5 acceptance).
//!
//! Workload: batch-1 greedy decode from a compressed (rANS-entropy)
//! container behind the paged KV cache, so after the untimed prefill
//! every timed token costs exactly one whole-model panel-decode — the
//! thing sharding accelerates. The single-engine path spawns its worker
//! threads and re-expands every rANS decode table **per linear call**,
//! while the shard executor's persistent workers keep scratch and
//! tables alive across the whole generation.
//!
//! Cells (all serving the identical prompt, outputs asserted
//! byte-identical):
//!
//! - `streaming-4t`  — `CachedNativeBackend::streaming`, one
//!                     `StreamingMatmul` engine, 4 threads (the PR-2
//!                     single-shard streaming path at 4 cores)
//! - `sharded-1x1`   — shard executor, 1 shard (overhead floor)
//! - `sharded-4x1`   — 4 shard workers × 1 thread = 4 cores
//!
//! Asserted acceptance (full mode): `sharded-4x1` reaches **≥ 1.5×**
//! the batch-1 decode tokens/s of `streaming-4t`, with identical tokens.
//! `GLVQ_BENCH_SMOKE=1` runs a miniature generation for CI: parity still
//! asserted, speedup reported but not asserted.
//!
//! Results append to `runs/bench/shard.json` (`{"runs": [...]}`).
//!
//! Run: `cargo bench --bench bench_shard`

use std::time::Instant;

use glvq::baselines::rtn::RtnQuantizer;
use glvq::bench_support::append_trajectory;
use glvq::coordinator::decode_stream::StreamingMatmul;
use glvq::coordinator::server::{CachedNativeBackend, LmBackend};
use glvq::eval::native_fwd::{self, CalibCapture};
use glvq::glvq::pipeline::{quantize_model, PipelineOpts};
use glvq::kvcache::KvCacheOpts;
use glvq::model::{init_params, ModelConfig};
use glvq::quant::format::QuantizedModel;
use glvq::shard::{imbalance, ShardOpts};
use glvq::tensor::TensorStore;
use glvq::util::json::Json;
use glvq::util::rng::Rng;

fn smoke() -> bool {
    std::env::var("GLVQ_BENCH_SMOKE").is_ok()
}

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "shardbench",
        vocab: 256,
        d_model: 64,
        n_layer: 2,
        n_head: 2,
        d_ff: 128,
        seq_len: 96,
        batch_train: 2,
        batch_eval: 2,
    }
}

/// Quantize the bench model once with rANS-entropy payloads; every cell
/// serves clones of the same container.
fn quantized_parts(cfg: &ModelConfig) -> (TensorStore, QuantizedModel) {
    let store = init_params(cfg, 0);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
    let mut cap = CalibCapture::new(16, 0);
    native_fwd::forward(cfg, &store, &toks, 2, Some(&mut cap)).expect("calibration forward");
    let calib = cap.into_calib_set();
    let mut opts = PipelineOpts::default();
    opts.target_bits = 3.0;
    opts.bit_allocation = false;
    opts.entropy = true;
    // 16-wide column groups → every tensor splits into ≥4 group-aligned
    // cells, so a 4-way shard plan actually spreads each linear
    opts.group_size = 16;
    let (qm, _) =
        quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).expect("quantize");
    (store, qm)
}

struct Cell {
    tok_s: f64,
    tokens: Vec<u8>,
    imbalance: f64,
}

/// Greedy batch-1 decode: untimed prefill + first token, then `gen`
/// timed one-token steps (each a whole-model panel decode through the
/// backend's engine).
fn run_cell(backend: &mut dyn LmBackend, prompt: &[u8], gen: usize) -> (f64, Vec<u8>) {
    let mut toks: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
    let start = toks.len();
    // untimed: prefill the prompt into the KV cache (also primes shard
    // decode tables and scratch) and emit the first token
    let first = backend.logits_last(&toks).expect("prefill forward");
    toks.push(native_fwd::argmax_logit(&first));
    let t0 = Instant::now();
    for _ in 0..gen {
        let logits = backend.logits_last(&toks).expect("decode step failed");
        toks.push(native_fwd::argmax_logit(&logits));
    }
    let secs = t0.elapsed().as_secs_f64();
    (
        gen as f64 / secs.max(1e-12),
        toks[start..].iter().map(|&t| t.clamp(0, 255) as u8).collect(),
    )
}

fn main() {
    let cfg = bench_cfg();
    let gen = if smoke() { 8 } else { 48 };
    let prompt = b"the kama sutra of rust ";
    let (store, qm) = quantized_parts(&cfg);
    println!(
        "# sharded vs single-engine streaming: d={} L={} — batch-1 decode, {} tokens, {}",
        cfg.d_model,
        cfg.n_layer,
        gen,
        if smoke() { "smoke" } else { "full" },
    );

    let kv = KvCacheOpts { page_rows: 16, ..Default::default() };
    let mut cells: Vec<(&str, Cell)> = Vec::new();

    {
        let mut b = CachedNativeBackend::streaming(
            cfg,
            store.clone(),
            qm.clone(),
            StreamingMatmul::new(16, 4),
            kv,
        );
        let (tok_s, tokens) = run_cell(&mut b, prompt, gen);
        cells.push(("streaming-4t", Cell { tok_s, tokens, imbalance: 0.0 }));
    }
    for &shards in &[1usize, 4] {
        let name = if shards == 1 { "sharded-1x1" } else { "sharded-4x1" };
        let mut b = CachedNativeBackend::sharded(
            cfg,
            store.clone(),
            qm.clone(),
            ShardOpts { shards, panel_rows: 16, threads_per_shard: 1 },
            kv,
        );
        let (tok_s, tokens) = run_cell(&mut b, prompt, gen);
        let imb = b.shard_stats().map(|s| imbalance(&s)).unwrap_or(0.0);
        cells.push((name, Cell { tok_s, tokens, imbalance: imb }));
    }

    let mut entries: Vec<Json> = Vec::new();
    for (mode, cell) in &cells {
        println!(
            "{mode:<14} {:>9.1} tok/s   shard imbalance {:.2}x",
            cell.tok_s, cell.imbalance
        );
        entries.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("gen_tokens", Json::num(gen as f64)),
            ("tok_s", Json::num(cell.tok_s)),
            ("shard_imbalance", Json::num(cell.imbalance)),
        ]));
    }

    // ---- acceptance ----
    let by = |m: &str| &cells.iter().find(|c| c.0 == m).expect("cell").1;
    let baseline = by("streaming-4t");
    for (mode, cell) in &cells {
        assert_eq!(
            cell.tokens, baseline.tokens,
            "{mode}: generated tokens diverged from the streaming path"
        );
    }
    let speedup = by("sharded-4x1").tok_s / baseline.tok_s.max(1e-12);
    println!("  sharded 4x1 vs streaming 4-thread (equal cores): {speedup:.2}x decode tok/s");
    if smoke() {
        println!("  (smoke mode: speedup not asserted)");
    } else {
        assert!(
            speedup >= 1.5,
            "sharded execution only {speedup:.2}x over single-shard streaming (need >= 1.5x)"
        );
    }

    append_trajectory(
        "shard",
        vec![
            ("smoke", Json::num(if smoke() { 1.0 } else { 0.0 })),
            ("speedup_vs_streaming", Json::num(speedup)),
            ("measurements", Json::Arr(entries)),
        ],
    );
}
