//! Bench: per-group quantization throughput across methods — the compute
//! behind Table 1/2/3 (quality tables). Reports groups/s and weights/s for
//! a canonical (256×128) group at 2 bits.
//!
//! Run: `cargo bench --bench bench_table1_quant`

use glvq::baselines;
use glvq::bench_support::Bencher;
use glvq::config::GlvqConfig;
use glvq::glvq::optimizer::GlvqGroupQuantizer;
use glvq::linalg::Mat;
use glvq::quant::traits::GroupQuantizer;
use glvq::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let data: Vec<f32> = (0..256 * 128).map(|_| rng.student_t(5.0) as f32 * 0.02).collect();
    let w = Mat::from_vec(256, 128, data);
    let x = Mat::random_normal(128, 192, 1.0, &mut rng);
    let weights = (w.rows * w.cols) as f64;

    let b = Bencher::default();
    println!("# Table 1/2/3 work unit: quantize one 256x128 group at 2 bits");

    for method in ["rtn", "omniquant_lite", "gptq", "kmeans_vq", "quip_lite", "tcq"] {
        let q = baselines::by_name(method).unwrap();
        let r = b.run(&format!("quantize/{method}"), weights, || {
            std::hint::black_box(q.quantize(&w, &x, 2));
        });
        println!("{}", r.report());
    }

    for (label, d, iters) in [("glvq-8d", 8usize, 16usize), ("glvq-16d", 16, 16), ("glvq-32d", 32, 16)] {
        let mut cfg = GlvqConfig::default();
        cfg.lattice_dim = d;
        cfg.iters = iters;
        let q = GlvqGroupQuantizer::new(cfg);
        let r = b.run(&format!("quantize/{label} ({iters} iters)"), weights, || {
            std::hint::black_box(q.quantize(&w, &x, 2));
        });
        println!("{}", r.report());
    }
}
