//! Bench: native hot-path kernels (L1 analogues on the rust side):
//! Babai batch encode, mu-law compand, blocked matmul, Hadamard, bit
//! pack/unpack. These are the §Perf optimization targets.
//!
//! Run: `cargo bench --bench bench_kernels`

use glvq::bench_support::Bencher;
use glvq::compand::MuLaw;
use glvq::lattice::babai::{babai_batch_into, BabaiEncoder};
use glvq::lattice::{GenLattice, LatticeEncoder};
use glvq::linalg::matrix::matmul_into;
use glvq::linalg::Mat;
use glvq::quant::pack::{code_range, PackedCodes};
use glvq::quant::traits::hadamard;
use glvq::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(1);

    println!("# L3 native kernel hot paths");

    // Babai batch encode: 2048 blocks x d
    for d in [8usize, 16, 32] {
        let mut g = Mat::eye(d).scale(0.05);
        for v in g.data.iter_mut() {
            *v += rng.normal_f32() * 0.002;
        }
        let lat = GenLattice::new(g).unwrap();
        let panel = Mat::random_normal(2048, d, 0.05, &mut rng);
        let mut z = Mat::zeros(2048, d);
        let work = (2048 * d * d) as f64; // MACs
        let r = b.run(&format!("babai_batch/d{d} (2048 blocks)"), work, || {
            babai_batch_into(&lat, &panel, &mut z);
            std::hint::black_box(&z);
        });
        println!("{}", r.report());

        let single = BabaiEncoder;
        let y = panel.row(0).to_vec();
        let r = b.run(&format!("babai_single/d{d}"), (d * d) as f64, || {
            std::hint::black_box(single.encode(&lat, &y));
        });
        println!("{}", r.report());
    }

    // mu-law forward+inverse on 32k elements
    let comp = MuLaw::new(87.6);
    let data = {
        let mut v = vec![0.0f32; 32768];
        rng.fill_normal(&mut v, 0.3);
        v
    };
    let mut buf = data.clone();
    let r = b.run("mu_law_fwd/32k", 32768.0, || {
        buf.copy_from_slice(&data);
        comp.forward_slice(&mut buf);
        std::hint::black_box(&buf);
    });
    println!("{}", r.report());
    let r = b.run("mu_law_inv/32k", 32768.0, || {
        comp.inverse_slice(&mut buf);
        std::hint::black_box(&buf);
    });
    println!("{}", r.report());

    // blocked matmul 256x256x256
    let a = Mat::random_normal(256, 256, 1.0, &mut rng);
    let bm = Mat::random_normal(256, 256, 1.0, &mut rng);
    let mut c = Mat::zeros(256, 256);
    let r = b.run("matmul/256^3", (256f64).powi(3), || {
        matmul_into(&a, &bm, &mut c);
        std::hint::black_box(&c);
    });
    println!("{}  ({:.2} GFLOP/s)", r.report(), 2.0 * r.throughput() / 1e9);

    // Hadamard d=128
    let x = {
        let mut v = vec![0.0f32; 128];
        rng.fill_normal(&mut v, 1.0);
        v
    };
    let r = b.run("hadamard/d128", 128.0 * 7.0, || {
        std::hint::black_box(hadamard(&x));
    });
    println!("{}", r.report());

    // pack/unpack 16384 2-bit codes
    let (lo, hi) = code_range(2);
    let codes: Vec<i32> = (0..16384).map(|i| (i % (hi - lo + 1) as usize) as i32 + lo).collect();
    let packed = PackedCodes::pack(&codes, 2);
    let mut out = vec![0i32; 16384];
    let r = b.run("pack/16k @2bit", 16384.0, || {
        std::hint::black_box(PackedCodes::pack(&codes, 2));
    });
    println!("{}", r.report());
    let r = b.run("unpack/16k @2bit", 16384.0, || {
        packed.unpack_into(&mut out);
        std::hint::black_box(&out);
    });
    println!("{}", r.report());
}
