//! Bench: native hot-path kernels (L1 analogues on the rust side):
//! Babai batch encode, mu-law compand, blocked matmul, Hadamard, bit
//! pack/unpack, and the fused decode-GEMM kernel vs the classic
//! decode-then-FMA slab path. These are the §Perf optimization targets.
//!
//! The fused section asserts ≥ 1.5× fused-over-slab on the LUT-eligible
//! 2–3-bit fixed-rate lattice cells at batch 1 (where decode dominates)
//! and appends a `bytes_vs_flops` roofline trajectory to
//! `runs/bench/kernels.json`. `GLVQ_BENCH_SMOKE=1` runs a miniature
//! workload for CI: parity still checked, perf assertions skipped.
//!
//! Run: `cargo bench --bench bench_kernels`

use glvq::bench_support::{append_trajectory, Bencher};
use glvq::compand::MuLaw;
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::kernels::{ExecMode, LUT_WARM_CALLS};
use glvq::lattice::babai::{babai_batch_into, BabaiEncoder};
use glvq::lattice::{GenLattice, LatticeEncoder};
use glvq::linalg::matrix::matmul_into;
use glvq::linalg::Mat;
use glvq::quant::format::QuantizedTensor;
use glvq::quant::pack::{code_range, PackedCodes};
use glvq::quant::traits::{hadamard, QuantizedGroup, SideInfo};
use glvq::util::json::Json;
use glvq::util::rng::Rng;

fn smoke() -> bool {
    std::env::var("GLVQ_BENCH_SMOKE").is_ok()
}

/// A synthetic single-group lattice tensor shaped like the quantizer's
/// output (near-diagonal generation matrix, random in-range codes) —
/// the decode cost is identical to a trained container, and building it
/// directly keeps the bench fast.
fn lattice_tensor(rows: usize, cols: usize, d: usize, bits: u8, seed: u64) -> QuantizedTensor {
    let mut rng = Rng::new(seed);
    let mut g = vec![0.0f32; d * d];
    for i in 0..d {
        g[i * d + i] = 0.05;
    }
    for v in g.iter_mut() {
        *v += rng.normal_f32() * 0.002;
    }
    let (lo, hi) = code_range(bits);
    let codes: Vec<i32> =
        (0..rows * cols).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
    let qg = QuantizedGroup {
        method: "glvq",
        bits,
        rows,
        cols,
        codes: PackedCodes::pack(&codes, bits).into(),
        side: SideInfo::Lattice { d, g, mu: 87.0, scale: 0.03 },
    };
    QuantizedTensor { name: format!("lat_d{d}_b{bits}"), rows, cols, groups: vec![(0, 0, qg)] }
}

/// Fused-vs-slab cells: per (d, bits) lattice family, parity-check then
/// time both modes and append the roofline trajectory.
fn bench_fused(b: &Bencher) {
    let (rows, cols) = if smoke() { (64, 64) } else { (512, 512) };
    println!("# fused decode-GEMM vs slab path: {rows}x{cols} lattice tensors");
    let mut entries: Vec<Json> = Vec::new();
    // (d, bits, LUT-eligible → asserted)
    for &(d, bits, asserted) in &[(8usize, 2u8, true), (4, 3, true), (8, 3, false)] {
        let qt = lattice_tensor(rows, cols, d, bits, 40 + d as u64 + bits as u64);
        let slab = StreamingMatmul::new(16, 1).with_mode(ExecMode::Slab);
        let fused = StreamingMatmul::new(16, 1).with_mode(ExecMode::Fused);
        let mut speedup_b1 = 0.0f64;
        for &batch in &[1usize, 8] {
            let mut rng = Rng::new(41);
            let x = Mat::random_normal(batch, cols, 1.0, &mut rng);
            let mut ys = Mat::zeros(batch, rows);
            let mut yf = Mat::zeros(batch, rows);
            let mut stats = DecodeStats::default();
            slab.matmul(&qt, &x, &mut ys, &mut stats);
            // warm the fused engine past the LUT threshold, checking
            // parity on every call (pre-warm direct and post-warm LUT
            // decode must both be bit-identical to the slab path)
            for _ in 0..LUT_WARM_CALLS + 1 {
                let mut s = DecodeStats::default();
                fused.matmul(&qt, &x, &mut yf, &mut s);
                assert_eq!(yf.data, ys.data, "d{d}/b{bits}: fused != slab (not bit-exact)");
            }
            let bytes_per_mac = stats.total_bytes() as f64 / stats.macs.max(1) as f64;

            let mut cell = Vec::new();
            for (mode, engine, y) in [("slab", &slab, &mut ys), ("fused", &fused, &mut yf)] {
                let label = format!("decode_matmul/d{d}/b{bits}/{mode}/B{batch}");
                let r = b.run(&label, batch as f64, || {
                    let mut s = DecodeStats::default();
                    engine.matmul(&qt, &x, y, &mut s);
                    std::hint::black_box(&y);
                });
                println!("{}", r.report());
                cell.push(r.mean_ns);
                entries.push(Json::obj(vec![
                    ("cell", Json::str(&format!("d{d}_b{bits}_B{batch}"))),
                    ("mode", Json::str(mode)),
                    ("bytes_per_mac", Json::num(bytes_per_mac)),
                    ("macs", Json::num(stats.macs as f64)),
                    ("ns", Json::num(r.mean_ns)),
                ]));
            }
            let speedup = cell[0] / cell[1].max(1e-12);
            println!("  d{d}/b{bits}/B{batch}: fused = {speedup:.2}x slab");
            entries.push(Json::obj(vec![
                ("cell", Json::str(&format!("d{d}_b{bits}_B{batch}"))),
                ("mode", Json::str("speedup")),
                ("speedup", Json::num(speedup)),
            ]));
            if batch == 1 {
                speedup_b1 = speedup;
            }
        }
        if asserted && !smoke() {
            assert!(
                speedup_b1 >= 1.5,
                "d{d}/b{bits}: fused only {speedup_b1:.2}x over slab at batch 1 (need 1.5x)"
            );
        }
    }
    append_trajectory("kernels", vec![("bytes_vs_flops", Json::Arr(entries))]);
}

fn main() {
    let b = if smoke() { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(1);

    bench_fused(&b);
    if smoke() {
        // CI smoke: the fused section above already parity-checked and
        // appended its trajectory; skip the long classic-kernel sweep
        println!("smoke mode: classic kernel cells skipped");
        return;
    }

    println!("# L3 native kernel hot paths");

    // Babai batch encode: 2048 blocks x d
    for d in [8usize, 16, 32] {
        let mut g = Mat::eye(d).scale(0.05);
        for v in g.data.iter_mut() {
            *v += rng.normal_f32() * 0.002;
        }
        let lat = GenLattice::new(g).unwrap();
        let panel = Mat::random_normal(2048, d, 0.05, &mut rng);
        let mut z = Mat::zeros(2048, d);
        let work = (2048 * d * d) as f64; // MACs
        let r = b.run(&format!("babai_batch/d{d} (2048 blocks)"), work, || {
            babai_batch_into(&lat, &panel, &mut z);
            std::hint::black_box(&z);
        });
        println!("{}", r.report());

        let single = BabaiEncoder;
        let y = panel.row(0).to_vec();
        let r = b.run(&format!("babai_single/d{d}"), (d * d) as f64, || {
            std::hint::black_box(single.encode(&lat, &y));
        });
        println!("{}", r.report());
    }

    // mu-law forward+inverse on 32k elements
    let comp = MuLaw::new(87.6);
    let data = {
        let mut v = vec![0.0f32; 32768];
        rng.fill_normal(&mut v, 0.3);
        v
    };
    let mut buf = data.clone();
    let r = b.run("mu_law_fwd/32k", 32768.0, || {
        buf.copy_from_slice(&data);
        comp.forward_slice(&mut buf);
        std::hint::black_box(&buf);
    });
    println!("{}", r.report());
    let r = b.run("mu_law_inv/32k", 32768.0, || {
        comp.inverse_slice(&mut buf);
        std::hint::black_box(&buf);
    });
    println!("{}", r.report());

    // blocked matmul 256x256x256
    let a = Mat::random_normal(256, 256, 1.0, &mut rng);
    let bm = Mat::random_normal(256, 256, 1.0, &mut rng);
    let mut c = Mat::zeros(256, 256);
    let r = b.run("matmul/256^3", (256f64).powi(3), || {
        matmul_into(&a, &bm, &mut c);
        std::hint::black_box(&c);
    });
    println!("{}  ({:.2} GFLOP/s)", r.report(), 2.0 * r.throughput() / 1e9);

    // Hadamard d=128
    let x = {
        let mut v = vec![0.0f32; 128];
        rng.fill_normal(&mut v, 1.0);
        v
    };
    let r = b.run("hadamard/d128", 128.0 * 7.0, || {
        std::hint::black_box(hadamard(&x));
    });
    println!("{}", r.report());

    // pack/unpack 16384 2-bit codes
    let (lo, hi) = code_range(2);
    let codes: Vec<i32> = (0..16384).map(|i| (i % (hi - lo + 1) as usize) as i32 + lo).collect();
    let packed = PackedCodes::pack(&codes, 2);
    let mut out = vec![0i32; 16384];
    let r = b.run("pack/16k @2bit", 16384.0, || {
        std::hint::black_box(PackedCodes::pack(&codes, 2));
    });
    println!("{}", r.report());
    let r = b.run("unpack/16k @2bit", 16384.0, || {
        packed.unpack_into(&mut out);
        std::hint::black_box(&out);
    });
    println!("{}", r.report());
}
