//! Binarization baselines for the sub-2-bit regime (Table 3):
//!
//! - `residual: false` → OneBit-lite: ŵ = α_r · sign(w) with the L2-optimal
//!   per-row scale α_r = mean|w_r| (1 bit/weight).
//! - `residual: true`  → BiLLM-lite: a second sign pass on the residual,
//!   ŵ = α_r·s₁ + β_r·s₂ (2 bits/weight) — captures BiLLM's
//!   residual-binarization mechanism without the salient-column split.

use crate::linalg::Mat;
use crate::quant::pack::{code_range, PackedCodes};
use crate::quant::traits::{GroupQuantizer, QuantizedGroup, SideInfo};

#[derive(Clone, Copy, Debug)]
pub struct BinaryQuantizer {
    pub residual: bool,
}

impl GroupQuantizer for BinaryQuantizer {
    fn quantize(&self, w: &Mat, _x: &Mat, bits: u8) -> QuantizedGroup {
        let (m, n) = (w.rows, w.cols);
        let eff_bits: u8 = if self.residual { 2 } else { 1 };
        let _ = bits; // rate is structural for binarization
        let (lo, _) = code_range(eff_bits);

        let mut row_scales = vec![0.0f32; m];
        let mut residual_scales = if self.residual { Some(vec![0.0f32; m]) } else { None };
        let mut codes = vec![0i32; m * n];

        for r in 0..m {
            let row = w.row(r);
            let alpha = row.iter().map(|v| v.abs()).sum::<f32>() / n as f32;
            row_scales[r] = alpha;
            if let Some(res_scales) = residual_scales.as_mut() {
                // residual pass
                let resid: Vec<f32> = row
                    .iter()
                    .map(|&v| v - alpha * if v >= 0.0 { 1.0 } else { -1.0 })
                    .collect();
                let beta = resid.iter().map(|v| v.abs()).sum::<f32>() / n as f32;
                res_scales[r] = beta;
                for c in 0..n {
                    let u1 = (row[c] >= 0.0) as u32;
                    let u2 = (resid[c] >= 0.0) as u32;
                    codes[r * n + c] = ((u1 | (u2 << 1)) as i32) + lo;
                }
            } else {
                for c in 0..n {
                    let u1 = (row[c] >= 0.0) as u32;
                    codes[r * n + c] = (u1 as i32) + lo;
                }
            }
        }

        QuantizedGroup {
            method: "binary",
            bits: eff_bits,
            rows: m,
            cols: n,
            codes: PackedCodes::pack(&codes, eff_bits).into(),
            side: SideInfo::Binary { row_scales, residual_scales },
        }
    }

    fn name(&self) -> &'static str {
        "binary"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn one_bit_reconstruction_is_scaled_signs() {
        let mut rng = Rng::new(1);
        let w = Mat::random_normal(4, 16, 0.05, &mut rng);
        let q = BinaryQuantizer { residual: false }.quantize(&w, &Mat::zeros(16, 1), 1);
        let w_hat = q.dequantize();
        for r in 0..4 {
            let alpha = w.row(r).iter().map(|v| v.abs()).sum::<f32>() / 16.0;
            for c in 0..16 {
                let want = alpha * if w.at(r, c) >= 0.0 { 1.0 } else { -1.0 };
                assert!((w_hat.at(r, c) - want).abs() < 1e-6);
            }
        }
        assert_eq!(q.bits, 1);
    }

    #[test]
    fn residual_pass_strictly_reduces_weight_mse() {
        proptest(20, |rig| {
            let (m, n) = (rig.usize_in(2, 12), 32);
            let w = Mat::from_vec(m, n, rig.vec_normal(m * n, 0.05));
            let zero_x = Mat::zeros(n, 1);
            let one = BinaryQuantizer { residual: false }.quantize(&w, &zero_x, 1);
            let two = BinaryQuantizer { residual: true }.quantize(&w, &zero_x, 2);
            let mse = |q: &QuantizedGroup| -> f64 {
                let h = q.dequantize();
                w.data
                    .iter()
                    .zip(&h.data)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum()
            };
            assert!(mse(&two) <= mse(&one) + 1e-12);
        });
    }

    #[test]
    fn scale_is_l2_optimal_for_signs() {
        // for fixed signs s, argmin_a ||w - a s||² = mean(w·s) = mean|w|
        let mut rng = Rng::new(2);
        let w = Mat::random_normal(1, 64, 0.1, &mut rng);
        let q = BinaryQuantizer { residual: false }.quantize(&w, &Mat::zeros(64, 1), 1);
        if let SideInfo::Binary { row_scales, .. } = &q.side {
            let alpha = row_scales[0];
            let mse = |a: f32| -> f32 {
                w.data
                    .iter()
                    .map(|&v| {
                        let s = if v >= 0.0 { 1.0 } else { -1.0 };
                        (v - a * s) * (v - a * s)
                    })
                    .sum()
            };
            assert!(mse(alpha) <= mse(alpha * 1.1) + 1e-7);
            assert!(mse(alpha) <= mse(alpha * 0.9) + 1e-7);
        } else {
            panic!("wrong side info");
        }
    }
}
