//! Round-to-nearest (RTN) uniform quantization with a per-group affine
//! grid — the universal PTQ floor every paper compares against.

use crate::linalg::Mat;
use crate::quant::pack::{code_range, PackedCodes};
use crate::quant::traits::{GroupQuantizer, QuantizedGroup, SideInfo};

#[derive(Clone, Copy, Debug, Default)]
pub struct RtnQuantizer;

/// Quantize with an explicit clip range [cmin, cmax] (shared with the
/// OmniQuant-lite grid search).
pub fn rtn_with_range(w: &Mat, bits: u8, cmin: f32, cmax: f32) -> QuantizedGroup {
    let (lo, hi) = code_range(bits);
    let levels = (hi - lo) as f32;
    let span = (cmax - cmin).max(1e-12);
    let scale = span / levels;
    let zero = cmin - lo as f32 * scale;
    let codes: Vec<i32> = w
        .data
        .iter()
        .map(|&v| {
            let c = ((v - zero) / scale).round();
            (c as i64).clamp(lo as i64, hi as i64) as i32
        })
        .collect();
    QuantizedGroup {
        method: "rtn",
        bits,
        rows: w.rows,
        cols: w.cols,
        codes: PackedCodes::pack(&codes, bits).into(),
        side: SideInfo::Uniform { scale, zero },
    }
}

impl GroupQuantizer for RtnQuantizer {
    fn quantize(&self, w: &Mat, _x: &Mat, bits: u8) -> QuantizedGroup {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &w.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        rtn_with_range(w, bits, mn, mx)
    }

    fn name(&self) -> &'static str {
        "rtn"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::traits::recon_error;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn error_bounded_by_half_step() {
        proptest(30, |rig| {
            let bits = rig.usize_in(2, 8) as u8;
            let (m, n) = (rig.usize_in(1, 20), rig.usize_in(1, 20));
            let w = Mat::from_vec(m, n, rig.vec_normal(m * n, 0.1));
            let q = RtnQuantizer.quantize(&w, &Mat::zeros(n, 1), bits);
            let w_hat = q.dequantize();
            let span = w.data.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &v| {
                (a.min(v), b.max(v))
            });
            let step = (span.1 - span.0) / (((1usize << bits) - 1) as f32);
            for (a, b) in w.data.iter().zip(&w_hat.data) {
                assert!((a - b).abs() <= step / 2.0 + 1e-5, "bits={bits}");
            }
        });
    }

    #[test]
    fn high_bits_near_lossless() {
        let mut rng = Rng::new(1);
        let w = Mat::random_normal(16, 16, 0.05, &mut rng);
        let x = Mat::random_normal(16, 8, 1.0, &mut rng);
        let q = RtnQuantizer.quantize(&w, &x, 8);
        let e = recon_error(&w, &q.dequantize(), &x);
        assert!(e < 1e-3, "e={e}");
    }

    #[test]
    fn monotone_in_bits() {
        let mut rng = Rng::new(2);
        let w = Mat::random_normal(32, 32, 0.05, &mut rng);
        let x = Mat::random_normal(32, 16, 1.0, &mut rng);
        let mut last = f64::INFINITY;
        for bits in [1u8, 2, 3, 4, 6] {
            let e = recon_error(&w, &RtnQuantizer.quantize(&w, &x, bits).dequantize(), &x);
            assert!(e <= last * 1.05, "bits={bits}: {e} vs {last}");
            last = e;
        }
    }

    #[test]
    fn constant_group_is_exact() {
        let w = Mat::from_vec(2, 2, vec![0.25; 4]);
        let q = RtnQuantizer.quantize(&w, &Mat::zeros(2, 1), 2);
        let w_hat = q.dequantize();
        for v in &w_hat.data {
            assert!((v - 0.25).abs() < 1e-6);
        }
    }
}
