//! Baseline quantizers — in-repo stand-ins for the paper's comparison
//! methods (DESIGN.md §3 maps each to its published counterpart):
//!
//! | module            | stands in for          | mechanism kept |
//! |-------------------|------------------------|----------------|
//! | [`rtn`]           | vanilla RTN            | per-group affine round-to-nearest |
//! | [`omniquant_lite`]| OmniQuant              | grid-searched learnable clipping |
//! | [`gptq`]          | GPTQ (full algorithm)  | Hessian-aware sequential column quant + error propagation |
//! | [`kmeans_vq`]     | AQLM / SqueezeLLM      | free-form VQ codebook (sensitivity-weighted k-means) |
//! | [`quip_lite`]     | QuIP#                  | randomized Hadamard incoherence + fixed E8 lattice |
//! | [`tcq`]           | QTIP                   | trellis-coded quantization with Viterbi encoding |
//! | [`binary`]        | OneBit / BiLLM         | sign+scale binarization (+ residual pass) |
//!
//! All implement [`crate::quant::GroupQuantizer`], so every method runs
//! through the identical pipeline and differs only in its group fit.

pub mod binary;
pub mod gptq;
pub mod kmeans_vq;
pub mod omniquant_lite;
pub mod quip_lite;
pub mod rtn;
pub mod tcq;

use crate::quant::traits::GroupQuantizer;

/// Resolve a method name (CLI / experiment tables) to a boxed quantizer.
/// GLVQ variants are constructed separately (they carry a config).
pub fn by_name(name: &str) -> Option<Box<dyn GroupQuantizer + Sync + Send>> {
    match name {
        "rtn" => Some(Box::new(rtn::RtnQuantizer)),
        "omniquant_lite" | "omniq" => Some(Box::new(omniquant_lite::OmniQuantLite::default())),
        "gptq" => Some(Box::new(gptq::GptqQuantizer::default())),
        "kmeans_vq" | "aqlm_lite" => Some(Box::new(kmeans_vq::KMeansVq::default())),
        "quip_lite" | "quip" => Some(Box::new(quip_lite::QuipLite::default())),
        "tcq" | "qtip_lite" => Some(Box::new(tcq::TcqQuantizer::default())),
        "binary" | "onebit_lite" => Some(Box::new(binary::BinaryQuantizer { residual: false })),
        "binary_residual" | "billm_lite" => Some(Box::new(binary::BinaryQuantizer { residual: true })),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_resolves_known_methods() {
        for m in [
            "rtn",
            "omniquant_lite",
            "gptq",
            "kmeans_vq",
            "quip_lite",
            "tcq",
            "binary",
            "binary_residual",
        ] {
            assert!(super::by_name(m).is_some(), "{m}");
        }
        assert!(super::by_name("nope").is_none());
    }
}
