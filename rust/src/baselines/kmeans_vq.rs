//! Free-form VQ baseline (AQLM / SqueezeLLM-lite): k-means codebook over
//! small weight blocks with Hessian-diagonal sensitivity weighting.
//!
//! Block dim v=2 for b ≤ 4 (index = 2b bits ≤ 8), v=1 otherwise. Unlike the
//! lattice methods, decode requires a *codebook lookup* — exactly the
//! operational cost the paper contrasts GLVQ against (Table 4 shows
//! AQLM-style methods pay for it in throughput; our streaming-decode bench
//! reproduces that gap).

use crate::linalg::Mat;
use crate::quant::pack::{code_range, PackedCodes};
use crate::quant::traits::{GroupQuantizer, QuantizedGroup, SideInfo};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct KMeansVq {
    pub lloyd_iters: usize,
    pub seed: u64,
}

impl Default for KMeansVq {
    fn default() -> Self {
        KMeansVq { lloyd_iters: 12, seed: 0x5EED }
    }
}

impl KMeansVq {
    fn block_dim(bits: u8) -> usize {
        if bits <= 4 {
            2
        } else {
            1
        }
    }
}

impl GroupQuantizer for KMeansVq {
    fn quantize(&self, w: &Mat, x: &Mat, bits: u8) -> QuantizedGroup {
        let (m, n) = (w.rows, w.cols);
        let v = Self::block_dim(bits);
        assert_eq!(n % v, 0);
        let idx_bits = bits as usize * v;
        assert!(idx_bits <= 8, "index bits {idx_bits} exceed packing width");
        let k = 1usize << idx_bits;
        let nblocks = m * n / v;

        // sensitivity per column = diag(X Xᵀ); block weight = mean of its
        // columns' sensitivities (SqueezeLLM's Fisher-diag analogue)
        let mut col_sens = vec![0.0f32; n];
        for c in 0..n {
            let row = x.row(c);
            col_sens[c] = row.iter().map(|a| a * a).sum::<f32>().max(1e-8);
        }

        // gather blocks (contiguous v-length runs within rows)
        let mut blocks = vec![0.0f32; nblocks * v];
        let mut weights = vec![0.0f32; nblocks];
        for b in 0..nblocks {
            let col0 = (b * v) % n;
            blocks[b * v..(b + 1) * v].copy_from_slice(&w.data[b * v..(b + 1) * v]);
            weights[b] = (0..v).map(|i| col_sens[col0 + i]).sum::<f32>() / v as f32;
        }

        // k-means++ init: first center random, then distance²-weighted picks
        let mut rng = Rng::new(self.seed);
        let mut centers = vec![0.0f32; k * v];
        let first = rng.below(nblocks);
        centers[0..v].copy_from_slice(&blocks[first * v..(first + 1) * v]);
        let mut d2 = vec![0.0f64; nblocks];
        for c in 1..k {
            for b in 0..nblocks {
                let bl = &blocks[b * v..(b + 1) * v];
                let mut best = f64::INFINITY;
                for cc in 0..c {
                    let ce = &centers[cc * v..(cc + 1) * v];
                    let mut dist = 0.0f64;
                    for i in 0..v {
                        let t = (bl[i] - ce[i]) as f64;
                        dist += t * t;
                    }
                    best = best.min(dist);
                }
                d2[b] = best;
            }
            let total: f64 = d2.iter().sum();
            let pick = if total > 0.0 {
                rng.categorical(&d2)
            } else {
                rng.below(nblocks)
            };
            centers[c * v..(c + 1) * v].copy_from_slice(&blocks[pick * v..(pick + 1) * v]);
        }

        let mut assign = vec![0usize; nblocks];
        for _ in 0..self.lloyd_iters {
            // assignment
            for b in 0..nblocks {
                let bl = &blocks[b * v..(b + 1) * v];
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let ce = &centers[c * v..(c + 1) * v];
                    let mut dist = 0.0f32;
                    for i in 0..v {
                        let t = bl[i] - ce[i];
                        dist += t * t;
                    }
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                assign[b] = best;
            }
            // weighted update
            let mut acc = vec![0.0f64; k * v];
            let mut wsum = vec![0.0f64; k];
            for b in 0..nblocks {
                let c = assign[b];
                wsum[c] += weights[b] as f64;
                for i in 0..v {
                    acc[c * v + i] += (weights[b] * blocks[b * v + i]) as f64;
                }
            }
            for c in 0..k {
                if wsum[c] > 0.0 {
                    for i in 0..v {
                        centers[c * v + i] = (acc[c * v + i] / wsum[c]) as f32;
                    }
                } else {
                    // dead center: reseed at a random block
                    let b = rng.below(nblocks);
                    centers[c * v..(c + 1) * v].copy_from_slice(&blocks[b * v..(b + 1) * v]);
                }
            }
        }

        // final assignment → codes
        let (lo, _) = code_range(idx_bits as u8);
        let codes: Vec<i32> = (0..nblocks)
            .map(|b| {
                let bl = &blocks[b * v..(b + 1) * v];
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let ce = &centers[c * v..(c + 1) * v];
                    let mut dist = 0.0f32;
                    for i in 0..v {
                        let t = bl[i] - ce[i];
                        dist += t * t;
                    }
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                best as i32 + lo
            })
            .collect();

        QuantizedGroup {
            method: "kmeans_vq",
            bits,
            rows: m,
            cols: n,
            codes: PackedCodes::pack(&codes, idx_bits as u8).into(),
            side: SideInfo::Codebook { dim: v, centers },
        }
    }

    fn name(&self) -> &'static str {
        "kmeans_vq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::quant::traits::recon_error;
    use crate::util::rng::Rng;

    #[test]
    fn reconstruction_uses_codebook_centers_only() {
        let mut rng = Rng::new(1);
        let w = Mat::random_normal(8, 16, 0.05, &mut rng);
        let x = Mat::random_normal(16, 16, 1.0, &mut rng);
        let q = KMeansVq::default().quantize(&w, &x, 2);
        let w_hat = q.dequantize();
        if let SideInfo::Codebook { dim, centers } = &q.side {
            assert_eq!(*dim, 2);
            assert_eq!(centers.len(), 16 * 2); // k = 2^(2*2)
            // every decoded block must be one of the centers
            for b in 0..(8 * 16 / 2) {
                let bl = &w_hat.data[b * 2..(b + 1) * 2];
                let found = (0..16).any(|c| {
                    (0..2).all(|i| (centers[c * 2 + i] - bl[i]).abs() < 1e-6)
                });
                assert!(found, "block {b} not a center");
            }
        } else {
            panic!("wrong side info");
        }
    }

    #[test]
    fn vq_beats_rtn_on_clustered_weights() {
        // weights drawn from a few discrete clusters — VQ's best case
        let mut rng = Rng::new(2);
        let clusters = [-0.08f32, -0.02, 0.01, 0.07];
        let data: Vec<f32> = (0..16 * 32)
            .map(|_| clusters[rng.below(4)] + rng.normal_f32() * 0.003)
            .collect();
        let w = Mat::from_vec(16, 32, data);
        let x = Mat::random_normal(32, 32, 1.0, &mut rng);
        let e_vq = recon_error(&w, &KMeansVq::default().quantize(&w, &x, 2).dequantize(), &x);
        let e_rtn = recon_error(&w, &RtnQuantizer.quantize(&w, &x, 2).dequantize(), &x);
        assert!(e_vq < e_rtn, "vq {e_vq} vs rtn {e_rtn}");
    }

    #[test]
    fn rate_accounting_matches_bits() {
        let mut rng = Rng::new(3);
        let w = Mat::random_normal(8, 16, 0.05, &mut rng);
        let x = Mat::random_normal(16, 8, 1.0, &mut rng);
        let q = KMeansVq::default().quantize(&w, &x, 3);
        // 3 bits/weight: 64 blocks of dim 2 at 6 bits = 48 bytes
        assert_eq!(q.payload_bits(), 8 * 16 * 3);
        assert_eq!(q.codes.payload_bytes(), (64 * 6usize).div_ceil(8));
    }
}
