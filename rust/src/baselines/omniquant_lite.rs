//! OmniQuant-lite: uniform quantization with *learned* clipping, realized as
//! a calibration-aware grid search over the clip ratio (the closed-form
//! equivalent of OmniQuant's learnable clipping parameters for the
//! weight-only case). The best ratio minimizes the paper's reconstruction
//! objective ||W X − Ŵ X||² rather than plain weight MSE.

use super::rtn::rtn_with_range;
use crate::linalg::Mat;
use crate::quant::traits::{recon_error, GroupQuantizer, QuantizedGroup};

#[derive(Clone, Debug)]
pub struct OmniQuantLite {
    /// candidate clip ratios (fraction of |max| kept)
    pub ratios: Vec<f32>,
}

impl Default for OmniQuantLite {
    fn default() -> Self {
        OmniQuantLite {
            ratios: vec![1.0, 0.95, 0.9, 0.85, 0.8, 0.7, 0.6, 0.5],
        }
    }
}

impl GroupQuantizer for OmniQuantLite {
    fn quantize(&self, w: &Mat, x: &Mat, bits: u8) -> QuantizedGroup {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &w.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        let mut best: Option<(f64, QuantizedGroup)> = None;
        for &r in &self.ratios {
            let mut q = rtn_with_range(w, bits, mn * r, mx * r);
            q.method = "omniquant_lite";
            let err = recon_error(w, &q.dequantize(), x);
            if best.as_ref().map_or(true, |(be, _)| err < *be) {
                best = Some((err, q));
            }
        }
        best.expect("at least one ratio").1
    }

    fn name(&self) -> &'static str {
        "omniquant_lite"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::quant::traits::recon_error;
    use crate::util::rng::Rng;

    #[test]
    fn never_worse_than_rtn() {
        let mut rng = Rng::new(3);
        for seed in 0..5u64 {
            let mut r2 = Rng::new(seed);
            // heavy-tailed weights where clipping helps
            let data: Vec<f32> = (0..512).map(|_| r2.student_t(3.0) as f32 * 0.02).collect();
            let w = Mat::from_vec(16, 32, data);
            let x = Mat::random_normal(32, 32, 1.0, &mut rng);
            let e_rtn = recon_error(&w, &RtnQuantizer.quantize(&w, &x, 2).dequantize(), &x);
            let e_omni = recon_error(
                &w,
                &OmniQuantLite::default().quantize(&w, &x, 2).dequantize(),
                &x,
            );
            assert!(e_omni <= e_rtn + 1e-9, "omni {e_omni} vs rtn {e_rtn}");
        }
    }

    #[test]
    fn clipping_strictly_helps_with_outliers() {
        let mut rng = Rng::new(4);
        let mut w = Mat::random_normal(16, 32, 0.01, &mut rng);
        w.data[5] = 1.0; // single massive outlier
        let x = Mat::random_normal(32, 32, 1.0, &mut rng);
        let e_rtn = recon_error(&w, &RtnQuantizer.quantize(&w, &x, 2).dequantize(), &x);
        let e_omni = recon_error(
            &w,
            &OmniQuantLite::default().quantize(&w, &x, 2).dequantize(),
            &x,
        );
        assert!(e_omni < e_rtn * 0.9, "omni {e_omni} vs rtn {e_rtn}");
    }
}
