//! GPTQ (Frantar et al., 2022) — full algorithm, not a stub:
//!
//! 1. Hessian H = X Xᵀ + λ·mean(diag)·I over the group's calibration slice,
//! 2. Hinv = H⁻¹, upper-Cholesky U with Hinv = Uᵀ U,
//! 3. quantize columns left→right on a fixed per-group uniform grid;
//!    after each column j, propagate the scaled quantization error into all
//!    remaining columns: W[:,k] -= e · U[j,k] / U[j,j].
//!
//! This is the data-aware scalar baseline the paper's Table-4 "Scalar
//! Quantization" block represents, and the strongest uniform-grid method in
//! the zoo (property-tested to beat RTN).

use crate::linalg::decomp::{cholesky, inverse};
use crate::linalg::Mat;
use crate::quant::pack::{code_range, PackedCodes};
use crate::quant::traits::{GroupQuantizer, QuantizedGroup, SideInfo};

#[derive(Clone, Copy, Debug)]
pub struct GptqQuantizer {
    /// Hessian damping fraction (of mean diagonal)
    pub damp: f32,
}

impl Default for GptqQuantizer {
    fn default() -> Self {
        GptqQuantizer { damp: 0.01 }
    }
}

impl GroupQuantizer for GptqQuantizer {
    fn quantize(&self, w: &Mat, x: &Mat, bits: u8) -> QuantizedGroup {
        let (m, n) = (w.rows, w.cols);
        assert_eq!(x.rows, n, "calib rows must equal group cols");
        let (lo, hi) = code_range(bits);
        let levels = (hi - lo) as f32;

        // fixed uniform grid from the *original* weights (group min/max)
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &w.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        let scale = ((mx - mn) / levels).max(1e-12);
        let zero = mn - lo as f32 * scale;
        let quant = |v: f32| -> (i32, f32) {
            let c = (((v - zero) / scale).round() as i64).clamp(lo as i64, hi as i64) as i32;
            (c, c as f32 * scale + zero)
        };

        // H = X Xᵀ + damping
        let mut h = x.matmul(&x.transpose());
        let mean_diag: f32 = (0..n).map(|i| h.at(i, i)).sum::<f32>() / n as f32;
        let damp = self.damp * mean_diag + 1e-8;
        for i in 0..n {
            *h.at_mut(i, i) += damp;
        }

        // Hinv = Uᵀ U  (U upper = Lᵀ of our lower Cholesky)
        let hinv = inverse(&h).unwrap_or_else(|_| Mat::eye(n).scale(1.0 / mean_diag.max(1e-8)));
        let u = match cholesky(&hinv) {
            Ok(l) => l.transpose(),
            Err(_) => Mat::eye(n), // degenerate calib → plain RTN behaviour
        };

        // sequential column quantization with error propagation
        let mut work = w.clone();
        let mut codes = vec![0i32; m * n];
        for j in 0..n {
            let ujj = u.at(j, j).max(1e-10);
            for r in 0..m {
                let v = work.at(r, j);
                let (c, q) = quant(v);
                codes[r * n + j] = c;
                let e = (v - q) / ujj;
                // propagate into the not-yet-quantized columns
                let urow = u.row(j);
                let wrow = work.row_mut(r);
                for k in j + 1..n {
                    wrow[k] -= e * urow[k];
                }
            }
        }

        QuantizedGroup {
            method: "gptq",
            bits,
            rows: m,
            cols: n,
            codes: PackedCodes::pack(&codes, bits).into(),
            side: SideInfo::Uniform { scale, zero },
        }
    }

    fn name(&self) -> &'static str {
        "gptq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::quant::traits::recon_error;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        // the entire point of GPTQ: with correlated X, error propagation
        // compensates; property-tested across seeds.
        proptest(10, |rig| {
            let (m, n, ncal) = (24, 32, 64);
            let w = Mat::from_vec(m, n, rig.vec_normal(m * n, 0.05));
            // correlated calibration: low-rank + noise
            let basis = Mat::from_vec(8, ncal, rig.vec_normal(8 * ncal, 1.0));
            let mixer = Mat::from_vec(n, 8, rig.vec_normal(n * 8, 0.5));
            let mut x = mixer.matmul(&basis);
            for v in x.data.iter_mut() {
                *v += rig.f32_in(-0.05, 0.05);
            }
            let e_gptq = recon_error(
                &w,
                &GptqQuantizer::default().quantize(&w, &x, 2).dequantize(),
                &x,
            );
            let e_rtn = recon_error(&w, &RtnQuantizer.quantize(&w, &x, 2).dequantize(), &x);
            assert!(e_gptq < e_rtn, "gptq {e_gptq} vs rtn {e_rtn}");
        });
    }

    #[test]
    fn codes_within_range_and_shapes() {
        let mut rng = Rng::new(7);
        let w = Mat::random_normal(8, 16, 0.05, &mut rng);
        let x = Mat::random_normal(16, 32, 1.0, &mut rng);
        for bits in [2u8, 3, 4] {
            let q = GptqQuantizer::default().quantize(&w, &x, bits);
            let (lo, hi) = code_range(bits);
            for c in q.codes.unpack() {
                assert!(c >= lo && c <= hi);
            }
            assert_eq!(q.dequantize().data.len(), 8 * 16);
        }
    }

    #[test]
    fn near_lossless_at_8_bits() {
        let mut rng = Rng::new(8);
        let w = Mat::random_normal(8, 16, 0.05, &mut rng);
        let x = Mat::random_normal(16, 24, 1.0, &mut rng);
        let e = recon_error(&w, &GptqQuantizer::default().quantize(&w, &x, 8).dequantize(), &x);
        assert!(e < 1e-3, "e={e}");
    }

    #[test]
    fn degenerate_calibration_does_not_crash() {
        let mut rng = Rng::new(9);
        let w = Mat::random_normal(4, 8, 0.05, &mut rng);
        let x = Mat::zeros(8, 16); // rank-0 calibration
        let q = GptqQuantizer::default().quantize(&w, &x, 3);
        assert!(q.dequantize().data.iter().all(|v| v.is_finite()));
    }
}
