//! QuIP#-lite: randomized Hadamard incoherence processing + a *fixed* E8
//! lattice codebook — the structured-but-not-learned lattice VQ the paper
//! positions GLVQ against ("QuIP# is constrained by the use of fixed
//! lattice designs across the entire model").
//!
//! Encode per 8-block: r = H(sign ⊙ w); p = nearest-E8(r / s);
//! store z = 2p (always integer since E8 ⊂ ½Z⁸ with parity); decode
//! reverses: ŵ = sign ⊙ H⁻¹(s·z/2). Clamping z into the b-bit range can
//! leave E8 (tail blocks) — the same saturation every fixed-codebook method
//! suffers, and part of why the learned lattice wins at 2 bits.

use crate::lattice::fixed::nearest_e8;
use crate::linalg::Mat;
use crate::quant::pack::{clamp_code, PackedCodes};
use crate::quant::traits::{hadamard, sign_vector, GroupQuantizer, QuantizedGroup, SideInfo};

#[derive(Clone, Copy, Debug)]
pub struct QuipLite {
    pub sign_seed: u64,
}

impl Default for QuipLite {
    fn default() -> Self {
        QuipLite { sign_seed: 0xC0DE }
    }
}

const D: usize = 8;

impl GroupQuantizer for QuipLite {
    fn quantize(&self, w: &Mat, _x: &Mat, bits: u8) -> QuantizedGroup {
        let (m, n) = (w.rows, w.cols);
        assert_eq!(n % D, 0, "group width must be divisible by 8 for E8");
        let nblocks = m * n / D;
        let signs = sign_vector(self.sign_seed, D);

        // rotate all blocks, collect statistics for the scale
        let mut rotated = vec![0.0f32; m * n];
        for b in 0..nblocks {
            let mut block = [0.0f32; D];
            for i in 0..D {
                block[i] = w.data[b * D + i] * signs[i];
            }
            let r = hadamard(&block);
            rotated[b * D..(b + 1) * D].copy_from_slice(&r);
        }
        let std = crate::linalg::stats::std_dev(&rotated) as f32;
        let code_span = (1i32 << (bits - 1)) as f32;
        // z = 2p ≈ 2r/s: grid-search the scale around std(z) ≈ code_span/2.5
        // minimizing rotated-domain MSE (the rotation is orthonormal, so this
        // equals the weight-domain MSE).
        let base = (5.0 * std / code_span).max(1e-8);
        let mut best: Option<(f64, f32, Vec<i32>)> = None;
        for mult in [0.6f32, 0.8, 1.0, 1.3, 1.7, 2.2] {
            let s = base * mult;
            let mut codes = vec![0i32; m * n];
            let mut err = 0.0f64;
            for b in 0..nblocks {
                let mut y = [0.0f32; D];
                for i in 0..D {
                    y[i] = rotated[b * D + i] / s;
                }
                let p = nearest_e8(&y);
                for i in 0..D {
                    let z = clamp_code(2.0 * p[i], bits);
                    codes[b * D + i] = z;
                    let rec = s * z as f32 * 0.5;
                    err += ((rotated[b * D + i] - rec) as f64).powi(2);
                }
            }
            if best.as_ref().map_or(true, |(be, _, _)| err < *be) {
                best = Some((err, s, codes));
            }
        }
        let (_, s, codes) = best.expect("non-empty grid");

        QuantizedGroup {
            method: "quip_lite",
            bits,
            rows: m,
            cols: n,
            codes: PackedCodes::pack(&codes, bits).into(),
            side: SideInfo::RotatedLattice { d: D, scale: s, sign_seed: self.sign_seed },
        }
    }

    fn name(&self) -> &'static str {
        "quip_lite"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::quant::traits::recon_error;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_is_reasonable_at_4_bits() {
        let mut rng = Rng::new(1);
        let w = Mat::random_normal(16, 32, 0.05, &mut rng);
        let x = Mat::random_normal(32, 16, 1.0, &mut rng);
        let q = QuipLite::default().quantize(&w, &x, 4);
        let w_hat = q.dequantize();
        let rel = w.frob_dist(&w_hat) / w.frob_norm();
        assert!(rel < 0.25, "relative error {rel}");
        let _ = recon_error(&w, &w_hat, &x);
    }

    #[test]
    fn beats_rtn_on_gaussian_weights_at_2_bits() {
        // E8 packing gain should show on near-Gaussian blocks
        let mut rng = Rng::new(2);
        let mut wins = 0;
        for seed in 0..6u64 {
            let mut r = Rng::new(seed + 10);
            let w = Mat::random_normal(32, 64, 0.05, &mut r);
            let x = Mat::random_normal(64, 32, 1.0, &mut rng);
            let e_q = recon_error(&w, &QuipLite::default().quantize(&w, &x, 2).dequantize(), &x);
            let e_r = recon_error(&w, &RtnQuantizer.quantize(&w, &x, 2).dequantize(), &x);
            if e_q < e_r {
                wins += 1;
            }
        }
        assert!(wins >= 4, "quip should usually beat rtn at 2 bits: {wins}/6");
    }

    #[test]
    fn decode_uses_recorded_seed() {
        let mut rng = Rng::new(3);
        let w = Mat::random_normal(8, 16, 0.05, &mut rng);
        let x = Mat::zeros(16, 4);
        let a = QuipLite { sign_seed: 1 }.quantize(&w, &x, 3);
        let b = QuipLite { sign_seed: 2 }.quantize(&w, &x, 3);
        // different rotations → different codes, but both must decode finitely
        assert!(a.dequantize().data.iter().all(|v| v.is_finite()));
        assert!(b.dequantize().data.iter().all(|v| v.is_finite()));
        assert_ne!(a.codes.unpack(), b.codes.unpack());
    }
}
