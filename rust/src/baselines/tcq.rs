//! Trellis-coded quantization (QTIP-lite): a stateful scalar quantizer
//! where the reachable codebook subset depends on a 4-state trellis — the
//! mechanism that lets QTIP decouple codebook size from bitrate.
//!
//! Codebook: 2^(b+1) Lloyd-Max scalar levels partitioned into 4 Ungerboeck
//! subsets (level i → subset i mod 4). From state s, input bit u selects
//! subset ((s&1)<<1)|u and the remaining b−1 bits select the level within
//! it; state' = ((s<<1)|u) & 3. Encoding runs exact Viterbi over the
//! group's weights (m·n samples), so each weight costs b bits but chooses
//! among 2^(b+1) effective levels.

use crate::linalg::stats::quantile;
use crate::linalg::Mat;
use crate::quant::pack::{code_range, PackedCodes};
use crate::quant::traits::{GroupQuantizer, QuantizedGroup, SideInfo};

#[derive(Clone, Copy, Debug)]
pub struct TcqQuantizer {
    pub lloyd_iters: usize,
}

impl Default for TcqQuantizer {
    fn default() -> Self {
        TcqQuantizer { lloyd_iters: 8 }
    }
}

const STATES: usize = 4;

/// Lloyd-Max scalar levels initialized at quantiles.
fn lloyd_levels(data: &[f32], k: usize, iters: usize) -> Vec<f32> {
    let mut levels: Vec<f32> = (0..k)
        .map(|i| quantile(data, (i as f64 + 0.5) / k as f64))
        .collect();
    for _ in 0..iters {
        let mut acc = vec![0.0f64; k];
        let mut cnt = vec![0usize; k];
        for &v in data {
            let mut best = 0usize;
            let mut bd = f32::INFINITY;
            for (i, &l) in levels.iter().enumerate() {
                let d = (v - l).abs();
                if d < bd {
                    bd = d;
                    best = i;
                }
            }
            acc[best] += v as f64;
            cnt[best] += 1;
        }
        for i in 0..k {
            if cnt[i] > 0 {
                levels[i] = (acc[i] / cnt[i] as f64) as f32;
            }
        }
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    levels
}

#[inline]
fn subset_of(state: usize, u: usize) -> usize {
    ((state & 1) << 1) | u
}

#[inline]
fn next_state(state: usize, u: usize) -> usize {
    ((state << 1) | u) & (STATES - 1)
}

impl GroupQuantizer for TcqQuantizer {
    fn quantize(&self, w: &Mat, _x: &Mat, bits: u8) -> QuantizedGroup {
        assert!(bits >= 1 && bits <= 7);
        let (m, n) = (w.rows, w.cols);
        let nsamp = m * n;
        let k = 1usize << (bits + 1); // total levels
        let per = k / 4; // levels per subset (= 2^{b-1})
        let sorted_levels = lloyd_levels(&w.data, k, self.lloyd_iters);
        // levels laid out [subset][j] — subset of sorted index i is i % 4
        let mut levels = vec![0.0f32; k];
        let mut counts = [0usize; 4];
        for (i, &l) in sorted_levels.iter().enumerate() {
            let sub = i % 4;
            levels[sub * per + counts[sub]] = l;
            counts[sub] += 1;
        }

        // exact Viterbi over the sample sequence
        let branches = 1usize << bits; // u (1 bit) × level-in-subset (b-1 bits)
        let mut cost = [0.0f64; STATES];
        let mut alive = [true, false, false, false]; // start in state 0
        // backpointers: (prev_state, code) per (t, state)
        let mut bp = vec![[(0u8, 0u8); STATES]; nsamp];
        for t in 0..nsamp {
            let v = w.data[t];
            let mut ncost = [f64::INFINITY; STATES];
            let mut nbp = [(0u8, 0u8); STATES];
            for s in 0..STATES {
                if !alive[s] || !cost[s].is_finite() {
                    continue;
                }
                for code in 0..branches {
                    let u = code & 1;
                    let j = code >> 1;
                    if j >= per {
                        continue;
                    }
                    let lvl = levels[subset_of(s, u) * per + j];
                    let c = cost[s] + ((v - lvl) as f64).powi(2);
                    let ns = next_state(s, u);
                    if c < ncost[ns] {
                        ncost[ns] = c;
                        nbp[ns] = (s as u8, code as u8);
                    }
                }
            }
            cost = ncost;
            bp[t] = nbp;
            alive = [true; STATES];
        }

        // traceback from the cheapest final state
        let mut state = (0..STATES)
            .min_by(|&a, &b| cost[a].partial_cmp(&cost[b]).unwrap())
            .unwrap();
        let mut codes_rev = Vec::with_capacity(nsamp);
        for t in (0..nsamp).rev() {
            let (ps, code) = bp[t][state];
            codes_rev.push(code as i32);
            state = ps as usize;
        }
        codes_rev.reverse();
        let (lo, _) = code_range(bits);
        let codes: Vec<i32> = codes_rev.into_iter().map(|c| c + lo).collect();

        QuantizedGroup {
            method: "tcq",
            bits,
            rows: m,
            cols: n,
            codes: PackedCodes::pack(&codes, bits).into(),
            side: SideInfo::Trellis { levels, states: STATES },
        }
    }

    fn name(&self) -> &'static str {
        "tcq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::quant::traits::recon_error;
    use crate::util::rng::Rng;

    #[test]
    fn decode_is_consistent_with_viterbi_path() {
        // quantize, decode, and verify the decoded values are all codebook
        // levels reachable by the state machine
        let mut rng = Rng::new(1);
        let w = Mat::random_normal(8, 16, 0.05, &mut rng);
        let q = TcqQuantizer::default().quantize(&w, &Mat::zeros(16, 1), 2);
        let w_hat = q.dequantize();
        if let SideInfo::Trellis { levels, .. } = &q.side {
            for v in &w_hat.data {
                assert!(levels.iter().any(|l| (l - v).abs() < 1e-6), "{v} not a level");
            }
        }
    }

    #[test]
    fn tcq_beats_rtn_at_same_rate() {
        // 2^(b+1) effective levels at b bits should beat 2^b uniform levels
        let mut rng = Rng::new(2);
        let mut wins = 0;
        for seed in 0..6u64 {
            let mut r = Rng::new(seed + 20);
            let data: Vec<f32> = (0..24 * 32).map(|_| r.student_t(5.0) as f32 * 0.03).collect();
            let w = Mat::from_vec(24, 32, data);
            let x = Mat::random_normal(32, 24, 1.0, &mut rng);
            let e_t = recon_error(&w, &TcqQuantizer::default().quantize(&w, &x, 2).dequantize(), &x);
            let e_r = recon_error(&w, &RtnQuantizer.quantize(&w, &x, 2).dequantize(), &x);
            if e_t < e_r {
                wins += 1;
            }
        }
        assert!(wins >= 5, "tcq should beat rtn: {wins}/6");
    }

    #[test]
    fn weight_mse_not_much_worse_than_unconstrained_lloyd() {
        // the trellis constraint costs something but must stay close to the
        // unconstrained scalar quantizer with the same level count
        let mut rng = Rng::new(3);
        let w = Mat::random_normal(16, 16, 0.05, &mut rng);
        let q = TcqQuantizer::default().quantize(&w, &Mat::zeros(16, 1), 3);
        let w_hat = q.dequantize();
        let mse_tcq: f64 = w
            .data
            .iter()
            .zip(&w_hat.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.data.len() as f64;
        // unconstrained Lloyd at 2^{b+1} levels
        let levels = lloyd_levels(&w.data, 16, 8);
        let mse_free: f64 = w
            .data
            .iter()
            .map(|&v| {
                levels
                    .iter()
                    .map(|&l| ((v - l) as f64).powi(2))
                    .fold(f64::INFINITY, f64::min)
            })
            .sum::<f64>()
            / w.data.len() as f64;
        assert!(mse_tcq <= mse_free * 4.0 + 1e-12, "tcq {mse_tcq} vs free {mse_free}");
    }

    #[test]
    fn all_bit_widths_roundtrip() {
        let mut rng = Rng::new(4);
        let w = Mat::random_normal(4, 8, 0.05, &mut rng);
        for bits in [1u8, 2, 3, 4] {
            let q = TcqQuantizer::default().quantize(&w, &Mat::zeros(8, 1), bits);
            assert!(q.dequantize().data.iter().all(|v| v.is_finite()));
            assert_eq!(q.payload_bits(), 4 * 8 * bits as usize);
        }
    }
}
