//! Quantized-weight representation shared by GLVQ and all baselines:
//! bit-packed integer codes ([`pack`]), per-group side information and the
//! `GroupQuantizer` contract ([`traits`]), and the on-disk `.glvq`
//! container ([`format`]) whose measured sizes back the Table-5 overhead
//! reproduction.

pub mod format;
pub mod pack;
pub mod traits;

pub use pack::PackedCodes;
pub use traits::{CodePayload, GroupQuantizer, QuantizedGroup, SideInfo};
