//! Bit-packing of integer codes, b ∈ 1..=8 bits per code.
//!
//! Codes are signed integers in the symmetric-ish range
//! [−2^(b−1), 2^(b−1)−1]; they are stored offset-shifted as unsigned
//! b-bit fields packed LSB-first into a byte stream. This is the payload
//! the Table-5 overhead accounting measures (`m·n·b/8` bytes, Eq. 26).

/// Inclusive signed code range for b bits.
pub fn code_range(bits: u8) -> (i32, i32) {
    assert!((1..=8).contains(&bits), "bits must be 1..=8");
    let half = 1i32 << (bits - 1);
    (-half, half - 1)
}

/// Clamp a raw (possibly out-of-range) integer code into the b-bit range.
#[inline]
pub fn clamp_code(v: f32, bits: u8) -> i32 {
    let (lo, hi) = code_range(bits);
    (v.round() as i64).clamp(lo as i64, hi as i64) as i32
}

/// Bit-packed code vector.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    pub bits: u8,
    pub n: usize,
    pub data: Vec<u8>,
}

impl PackedCodes {
    /// Pack signed codes; panics if any code is out of range (callers clamp
    /// with [`clamp_code`] first — out-of-range here means a logic bug).
    pub fn pack(codes: &[i32], bits: u8) -> PackedCodes {
        let (lo, hi) = code_range(bits);
        let nbits = codes.len() * bits as usize;
        let mut data = vec![0u8; nbits.div_ceil(8)];
        let mut bitpos = 0usize;
        for &c in codes {
            assert!(c >= lo && c <= hi, "code {c} out of {bits}-bit range [{lo},{hi}]");
            let u = (c - lo) as u32;
            let byte = bitpos / 8;
            let off = bitpos % 8;
            data[byte] |= (u << off) as u8;
            if off + bits as usize > 8 {
                data[byte + 1] |= (u >> (8 - off)) as u8;
            }
            bitpos += bits as usize;
        }
        PackedCodes { bits, n: codes.len(), data }
    }

    /// Unpack all codes.
    pub fn unpack(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.n];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a caller-provided buffer (len == n). Allocation-free —
    /// this is on the streaming-decode hot path.
    pub fn unpack_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.n);
        let (lo, _) = code_range(self.bits);
        let b = self.bits as usize;
        let mask = ((1u32 << b) - 1) as u32;
        let mut bitpos = 0usize;
        for slot in out.iter_mut() {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let mut u = (self.data[byte] as u32) >> off;
            if off + b > 8 {
                u |= (self.data[byte + 1] as u32) << (8 - off);
            }
            *slot = (u & mask) as i32 + lo;
            bitpos += b;
        }
    }

    /// Unpack a sub-range [start, start+len) without touching the rest —
    /// used by the streaming decoder to materialize one sub-block at a time.
    pub fn unpack_range_into(&self, start: usize, out: &mut [i32]) {
        assert!(start + out.len() <= self.n);
        let (lo, _) = code_range(self.bits);
        let b = self.bits as usize;
        let mask = ((1u32 << b) - 1) as u32;
        let mut bitpos = start * b;
        for slot in out.iter_mut() {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let mut u = (self.data[byte] as u32) >> off;
            if off + b > 8 {
                u |= (self.data[byte + 1] as u32) << (8 - off);
            }
            *slot = (u & mask) as i32 + lo;
            bitpos += b;
        }
    }

    /// Read `count` consecutive b-bit fields starting at code `start` as
    /// one little-endian integer: field j (the offset code `c_j − lo`)
    /// occupies bits `[j·b, (j+1)·b)` of the result — the raw bit pattern
    /// of the run as stored. This is the fused kernel's table index: a
    /// d-block's code→vector LUT entry is addressed by exactly this value,
    /// so lookup decode reads the payload without materializing signed
    /// codes. `count·bits` must be ≤ 32.
    #[inline]
    pub fn read_code_run(&self, start: usize, count: usize) -> u32 {
        let b = self.bits as usize;
        let total = count * b;
        debug_assert!(total > 0 && total <= 32, "run of {count} {b}-bit fields exceeds 32 bits");
        debug_assert!(start + count <= self.n);
        let bitpos = start * b;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        // gather up to 8 bytes: a 32-bit run at a 7-bit offset spans ≤ 5
        let mut v = 0u64;
        for (k, &x) in self.data[byte..self.data.len().min(byte + 8)].iter().enumerate() {
            v |= (x as u64) << (8 * k);
        }
        ((v >> off) & ((1u64 << total) - 1)) as u32
    }

    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    #[test]
    fn ranges_are_symmetricish() {
        assert_eq!(code_range(1), (-1, 0));
        assert_eq!(code_range(2), (-2, 1));
        assert_eq!(code_range(4), (-8, 7));
        assert_eq!(code_range(8), (-128, 127));
    }

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        proptest(60, |rig| {
            let bits = rig.usize_in(1, 8) as u8;
            let (lo, hi) = code_range(bits);
            let n = rig.usize_in(0, 300);
            let codes: Vec<i32> = (0..n)
                .map(|_| rig.usize_in(0, (hi - lo) as usize) as i32 + lo)
                .collect();
            let packed = PackedCodes::pack(&codes, bits);
            assert_eq!(packed.unpack(), codes);
            assert_eq!(packed.payload_bytes(), (n * bits as usize).div_ceil(8));
        });
    }

    #[test]
    fn range_unpack_matches_full_unpack() {
        proptest(40, |rig| {
            let bits = rig.usize_in(1, 8) as u8;
            let (lo, hi) = code_range(bits);
            let n = rig.usize_in(1, 200);
            let codes: Vec<i32> = (0..n)
                .map(|_| rig.usize_in(0, (hi - lo) as usize) as i32 + lo)
                .collect();
            let packed = PackedCodes::pack(&codes, bits);
            let start = rig.usize_in(0, n - 1);
            let len = rig.usize_in(0, n - start);
            let mut out = vec![0i32; len];
            packed.unpack_range_into(start, &mut out);
            assert_eq!(&out[..], &codes[start..start + len]);
        });
    }

    #[test]
    fn code_run_equals_refolded_unpacked_fields() {
        // read_code_run(start, count) must equal the little-endian fold of
        // the `count` unpacked offset codes — at every bit alignment
        proptest(60, |rig| {
            let bits = rig.usize_in(1, 8) as u8;
            let (lo, hi) = code_range(bits);
            let n = rig.usize_in(1, 120);
            let codes: Vec<i32> = (0..n)
                .map(|_| rig.usize_in(0, (hi - lo) as usize) as i32 + lo)
                .collect();
            let packed = PackedCodes::pack(&codes, bits);
            let max_count = (32 / bits as usize).min(n);
            let count = rig.usize_in(1, max_count);
            let start = rig.usize_in(0, n - count);
            let got = packed.read_code_run(start, count);
            let want: u64 = codes[start..start + count]
                .iter()
                .enumerate()
                .map(|(j, &c)| ((c - lo) as u64) << (j * bits as usize))
                .sum();
            assert_eq!(got as u64, want, "bits={bits} start={start} count={count}");
        });
    }

    #[test]
    fn code_run_reads_tail_of_payload() {
        // the last run ends flush with the payload; the byte gather must
        // not read past data.len()
        let codes = vec![1i32, -2, 0, 1, -1];
        let p = PackedCodes::pack(&codes, 3);
        let want: u64 = codes
            .iter()
            .enumerate()
            .map(|(j, &c)| ((c + 4) as u64) << (3 * j))
            .sum();
        assert_eq!(p.read_code_run(0, 5) as u64, want);
        assert_eq!(p.read_code_run(4, 1) as u64, (codes[4] + 4) as u64);
    }

    #[test]
    fn clamp_code_saturates() {
        assert_eq!(clamp_code(100.0, 2), 1);
        assert_eq!(clamp_code(-100.0, 2), -2);
        assert_eq!(clamp_code(0.4, 2), 0);
        assert_eq!(clamp_code(-1.6, 2), -2);
    }

    #[test]
    fn out_of_range_pack_panics() {
        let r = std::panic::catch_unwind(|| PackedCodes::pack(&[5], 2));
        assert!(r.is_err());
    }

    #[test]
    fn boundary_values_survive() {
        for bits in 1..=8u8 {
            let (lo, hi) = code_range(bits);
            let codes = vec![lo, hi, 0.min(hi).max(lo)];
            let p = PackedCodes::pack(&codes, bits);
            assert_eq!(p.unpack(), codes);
        }
    }
}
