//! `.glvq` container: the on-disk format for a fully quantized model.
//!
//! Layout (little-endian):
//!   magic "GLVQ" | u32 version
//!   u32 n_tensors
//!   per tensor: name | u32 rows | u32 cols | u32 n_groups
//!     per group: u8 method_tag | u8 bits | u32 rows | u32 cols |
//!                u32 col_offset | u32 row_offset |
//!                codes (u32 len + bytes) | side info (tagged)
//!   u32 crc32 of everything after magic
//!
//! Measured file sizes from this container back the Table-5 overhead
//! reproduction (`glvq exp table5` reports analytic Eq. 27 vs measured).

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::pack::PackedCodes;
use crate::quant::traits::{QuantizedGroup, SideInfo};
use crate::tensor::crc32;

const MAGIC: &[u8; 4] = b"GLVQ";
const VERSION: u32 = 1;

/// One quantized tensor: its grid of quantized groups + placement.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedTensor {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// groups with their (row_offset, col_offset) placement in the tensor
    pub groups: Vec<(usize, usize, QuantizedGroup)>,
}

impl QuantizedTensor {
    /// Reassemble the dense weight matrix from all groups.
    pub fn dequantize(&self) -> crate::linalg::Mat {
        let mut out = crate::linalg::Mat::zeros(self.rows, self.cols);
        for (r0, c0, g) in &self.groups {
            let block = g.dequantize();
            out.set_block(*r0, *c0, &block);
        }
        out
    }

    pub fn payload_bits(&self) -> usize {
        self.groups.iter().map(|(_, _, g)| g.payload_bits()).sum()
    }

    pub fn side_bytes(&self) -> usize {
        self.groups.iter().map(|(_, _, g)| g.side_bytes()).sum()
    }

    /// Average bits per weight (codes only).
    pub fn avg_bits(&self) -> f64 {
        self.payload_bits() as f64 / (self.rows * self.cols) as f64
    }
}

/// A complete quantized model container.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantizedModel {
    pub tensors: Vec<QuantizedTensor>,
}

fn method_tag(m: &str) -> u8 {
    match m {
        "glvq" => 1,
        "rtn" => 2,
        "omniquant_lite" => 3,
        "gptq" => 4,
        "kmeans_vq" => 5,
        "quip_lite" => 6,
        "tcq" => 7,
        "binary" => 8,
        "glvq_fixed" => 9,
        _ => 0,
    }
}

fn method_name(t: u8) -> &'static str {
    match t {
        1 => "glvq",
        2 => "rtn",
        3 => "omniquant_lite",
        4 => "gptq",
        5 => "kmeans_vq",
        6 => "quip_lite",
        7 => "tcq",
        8 => "binary",
        9 => "glvq_fixed",
        _ => "unknown",
    }
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f32(x);
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Result<u8> {
        if self.pos >= self.b.len() {
            bail!("truncated (u8)");
        }
        let v = self.b[self.pos];
        self.pos += 1;
        Ok(v)
    }
    fn u32(&mut self) -> Result<u32> {
        if self.pos + 4 > self.b.len() {
            bail!("truncated (u32)");
        }
        let v = u32::from_le_bytes(self.b[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }
    fn u64(&mut self) -> Result<u64> {
        if self.pos + 8 > self.b.len() {
            bail!("truncated (u64)");
        }
        let v = u64::from_le_bytes(self.b[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        if self.pos + n > self.b.len() {
            bail!("truncated (bytes)");
        }
        let v = self.b[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(v)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }
}

fn write_side(w: &mut Writer, s: &SideInfo) {
    match s {
        SideInfo::Uniform { scale, zero } => {
            w.u8(1);
            w.f32(*scale);
            w.f32(*zero);
        }
        SideInfo::Lattice { d, g, mu, scale } => {
            w.u8(2);
            w.u32(*d as u32);
            w.f32s(g);
            w.f32(*mu);
            w.f32(*scale);
        }
        SideInfo::RotatedLattice { d, scale, sign_seed } => {
            w.u8(3);
            w.u32(*d as u32);
            w.f32(*scale);
            w.u64(*sign_seed);
        }
        SideInfo::Codebook { dim, centers } => {
            w.u8(4);
            w.u32(*dim as u32);
            w.f32s(centers);
        }
        SideInfo::Trellis { levels, states } => {
            w.u8(5);
            w.u32(*states as u32);
            w.f32s(levels);
        }
        SideInfo::Binary { row_scales, residual_scales } => {
            w.u8(6);
            w.f32s(row_scales);
            match residual_scales {
                Some(r) => {
                    w.u8(1);
                    w.f32s(r);
                }
                None => w.u8(0),
            }
        }
    }
}

fn read_side(r: &mut Reader) -> Result<SideInfo> {
    Ok(match r.u8()? {
        1 => SideInfo::Uniform { scale: r.f32()?, zero: r.f32()? },
        2 => {
            let d = r.u32()? as usize;
            let g = r.f32s()?;
            let mu = r.f32()?;
            let scale = r.f32()?;
            SideInfo::Lattice { d, g, mu, scale }
        }
        3 => SideInfo::RotatedLattice {
            d: r.u32()? as usize,
            scale: r.f32()?,
            sign_seed: r.u64()?,
        },
        4 => SideInfo::Codebook { dim: r.u32()? as usize, centers: r.f32s()? },
        5 => {
            let states = r.u32()? as usize;
            SideInfo::Trellis { levels: r.f32s()?, states }
        }
        6 => {
            let row_scales = r.f32s()?;
            let residual_scales = if r.u8()? == 1 { Some(r.f32s()?) } else { None };
            SideInfo::Binary { row_scales, residual_scales }
        }
        t => bail!("unknown side-info tag {t}"),
    })
}

impl QuantizedModel {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = Writer { buf: Vec::new() };
        w.u32(VERSION);
        w.u32(self.tensors.len() as u32);
        for t in &self.tensors {
            w.bytes(t.name.as_bytes());
            w.u32(t.rows as u32);
            w.u32(t.cols as u32);
            w.u32(t.groups.len() as u32);
            for (r0, c0, g) in &t.groups {
                w.u8(method_tag(g.method));
                w.u8(g.bits);
                w.u32(g.rows as u32);
                w.u32(g.cols as u32);
                w.u32(*r0 as u32);
                w.u32(*c0 as u32);
                w.u8(g.codes.bits);
                w.u32(g.codes.n as u32);
                w.bytes(&g.codes.data);
                write_side(&mut w, &g.side);
            }
        }
        let crc = crc32(&w.buf);
        let mut out = Vec::with_capacity(w.buf.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&w.buf);
        out.extend_from_slice(&crc.to_le_bytes());
        std::fs::write(path, &out).with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<QuantizedModel> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut buf)?;
        if buf.len() < 12 || &buf[..4] != MAGIC {
            bail!("{}: not a GLVQ container", path.display());
        }
        let body = &buf[4..buf.len() - 4];
        let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        if crc32(body) != stored {
            bail!("{}: CRC mismatch", path.display());
        }
        let mut r = Reader { b: body, pos: 0 };
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported container version {version}");
        }
        let nt = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(nt);
        for _ in 0..nt {
            let name = String::from_utf8(r.bytes()?)?;
            let rows = r.u32()? as usize;
            let cols = r.u32()? as usize;
            let ng = r.u32()? as usize;
            let mut groups = Vec::with_capacity(ng);
            for _ in 0..ng {
                let tag = r.u8()?;
                let bits = r.u8()?;
                let grows = r.u32()? as usize;
                let gcols = r.u32()? as usize;
                let r0 = r.u32()? as usize;
                let c0 = r.u32()? as usize;
                let cbits = r.u8()?;
                let cn = r.u32()? as usize;
                let cdata = r.bytes()?;
                let side = read_side(&mut r)?;
                groups.push((
                    r0,
                    c0,
                    QuantizedGroup {
                        method: method_name(tag),
                        bits,
                        rows: grows,
                        cols: gcols,
                        codes: PackedCodes { bits: cbits, n: cn, data: cdata },
                        side,
                    },
                ));
            }
            tensors.push(QuantizedTensor { name, rows, cols, groups });
        }
        Ok(QuantizedModel { tensors })
    }

    pub fn get(&self, name: &str) -> Option<&QuantizedTensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Whole-model average bits per quantized weight.
    pub fn avg_bits(&self) -> f64 {
        let bits: usize = self.tensors.iter().map(|t| t.payload_bits()).sum();
        let weights: usize = self.tensors.iter().map(|t| t.rows * t.cols).sum();
        bits as f64 / weights.max(1) as f64
    }

    /// Total size accounting: (payload_bytes, side_bytes).
    pub fn size_bytes(&self) -> (usize, usize) {
        let payload = self
            .tensors
            .iter()
            .map(|t| t.groups.iter().map(|(_, _, g)| g.codes.payload_bytes()).sum::<usize>())
            .sum();
        let side = self.tensors.iter().map(|t| t.side_bytes()).sum();
        (payload, side)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{code_range, PackedCodes};

    fn sample_model() -> QuantizedModel {
        let (lo, hi) = code_range(2);
        let codes: Vec<i32> = (0..64).map(|i| (i % (hi - lo + 1)) + lo).collect();
        let g1 = QuantizedGroup {
            method: "glvq",
            bits: 2,
            rows: 8,
            cols: 8,
            codes: PackedCodes::pack(&codes, 2),
            side: SideInfo::Lattice {
                d: 8,
                g: (0..64).map(|i| i as f32 * 0.01).collect(),
                mu: 42.5,
                scale: 0.7,
            },
        };
        let g2 = QuantizedGroup {
            method: "rtn",
            bits: 2,
            rows: 8,
            cols: 8,
            codes: PackedCodes::pack(&codes, 2),
            side: SideInfo::Uniform { scale: 0.02, zero: 0.0 },
        };
        QuantizedModel {
            tensors: vec![QuantizedTensor {
                name: "00.attn.wq".into(),
                rows: 8,
                cols: 16,
                groups: vec![(0, 0, g1), (0, 8, g2)],
            }],
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let m = sample_model();
        let dir = std::env::temp_dir().join(format!("glvq_fmt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.glvq");
        m.save(&p).unwrap();
        let loaded = QuantizedModel::load(&p).unwrap();
        assert_eq!(m, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let m = sample_model();
        let dir = std::env::temp_dir().join(format!("glvq_fmt_c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.glvq");
        m.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x55;
        std::fs::write(&p, &bytes).unwrap();
        assert!(QuantizedModel::load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dequantize_assembles_blocks_in_place() {
        let m = sample_model();
        let t = &m.tensors[0];
        let full = t.dequantize();
        assert_eq!((full.rows, full.cols), (8, 16));
        let left = t.groups[0].2.dequantize();
        let right = t.groups[1].2.dequantize();
        for r in 0..8 {
            for c in 0..8 {
                assert_eq!(full.at(r, c), left.at(r, c));
                assert_eq!(full.at(r, c + 8), right.at(r, c));
            }
        }
    }

    #[test]
    fn rate_accounting() {
        let m = sample_model();
        assert!((m.avg_bits() - 2.0).abs() < 1e-9);
        let (payload, side) = m.size_bytes();
        assert_eq!(payload, 2 * 64 * 2 / 8);
        assert_eq!(side, (2 * 64 + 4) + 4);
    }
}
