//! `.glvq` container: the on-disk format for a fully quantized model.
//!
//! **The normative byte-level specification is `FORMAT.md` at the repo
//! root** (magic/version/tensor/group layouts, payload tag encoding,
//! chunk framing, CRC coverage, v1↔v2 compatibility rules); its offsets
//! are cross-checked against this implementation by
//! `rust/tests/format_spec.rs`. Summary of the layout (little-endian):
//!   magic "GLVQ" | u32 version (1 or 2)
//!   u32 n_tensors
//!   per tensor: name | u32 rows | u32 cols | u32 n_groups
//!     per group: u8 method_tag | u8 bits | u32 rows | u32 cols |
//!                u32 row_offset | u32 col_offset |
//!                codes | side info (tagged)
//!   u32 crc32 of everything after magic
//!
//! **v1** codes are always fixed-width: `u8 bits | u32 n | bytes`.
//! **v2** codes are tagged payloads (`u8 payload_tag`):
//!   - tag 0 (fixed): `u8 bits | u32 n | bytes` — identical to v1's body;
//!   - tag 1 (rANS):  `u8 bits | u32 n | u32 chunk_len | u8 lanes |
//!                     u32 n_syms + u16 freqs… |
//!                     u32 n_chunks, per chunk: lanes×u32 states |
//!                     bytes stream | u32 n_escapes + i32 raw escapes…`.
//!
//! The writer emits v1 whenever every payload is fixed-width (so seed-era
//! files and tools stay byte-compatible) and v2 otherwise; the reader
//! accepts both. The CRC is verified **incrementally while parsing** — a
//! corrupted length field surfaces as a structured [`FormatError`] before
//! any oversized allocation, and the trailing checksum is checked against
//! the running digest. Measured file sizes from this container back the
//! Table-5 overhead reproduction (`glvq exp table5`), and with `--entropy`
//! the new measured-with-entropy column.

use std::fmt;
use std::io::Read;
use std::path::Path;

use anyhow::{Context, Result};

use crate::entropy::histogram::CodeHistogram;
use crate::entropy::stream::{RansChunk, RansCodes};
use crate::quant::pack::PackedCodes;
use crate::quant::traits::{CodePayload, QuantizedGroup, SideInfo};
use crate::tensor::{crc32, Crc32};

const MAGIC: &[u8; 4] = b"GLVQ";
/// Fixed-width-only container (seed format).
pub const VERSION_V1: u32 = 1;
/// Tagged-payload container with entropy-coded codes.
pub const VERSION_V2: u32 = 2;

/// Structured container errors — stable for callers to match on
/// (`err.downcast_ref::<FormatError>()`), instead of string-matching
/// `bail!` messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FormatError {
    /// The file does not start with the "GLVQ" magic.
    BadMagic,
    /// The container version is not one this build reads.
    UnsupportedVersion(u32),
    /// The trailing CRC32 does not match the streamed digest.
    CrcMismatch { stored: u32, computed: u32 },
    /// The file ended (or a length field overran the body) while reading
    /// the named field.
    Truncated(&'static str),
    /// An unknown tag byte for the named field.
    UnknownTag { what: &'static str, tag: u8 },
    /// A structurally invalid value (e.g. a malformed frequency table).
    Invalid(String),
}

impl fmt::Display for FormatError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FormatError::BadMagic => write!(f, "not a GLVQ container (bad magic)"),
            FormatError::UnsupportedVersion(v) => {
                write!(f, "unsupported container version {v} (supported: 1, 2)")
            }
            FormatError::CrcMismatch { stored, computed } => {
                write!(f, "CRC mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            FormatError::Truncated(what) => write!(f, "truncated container ({what})"),
            FormatError::UnknownTag { what, tag } => write!(f, "unknown {what} tag {tag}"),
            FormatError::Invalid(msg) => write!(f, "invalid container field: {msg}"),
        }
    }
}

impl std::error::Error for FormatError {}

/// One quantized tensor: its grid of quantized groups + placement.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedTensor {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// groups with their (row_offset, col_offset) placement in the tensor
    pub groups: Vec<(usize, usize, QuantizedGroup)>,
}

impl QuantizedTensor {
    /// Reassemble the dense weight matrix from all groups.
    pub fn dequantize(&self) -> crate::linalg::Mat {
        let mut out = crate::linalg::Mat::zeros(self.rows, self.cols);
        for (r0, c0, g) in &self.groups {
            let block = g.dequantize();
            out.set_block(*r0, *c0, &block);
        }
        out
    }

    pub fn payload_bits(&self) -> usize {
        self.groups.iter().map(|(_, _, g)| g.payload_bits()).sum()
    }

    pub fn side_bytes(&self) -> usize {
        self.groups.iter().map(|(_, _, g)| g.side_bytes()).sum()
    }

    /// True stored code bytes (compressed size for entropy payloads).
    pub fn payload_bytes(&self) -> usize {
        self.groups.iter().map(|(_, _, g)| g.codes.payload_bytes()).sum()
    }

    /// What the codes would occupy fixed-width (`Σ ⌈n·b/8⌉`).
    pub fn fixed_payload_bytes(&self) -> usize {
        self.groups.iter().map(|(_, _, g)| g.codes.fixed_payload_bytes()).sum()
    }

    /// Average *nominal* bits per weight (codes only, paper convention).
    pub fn avg_bits(&self) -> f64 {
        self.payload_bits() as f64 / (self.rows * self.cols) as f64
    }

    // ---- group-aligned slicing (the tensor-parallel sharding unit) ----
    //
    // A quantized tensor partitions losslessly along its group grid: a
    // slice taken at group boundaries carries whole `QuantizedGroup`s —
    // codes, side info, rANS chunks — untouched, so slicing never splits
    // a lattice group or an entropy-coded chunk, and `concat_cols` /
    // `concat_rows` reassembles the original tensor bit-for-bit
    // (property-tested below across every `SideInfo` family). This is
    // what makes grouped-lattice weights a natural sharding unit: the
    // shard planner (`crate::shard`) picks its partition from
    // `col_split_points` / `row_split_points`.

    /// Column positions where the tensor can be split without cutting
    /// through any group: ascending, always including 0 and `cols`.
    pub fn col_split_points(&self) -> Vec<usize> {
        let mut pts: Vec<usize> = vec![0, self.cols];
        for (_, c0, g) in &self.groups {
            pts.push(*c0);
            pts.push(c0 + g.cols);
        }
        pts.sort_unstable();
        pts.dedup();
        pts.retain(|&c| {
            self.groups.iter().all(|(_, c0, g)| c <= *c0 || c >= c0 + g.cols)
        });
        pts
    }

    /// Row positions where the tensor can be split without cutting
    /// through any group: ascending, always including 0 and `rows`.
    pub fn row_split_points(&self) -> Vec<usize> {
        let mut pts: Vec<usize> = vec![0, self.rows];
        for (r0, _, g) in &self.groups {
            pts.push(*r0);
            pts.push(r0 + g.rows);
        }
        pts.sort_unstable();
        pts.dedup();
        pts.retain(|&r| {
            self.groups.iter().all(|(r0, _, g)| r <= *r0 || r >= r0 + g.rows)
        });
        pts
    }

    /// Slice the column range `[c0, c1)`. Every group must lie entirely
    /// inside or outside the range — a straddling group is an error, so a
    /// slice can never split a lattice group or rANS chunk. Offsets are
    /// rebased to the slice.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Result<QuantizedTensor> {
        anyhow::ensure!(c0 < c1 && c1 <= self.cols, "{}: bad column range [{c0}, {c1})", self.name);
        let mut groups = Vec::new();
        for (r0, gc0, g) in &self.groups {
            let (lo, hi) = (*gc0, gc0 + g.cols);
            if hi <= c0 || lo >= c1 {
                continue;
            }
            anyhow::ensure!(
                lo >= c0 && hi <= c1,
                "{}: column split [{c0}, {c1}) cuts through group at cols [{lo}, {hi})",
                self.name
            );
            groups.push((*r0, lo - c0, g.clone()));
        }
        Ok(QuantizedTensor { name: self.name.clone(), rows: self.rows, cols: c1 - c0, groups })
    }

    /// Slice the row range `[r0, r1)` — the row-axis dual of
    /// [`QuantizedTensor::slice_cols`].
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Result<QuantizedTensor> {
        anyhow::ensure!(r0 < r1 && r1 <= self.rows, "{}: bad row range [{r0}, {r1})", self.name);
        let mut groups = Vec::new();
        for (gr0, c0, g) in &self.groups {
            let (lo, hi) = (*gr0, gr0 + g.rows);
            if hi <= r0 || lo >= r1 {
                continue;
            }
            anyhow::ensure!(
                lo >= r0 && hi <= r1,
                "{}: row split [{r0}, {r1}) cuts through group at rows [{lo}, {hi})",
                self.name
            );
            groups.push((lo - r0, *c0, g.clone()));
        }
        Ok(QuantizedTensor { name: self.name.clone(), rows: r1 - r0, cols: self.cols, groups })
    }

    /// Reassemble column slices (in order) into one tensor. Inverse of
    /// slicing at [`QuantizedTensor::col_split_points`]: offsets are
    /// rebased back, group order within each part is preserved, and the
    /// result compares equal to the original tensor bit-for-bit.
    pub fn concat_cols(parts: &[QuantizedTensor]) -> Result<QuantizedTensor> {
        anyhow::ensure!(!parts.is_empty(), "concat_cols of zero tensors");
        let rows = parts[0].rows;
        let mut groups = Vec::new();
        let mut cols = 0usize;
        for p in parts {
            anyhow::ensure!(p.rows == rows, "{}: row count mismatch in concat_cols", p.name);
            for (r0, c0, g) in &p.groups {
                groups.push((*r0, c0 + cols, g.clone()));
            }
            cols += p.cols;
        }
        // canonical group order: column-major panels, as the pipeline emits
        groups.sort_by_key(|(r0, c0, _)| (*c0, *r0));
        Ok(QuantizedTensor { name: parts[0].name.clone(), rows, cols, groups })
    }

    /// Reassemble row slices (in order) into one tensor — the row-axis
    /// dual of [`QuantizedTensor::concat_cols`].
    pub fn concat_rows(parts: &[QuantizedTensor]) -> Result<QuantizedTensor> {
        anyhow::ensure!(!parts.is_empty(), "concat_rows of zero tensors");
        let cols = parts[0].cols;
        let mut groups = Vec::new();
        let mut rows = 0usize;
        for p in parts {
            anyhow::ensure!(p.cols == cols, "{}: col count mismatch in concat_rows", p.name);
            for (r0, c0, g) in &p.groups {
                groups.push((r0 + rows, *c0, g.clone()));
            }
            rows += p.rows;
        }
        groups.sort_by_key(|(r0, c0, _)| (*c0, *r0));
        Ok(QuantizedTensor { name: parts[0].name.clone(), rows, cols, groups })
    }
}

/// A complete quantized model container.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantizedModel {
    pub tensors: Vec<QuantizedTensor>,
}

fn method_tag(m: &str) -> u8 {
    match m {
        "glvq" => 1,
        "rtn" => 2,
        "omniquant_lite" => 3,
        "gptq" => 4,
        "kmeans_vq" => 5,
        "quip_lite" => 6,
        "tcq" => 7,
        "binary" => 8,
        "glvq_fixed" => 9,
        _ => 0,
    }
}

fn method_name(t: u8) -> &'static str {
    match t {
        1 => "glvq",
        2 => "rtn",
        3 => "omniquant_lite",
        4 => "gptq",
        5 => "kmeans_vq",
        6 => "quip_lite",
        7 => "tcq",
        8 => "binary",
        9 => "glvq_fixed",
        _ => "unknown",
    }
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f32(x);
        }
    }
}

/// Streaming reader: pulls from an `io::Read`, tracks the remaining body
/// length (so corrupt length fields fail fast instead of over-allocating)
/// and feeds every consumed byte into an incremental CRC.
struct Reader<R: Read> {
    inner: R,
    crc: Crc32,
    /// body bytes left to consume (excludes the trailing CRC word)
    remaining: u64,
}

impl<R: Read> Reader<R> {
    fn fill(&mut self, what: &'static str, buf: &mut [u8]) -> Result<()> {
        if (buf.len() as u64) > self.remaining {
            return Err(FormatError::Truncated(what).into());
        }
        self.inner
            .read_exact(buf)
            .map_err(|_| anyhow::Error::new(FormatError::Truncated(what)))?;
        self.crc.update(buf);
        self.remaining -= buf.len() as u64;
        Ok(())
    }

    fn u8(&mut self, what: &'static str) -> Result<u8> {
        let mut b = [0u8; 1];
        self.fill(what, &mut b)?;
        Ok(b[0])
    }
    fn u16(&mut self, what: &'static str) -> Result<u16> {
        let mut b = [0u8; 2];
        self.fill(what, &mut b)?;
        Ok(u16::from_le_bytes(b))
    }
    fn u32(&mut self, what: &'static str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.fill(what, &mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self, what: &'static str) -> Result<u64> {
        let mut b = [0u8; 8];
        self.fill(what, &mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f32(&mut self, what: &'static str) -> Result<f32> {
        Ok(f32::from_bits(self.u32(what)?))
    }
    fn i32(&mut self, what: &'static str) -> Result<i32> {
        Ok(self.u32(what)? as i32)
    }
    fn bytes(&mut self, what: &'static str) -> Result<Vec<u8>> {
        let n = self.u32(what)? as usize;
        if (n as u64) > self.remaining {
            return Err(FormatError::Truncated(what).into());
        }
        let mut v = vec![0u8; n];
        self.fill(what, &mut v)?;
        Ok(v)
    }
    fn f32s(&mut self, what: &'static str) -> Result<Vec<f32>> {
        let n = self.u32(what)? as usize;
        if (n as u64) * 4 > self.remaining {
            return Err(FormatError::Truncated(what).into());
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32(what)?);
        }
        Ok(v)
    }
}

fn write_side(w: &mut Writer, s: &SideInfo) {
    match s {
        SideInfo::Uniform { scale, zero } => {
            w.u8(1);
            w.f32(*scale);
            w.f32(*zero);
        }
        SideInfo::Lattice { d, g, mu, scale } => {
            w.u8(2);
            w.u32(*d as u32);
            w.f32s(g);
            w.f32(*mu);
            w.f32(*scale);
        }
        SideInfo::RotatedLattice { d, scale, sign_seed } => {
            w.u8(3);
            w.u32(*d as u32);
            w.f32(*scale);
            w.u64(*sign_seed);
        }
        SideInfo::Codebook { dim, centers } => {
            w.u8(4);
            w.u32(*dim as u32);
            w.f32s(centers);
        }
        SideInfo::Trellis { levels, states } => {
            w.u8(5);
            w.u32(*states as u32);
            w.f32s(levels);
        }
        SideInfo::Binary { row_scales, residual_scales } => {
            w.u8(6);
            w.f32s(row_scales);
            match residual_scales {
                Some(r) => {
                    w.u8(1);
                    w.f32s(r);
                }
                None => w.u8(0),
            }
        }
    }
}

fn read_side<R: Read>(r: &mut Reader<R>) -> Result<SideInfo> {
    Ok(match r.u8("side tag")? {
        1 => SideInfo::Uniform { scale: r.f32("side scale")?, zero: r.f32("side zero")? },
        2 => {
            let d = r.u32("lattice d")? as usize;
            let g = r.f32s("lattice G")?;
            let mu = r.f32("lattice mu")?;
            let scale = r.f32("lattice scale")?;
            SideInfo::Lattice { d, g, mu, scale }
        }
        3 => SideInfo::RotatedLattice {
            d: r.u32("rotated d")? as usize,
            scale: r.f32("rotated scale")?,
            sign_seed: r.u64("rotated seed")?,
        },
        4 => SideInfo::Codebook {
            dim: r.u32("codebook dim")? as usize,
            centers: r.f32s("codebook centers")?,
        },
        5 => {
            let states = r.u32("trellis states")? as usize;
            SideInfo::Trellis { levels: r.f32s("trellis levels")?, states }
        }
        6 => {
            let row_scales = r.f32s("binary scales")?;
            let residual_scales = if r.u8("binary residual flag")? == 1 {
                Some(r.f32s("binary residual scales")?)
            } else {
                None
            };
            SideInfo::Binary { row_scales, residual_scales }
        }
        t => return Err(FormatError::UnknownTag { what: "side-info", tag: t }.into()),
    })
}

fn write_fixed_codes(w: &mut Writer, p: &PackedCodes) {
    w.u8(p.bits);
    w.u32(p.n as u32);
    w.bytes(&p.data);
}

fn write_rans_codes(w: &mut Writer, r: &RansCodes) {
    w.u8(r.bits);
    w.u32(r.n as u32);
    w.u32(r.chunk_len as u32);
    w.u8(r.lanes);
    w.u32(r.hist.freqs.len() as u32);
    for &f in &r.hist.freqs {
        w.u16(f);
    }
    w.u32(r.chunks.len() as u32);
    for c in &r.chunks {
        // lane count is fixed per payload; states are stored bare
        for &s in &c.states {
            w.u32(s);
        }
        w.bytes(&c.bytes);
        w.u32(c.escapes.len() as u32);
        for &e in &c.escapes {
            w.i32(e);
        }
    }
}

fn write_payload_v2(w: &mut Writer, codes: &CodePayload) {
    match codes {
        CodePayload::Fixed(p) => {
            w.u8(0);
            write_fixed_codes(w, p);
        }
        CodePayload::Rans(r) => {
            w.u8(1);
            write_rans_codes(w, r);
        }
    }
}

fn read_fixed_codes<R: Read>(r: &mut Reader<R>) -> Result<PackedCodes> {
    let bits = r.u8("code bits")?;
    let n = r.u32("code count")? as usize;
    let data = r.bytes("code bytes")?;
    // consistency guard: a CRC-valid but crafted file must not be able to
    // trigger an out-of-bounds panic at first unpack
    if !(1..=8).contains(&bits) {
        return Err(FormatError::Invalid(format!("fixed payload bits {bits} not in 1..=8")).into());
    }
    if data.len() != (n * bits as usize).div_ceil(8) {
        return Err(FormatError::Invalid(format!(
            "fixed payload has {} bytes, want {} for n={n} bits={bits}",
            data.len(),
            (n * bits as usize).div_ceil(8)
        ))
        .into());
    }
    Ok(PackedCodes { bits, n, data })
}

fn read_rans_codes<R: Read>(r: &mut Reader<R>) -> Result<RansCodes> {
    let bits = r.u8("rans bits")?;
    if !(1..=8).contains(&bits) {
        return Err(FormatError::Invalid(format!("rans payload bits {bits} not in 1..=8")).into());
    }
    let n = r.u32("rans count")? as usize;
    let chunk_len = r.u32("rans chunk_len")? as usize;
    let lanes = r.u8("rans lanes")?;
    if chunk_len == 0 || lanes == 0 {
        return Err(FormatError::Invalid("rans chunk_len/lanes must be > 0".into()).into());
    }
    let nfreq = r.u32("rans freq count")? as usize;
    if (nfreq as u64) * 2 > r.remaining {
        return Err(FormatError::Truncated("rans freqs").into());
    }
    let mut freqs = Vec::with_capacity(nfreq);
    for _ in 0..nfreq {
        freqs.push(r.u16("rans freq")?);
    }
    let hist = CodeHistogram::from_freqs(bits, freqs)
        .map_err(|e| anyhow::Error::new(FormatError::Invalid(e)))?;
    let n_chunks = r.u32("rans chunk count")? as usize;
    let expect_chunks = n.div_ceil(chunk_len);
    if n_chunks != expect_chunks {
        return Err(FormatError::Invalid(format!(
            "rans payload has {n_chunks} chunks, want {expect_chunks} for n={n} chunk_len={chunk_len}"
        ))
        .into());
    }
    // every chunk costs at least lanes×u32 states + two length words —
    // reject impossible counts before reserving anything
    if (n_chunks as u64) * (4 * lanes as u64 + 8) > r.remaining {
        return Err(FormatError::Truncated("rans chunks").into());
    }
    let mut chunks = Vec::with_capacity(n_chunks);
    for ci in 0..n_chunks {
        let mut states = Vec::with_capacity(lanes as usize);
        for _ in 0..lanes {
            states.push(r.u32("rans state")?);
        }
        let bytes = r.bytes("rans stream")?;
        let n_esc = r.u32("rans escape count")? as usize;
        let chunk_syms = chunk_len.min(n - ci * chunk_len);
        if n_esc > chunk_syms {
            return Err(FormatError::Invalid(format!(
                "rans chunk {ci} has {n_esc} escapes for {chunk_syms} symbols"
            ))
            .into());
        }
        if (n_esc as u64) * 4 > r.remaining {
            return Err(FormatError::Truncated("rans escapes").into());
        }
        let mut escapes = Vec::with_capacity(n_esc);
        for _ in 0..n_esc {
            escapes.push(r.i32("rans escape")?);
        }
        chunks.push(RansChunk { states, bytes, escapes });
    }
    Ok(RansCodes { bits, n, chunk_len, lanes, hist, chunks })
}

fn read_payload<R: Read>(r: &mut Reader<R>, version: u32) -> Result<CodePayload> {
    if version == VERSION_V1 {
        return Ok(CodePayload::Fixed(read_fixed_codes(r)?));
    }
    match r.u8("payload tag")? {
        0 => Ok(CodePayload::Fixed(read_fixed_codes(r)?)),
        1 => Ok(CodePayload::Rans(read_rans_codes(r)?)),
        t => Err(FormatError::UnknownTag { what: "payload", tag: t }.into()),
    }
}

impl QuantizedModel {
    /// True if any group carries an entropy-coded payload (forces v2).
    pub fn has_entropy_payloads(&self) -> bool {
        self.tensors
            .iter()
            .any(|t| t.groups.iter().any(|(_, _, g)| g.codes.is_entropy()))
    }

    /// The container version [`QuantizedModel::save`] will emit.
    pub fn container_version(&self) -> u32 {
        if self.has_entropy_payloads() {
            VERSION_V2
        } else {
            VERSION_V1
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let version = self.container_version();
        let mut w = Writer { buf: Vec::new() };
        w.u32(version);
        w.u32(self.tensors.len() as u32);
        for t in &self.tensors {
            w.bytes(t.name.as_bytes());
            w.u32(t.rows as u32);
            w.u32(t.cols as u32);
            w.u32(t.groups.len() as u32);
            for (r0, c0, g) in &t.groups {
                w.u8(method_tag(g.method));
                w.u8(g.bits);
                w.u32(g.rows as u32);
                w.u32(g.cols as u32);
                w.u32(*r0 as u32);
                w.u32(*c0 as u32);
                match (&g.codes, version) {
                    (CodePayload::Fixed(p), VERSION_V1) => write_fixed_codes(&mut w, p),
                    (codes, _) => write_payload_v2(&mut w, codes),
                }
                write_side(&mut w, &g.side);
            }
        }
        let crc = crc32(&w.buf);
        let mut out = Vec::with_capacity(w.buf.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&w.buf);
        out.extend_from_slice(&crc.to_le_bytes());
        std::fs::write(path, &out).with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<QuantizedModel> {
        let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let len = file.metadata().with_context(|| format!("stat {}", path.display()))?.len();
        if len < 12 {
            return Err(FormatError::Truncated("header").into());
        }
        let mut inner = std::io::BufReader::new(file);

        let mut magic = [0u8; 4];
        inner
            .read_exact(&mut magic)
            .map_err(|_| anyhow::Error::new(FormatError::Truncated("magic")))?;
        if &magic != MAGIC {
            return Err(FormatError::BadMagic.into());
        }

        // body = everything between magic and trailing CRC word; the CRC is
        // accumulated as the parser consumes it (no whole-file buffering).
        let mut r = Reader { inner, crc: Crc32::new(), remaining: len - 8 };
        let model = Self::read_body(&mut r)
            .map_err(|e| e.context(format!("parse {}", path.display())))?;
        if r.remaining != 0 {
            return Err(FormatError::Truncated("unconsumed body bytes").into());
        }
        let computed = r.crc.finalize();
        let mut tail = [0u8; 4];
        r.inner
            .read_exact(&mut tail)
            .map_err(|_| anyhow::Error::new(FormatError::Truncated("crc")))?;
        let stored = u32::from_le_bytes(tail);
        if stored != computed {
            return Err(FormatError::CrcMismatch { stored, computed }.into());
        }
        Ok(model)
    }

    fn read_body<R: Read>(r: &mut Reader<R>) -> Result<QuantizedModel> {
        let version = r.u32("version")?;
        if version != VERSION_V1 && version != VERSION_V2 {
            return Err(FormatError::UnsupportedVersion(version).into());
        }
        let nt = r.u32("tensor count")? as usize;
        let mut tensors = Vec::with_capacity(nt.min(1 << 20));
        for _ in 0..nt {
            let name = String::from_utf8(r.bytes("tensor name")?)
                .map_err(|_| anyhow::Error::new(FormatError::Invalid("tensor name not utf-8".into())))?;
            let rows = r.u32("tensor rows")? as usize;
            let cols = r.u32("tensor cols")? as usize;
            let ng = r.u32("group count")? as usize;
            let mut groups = Vec::with_capacity(ng.min(1 << 20));
            for _ in 0..ng {
                let tag = r.u8("method tag")?;
                let bits = r.u8("group bits")?;
                let grows = r.u32("group rows")? as usize;
                let gcols = r.u32("group cols")? as usize;
                let r0 = r.u32("group row offset")? as usize;
                let c0 = r.u32("group col offset")? as usize;
                let codes = read_payload(r, version)?;
                let side = read_side(r)?;
                groups.push((
                    r0,
                    c0,
                    QuantizedGroup {
                        method: method_name(tag),
                        bits,
                        rows: grows,
                        cols: gcols,
                        codes,
                        side,
                    },
                ));
            }
            tensors.push(QuantizedTensor { name, rows, cols, groups });
        }
        Ok(QuantizedModel { tensors })
    }

    pub fn get(&self, name: &str) -> Option<&QuantizedTensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Whole-model average nominal bits per quantized weight.
    pub fn avg_bits(&self) -> f64 {
        let bits: usize = self.tensors.iter().map(|t| t.payload_bits()).sum();
        let weights: usize = self.tensors.iter().map(|t| t.rows * t.cols).sum();
        bits as f64 / weights.max(1) as f64
    }

    /// Total size accounting: (payload_bytes, side_bytes). Payload is the
    /// true stored size — compressed for entropy-coded groups.
    pub fn size_bytes(&self) -> (usize, usize) {
        let payload = self.tensors.iter().map(|t| t.payload_bytes()).sum();
        let side = self.tensors.iter().map(|t| t.side_bytes()).sum();
        (payload, side)
    }

    /// What the codes would occupy fixed-width — the entropy-saving
    /// baseline (`glvq info --container` reports both).
    pub fn fixed_payload_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.fixed_payload_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{code_range, PackedCodes};

    fn sample_model() -> QuantizedModel {
        let (lo, hi) = code_range(2);
        let codes: Vec<i32> = (0..64).map(|i| (i % (hi - lo + 1)) + lo).collect();
        let g1 = QuantizedGroup {
            method: "glvq",
            bits: 2,
            rows: 8,
            cols: 8,
            codes: PackedCodes::pack(&codes, 2).into(),
            side: SideInfo::Lattice {
                d: 8,
                g: (0..64).map(|i| i as f32 * 0.01).collect(),
                mu: 42.5,
                scale: 0.7,
            },
        };
        let g2 = QuantizedGroup {
            method: "rtn",
            bits: 2,
            rows: 8,
            cols: 8,
            codes: PackedCodes::pack(&codes, 2).into(),
            side: SideInfo::Uniform { scale: 0.02, zero: 0.0 },
        };
        QuantizedModel {
            tensors: vec![QuantizedTensor {
                name: "00.attn.wq".into(),
                rows: 8,
                cols: 16,
                groups: vec![(0, 0, g1), (0, 8, g2)],
            }],
        }
    }

    /// The sample model with every payload entropy-coded (forces v2).
    fn sample_model_entropy() -> QuantizedModel {
        let mut m = sample_model();
        for t in &mut m.tensors {
            for (_, _, g) in &mut t.groups {
                g.codes = g.codes.to_entropy(16, 2);
            }
        }
        m
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("glvq_fmt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn save_load_roundtrip() {
        let m = sample_model();
        let dir = tmp_dir("v1");
        let p = dir.join("m.glvq");
        m.save(&p).unwrap();
        let loaded = QuantizedModel::load(&p).unwrap();
        assert_eq!(m, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_fixed_models_stay_on_v1() {
        let m = sample_model();
        assert_eq!(m.container_version(), VERSION_V1);
        let dir = tmp_dir("v1b");
        let p = dir.join("m.glvq");
        m.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..4], b"GLVQ");
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION_V1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_roundtrips_entropy_and_mixed_payloads() {
        // all-entropy
        let m = sample_model_entropy();
        assert_eq!(m.container_version(), VERSION_V2);
        let dir = tmp_dir("v2");
        let p = dir.join("m.glvq");
        m.save(&p).unwrap();
        let loaded = QuantizedModel::load(&p).unwrap();
        assert_eq!(m, loaded);

        // mixed: one fixed + one entropy group in the same tensor
        let mut mixed = sample_model();
        mixed.tensors[0].groups[1].2.codes =
            mixed.tensors[0].groups[1].2.codes.to_entropy(16, 4);
        assert_eq!(mixed.container_version(), VERSION_V2);
        mixed.save(&p).unwrap();
        let loaded = QuantizedModel::load(&p).unwrap();
        assert_eq!(mixed, loaded);

        // write→read→write→read is stable
        let p2 = dir.join("m2.glvq");
        loaded.save(&p2).unwrap();
        assert_eq!(QuantizedModel::load(&p2).unwrap(), loaded);
        assert_eq!(std::fs::read(&p).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn entropy_payload_decodes_identically() {
        let m = sample_model();
        let me = sample_model_entropy();
        for (t, te) in m.tensors.iter().zip(&me.tensors) {
            for ((_, _, g), (_, _, ge)) in t.groups.iter().zip(&te.groups) {
                assert_eq!(g.codes.unpack(), ge.codes.unpack());
                assert_eq!(g.dequantize().data, ge.dequantize().data);
            }
        }
    }

    #[test]
    fn corruption_detected() {
        let m = sample_model_entropy();
        let dir = tmp_dir("c");
        let p = dir.join("m.glvq");
        m.save(&p).unwrap();
        let clean = std::fs::read(&p).unwrap();
        // flip one byte at every eighth position — every corruption must be
        // rejected (structured parse error or CRC mismatch), never OK
        for pos in (4..clean.len()).step_by(8) {
            let mut bytes = clean.clone();
            bytes[pos] ^= 0x55;
            std::fs::write(&p, &bytes).unwrap();
            assert!(QuantizedModel::load(&p).is_err(), "corruption at {pos} accepted");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn structured_errors_are_matchable() {
        let dir = tmp_dir("e");
        let p = dir.join("m.glvq");

        // bad magic
        std::fs::write(&p, b"NOPE0000000000").unwrap();
        let err = QuantizedModel::load(&p).unwrap_err();
        assert_eq!(err.downcast_ref::<FormatError>(), Some(&FormatError::BadMagic));

        // unsupported version
        let m = sample_model();
        m.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[4] = 9; // version word
        let body_end = bytes.len() - 4;
        let crc = crc32(&bytes[4..body_end]);
        bytes[body_end..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = QuantizedModel::load(&p).unwrap_err();
        assert_eq!(
            err.downcast_ref::<FormatError>(),
            Some(&FormatError::UnsupportedVersion(9))
        );

        // CRC mismatch (flip a bit in the stored checksum itself)
        m.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = QuantizedModel::load(&p).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<FormatError>(), Some(FormatError::CrcMismatch { .. })),
            "{err:?}"
        );

        // truncation
        m.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        let err = QuantizedModel::load(&p).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<FormatError>(), Some(FormatError::Truncated(_))),
            "{err:?}"
        );

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dequantize_assembles_blocks_in_place() {
        let m = sample_model();
        let t = &m.tensors[0];
        let full = t.dequantize();
        assert_eq!((full.rows, full.cols), (8, 16));
        let left = t.groups[0].2.dequantize();
        let right = t.groups[1].2.dequantize();
        for r in 0..8 {
            for c in 0..8 {
                assert_eq!(full.at(r, c), left.at(r, c));
                assert_eq!(full.at(r, c + 8), right.at(r, c));
            }
        }
    }

    #[test]
    fn rate_accounting() {
        let m = sample_model();
        assert!((m.avg_bits() - 2.0).abs() < 1e-9);
        let (payload, side) = m.size_bytes();
        assert_eq!(payload, 2 * 64 * 2 / 8);
        assert_eq!(side, (2 * 64 + 4) + 4);
        assert_eq!(m.fixed_payload_bytes(), payload);
    }

    /// One 8×8 group of every side-info family (all code payloads valid
    /// for their family's decode), laid out as six column panels.
    fn all_families_tensor() -> QuantizedTensor {
        let (lo, hi) = code_range(2);
        let codes2: Vec<i32> = (0..64).map(|i| (i % (hi - lo + 1)) + lo).collect();
        let codes1: Vec<i32> = (0..64).map(|i| (i % 2) - 1).collect();
        let mk = |method: &'static str, bits: u8, codes: &[i32], side: SideInfo| QuantizedGroup {
            method,
            bits,
            rows: 8,
            cols: 8,
            codes: PackedCodes::pack(codes, bits).into(),
            side,
        };
        let groups: Vec<(usize, usize, QuantizedGroup)> = vec![
            mk("rtn", 2, &codes2, SideInfo::Uniform { scale: 0.05, zero: 0.01 }),
            mk(
                "glvq",
                2,
                &codes2,
                SideInfo::Lattice {
                    d: 8,
                    g: (0..64).map(|i| i as f32 * 0.01).collect(),
                    mu: 40.0,
                    scale: 0.6,
                },
            ),
            mk(
                "quip_lite",
                2,
                &codes2,
                SideInfo::RotatedLattice { d: 8, scale: 0.3, sign_seed: 17 },
            ),
            {
                // codebook: one code per dim-2 block → 32 stored codes
                let (clo, _) = code_range(1);
                let idx: Vec<i32> = (0..32).map(|i| (i % 2) + clo).collect();
                QuantizedGroup {
                    method: "kmeans_vq",
                    bits: 1,
                    rows: 8,
                    cols: 8,
                    codes: PackedCodes::pack(&idx, 1).into(),
                    side: SideInfo::Codebook { dim: 2, centers: vec![0.1, 0.2, -0.3, -0.4] },
                }
            },
            mk(
                "tcq",
                2,
                &codes2,
                SideInfo::Trellis { levels: (0..8).map(|i| i as f32 * 0.1 - 0.4).collect(), states: 4 },
            ),
            mk(
                "binary",
                1,
                &codes1,
                SideInfo::Binary {
                    row_scales: (0..8).map(|i| 0.1 + i as f32 * 0.01).collect(),
                    residual_scales: Some((0..8).map(|i| 0.05 + i as f32 * 0.01).collect()),
                },
            ),
        ]
        .into_iter()
        .enumerate()
        .map(|(i, g)| (0usize, i * 8, g))
        .collect();
        QuantizedTensor { name: "fam".into(), rows: 8, cols: 48, groups }
    }

    #[test]
    fn split_points_are_group_boundaries() {
        let t = all_families_tensor();
        assert_eq!(t.col_split_points(), vec![0, 8, 16, 24, 32, 40, 48]);
        // all groups span the full row extent → only trivial row splits
        assert_eq!(t.row_split_points(), vec![0, 8]);
    }

    #[test]
    fn group_aligned_slice_concat_is_bitwise_identity_all_families() {
        // the sharding invariant: slicing at ANY group-aligned partition
        // and concatenating reconstructs the original tensor bitwise —
        // for every side-info family and for fixed and rANS payloads
        let mut variants = vec![all_families_tensor()];
        {
            // entropy-code the streaming-family payloads (chunk = 2 rows)
            let mut t = all_families_tensor();
            for (_, _, g) in &mut t.groups {
                if matches!(
                    g.side,
                    SideInfo::Uniform { .. }
                        | SideInfo::Lattice { .. }
                        | SideInfo::RotatedLattice { .. }
                ) {
                    g.codes = g.codes.to_entropy(g.cols * 2, 4);
                }
            }
            variants.push(t);
        }
        for t in &variants {
            let pts = t.col_split_points();
            // every contiguous partition spanned by adjacent split points
            for take in [1usize, 2, 3, 6] {
                let mut parts = Vec::new();
                let mut i = 0;
                while i + 1 < pts.len() {
                    let j = (i + take).min(pts.len() - 1);
                    parts.push(t.slice_cols(pts[i], pts[j]).unwrap());
                    i = j;
                }
                let back = QuantizedTensor::concat_cols(&parts).unwrap();
                assert_eq!(&back, t, "take={take}: slice→concat not bitwise identity");
                assert_eq!(back.dequantize().data, t.dequantize().data);
            }
        }
    }

    #[test]
    fn row_slice_concat_roundtrips_on_a_grid() {
        // a 2×2 grid of groups slices on both axes
        let (lo, hi) = code_range(2);
        let codes: Vec<i32> = (0..64).map(|i| (i % (hi - lo + 1)) + lo).collect();
        let mk = |scale: f32| QuantizedGroup {
            method: "rtn",
            bits: 2,
            rows: 8,
            cols: 8,
            codes: PackedCodes::pack(&codes, 2).into(),
            side: SideInfo::Uniform { scale, zero: 0.0 },
        };
        let t = QuantizedTensor {
            name: "grid".into(),
            rows: 16,
            cols: 16,
            // canonical (c0, r0) order
            groups: vec![(0, 0, mk(0.1)), (8, 0, mk(0.2)), (0, 8, mk(0.3)), (8, 8, mk(0.4))],
        };
        assert_eq!(t.row_split_points(), vec![0, 8, 16]);
        assert_eq!(t.col_split_points(), vec![0, 8, 16]);
        let top = t.slice_rows(0, 8).unwrap();
        let bot = t.slice_rows(8, 16).unwrap();
        assert_eq!(QuantizedTensor::concat_rows(&[top, bot]).unwrap(), t);
        let left = t.slice_cols(0, 8).unwrap();
        let right = t.slice_cols(8, 16).unwrap();
        assert_eq!(QuantizedTensor::concat_cols(&[left, right]).unwrap(), t);
    }

    #[test]
    fn straddling_slices_are_refused() {
        let t = all_families_tensor();
        // mid-group column cut would split a lattice group → hard error
        assert!(t.slice_cols(0, 4).is_err());
        assert!(t.slice_cols(4, 48).is_err());
        assert!(t.slice_cols(0, 0).is_err());
        assert!(t.slice_cols(0, 49).is_err());
        // group-aligned cuts succeed and carry whole groups
        let s = t.slice_cols(8, 24).unwrap();
        assert_eq!((s.rows, s.cols), (8, 16));
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].1, 0);
        assert_eq!(s.groups[1].1, 8);
    }
}
