//! The quantizer contract every method (GLVQ + baselines) implements, and
//! the unified quantized-group representation with per-method side info.
//!
//! A *group* is an (rows × cols) weight panel — the paper's column group of
//! one linear layer (cols = group size, default 128; rows = output dim).
//! Calibration inputs X are (cols × N): the activations feeding those
//! columns. `quantize` returns codes + side info; `dequantize` must
//! reproduce exactly what the runtime streaming decoder computes.

use crate::compand::MuLaw;
use crate::entropy::RansCodes;
use crate::linalg::Mat;
use crate::quant::pack::PackedCodes;

/// The stored form of a group's integer codes — the abstraction every
/// compressed-payload backend plugs into.
///
/// - [`CodePayload::Fixed`]: bit-packed `m·n·b/8` payload (Eq. 26, the
///   paper's convention; rate is exactly `bits` per weight).
/// - [`CodePayload::Rans`]: entropy-coded chunks
///   ([`crate::entropy::stream::RansCodes`]) whose size tracks the codes'
///   empirical entropy — smaller files at equal nominal bits.
///
/// Both variants decode to the identical code vector, so every decode
/// path (dense dequantize, streaming matvec) is payload-agnostic.
#[derive(Clone, Debug, PartialEq)]
pub enum CodePayload {
    Fixed(PackedCodes),
    Rans(RansCodes),
}

impl From<PackedCodes> for CodePayload {
    fn from(p: PackedCodes) -> CodePayload {
        CodePayload::Fixed(p)
    }
}

impl From<RansCodes> for CodePayload {
    fn from(r: RansCodes) -> CodePayload {
        CodePayload::Rans(r)
    }
}

impl CodePayload {
    pub fn bits(&self) -> u8 {
        match self {
            CodePayload::Fixed(p) => p.bits,
            CodePayload::Rans(r) => r.bits,
        }
    }

    /// Number of codes stored.
    pub fn n(&self) -> usize {
        match self {
            CodePayload::Fixed(p) => p.n,
            CodePayload::Rans(r) => r.n,
        }
    }

    pub fn is_entropy(&self) -> bool {
        matches!(self, CodePayload::Rans(_))
    }

    /// True on-disk payload size (codes only, excluding side info).
    pub fn payload_bytes(&self) -> usize {
        match self {
            CodePayload::Fixed(p) => p.payload_bytes(),
            CodePayload::Rans(r) => r.payload_bytes(),
        }
    }

    /// What the payload would cost fixed-width (`⌈n·b/8⌉`) — the baseline
    /// for entropy-saving reports.
    pub fn fixed_payload_bytes(&self) -> usize {
        (self.n() * self.bits() as usize).div_ceil(8)
    }

    /// Payload bytes touched when decoding `[start, start+len)` — the
    /// bytes-moved model for streaming decode stats. Fixed payloads are
    /// bit-granular; rANS payloads are chunk-granular (plus the frequency
    /// table with the first chunk).
    pub fn range_payload_bytes(&self, start: usize, len: usize) -> usize {
        match self {
            CodePayload::Fixed(p) => (len * p.bits as usize).div_ceil(8),
            CodePayload::Rans(r) => r.range_payload_bytes(start, len),
        }
    }

    /// Decode all codes.
    pub fn unpack(&self) -> Vec<i32> {
        match self {
            CodePayload::Fixed(p) => p.unpack(),
            CodePayload::Rans(r) => r.decode(),
        }
    }

    /// Decode all codes into a caller buffer (`len == n`).
    pub fn unpack_into(&self, out: &mut [i32]) {
        match self {
            CodePayload::Fixed(p) => p.unpack_into(out),
            CodePayload::Rans(r) => r.decode_into(out),
        }
    }

    /// Decode the sub-range `[start, start+out.len())` — the streaming
    /// decoder's entry point, valid for both variants.
    pub fn unpack_range_into(&self, start: usize, out: &mut [i32]) {
        match self {
            CodePayload::Fixed(p) => p.unpack_range_into(start, out),
            CodePayload::Rans(r) => r.decode_range_into(start, out),
        }
    }

    /// Re-encode as an entropy-coded payload (lossless; no-op if already
    /// entropy-coded). `chunk_len` should be a multiple of the group width
    /// so streamed panels touch whole chunks.
    pub fn to_entropy(&self, chunk_len: usize, lanes: u8) -> CodePayload {
        match self {
            CodePayload::Fixed(p) => {
                CodePayload::Rans(RansCodes::encode(&p.unpack(), p.bits, chunk_len, lanes))
            }
            CodePayload::Rans(_) => self.clone(),
        }
    }
}

/// Per-group side information — the "extra storage" Table 5 accounts for.
#[derive(Clone, Debug, PartialEq)]
pub enum SideInfo {
    /// Uniform scalar quantization: w ≈ scale · code (+ zero).
    Uniform { scale: f32, zero: f32 },
    /// Lattice VQ (GLVQ / fixed-lattice): d×d generation matrix, μ, and the
    /// group normalization scale. Codes live on the *half-integer* grid:
    /// weights decode as scale·F_μ⁻¹(G (z + ½·1)) per d-length sub-block
    /// (symmetric reconstruction levels at every bit width — the same ½
    /// offset convention as QuIP#'s E8+½). The paper stores G+μ; we add one
    /// FP16 scalar for the normalization — side info is 2d²+4 instead of
    /// 2d²+2 bytes, a documented deviation that keeps the container
    /// bit-exact with the training objective.
    Lattice { d: usize, g: Vec<f32>, mu: f32, scale: f32 },
    /// Hadamard-rotated lattice (QuIP#-lite): sign diagonal seed + scale;
    /// decode = unrotate(scale · nearest-lattice-point).
    RotatedLattice { d: usize, scale: f32, sign_seed: u64 },
    /// Free-form VQ codebook (AQLM/SqueezeLLM-lite): k centers of dim `dim`.
    Codebook { dim: usize, centers: Vec<f32> },
    /// Trellis-coded quantization (QTIP-lite): scalar reproduction levels
    /// per trellis branch (levels.len() = 2^branch_bits · states).
    Trellis { levels: Vec<f32>, states: usize },
    /// Binarization (OneBit/BiLLM-lite): per-row scale(s); `residual` adds a
    /// second sign pass over the residual for the high-salience rows.
    Binary { row_scales: Vec<f32>, residual_scales: Option<Vec<f32>> },
}

impl SideInfo {
    /// Bytes this side info costs on disk at FP16 storage (the paper stores
    /// G and μ in FP16 — Appendix B, Eq. 26: 2d² + 2 bytes for lattice).
    pub fn fp16_bytes(&self) -> usize {
        match self {
            SideInfo::Uniform { .. } => 4,
            SideInfo::Lattice { d, .. } => 2 * d * d + 4,
            SideInfo::RotatedLattice { .. } => 2 + 8,
            SideInfo::Codebook { centers, .. } => 2 * centers.len(),
            SideInfo::Trellis { levels, .. } => 2 * levels.len(),
            SideInfo::Binary { row_scales, residual_scales } => {
                2 * row_scales.len()
                    + residual_scales.as_ref().map_or(0, |r| 2 * r.len())
            }
        }
    }
}

/// A quantized weight group: packed codes + side info + shape.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedGroup {
    pub method: &'static str,
    pub bits: u8,
    pub rows: usize,
    pub cols: usize,
    pub codes: CodePayload,
    pub side: SideInfo,
}

impl QuantizedGroup {
    /// Total payload bits for rate accounting (codes only, paper convention;
    /// side info reported separately — Table 5).
    pub fn payload_bits(&self) -> usize {
        self.rows * self.cols * self.bits as usize
    }

    pub fn side_bytes(&self) -> usize {
        self.side.fp16_bytes()
    }

    /// Reconstruct the full (rows × cols) weight panel.
    pub fn dequantize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        self.dequantize_into(&mut out);
        out
    }

    /// Allocation-light reconstruction into a caller buffer; mirrors the
    /// runtime streaming decoder's math exactly (tested for equality).
    pub fn dequantize_into(&self, out: &mut Mat) {
        assert_eq!((out.rows, out.cols), (self.rows, self.cols));
        let codes = self.codes.unpack();
        match &self.side {
            SideInfo::Uniform { scale, zero } => {
                for (o, &c) in out.data.iter_mut().zip(&codes) {
                    *o = c as f32 * scale + zero;
                }
            }
            SideInfo::Lattice { d, g, mu, scale } => {
                let d = *d;
                let gm = Mat::from_vec(d, d, g.clone());
                let comp = MuLaw::new(*mu);
                let blocks = self.rows * self.cols / d;
                debug_assert_eq!(codes.len(), blocks * d);
                let mut y = vec![0.0f32; d];
                for b in 0..blocks {
                    let z = &codes[b * d..(b + 1) * d];
                    // ŵ = scale · F⁻¹(G (z + ½))
                    for i in 0..d {
                        let mut acc = 0.0f32;
                        let row = gm.row(i);
                        for (j, &zj) in z.iter().enumerate() {
                            acc += row[j] * (zj as f32 + 0.5);
                        }
                        y[i] = scale * comp.inverse(acc);
                    }
                    out.data[b * d..(b + 1) * d].copy_from_slice(&y);
                }
            }
            SideInfo::RotatedLattice { d, scale, sign_seed } => {
                let d = *d;
                let blocks = self.rows * self.cols / d;
                let signs = sign_vector(*sign_seed, d);
                let mut y = vec![0.0f32; d];
                for b in 0..blocks {
                    let z = &codes[b * d..(b + 1) * d];
                    for i in 0..d {
                        y[i] = z[i] as f32 * 0.5; // half-integer E8 grid units
                    }
                    let mut w = hadamard_inverse(&y);
                    for i in 0..d {
                        w[i] *= signs[i] * scale;
                    }
                    out.data[b * d..(b + 1) * d].copy_from_slice(&w);
                }
            }
            SideInfo::Codebook { dim, centers } => {
                let dim = *dim;
                let lo = crate::quant::pack::code_range(self.codes.bits()).0;
                let blocks = self.rows * self.cols / dim;
                for b in 0..blocks {
                    let idx = (codes[b] - lo) as usize;
                    let c = &centers[idx * dim..(idx + 1) * dim];
                    out.data[b * dim..(b + 1) * dim].copy_from_slice(c);
                }
            }
            SideInfo::Trellis { levels, states } => {
                // Stateful TCQ decode (QTIP-lite, baselines::tcq): levels are
                // laid out [subset][j] with 4 Ungerboeck subsets; each b-bit
                // code is (u | j<<1): u drives the state machine, j indexes
                // within the allowed subset. state' = ((state<<1)|u) & (S-1).
                let per = levels.len() / 4;
                let lo = crate::quant::pack::code_range(self.bits).0;
                let smask = states - 1;
                let mut state = 0usize;
                for (o, &c) in out.data.iter_mut().zip(&codes) {
                    let u = ((c - lo) as usize) & 1;
                    let j = ((c - lo) as usize) >> 1;
                    let subset = ((state & 1) << 1) | u;
                    *o = levels[subset * per + j.min(per - 1)];
                    state = ((state << 1) | u) & smask;
                }
            }
            SideInfo::Binary { row_scales, residual_scales } => {
                let lo = crate::quant::pack::code_range(self.bits).0;
                for r in 0..self.rows {
                    let s = row_scales[r];
                    for c in 0..self.cols {
                        let u = (codes[r * self.cols + c] - lo) as u32;
                        // bit0 = primary sign, bit1 = residual sign (BiLLM-lite)
                        let v = if let Some(rs) = residual_scales {
                            let s2 = rs[r];
                            let sign1 = if u & 1 != 0 { 1.0 } else { -1.0 };
                            let sign2 = if u & 2 != 0 { 1.0 } else { -1.0 };
                            s * sign1 + s2 * sign2
                        } else {
                            let sign1 = if u & 1 != 0 { 1.0 } else { -1.0 };
                            s * sign1
                        };
                        out.data[r * self.cols + c] = v;
                    }
                }
            }
        }
    }
}

/// Deterministic ±1 diagonal from a seed (QuIP#-lite randomized rotation).
pub fn sign_vector(seed: u64, d: usize) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..d)
        .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
        .collect()
}

/// In-place fast Walsh-Hadamard transform (normalized by 1/sqrt(d)).
pub fn hadamard(x: &[f32]) -> Vec<f32> {
    let d = x.len();
    assert!(d.is_power_of_two(), "hadamard needs power-of-two dim");
    let mut v = x.to_vec();
    let mut h = 1;
    while h < d {
        for i in (0..d).step_by(h * 2) {
            for j in i..i + h {
                let a = v[j];
                let b = v[j + h];
                v[j] = a + b;
                v[j + h] = a - b;
            }
        }
        h *= 2;
    }
    let norm = 1.0 / (d as f32).sqrt();
    for t in v.iter_mut() {
        *t *= norm;
    }
    v
}

/// Inverse WHT (the normalized transform is an involution).
pub fn hadamard_inverse(x: &[f32]) -> Vec<f32> {
    hadamard(x)
}

/// The quantizer contract. `bits` is the per-weight budget for this group.
pub trait GroupQuantizer {
    /// Quantize a (rows × cols) panel given calibration X (cols × N).
    fn quantize(&self, w: &Mat, x: &Mat, bits: u8) -> QuantizedGroup;

    fn name(&self) -> &'static str;
}

/// Reconstruction objective the paper optimizes (Eq. 5):
/// ||W X − Ŵ X||_F² — the shared metric for comparing methods on a group.
pub fn recon_error(w: &Mat, w_hat: &Mat, x: &Mat) -> f64 {
    let diff = w.sub(w_hat);
    let proj = diff.matmul(x);
    proj.data.iter().map(|v| (*v as f64) * (*v as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::PackedCodes;
    use crate::util::proptest::proptest;

    #[test]
    fn hadamard_is_involution_and_orthonormal() {
        proptest(30, |rig| {
            let d = *rig.choice(&[2usize, 4, 8, 16, 32, 64, 128]);
            let x = rig.vec_normal(d, 1.0);
            let y = hadamard(&x);
            let back = hadamard_inverse(&y);
            let n_in: f32 = x.iter().map(|v| v * v).sum();
            let n_out: f32 = y.iter().map(|v| v * v).sum();
            assert!((n_in - n_out).abs() < 1e-3 * (1.0 + n_in), "not orthonormal");
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn sign_vector_deterministic_and_pm_one() {
        let a = sign_vector(42, 16);
        let b = sign_vector(42, 16);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| *v == 1.0 || *v == -1.0));
        assert_ne!(sign_vector(43, 16), a);
    }

    #[test]
    fn uniform_dequantize() {
        let codes = vec![-2, -1, 0, 1];
        let qg = QuantizedGroup {
            method: "rtn",
            bits: 2,
            rows: 2,
            cols: 2,
            codes: PackedCodes::pack(&codes, 2).into(),
            side: SideInfo::Uniform { scale: 0.5, zero: 0.1 },
        };
        let m = qg.dequantize();
        assert_eq!(m.data, vec![-0.9, -0.4, 0.1, 0.6]);
        assert_eq!(qg.payload_bits(), 8);
        assert_eq!(qg.side_bytes(), 4);
    }

    #[test]
    fn lattice_dequantize_matches_manual_chain() {
        // d=2, G = [[s,0],[0,s]], mu-law inverse applied after G z
        let d = 2;
        let s = 0.04f32;
        let mu = 60.0f32;
        let codes = vec![1, -2, 0, 3];
        let qg = QuantizedGroup {
            method: "glvq",
            bits: 3,
            rows: 1,
            cols: 4,
            codes: PackedCodes::pack(&codes, 3).into(),
            side: SideInfo::Lattice { d, g: vec![s, 0.0, 0.0, s], mu, scale: 0.5 },
        };
        let m = qg.dequantize();
        let c = MuLaw::new(mu);
        let want: Vec<f32> =
            codes.iter().map(|&z| 0.5 * c.inverse(s * (z as f32 + 0.5))).collect();
        for (a, b) in m.data.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(qg.side_bytes(), 2 * 4 + 4);
    }

    #[test]
    fn codebook_dequantize_places_centers() {
        let centers = vec![0.1, 0.2, -0.3, -0.4]; // two centers of dim 2
        // logical indices [1, 0] stored as signed 1-bit codes offset by lo=-1
        let (lo, _) = crate::quant::pack::code_range(1);
        let stored: Vec<i32> = [1i32, 0].iter().map(|&i| i + lo).collect();
        let qg = QuantizedGroup {
            method: "kmeans",
            bits: 1,
            rows: 1,
            cols: 4,
            codes: PackedCodes::pack(&stored, 1).into(),
            side: SideInfo::Codebook { dim: 2, centers: centers.clone() },
        };
        let got = qg.dequantize();
        assert_eq!(got.data, vec![-0.3, -0.4, 0.1, 0.2]);
    }

    #[test]
    fn payload_range_decode_edges_agree_across_variants() {
        // unpack_range_into on both payload variants, at every boundary
        // class a shard slice can land on: chunk starts/ends, the last
        // (partial) chunk, single symbols, and the full span
        let mut rng = crate::util::rng::Rng::new(33);
        let bits = 3u8;
        let (lo, hi) = crate::quant::pack::code_range(bits);
        // 250 codes, chunk_len 64 → chunks [0,64),[64,128),[128,192),[192,250)
        let codes: Vec<i32> = (0..250)
            .map(|_| (rng.below((hi - lo + 1) as usize) as i32) + lo)
            .collect();
        let fixed: CodePayload = PackedCodes::pack(&codes, bits).into();
        let rans = fixed.to_entropy(64, 4);
        assert!(rans.is_entropy());
        let spans: &[(usize, usize)] = &[
            (0, 64),    // exactly chunk 0
            (64, 64),   // exactly chunk 1
            (0, 128),   // two whole chunks
            (63, 2),    // straddles a chunk boundary
            (192, 58),  // exactly the last partial chunk
            (191, 59),  // straddles into the last partial chunk
            (249, 1),   // final symbol
            (0, 250),   // everything
            (10, 0),    // empty
        ];
        for &(start, len) in spans {
            let mut a = vec![0i32; len];
            let mut b = vec![0i32; len];
            fixed.unpack_range_into(start, &mut a);
            rans.unpack_range_into(start, &mut b);
            assert_eq!(a, &codes[start..start + len], "fixed span ({start},{len})");
            assert_eq!(b, &codes[start..start + len], "rans span ({start},{len})");
        }
    }

    #[test]
    fn range_payload_bytes_edges() {
        let codes: Vec<i32> = (0..250).map(|i| (i % 3) - 1).collect();
        let fixed: CodePayload = PackedCodes::pack(&codes, 3).into();
        // fixed payloads are bit-granular
        assert_eq!(fixed.range_payload_bytes(0, 0), 0);
        assert_eq!(fixed.range_payload_bytes(0, 8), 3);
        assert_eq!(fixed.range_payload_bytes(100, 1), 1);
        assert_eq!(fixed.range_payload_bytes(0, 250), fixed.payload_bytes());

        let rans = fixed.to_entropy(64, 4);
        let rc = match &rans {
            CodePayload::Rans(rc) => rc,
            _ => unreachable!(),
        };
        // chunk-granular: a window inside chunk 1 charges chunk 1 only
        assert_eq!(rans.range_payload_bytes(64, 64), rc.chunks[1].payload_bytes());
        assert_eq!(rans.range_payload_bytes(70, 10), rc.chunks[1].payload_bytes());
        // the frequency table is charged with chunk 0
        assert_eq!(
            rans.range_payload_bytes(0, 1),
            rc.chunks[0].payload_bytes() + rc.hist.table_bytes()
        );
        // a boundary-straddling window charges both covering chunks
        assert_eq!(
            rans.range_payload_bytes(63, 2),
            rc.chunks[0].payload_bytes() + rc.chunks[1].payload_bytes() + rc.hist.table_bytes()
        );
        // the last partial chunk charges exactly itself
        assert_eq!(rans.range_payload_bytes(192, 58), rc.chunks[3].payload_bytes());
        assert_eq!(rans.range_payload_bytes(249, 1), rc.chunks[3].payload_bytes());
        // the whole span charges the whole payload, empty charges nothing
        assert_eq!(rans.range_payload_bytes(0, 250), rans.payload_bytes());
        assert_eq!(rans.range_payload_bytes(200, 0), 0);
    }

    #[test]
    fn recon_error_zero_for_exact_reconstruction() {
        let mut rng = crate::util::rng::Rng::new(2);
        let w = Mat::random_normal(4, 6, 0.1, &mut rng);
        let x = Mat::random_normal(6, 10, 1.0, &mut rng);
        assert_eq!(recon_error(&w, &w, &x), 0.0);
        let w2 = w.scale(1.1);
        assert!(recon_error(&w, &w2, &x) > 0.0);
    }
}
