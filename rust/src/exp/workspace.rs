//! Experiment workspace: trained checkpoints, calibration capture, corpora,
//! quantization and evaluation helpers shared by every table driver.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::baselines;
use crate::config::GlvqConfig;
use crate::data::batches::BatchIter;
use crate::data::corpus::{Corpus, Mix};
use crate::data::tokenizer::encode;
use crate::eval::native_fwd::CalibCapture;
use crate::eval::perplexity::{ppl_pjrt, PplResult};
use crate::eval::zeroshot::{self, PjrtScorer};
use crate::glvq::optimizer::GlvqGroupQuantizer;
use crate::glvq::pipeline::{dequantized_store, quantize_model, CalibSet, PipelineOpts};
use crate::model::ModelConfig;
use crate::quant::format::QuantizedModel;
use crate::runtime::exec::{TrainState, TrainStepExec};
use crate::runtime::Engine;
use crate::tensor::TensorStore;
use crate::{info, warnlog};

/// Seeds: training corpus, eval corpora, calibration stream.
pub const TRAIN_SEED: u64 = 42;
pub const EVAL_WIKI_SEED: u64 = 1042;
pub const EVAL_WEB_SEED: u64 = 1043;
pub const CALIB_SEED: u64 = 7;

/// How many eval batches per perplexity measurement (fixed across methods).
pub const EVAL_BATCHES: usize = 12;
/// Zero-shot items per probe task.
pub const ZS_ITEMS: usize = 40;

pub struct Workspace {
    pub engine: Engine,
    pub dir: PathBuf,
    pub results_dir: PathBuf,
    calib_cache: BTreeMap<String, CalibSet>,
    store_cache: BTreeMap<String, TensorStore>,
    eval_tokens: BTreeMap<Mix, Vec<i32>>,
    quant_cache: BTreeMap<String, (QuantizedModel, TensorStore)>,
}

impl Workspace {
    pub fn new(artifacts: &str, dir: &str) -> Result<Workspace> {
        let engine = Engine::new(std::path::Path::new(artifacts))?;
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let results_dir = dir.join("results");
        std::fs::create_dir_all(&results_dir)?;
        Ok(Workspace {
            engine,
            dir,
            results_dir,
            calib_cache: BTreeMap::new(),
            store_cache: BTreeMap::new(),
            eval_tokens: BTreeMap::new(),
            quant_cache: BTreeMap::new(),
        })
    }

    pub fn model_cfg(&self, model: &str) -> Result<ModelConfig> {
        Ok(self
            .engine
            .models
            .get(model)
            .with_context(|| format!("artifacts missing model {model}"))?
            .config)
    }

    /// Held-out eval token stream for a mix (cached).
    pub fn eval_tokens(&mut self, mix: Mix) -> &[i32] {
        self.eval_tokens.entry(mix).or_insert_with(|| {
            let seed = if mix == Mix::Wiki { EVAL_WIKI_SEED } else { EVAL_WEB_SEED };
            encode(&Corpus::new(mix, seed).generate(1 << 18))
        })
    }

    /// Train a model through the AOT train-step artifact (or load the cached
    /// checkpoint). Returns the trained store; loss curve is written next to
    /// the checkpoint.
    pub fn trained(&mut self, model: &str, steps: usize, lr: f32) -> Result<TensorStore> {
        if let Some(s) = self.store_cache.get(model) {
            return Ok(s.clone());
        }
        let path = self.dir.join(format!("model_{model}.gten"));
        if path.exists() {
            let store = TensorStore::load(&path)?;
            self.store_cache.insert(model.to_string(), store.clone());
            return Ok(store);
        }
        let cfg = self.model_cfg(model)?;
        info!("training model {model} for {steps} steps (lr={lr})");
        let corpus = Corpus::new(Mix::Wiki, TRAIN_SEED).generate(1 << 21);
        let tokens = encode(&corpus);
        let init = crate::model::init_params(&cfg, 0);
        let exec = TrainStepExec::new(&self.engine, model)?;
        let mut state = TrainState::from_store(&self.engine, model, &init)?;
        let mut it = BatchIter::new(&tokens, cfg.batch_train, cfg.seq_len, TRAIN_SEED, true);
        let mut curve: Vec<(usize, f32)> = Vec::new();
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let (x, y) = it.next_batch().context("corpus exhausted")?;
            // cosine-decayed lr with short warmup
            let warm = (step as f32 / 20.0).min(1.0);
            let cos = 0.5 * (1.0 + (std::f32::consts::PI * step as f32 / steps as f32).cos());
            let lr_t = lr * warm * (0.1 + 0.9 * cos);
            let loss = exec.step(&mut state, lr_t, &x, &y)?;
            if step % 20 == 0 || step + 1 == steps {
                info!("  step {step:4} loss {loss:.4} ({:.1}s)", t0.elapsed().as_secs_f64());
                curve.push((step, loss));
            }
        }
        let store = state.to_store()?;
        store.save(&path)?;
        let curve_txt: String = curve
            .iter()
            .map(|(s, l)| format!("{s}\t{l}\n"))
            .collect();
        std::fs::write(self.dir.join(format!("model_{model}.loss.tsv")), curve_txt)?;
        self.store_cache.insert(model.to_string(), store.clone());
        Ok(store)
    }

    /// Default training budget per model size.
    pub fn default_steps(model: &str) -> usize {
        match model {
            "s" => 400,
            "m" => 250,
            _ => 150,
        }
    }

    pub fn trained_default(&mut self, model: &str) -> Result<TensorStore> {
        self.trained(model, Self::default_steps(model), 3e-3)
    }

    /// Calibration activations captured by the native forward on a fresh
    /// calibration stream (cached per model+budget).
    pub fn calibration(&mut self, model: &str, n_cols: usize) -> Result<CalibSet> {
        let key = format!("{model}:{n_cols}");
        if let Some(c) = self.calib_cache.get(&key) {
            return Ok(c.clone());
        }
        let cfg = self.model_cfg(model)?;
        let store = self.trained_default(model)?;
        let corpus = Corpus::new(Mix::Wiki, CALIB_SEED).generate(1 << 17);
        let tokens = encode(&corpus);
        let mut cap = CalibCapture::new(n_cols, CALIB_SEED);
        let mut it = BatchIter::new(&tokens, cfg.batch_eval, cfg.seq_len, CALIB_SEED, true);
        // enough batches to fill the reservoir a few times over
        let batches = (2 * n_cols).div_ceil(cfg.batch_eval * cfg.seq_len).max(2);
        for _ in 0..batches {
            let (x, _) = it.next_batch().context("calib exhausted")?;
            crate::eval::native_fwd::forward(&cfg, &store, &x, cfg.batch_eval, Some(&mut cap))?;
        }
        let calib = cap.into_calib_set();
        self.calib_cache.insert(key, calib.clone());
        Ok(calib)
    }

    /// Build a GLVQ quantizer for a method string like "glvq-8d",
    /// "glvq-32d", "glvq-8d-u"; None if the name is a baseline.
    pub fn glvq_for(method: &str, bits: f64, group_size: usize) -> Option<(GlvqGroupQuantizer, bool)> {
        let cfg = GlvqConfig::preset(method).ok()?;
        let mut cfg = cfg;
        cfg.target_bits = bits;
        cfg.group_size = group_size;
        cfg.iters = 32;
        let bit_alloc = cfg.bit_allocation;
        Some((GlvqGroupQuantizer::new(cfg), bit_alloc))
    }

    /// Cache key for a quantization request (shared by the two entry
    /// points below so container-only and full requests reuse each other).
    fn quant_key(model: &str, method: &str, bits: f64, opts: &Option<PipelineOpts>) -> String {
        let gs = opts.as_ref().map_or(128, |o| o.group_size);
        let entropy = opts.as_ref().is_some_and(|o| o.entropy);
        format!("{model}:{method}:{bits}:{gs}:{entropy}")
    }

    /// Quantize a trained model with a named method at a bit target.
    /// Method names: glvq-8d / glvq-16d / glvq-32d / glvq-*-u / any
    /// baselines::by_name key. Returns (container, dequantized store).
    pub fn quantize(
        &mut self,
        model: &str,
        method: &str,
        bits: f64,
        opts_override: Option<PipelineOpts>,
    ) -> Result<(QuantizedModel, TensorStore)> {
        let key = Self::quant_key(model, method, bits, &opts_override);
        if let Some(hit) = self.quant_cache.get(&key) {
            return Ok(hit.clone());
        }
        let t0 = std::time::Instant::now();
        let (qm, report) = self.quantize_pipeline(model, method, bits, opts_override)?;
        if report.tensors.is_empty() {
            warnlog!("{method}: no tensors quantized");
        }
        let store = self.trained_default(model)?;
        let dq = dequantized_store(&qm, &store);
        info!(
            "quantized {model} with {method}@{bits}b: avg_bits={:.3} err={:.2} ({:.1}s)",
            qm.avg_bits(),
            report.total_recon_error(),
            t0.elapsed().as_secs_f64()
        );
        self.quant_cache.insert(key, (qm.clone(), dq.clone()));
        Ok((qm, dq))
    }

    /// Quantize to the compressed container **only** — the
    /// `serve --streaming` path. Unlike [`Workspace::quantize`], no dense
    /// dequantized copy of the model is built or cached, so peak memory
    /// stays at weights-compressed + activations end to end.
    pub fn quantize_container(
        &mut self,
        model: &str,
        method: &str,
        bits: f64,
        opts_override: Option<PipelineOpts>,
    ) -> Result<QuantizedModel> {
        let key = Self::quant_key(model, method, bits, &opts_override);
        if let Some((qm, _)) = self.quant_cache.get(&key) {
            return Ok(qm.clone());
        }
        let t0 = std::time::Instant::now();
        let (qm, report) = self.quantize_pipeline(model, method, bits, opts_override)?;
        if report.tensors.is_empty() {
            warnlog!("{method}: no tensors quantized");
        }
        info!(
            "quantized {model} with {method}@{bits}b (container only): avg_bits={:.3} err={:.2} ({:.1}s)",
            qm.avg_bits(),
            report.total_recon_error(),
            t0.elapsed().as_secs_f64()
        );
        Ok(qm)
    }

    /// Shared pipeline body: train/load, calibrate, dispatch to the named
    /// quantizer. No caching, no dequantized store.
    fn quantize_pipeline(
        &mut self,
        model: &str,
        method: &str,
        bits: f64,
        opts_override: Option<PipelineOpts>,
    ) -> Result<(QuantizedModel, crate::glvq::pipeline::PipelineReport)> {
        let cfg = self.model_cfg(model)?;
        let store = self.trained_default(model)?;
        let calib = self.calibration(model, 192)?;
        let specs = cfg.param_specs();
        let mut opts = opts_override.unwrap_or_default();
        opts.target_bits = bits;

        let (qm, report) = if let Some((q, bit_alloc)) = Self::glvq_for(method, bits, opts.group_size) {
            opts.bit_allocation = bit_alloc && opts.bit_allocation;
            quantize_model(&specs, &store, &calib, &q, &opts)?
        } else if method.starts_with("glvq-fixed") {
            // Table-7 ablation: shared fixed lattice
            let mut c = GlvqConfig::default();
            c.lattice_dim = 8;
            c.group_size = opts.group_size;
            c.adaptive_lattice = false;
            c.target_bits = bits;
            c.iters = 32;
            let q = GlvqGroupQuantizer::new(c);
            quantize_model(&specs, &store, &calib, &q, &opts)?
        } else if method == "glvq-8d-nocompand" {
            // Table-8 ablation: fixed global μ
            let mut c = GlvqConfig::default();
            c.lattice_dim = 8;
            c.group_size = opts.group_size;
            c.adaptive_companding = false;
            c.target_bits = bits;
            c.iters = 32;
            let q = GlvqGroupQuantizer::new(c);
            quantize_model(&specs, &store, &calib, &q, &opts)?
        } else if method == "glvq-8d-gcd" {
            // Table-12/13 ablation: GCD assignment
            let mut c = GlvqConfig::default();
            c.lattice_dim = 8;
            c.group_size = opts.group_size;
            c.assignment = crate::config::Assignment::Gcd;
            c.target_bits = bits;
            c.iters = 32;
            let q = GlvqGroupQuantizer::new(c);
            quantize_model(&specs, &store, &calib, &q, &opts)?
        } else {
            let q = baselines::by_name(method)
                .with_context(|| format!("unknown method {method}"))?;
            opts.bit_allocation = false; // baselines use uniform allocation
            quantize_model(&specs, &store, &calib, &*q, &opts)?
        };
        Ok((qm, report))
    }

    /// Calibration with an explicit column budget (Table-11 sweep).
    pub fn calibration_sized(&mut self, model: &str, n_cols: usize) -> Result<CalibSet> {
        self.calibration(model, n_cols)
    }

    /// Quantize against an explicit calibration set (bypasses the quantized-
    /// model cache — used by the calibration-size sweep).
    pub fn quantize_with_calib(
        &mut self,
        model: &str,
        method: &str,
        bits: f64,
        calib: &CalibSet,
    ) -> Result<(QuantizedModel, TensorStore)> {
        let cfg = self.model_cfg(model)?;
        let store = self.trained_default(model)?;
        let specs = cfg.param_specs();
        let mut opts = PipelineOpts::default();
        opts.target_bits = bits;
        let (q, bit_alloc) = Self::glvq_for(method, bits, opts.group_size)
            .with_context(|| format!("{method} is not a GLVQ preset"))?;
        opts.bit_allocation = bit_alloc;
        let (qm, _) = quantize_model(&specs, &store, calib, &q, &opts)?;
        let dq = dequantized_store(&qm, &store);
        Ok((qm, dq))
    }

    /// Perplexity of a (possibly quantized) store through PJRT ForwardLoss.
    pub fn ppl(&mut self, model: &str, store: &TensorStore, mix: Mix) -> Result<PplResult> {
        let tokens = self.eval_tokens(mix).to_vec();
        ppl_pjrt(&self.engine, model, store, &tokens, EVAL_BATCHES)
    }

    /// Zero-shot probe accuracies (task name → %).
    pub fn zeroshot(&mut self, model: &str, store: &TensorStore) -> Result<Vec<(String, f64)>> {
        let vocab = crate::data::corpus::Vocabulary::build(1);
        let tasks = zeroshot::gen_all_tasks(&vocab, ZS_ITEMS, 11);
        let mut scorer = PjrtScorer::new(&self.engine, model, store)?;
        let mut out = Vec::new();
        for (name, items) in tasks {
            let acc = zeroshot::eval_task(&mut scorer, &items)?;
            out.push((name, acc));
        }
        Ok(out)
    }

    /// Write a result blob under results/.
    pub fn write_result(&self, id: &str, text: &str) -> Result<()> {
        let path = self.results_dir.join(format!("{id}.txt"));
        std::fs::write(&path, text)?;
        info!("wrote {}", path.display());
        Ok(())
    }
}
