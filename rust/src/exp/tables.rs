//! Table drivers — each regenerates one table of the paper's evaluation
//! (same rows/series; our substrate is the S/M synthetic-corpus models, so
//! the claim is the *shape*, not the absolute numbers — DESIGN.md §3/§5).
//!
//! Method name mapping (ours → paper row):
//!   rtn              → vanilla RTN floor
//!   omniquant_lite   → OmniQ
//!   gptq             → GPTQ (the strongest uniform scalar method)
//!   kmeans_vq        → AQLM (free-form VQ with lookup decode)
//!   quip_lite        → QuIP# (Hadamard + fixed E8)
//!   tcq              → QTIP (trellis-coded)
//!   binary           → OneBit (1-bit sign+scale)
//!   binary_residual  → BiLLM-lite (2-bit residual binarization; the paper's
//!                      BiLLM is ~1.1 b — ours is the same mechanism at 2 b,
//!                      reported at its true rate)
//!   glvq-8d/-32d(-u) → GLVQ variants

use anyhow::Result;

use crate::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use crate::linalg::Mat;
use crate::data::corpus::Mix;
use crate::glvq::pipeline::PipelineOpts;
use crate::info;
use crate::util::rng::Rng;

use super::workspace::Workspace;

/// Simple fixed-width table printer (also returned as the result text).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self, title: &str) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let mut out = format!("## {title}\n");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

fn f2(v: f64) -> String {
    format!("{v:.2}")
}

fn f1(v: f64) -> String {
    format!("{v:.1}")
}

const T1_METHODS: &[&str] = &["rtn", "omniquant_lite", "gptq", "quip_lite", "tcq", "glvq-8d", "glvq-32d"];
const T2_METHODS: &[&str] = &["omniquant_lite", "gptq", "kmeans_vq", "quip_lite", "tcq", "glvq-8d"];
pub const T1_MODELS: &[&str] = &["s", "m"];

/// Table 1: 2-bit perplexity across model sizes and both eval mixes.
pub fn table1(ws: &mut Workspace) -> Result<String> {
    let mut t = Table::new(&["Method", "Bits", "wiki-S", "wiki-M", "web-S", "web-M"]);
    // FP16 reference row
    let mut fp16 = vec!["FP16".to_string(), "16".to_string()];
    for mix in [Mix::Wiki, Mix::Web] {
        for model in T1_MODELS {
            let store = ws.trained_default(model)?;
            fp16.push(f2(ws.ppl(model, &store, mix)?.ppl));
        }
    }
    t.row(fp16);
    for method in T1_METHODS {
        let mut row = vec![method.to_string(), "2".to_string()];
        for mix in [Mix::Wiki, Mix::Web] {
            for model in T1_MODELS {
                let (_, dq) = ws.quantize(model, method, 2.0, None)?;
                row.push(f2(ws.ppl(model, &dq, mix)?.ppl));
            }
        }
        t.row(row);
    }
    let text = t.render("Table 1: perplexity (2-bit), wiki + web mixes, S/M models");
    ws.write_result("table1", &text)?;
    Ok(text)
}

/// Table 2: zero-shot probe accuracy at 4/3/2 bits.
pub fn table2(ws: &mut Workspace) -> Result<String> {
    let mut t = Table::new(&["Model", "Method", "Bits", "BracketC", "BigramE", "Plaus", "Induct"]);
    for model in T1_MODELS {
        let store = ws.trained_default(model)?;
        let mut row = vec![model.to_string(), "FP16".into(), "16".into()];
        for (_, acc) in ws.zeroshot(model, &store)? {
            row.push(f1(acc));
        }
        t.row(row);
        for bits in [4.0, 3.0, 2.0] {
            for method in T2_METHODS {
                let (_, dq) = ws.quantize(model, method, bits, None)?;
                let mut row = vec![model.to_string(), method.to_string(), format!("{bits}")];
                for (_, acc) in ws.zeroshot(model, &dq)? {
                    row.push(f1(acc));
                }
                t.row(row);
            }
        }
    }
    let text = t.render("Table 2: zero-shot probe accuracy (acc %, LM-score forced choice)");
    ws.write_result("table2", &text)?;
    Ok(text)
}

/// Table 3: fractional and sub-2-bit rates.
pub fn table3(ws: &mut Workspace) -> Result<String> {
    let mut t = Table::new(&["Method", "Bits", "ppl-S", "ppl-M", "Δ to GLVQ"]);
    let rows: &[(&str, f64)] = &[
        ("binary", 1.0),      // OneBit-lite
        ("glvq-8d", 1.0),     // GLVQ 1.0 bit (uniform 1-bit groups)
        ("binary_residual", 2.0), // BiLLM-lite (true rate 2.0)
        ("glvq-8d", 1.5),     // GLVQ 1.5 bit (SDBA 1/2 mix)
        ("rtn", 2.0),         // 2-bit uniform reference
        ("glvq-8d", 2.0),
    ];
    let mut glvq_at: std::collections::BTreeMap<String, f64> = Default::default();
    let mut measured: Vec<(String, f64, f64, f64)> = Vec::new();
    for (method, bits) in rows {
        let mut ppls = [0.0f64; 2];
        for (i, model) in T1_MODELS.iter().enumerate() {
            let (qm, dq) = ws.quantize(model, method, *bits, None)?;
            ppls[i] = ws.ppl(model, &dq, Mix::Wiki)?.ppl;
            if *method == "glvq-8d" {
                glvq_at.insert(format!("{model}:{bits}"), ppls[i]);
            }
            let _ = qm;
        }
        measured.push((method.to_string(), *bits, ppls[0], ppls[1]));
    }
    for (method, bits, p_s, p_m) in measured {
        let anchor = glvq_at
            .get(&format!("s:{}", if bits <= 1.0 { 1.0 } else if bits <= 1.5 { 1.5 } else { 2.0 }))
            .copied()
            .unwrap_or(p_s);
        let delta = p_s - anchor;
        t.row(vec![method, format!("{bits}"), f2(p_s), f2(p_m), format!("{delta:+.2}")]);
    }
    let text = t.render("Table 3: fractional / sub-2-bit rates (wiki ppl)");
    ws.write_result("table3", &text)?;
    Ok(text)
}

/// Table 4: decode throughput (TOK/s proxy), bytes-moved bandwidth model,
/// and 2-bit perplexity — the accuracy/efficiency trade-off.
pub fn table4(ws: &mut Workspace) -> Result<String> {
    let model = "m";
    let methods: &[&str] = &[
        "rtn",
        "gptq",
        "kmeans_vq",
        "quip_lite",
        "tcq",
        "glvq-8d-u",
        "glvq-32d-u",
        "glvq-8d",
        "glvq-32d",
    ];
    let mut t = Table::new(&["Method", "TOK/s", "MB/tok", "GB/s(model)", "ppl(2bit)"]);
    let cfg = ws.model_cfg(model)?;
    let mut rng = Rng::new(5);
    for method in methods {
        let (qm, dq) = ws.quantize(model, method, 2.0, None)?;
        let ppl = ws.ppl(model, &dq, Mix::Wiki)?.ppl;
        // one "token" = one streaming decode-matmul pass through every
        // quantized tensor (the dequant-GEMV workload of autoregressive
        // decode), driven by the same batched engine the serving path uses
        // (single thread, batch 1: the per-method apples-to-apples setting)
        let sm = StreamingMatmul::new(16, 1);
        let reps = 20usize;
        let mut stats = DecodeStats::default();
        let inputs: Vec<Mat> = qm
            .tensors
            .iter()
            .map(|qt| Mat::random_normal(1, qt.cols, 1.0, &mut rng))
            .collect();
        let mut outs: Vec<Mat> = qm.tensors.iter().map(|qt| Mat::zeros(1, qt.rows)).collect();
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            for (i, qt) in qm.tensors.iter().enumerate() {
                sm.matmul(qt, &inputs[i], &mut outs[i], &mut stats);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let tok_s = reps as f64 / secs;
        let bytes_per_tok = stats.total_bytes() as f64 / reps as f64;
        let gbs = bytes_per_tok * tok_s / 1e9;
        t.row(vec![
            method.to_string(),
            f1(tok_s),
            format!("{:.3}", bytes_per_tok / 1e6),
            format!("{gbs:.3}"),
            f2(ppl),
        ]);
        let _ = cfg;
    }
    let text =
        t.render("Table 4: streaming decode throughput + bytes-moved bandwidth (model M, 2-bit)");
    ws.write_result("table4", &text)?;
    Ok(text)
}

/// Table 5: side-information overhead — analytic Eq. 27 vs measured, plus
/// the measured-with-entropy column: the same container with the rANS
/// backend (`--entropy`), whose payload shrinks to the codes' empirical
/// entropy while the side info stays fixed.
pub fn table5(ws: &mut Workspace) -> Result<String> {
    let mut t = Table::new(&[
        "d",
        "m_g",
        "n_g",
        "b=2 (%)",
        "b=3 (%)",
        "b=4 (%)",
        "measured (%)",
        "w/entropy (%)",
        "code save (%)",
    ]);
    for &d in &[8usize, 16, 32] {
        for &ng in &[128usize, 256] {
            let mg = 4096usize;
            let mut cells = vec![d.to_string(), mg.to_string(), ng.to_string()];
            for &b in &[2usize, 3, 4] {
                // Eq. 27 with our +2-byte scale deviation: (16d²+32+16)/(m n b)
                let oh = (16.0 * (d * d) as f64 + 48.0) / ((mg * ng * b) as f64) * 100.0;
                cells.push(format!("{oh:.3}"));
            }
            // measured from a real container (model s, glvq at this d, 2-bit)
            let method = match d {
                8 => "glvq-8d",
                16 => "glvq-16d",
                _ => "glvq-32d",
            };
            let (qm, _) = ws.quantize("s", method, 2.0, None)?;
            let (payload, side) = qm.size_bytes();
            cells.push(format!("{:.3}", side as f64 / payload as f64 * 100.0));
            // measured-with-entropy: same codes, rANS-coded payload
            // (.glvq v2). Re-encoding the cached container is lossless and
            // avoids a second full quantization run.
            let mut qme = qm.clone();
            for tensor in &mut qme.tensors {
                for (_, _, g) in &mut tensor.groups {
                    g.codes = g.codes.to_entropy(
                        crate::glvq::pipeline::entropy_chunk_len(g.cols),
                        crate::entropy::DEFAULT_LANES,
                    );
                }
            }
            let (payload_e, side_e) = qme.size_bytes();
            cells.push(format!("{:.3}", side_e as f64 / payload_e.max(1) as f64 * 100.0));
            let fixed = qme.fixed_payload_bytes().max(1);
            cells.push(format!("{:.1}", 100.0 * (1.0 - payload_e as f64 / fixed as f64)));
            t.row(cells);
        }
    }
    let text = t.render(
        "Table 5: side-info overhead, analytic (Eq. 27) vs measured container (fixed + entropy-coded payloads)",
    );
    ws.write_result("table5", &text)?;
    Ok(text)
}

/// Generic two-variant ablation over bits × models (Tables 6, 7, 8).
fn ablation_table(
    ws: &mut Workspace,
    id: &str,
    title: &str,
    with: (&str, &str),
    without: (&str, &str),
) -> Result<String> {
    let mut t = Table::new(&["Variant", "Bits", "ppl-S", "ppl-M"]);
    for bits in [2.0, 3.0, 4.0] {
        for (label, method) in [with, without] {
            let mut row = vec![label.to_string(), format!("{bits}")];
            for model in T1_MODELS {
                let (_, dq) = ws.quantize(model, method, bits, None)?;
                row.push(f2(ws.ppl(model, &dq, Mix::Wiki)?.ppl));
            }
            t.row(row);
        }
    }
    let text = t.render(title);
    ws.write_result(id, &text)?;
    Ok(text)
}

/// Table 6: SDBA bit allocation on/off.
pub fn table6(ws: &mut Workspace) -> Result<String> {
    ablation_table(
        ws,
        "table6",
        "Table 6: salience-determined bit allocation ablation (wiki ppl)",
        ("glvq-8d (SDBA)", "glvq-8d"),
        ("glvq-8d-u (uniform)", "glvq-8d-u"),
    )
}

/// Table 7: adaptive vs fixed (shared) lattice.
pub fn table7(ws: &mut Workspace) -> Result<String> {
    ablation_table(
        ws,
        "table7",
        "Table 7: adaptive vs fixed lattice basis (wiki ppl)",
        ("glvq-8d (adaptive)", "glvq-8d"),
        ("glvq-8d (fixed)", "glvq-fixed"),
    )
}

/// Table 8: group-specific companding on/off.
pub fn table8(ws: &mut Workspace) -> Result<String> {
    ablation_table(
        ws,
        "table8",
        "Table 8: group-specific mu-law companding ablation (wiki ppl)",
        ("glvq-8d (companding)", "glvq-8d"),
        ("glvq-8d (fixed mu)", "glvq-8d-nocompand"),
    )
}

/// Tables 9+10: group-size sweep on model S, both eval mixes.
pub fn table9(ws: &mut Workspace) -> Result<String> {
    let mut t = Table::new(&["GroupSize", "2b wiki", "3b wiki", "4b wiki", "2b web", "3b web", "4b web", "side/payload %"]);
    for &gs in &[32usize, 64, 128, 256, 512] {
        let mut row = vec![gs.to_string()];
        let mut overhead = 0.0f64;
        for mix in [Mix::Wiki, Mix::Web] {
            for bits in [2.0, 3.0, 4.0] {
                let opts = PipelineOpts { group_size: gs, target_bits: bits, ..Default::default() };
                let (qm, dq) = ws.quantize("s", "glvq-8d", bits, Some(opts))?;
                row.push(f2(ws.ppl("s", &dq, mix)?.ppl));
                if bits == 2.0 && mix == Mix::Wiki {
                    let (payload, side) = qm.size_bytes();
                    overhead = side as f64 / payload as f64 * 100.0;
                }
            }
        }
        row.push(format!("{overhead:.2}"));
        t.row(row);
    }
    let text = t.render("Tables 9+10: group-size sweep (GLVQ-8D, model S)");
    ws.write_result("table9", &text)?;
    Ok(text)
}

/// Table 11: calibration-size sweep (columns captured per group).
pub fn table11(ws: &mut Workspace) -> Result<String> {
    let mut t = Table::new(&["CalibCols", "ppl-S wiki", "ppl-S web"]);
    for &n in &[16usize, 32, 64, 128, 192, 256] {
        // calibration size flows through the capture budget
        let calib = ws.calibration_sized("s", n)?;
        let (_, dq) = ws.quantize_with_calib("s", "glvq-8d", 2.0, &calib)?;
        let w = ws.ppl("s", &dq, Mix::Wiki)?.ppl;
        let c = ws.ppl("s", &dq, Mix::Web)?.ppl;
        t.row(vec![n.to_string(), f2(w), f2(c)]);
    }
    let text = t.render("Table 11: calibration-set size sweep (GLVQ-8D 2-bit, model S)");
    ws.write_result("table11", &text)?;
    Ok(text)
}

/// Tables 12+13: Babai vs GCD (ppl + zero-shot).
pub fn table12(ws: &mut Workspace) -> Result<String> {
    let mut t = Table::new(&[
        "Assignment", "Bits", "ppl-S", "ppl-M", "BracketC", "BigramE", "Plaus", "Induct",
    ]);
    for bits in [4.0, 3.0, 2.0] {
        for (label, method) in [("babai", "glvq-8d"), ("gcd", "glvq-8d-gcd")] {
            let mut row = vec![label.to_string(), format!("{bits}")];
            for model in T1_MODELS {
                let (_, dq) = ws.quantize(model, method, bits, None)?;
                row.push(f2(ws.ppl(model, &dq, Mix::Wiki)?.ppl));
            }
            let (_, dq) = ws.quantize("s", method, bits, None)?;
            for (_, acc) in ws.zeroshot("s", &dq)? {
                row.push(f1(acc));
            }
            t.row(row);
        }
    }
    let text = t.render("Tables 12+13: Babai rounding vs greedy coordinate descent");
    ws.write_result("table12", &text)?;
    Ok(text)
}

/// Run one table by id ("table1".."table13", "all").
pub fn run(ws: &mut Workspace, id: &str) -> Result<()> {
    let run_one = |ws: &mut Workspace, id: &str| -> Result<String> {
        match id {
            "table1" => table1(ws),
            "table2" => table2(ws),
            "table3" => table3(ws),
            "table4" => table4(ws),
            "table5" => table5(ws),
            "table6" => table6(ws),
            "table7" => table7(ws),
            "table8" => table8(ws),
            "table9" | "table10" => table9(ws),
            "table11" => table11(ws),
            "table12" | "table13" => table12(ws),
            _ => anyhow::bail!("unknown table id {id}"),
        }
    };
    if id == "all" {
        for id in [
            "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8",
            "table9", "table11", "table12",
        ] {
            info!("=== running {id} ===");
            let text = run_one(ws, id)?;
            println!("{text}");
        }
    } else {
        let text = run_one(ws, id)?;
        println!("{text}");
    }
    Ok(())
}
