//! Experiment drivers: one function per paper table (DESIGN.md §5).
//!
//! Shared workflow: train (or load) a checkpoint through the AOT train-step
//! artifact → capture calibration activations with the native forward →
//! quantize with each method → evaluate perplexity (PJRT ForwardLoss) and
//! zero-shot probes (PJRT Logits) → print the table and write
//! `results/<id>.txt`.

pub mod tables;
pub mod workspace;

pub use workspace::Workspace;
