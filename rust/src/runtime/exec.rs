//! Typed executors over the AOT artifacts + Tensor⇄Literal conversion.
//!
//! Executable signatures (fixed by aot.py, P = number of params):
//!   train_step:   (P params, P m, P v, t, lr, x[Bt,T], y[Bt,T])
//!                 → (loss, P params', P m', P v')
//!   forward_loss: (P params, x[Be,T], y[Be,T]) → (nll_sum,)
//!   logits:       (P params, x[1,T]) → (logits[1,T,V],)
//!   glvq step:    (w[R,n], x[n,N], g, ginv, mu, g0) → (loss, dG, dμ)
//!   glvq encode:  (w[R,n], ginv, mu) → (z[R,n/d,d],)
//!   glvq decode:  (z[R,n/d,d], g, mu) → (w_hat[R,n],)

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;
use crate::runtime::engine::Engine;
use crate::tensor::{Tensor, TensorStore};

/// f32 tensor → device literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

/// (batch, seq) token ids → i32 literal.
pub fn tokens_to_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    if tokens.len() != batch * seq {
        bail!("token count {} != {}x{}", tokens.len(), batch, seq);
    }
    Ok(xla::Literal::vec1(tokens).reshape(&[batch as i64, seq as i64])?)
}

pub fn literal_to_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// A device buffer paired with the host literal it was uploaded from.
/// `BufferFromHostLiteral` copies asynchronously, so the literal must stay
/// alive until an execution consuming the buffer has synchronized — holding
/// both together makes that invariant structural. Buffers/literals are
/// freed on Drop; this replaces the crate's literal-based `execute`, whose
/// internal conversions leak (~3.4 MB/call measured for model S — see
/// EXPERIMENTS.md §Perf).
pub struct StagedBuf {
    pub buf: xla::PjRtBuffer,
    _lit: xla::Literal,
}

/// Upload a literal to a device buffer (takes ownership to pin the host
/// memory for the async transfer).
pub fn to_buffer(client: &xla::PjRtClient, lit: xla::Literal) -> Result<StagedBuf> {
    let buf = client.buffer_from_host_literal(None, &lit)?;
    Ok(StagedBuf { buf, _lit: lit })
}

/// Run a buffer-argument execution and return the first output as a
/// decomposed tuple of literals. `to_literal_sync` synchronizes, so by the
/// time this returns the input transfers have completed and the callers'
/// StagedBufs may be dropped.
fn run_b(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<Vec<xla::Literal>> {
    let mut result = exe.execute_b::<&xla::PjRtBuffer>(args)?[0][0].to_literal_sync()?;
    Ok(result.decompose_tuple()?)
}

/// Training state that lives as device literals between steps (no
/// per-step Tensor conversion of the full parameter set).
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    pub step: usize,
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
}

impl TrainState {
    /// Initialize from a parameter store (Adam moments zeroed).
    pub fn from_store(engine: &Engine, model: &str, store: &TensorStore) -> Result<TrainState> {
        let arts = engine.models.get(model).context("unknown model")?;
        let mut params = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        for (name, shape, _) in &arts.params {
            let t = store
                .get(name)
                .with_context(|| format!("store missing {name}"))?;
            if &t.shape != shape {
                bail!("{name}: shape {:?} != manifest {:?}", t.shape, shape);
            }
            params.push(tensor_to_literal(t)?);
            let zeros = Tensor::zeros(shape);
            m.push(tensor_to_literal(&zeros)?);
            v.push(tensor_to_literal(&zeros)?);
            names.push(name.clone());
            shapes.push(shape.clone());
        }
        Ok(TrainState { params, m, v, step: 0, names, shapes })
    }

    /// Export current parameters back to a TensorStore.
    pub fn to_store(&self) -> Result<TensorStore> {
        let mut store = TensorStore::new();
        for ((lit, name), shape) in self.params.iter().zip(&self.names).zip(&self.shapes) {
            let data = literal_to_f32s(lit)?;
            store.insert(name, Tensor::from_vec(shape, data));
        }
        Ok(store)
    }
}

/// The train-step executor.
pub struct TrainStepExec {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub seq: usize,
}

impl TrainStepExec {
    pub fn new(engine: &Engine, model: &str) -> Result<TrainStepExec> {
        let arts = engine.models.get(model).context("unknown model")?;
        let file = engine.model_program(model, "train_step")?;
        Ok(TrainStepExec {
            exe: engine.load(&file)?,
            batch: arts.config.batch_train,
            seq: arts.config.seq_len,
        })
    }

    /// One optimizer step; updates `state` in place, returns the loss.
    pub fn step(&self, state: &mut TrainState, lr: f32, x: &[i32], y: &[i32]) -> Result<f32> {
        let p = state.params.len();
        state.step += 1;
        let client = self.exe.client().clone();
        let mut bufs: Vec<StagedBuf> = Vec::with_capacity(3 * p + 4);
        // state literals are cloned into the staged pairs (host-side copy)
        for lit in state.params.iter().chain(&state.m).chain(&state.v) {
            bufs.push(to_buffer(&client, lit.clone())?);
        }
        bufs.push(to_buffer(&client, scalar_literal(state.step as f32))?);
        bufs.push(to_buffer(&client, scalar_literal(lr))?);
        bufs.push(to_buffer(&client, tokens_to_literal(x, self.batch, self.seq)?)?);
        bufs.push(to_buffer(&client, tokens_to_literal(y, self.batch, self.seq)?)?);
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|b| &b.buf).collect();
        let mut tup = run_b(&self.exe, &refs)?;
        if tup.len() != 1 + 3 * p {
            bail!("train_step returned {} outputs, expected {}", tup.len(), 1 + 3 * p);
        }
        let loss = tup[0].get_first_element::<f32>()?;
        let rest: Vec<xla::Literal> = tup.drain(1..).collect();
        let mut it = rest.into_iter();
        state.params = it.by_ref().take(p).collect();
        state.m = it.by_ref().take(p).collect();
        state.v = it.by_ref().take(p).collect();
        Ok(loss)
    }
}

/// The forward-loss (NLL sum) executor for perplexity evaluation.
pub struct ForwardLossExec {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub seq: usize,
    param_names: Vec<String>,
}

impl ForwardLossExec {
    pub fn new(engine: &Engine, model: &str) -> Result<ForwardLossExec> {
        let arts = engine.models.get(model).context("unknown model")?;
        let file = engine.model_program(model, "forward_loss")?;
        Ok(ForwardLossExec {
            exe: engine.load(&file)?,
            batch: arts.config.batch_eval,
            seq: arts.config.seq_len,
            param_names: arts.params.iter().map(|(n, _, _)| n.clone()).collect(),
        })
    }

    /// Upload the parameter set to device buffers once; reuse across
    /// eval batches (leak-free: buffers are dropped when the Vec drops).
    pub fn stage_params(&self, store: &TensorStore) -> Result<Vec<StagedBuf>> {
        let client = self.exe.client();
        self.param_names
            .iter()
            .map(|n| {
                let t = store.get(n).with_context(|| format!("store missing {n}"))?;
                to_buffer(client, tensor_to_literal(t)?)
            })
            .collect()
    }

    /// Total NLL over one (batch × seq) batch.
    pub fn nll_sum(&self, params: &[StagedBuf], x: &[i32], y: &[i32]) -> Result<f64> {
        let client = self.exe.client();
        let xb = to_buffer(client, tokens_to_literal(x, self.batch, self.seq)?)?;
        let yb = to_buffer(client, tokens_to_literal(y, self.batch, self.seq)?)?;
        let mut refs: Vec<&xla::PjRtBuffer> = params.iter().map(|b| &b.buf).collect();
        refs.push(&xb.buf);
        refs.push(&yb.buf);
        let tup = run_b(&self.exe, &refs)?;
        Ok(tup[0].get_first_element::<f32>()? as f64)
    }
}

/// The logits executor (single-sequence scoring / generation).
pub struct LogitsExec {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub seq: usize,
    pub vocab: usize,
    param_names: Vec<String>,
}

impl LogitsExec {
    pub fn new(engine: &Engine, model: &str) -> Result<LogitsExec> {
        let arts = engine.models.get(model).context("unknown model")?;
        let file = engine.model_program(model, "logits")?;
        Ok(LogitsExec {
            exe: engine.load(&file)?,
            seq: arts.config.seq_len,
            vocab: arts.config.vocab,
            param_names: arts.params.iter().map(|(n, _, _)| n.clone()).collect(),
        })
    }

    pub fn stage_params(&self, store: &TensorStore) -> Result<Vec<StagedBuf>> {
        let client = self.exe.client();
        self.param_names
            .iter()
            .map(|n| {
                let t = store.get(n).with_context(|| format!("store missing {n}"))?;
                to_buffer(client, tensor_to_literal(t)?)
            })
            .collect()
    }

    /// Logits for one sequence (padded to seq_len); returns (seq×vocab).
    pub fn logits(&self, params: &[StagedBuf], x: &[i32]) -> Result<Vec<f32>> {
        if x.len() != self.seq {
            bail!("sequence must be padded to {}", self.seq);
        }
        let client = self.exe.client();
        let xb = to_buffer(client, tokens_to_literal(x, 1, self.seq)?)?;
        let mut refs: Vec<&xla::PjRtBuffer> = params.iter().map(|b| &b.buf).collect();
        refs.push(&xb.buf);
        let tup = run_b(&self.exe, &refs)?;
        literal_to_f32s(&tup[0])
    }
}

/// The GLVQ group-step executor (accelerated alternating optimization).
pub struct GlvqStepExec {
    step: std::sync::Arc<xla::PjRtLoadedExecutable>,
    encode: std::sync::Arc<xla::PjRtLoadedExecutable>,
    decode: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub d: usize,
    pub r: usize,
    pub n: usize,
    pub ncal: usize,
}

impl GlvqStepExec {
    pub fn new(engine: &Engine, d: usize) -> Result<GlvqStepExec> {
        let arts = engine.glvq.get(&d).context("no glvq artifacts for d")?;
        Ok(GlvqStepExec {
            step: engine.load(&engine.glvq_program(d, "step")?)?,
            encode: engine.load(&engine.glvq_program(d, "encode")?)?,
            decode: engine.load(&engine.glvq_program(d, "decode")?)?,
            d,
            r: arts.r,
            n: arts.n,
            ncal: arts.ncal,
        })
    }

    /// One alternating-opt observation on a canonical (R×n) tile.
    /// Returns (loss, dG, dμ).
    pub fn step(
        &self,
        w: &Mat,
        x: &Mat,
        g: &Mat,
        ginv: &Mat,
        mu: f32,
        g0: &Mat,
    ) -> Result<(f64, Mat, f32)> {
        let client = self.step.client();
        let bufs = [
            to_buffer(client, mat_to_literal(w)?)?,
            to_buffer(client, mat_to_literal(x)?)?,
            to_buffer(client, mat_to_literal(g)?)?,
            to_buffer(client, mat_to_literal(ginv)?)?,
            to_buffer(client, scalar_literal(mu))?,
            to_buffer(client, mat_to_literal(g0)?)?,
        ];
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|b| &b.buf).collect();
        let tup = run_b(&self.step, &refs)?;
        let loss = tup[0].get_first_element::<f32>()? as f64;
        let dg = Mat::from_vec(self.d, self.d, literal_to_f32s(&tup[1])?);
        let dmu = tup[2].get_first_element::<f32>()?;
        Ok((loss, dg, dmu))
    }

    /// Final Babai encode of a tile → codes (R·n/d·d integer-valued f32).
    pub fn encode(&self, w: &Mat, ginv: &Mat, mu: f32) -> Result<Vec<f32>> {
        let client = self.encode.client();
        let bufs = [
            to_buffer(client, mat_to_literal(w)?)?,
            to_buffer(client, mat_to_literal(ginv)?)?,
            to_buffer(client, scalar_literal(mu))?,
        ];
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|b| &b.buf).collect();
        let tup = run_b(&self.encode, &refs)?;
        literal_to_f32s(&tup[0])
    }

    /// Decode codes back to a (R×n) tile.
    pub fn decode(&self, z: &[f32], g: &Mat, mu: f32) -> Result<Mat> {
        let blocks = (self.r * self.n / self.d) as i64;
        let zlit =
            xla::Literal::vec1(z).reshape(&[self.r as i64, blocks / self.r as i64, self.d as i64])?;
        let client = self.decode.client();
        let bufs = [
            to_buffer(client, zlit)?,
            to_buffer(client, mat_to_literal(g)?)?,
            to_buffer(client, scalar_literal(mu))?,
        ];
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|b| &b.buf).collect();
        let tup = run_b(&self.decode, &refs)?;
        Ok(Mat::from_vec(self.r, self.n, literal_to_f32s(&tup[0])?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_f32s(&lit).unwrap(), t.data);
    }

    #[test]
    fn token_literal_shape_checked() {
        assert!(tokens_to_literal(&[1, 2, 3], 2, 2).is_err());
        assert!(tokens_to_literal(&[1, 2, 3, 4], 2, 2).is_ok());
    }
}
