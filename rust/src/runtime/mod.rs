//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! - [`engine`] — PJRT CPU client + artifact registry (parses
//!   `artifacts/manifest.json`) + compile cache,
//! - [`exec`] — typed executors: TrainStep / ForwardLoss / Logits / GlvqStep
//!   with Tensor⇄Literal conversion.
//!
//! Python never runs at runtime; interchange is HLO *text* (xla_extension
//! 0.5.1 rejects jax≥0.5 serialized protos — see DESIGN.md).

pub mod engine;
pub mod exec;

pub use engine::Engine;
