//! PJRT engine: client lifecycle, manifest parsing, executable cache.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;
use crate::util::json::Json;

/// Parsed manifest entry for one exported model.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub config: ModelConfig,
    /// parameter names in canonical order with shapes + quantizable flags
    pub params: Vec<(String, Vec<usize>, bool)>,
    /// program name → artifact file name
    pub programs: BTreeMap<String, String>,
}

/// Parsed manifest entry for one lattice dimension's GLVQ programs.
#[derive(Clone, Debug)]
pub struct GlvqArtifacts {
    pub d: usize,
    pub r: usize,
    pub n: usize,
    pub ncal: usize,
    pub programs: BTreeMap<String, String>,
}

/// The runtime engine: one PJRT CPU client + lazily compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
    pub glvq: BTreeMap<usize, GlvqArtifacts>,
    cache: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

fn static_name(n: &str) -> &'static str {
    // ModelConfig.name is &'static; the manifest only ever contains s/m/l
    match n {
        "s" => "s",
        "m" => "m",
        "l" => "l",
        _ => "custom",
    }
}

impl Engine {
    /// Create the engine from an artifacts directory (manifest.json inside).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mpath = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {}", mpath.display()))?;
        let j = Json::parse(&text).context("parse manifest.json")?;
        if j.get("version").as_usize() != Some(1) {
            bail!("unsupported manifest version");
        }

        let mut models = BTreeMap::new();
        if let Some(mobj) = j.get("models").as_obj() {
            for (name, entry) in mobj {
                let c = entry.get("config");
                let cfg = ModelConfig {
                    name: static_name(name),
                    vocab: c.get("vocab").as_usize().context("vocab")?,
                    d_model: c.get("d_model").as_usize().context("d_model")?,
                    n_layer: c.get("n_layer").as_usize().context("n_layer")?,
                    n_head: c.get("n_head").as_usize().context("n_head")?,
                    d_ff: c.get("d_ff").as_usize().context("d_ff")?,
                    seq_len: c.get("seq_len").as_usize().context("seq_len")?,
                    batch_train: c.get("batch_train").as_usize().context("batch_train")?,
                    batch_eval: c.get("batch_eval").as_usize().context("batch_eval")?,
                };
                let mut params = Vec::new();
                for p in entry.get("params").as_arr().context("params")? {
                    let pname = p.get("name").as_str().context("param name")?.to_string();
                    let shape: Vec<usize> = p
                        .get("shape")
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect();
                    let q = p.get("quantizable").as_bool().unwrap_or(false);
                    params.push((pname, shape, q));
                }
                let mut programs = BTreeMap::new();
                if let Some(progs) = entry.get("programs").as_obj() {
                    for (k, v) in progs {
                        programs.insert(k.clone(), v.as_str().unwrap_or("").to_string());
                    }
                }
                models.insert(name.clone(), ModelArtifacts { config: cfg, params, programs });
            }
        }

        let mut glvq = BTreeMap::new();
        if let Some(gobj) = j.get("glvq").as_obj() {
            for (dstr, entry) in gobj {
                let d: usize = dstr.parse().unwrap_or(0);
                let mut programs = BTreeMap::new();
                if let Some(progs) = entry.get("programs").as_obj() {
                    for (k, v) in progs {
                        programs.insert(k.clone(), v.as_str().unwrap_or("").to_string());
                    }
                }
                glvq.insert(
                    d,
                    GlvqArtifacts {
                        d,
                        r: entry.get("r").as_usize().unwrap_or(128),
                        n: entry.get("n").as_usize().unwrap_or(128),
                        ncal: entry.get("ncal").as_usize().unwrap_or(256),
                        programs,
                    },
                );
            }
        }

        Ok(Engine {
            client,
            dir: artifacts_dir.to_path_buf(),
            models,
            glvq,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile (or fetch from cache) an artifact by file name.
    pub fn load(&self, file: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {}", path.display()))?;
        let arc = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(file.to_string(), arc.clone());
        Ok(arc)
    }

    /// Look up a model's program artifact file.
    pub fn model_program(&self, model: &str, program: &str) -> Result<String> {
        self.models
            .get(model)
            .and_then(|m| m.programs.get(program))
            .cloned()
            .with_context(|| format!("manifest has no {program} for model {model}"))
    }

    pub fn glvq_program(&self, d: usize, program: &str) -> Result<String> {
        self.glvq
            .get(&d)
            .and_then(|g| g.programs.get(program))
            .cloned()
            .with_context(|| format!("manifest has no glvq {program} for d={d}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need real artifacts live in rust/tests/ (integration);
    // here we test manifest parsing against a synthetic manifest.
    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("glvq_engine_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "version": 1,
          "models": {
            "s": {
              "config": {"vocab":256,"d_model":128,"n_layer":4,"n_head":4,
                          "d_ff":512,"seq_len":128,"batch_train":16,"batch_eval":8},
              "params": [{"name":"emb","shape":[256,128],"quantizable":false}],
              "programs": {"train_step":"train_step_s.hlo.txt"}
            }
          },
          "glvq": {"8": {"d":8,"r":128,"n":128,"ncal":256,
                          "programs":{"step":"glvq_step_d8.hlo.txt"}}}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let eng = Engine::new(&dir).unwrap();
        assert_eq!(eng.models["s"].config.d_model, 128);
        assert_eq!(eng.models["s"].params[0].0, "emb");
        assert_eq!(eng.glvq[&8].ncal, 256);
        assert_eq!(eng.model_program("s", "train_step").unwrap(), "train_step_s.hlo.txt");
        assert!(eng.model_program("s", "nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
