//! Micro-benchmark harness (criterion is not in the vendored crate set):
//! warmup + timed iterations with mean / p50 / p95 and a throughput
//! helper, plus the shared `runs/bench/*.json` trajectory writer
//! ([`append_trajectory`]) every bench appends its measurements through.
//! Used by `benches/*.rs` (cargo bench targets with `harness = false`).

use std::time::Instant;

use crate::util::json::Json;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// optional work units per iteration (bytes, MACs, tokens…)
    pub work_per_iter: f64,
}

impl BenchResult {
    /// work units per second at the mean time.
    pub fn throughput(&self) -> f64 {
        if self.mean_ns > 0.0 {
            self.work_per_iter * 1e9 / self.mean_ns
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let tp = if self.work_per_iter > 0.0 {
            format!("  {:>10.3} Mwork/s", self.throughput() / 1e6)
        } else {
            String::new()
        };
        format!(
            "{:<44} {:>10.1} µs/iter  p50 {:>8.1}  p95 {:>8.1}{}",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p95_ns / 1e3,
            tp
        )
    }
}

/// Benchmark runner: auto-calibrates iteration count to the time budget.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub budget_ms: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, min_iters: 10, budget_ms: 500.0 }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher { warmup_iters: 1, min_iters: 3, budget_ms: 80.0 }
    }

    /// Time `f`, returning per-iteration statistics. `work_per_iter` feeds
    /// the throughput column (0 to omit).
    pub fn run<F: FnMut()>(&self, name: &str, work_per_iter: f64, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        // estimate single-iteration cost
        let probe = Instant::now();
        f();
        let est_ns = probe.elapsed().as_nanos().max(1) as f64;
        let iters = ((self.budget_ms * 1e6 / est_ns) as usize).clamp(self.min_iters, 1_000_000);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: p(0.5),
            p95_ns: p(0.95),
            work_per_iter,
        }
    }
}

/// Append one run to the `runs/bench/<stem>.json` trajectory
/// (`{"runs": [...]}` — one entry per invocation, so successive runs
/// form a per-commit performance history; CI uploads the files as a
/// workflow artifact). A `unix_time` stamp is added automatically;
/// `fields` carries the run's payload (conventionally a
/// `"measurements"` array plus any top-line numbers worth trending).
/// Best-effort: IO problems warn on stderr instead of failing the bench.
pub fn append_trajectory(stem: &str, fields: Vec<(&str, Json)>) {
    let dir = std::path::Path::new("runs/bench");
    if let Err(e) = std::fs::create_dir_all(dir) {
        eprintln!("WARN cannot create {}: {e}", dir.display());
        return;
    }
    let path = dir.join(format!("{stem}.json"));
    let mut doc = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .unwrap_or_else(|| Json::obj(vec![("runs", Json::arr(Vec::new()))]));
    let mut runs: Vec<Json> = doc.get("runs").as_arr().map(|a| a.to_vec()).unwrap_or_default();
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut run = vec![("unix_time", Json::num(stamp as f64))];
    run.extend(fields);
    runs.push(Json::obj(run));
    doc.set("runs", Json::Arr(runs));
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("appended trajectory point to {}", path.display()),
        Err(e) => eprintln!("WARN cannot write {}: {e}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trajectory_appends_runs() {
        // unique stem so parallel test runs never collide; cwd-relative
        // like the real benches
        let stem = format!("selftest_{}", std::process::id());
        let path = std::path::Path::new("runs/bench").join(format!("{stem}.json"));
        std::fs::remove_file(&path).ok();
        append_trajectory(&stem, vec![("speedup", Json::num(2.0))]);
        append_trajectory(&stem, vec![("measurements", Json::arr(vec![Json::num(1.0)]))]);
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let runs = doc.get("runs").as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("speedup").as_f64(), Some(2.0));
        assert!(runs[0].get("unix_time").as_f64().unwrap() > 0.0);
        assert_eq!(runs[1].get("measurements").as_arr().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn measures_a_known_sleep() {
        let b = Bencher { warmup_iters: 1, min_iters: 5, budget_ms: 30.0 };
        let r = b.run("sleep1ms", 0.0, || {
            std::thread::sleep(std::time::Duration::from_millis(1))
        });
        assert!(r.mean_ns > 0.8e6, "mean {}", r.mean_ns);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.iters >= 5);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            p50_ns: 1e9,
            p95_ns: 1e9,
            work_per_iter: 1000.0,
        };
        assert!((r.throughput() - 1000.0).abs() < 1e-9);
        assert!(r.report().contains("x"));
    }
}
