//! The continuous-batching scheduler: one ragged step batch per
//! iteration, sequences joining and leaving per token.
//!
//! Each [`ContinuousScheduler::step`] does, in order:
//!
//! 1. **sweep** — drop sequences finished/failed last iteration (their KV
//!    pages were already freed the moment they retired);
//! 2. **resume** — restore preempted sequences, highest priority first,
//!    as soon as the arena has their pages back;
//! 3. **admit** — pop queued requests into the running set while the seq
//!    budget (`max_batch`), the token budget (`max_tokens_in_flight`) and
//!    the free-page watermark allow — requests join mid-flight, never
//!    waiting for a batch boundary;
//! 4. **plan** — every decoding sequence contributes its one-token step;
//!    prompts still being fed contribute chunks from a shared
//!    `prefill_chunk`-token budget, so a long prefill is interleaved with
//!    decode steps instead of monopolizing them;
//! 5. **preempt** — if the planned appends need more pages than the arena
//!    has free, the lowest-priority (most recently admitted) sequences
//!    are spilled (quantize-to-spill) until the step fits;
//! 6. **run** — one `forward_ragged` call for the whole step batch, then
//!    sample/score from the returned rows; finished sequences retire and
//!    free their pages immediately.
//!
//! The scheduler is deterministic: the same submission sequence produces
//! the same step batches, and because every per-row operation of the
//! ragged forward is independent of batch composition, the same *outputs*
//! as serving each request alone (`tests/continuous_parity.rs`).

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::decode_stream::DecodeStats;
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::server::{Request, Response};
use crate::eval::native_fwd::argmax_logit;
use crate::kvcache::{KvCacheStats, SeqId, SpilledSeq};
use crate::linalg::Mat;
use crate::obs::{span, Mark, RequestTimeline};

use super::queue::{Backpressure, QueueOpts, RequestQueue};

/// What the scheduler needs from a model backend: per-sequence lifecycle
/// hooks over a paged KV cache plus one ragged forward per step batch.
/// Implemented by `coordinator::server::CachedNativeBackend` (dense or
/// streamed-compressed weights) and by a mock in the unit tests below.
pub trait SeqBackend {
    /// Model context length (positions per sequence). (Named apart from
    /// `LmBackend::seq_len` so a backend can implement both traits.)
    fn ctx_len(&self) -> usize;

    /// Register a fresh cache sequence.
    fn begin_seq(&mut self) -> SeqId;

    /// Register a fresh cache sequence, claiming the longest cached
    /// prefix of `tokens` (capped at `max_rows`) when the backend shares
    /// prefixes. Returns the sequence plus the number of leading tokens
    /// whose K/V rows are already cached — the scheduler starts feeding
    /// at that offset. Backends without sharing claim nothing.
    fn begin_seq_prefixed(&mut self, tokens: &[i32], max_rows: usize) -> (SeqId, usize) {
        let _ = (tokens, max_rows);
        (self.begin_seq(), 0)
    }

    /// Publish a sequence's fed `tokens` into the shared-prefix index so
    /// later admissions can claim them (no-op without sharing).
    /// Idempotent: re-publishing a longer prefix of the same stream only
    /// extends the shared path.
    fn publish_seq(&mut self, sid: SeqId, tokens: &[i32]) {
        let _ = (sid, tokens);
    }

    /// Advance every `(sequence, new-tokens)` pair in one forward; logits
    /// for all new positions, sequence-major (`Σ nᵦ × V`).
    fn step_ragged(&mut self, items: &[(SeqId, &[i32])]) -> Result<Mat>;

    /// Drop a sequence, returning its pages to the arena immediately.
    fn retire_seq(&mut self, sid: SeqId);

    /// Park a sequence outside the arena (`quantize` = compress pages on
    /// the way out).
    fn preempt_seq(&mut self, sid: SeqId, quantize: bool) -> Result<SpilledSeq>;

    /// Bring a parked sequence back under a fresh id. When the arena
    /// still lacks the pages, the **untouched** state comes back in
    /// `Err` — a failed resume never destroys a parked sequence; the
    /// scheduler re-parks it and retries later.
    fn resume_seq(&mut self, sp: SpilledSeq) -> std::result::Result<SeqId, SpilledSeq>;

    /// Pages still allocatable (`None` = unbounded arena).
    fn free_pages(&self) -> Option<usize>;

    /// Hard arena capacity (`None` = unbounded).
    fn page_capacity(&self) -> Option<usize>;

    /// Exact pages needed to append `n_new` rows to a sequence holding
    /// `rows` rows.
    fn pages_for(&self, rows: usize, n_new: usize) -> usize;

    /// KV-cache counters, if the backend maintains a paged cache.
    fn kv_stats(&self) -> Option<KvCacheStats>;

    /// Streaming-decode counters, if the backend serves from compressed
    /// weights.
    fn stream_stats(&self) -> Option<DecodeStats>;

    /// Per-shard decode counters, if the backend runs tensor-parallel
    /// over the shard executor. (Named apart from
    /// `LmBackend::shard_stats` so a backend can implement both traits
    /// without ambiguity.)
    fn sharded_stats(&self) -> Option<Vec<crate::shard::ShardStat>> {
        None
    }

    /// Draft/verify counters, if the backend decodes speculatively.
    /// (Named apart from `LmBackend::spec_stats` for the same reason as
    /// [`SeqBackend::sharded_stats`].)
    fn speculative_stats(&self) -> Option<crate::spec::SpecStats> {
        None
    }
}

/// Continuous-scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct ContinuousOpts {
    /// max sequences in flight (running + preempted) — the step-batch
    /// budget
    pub max_batch: usize,
    /// prefill tokens fed per step, shared across all prefilling
    /// sequences in priority order
    pub prefill_chunk: usize,
    /// bounded admission-queue depth
    pub max_queue: usize,
    /// token budget (prompt + output) across everything admitted
    pub max_tokens_in_flight: usize,
    /// compress preempted pages through the KV quantizer
    /// (quantize-to-spill) instead of parking them as f32
    pub quantize_spill: bool,
}

impl Default for ContinuousOpts {
    fn default() -> Self {
        ContinuousOpts {
            max_batch: 16,
            prefill_chunk: 32,
            max_queue: 256,
            max_tokens_in_flight: 4096,
            quantize_spill: false,
        }
    }
}

/// Request kind plus its scoring/sampling state.
enum Kind {
    Gen { prompt_len: usize, max_new: usize },
    Score { prompt_len: usize, logprob: f64 },
}

/// Where a running sequence's KV state lives right now.
enum CacheSlot {
    /// resident in the arena
    Active(SeqId),
    /// preempted: parked outside the arena, waiting to resume
    Spilled(SpilledSeq),
    /// retired/failed (swept next step) or mid-transition
    Parked,
}

/// One admitted request: its token stream, feed progress, and cache slot.
struct RunSeq {
    rid: u64,
    kind: Kind,
    /// full intended prefix: prompt, then generated tokens (Gen) or the
    /// forced continuation (Score)
    tokens: Vec<i32>,
    /// tokens fed into the cache so far
    fed: usize,
    slot: CacheSlot,
    /// token-budget charge (held until retirement)
    need: usize,
    submitted: Instant,
    first_token: bool,
    dead: bool,
    /// lifecycle stamps (admit, prefill chunks, first token, decode
    /// steps, preempt/resume) — moved into the metrics at retirement
    timeline: RequestTimeline,
}

impl RunSeq {
    /// Tokens that ever need feeding: a Gen feeds everything it samples
    /// (each sampled token seeds the next step); a Score never feeds the
    /// final continuation token (its logprob comes from the position
    /// before it).
    fn feed_end(&self) -> usize {
        match self.kind {
            Kind::Gen { .. } => self.tokens.len(),
            Kind::Score { .. } => self.tokens.len() - 1,
        }
    }
}

fn elapsed_ms(from: Instant) -> f64 {
    from.elapsed().as_secs_f64() * 1e3
}

/// The continuous-batching engine (see module docs for the step anatomy).
pub struct ContinuousScheduler<B: SeqBackend> {
    backend: B,
    queue: RequestQueue,
    /// priority order: index 0 = oldest admission = highest priority
    running: Vec<RunSeq>,
    finished: Vec<(u64, Response)>,
    metrics: ServerMetrics,
    opts: ContinuousOpts,
    tokens_in_flight: usize,
}

impl<B: SeqBackend> ContinuousScheduler<B> {
    pub fn new(backend: B, opts: ContinuousOpts) -> ContinuousScheduler<B> {
        let opts = ContinuousOpts {
            max_batch: opts.max_batch.max(1),
            prefill_chunk: opts.prefill_chunk.max(1),
            ..opts
        };
        let queue = RequestQueue::new(QueueOpts {
            max_depth: opts.max_queue,
            max_tokens_in_flight: opts.max_tokens_in_flight,
        });
        ContinuousScheduler {
            backend,
            queue,
            running: Vec::new(),
            finished: Vec::new(),
            metrics: ServerMetrics::default(),
            opts,
            tokens_in_flight: 0,
        }
    }

    /// Submit a request. Structurally infeasible requests are refused with
    /// the exact [`Backpressure`] reason; trivially-complete requests
    /// (`max_new == 0`, empty continuation) are answered without touching
    /// the model. Returns the request id whose response will appear in
    /// [`ContinuousScheduler::drain_finished`].
    pub fn submit(&mut self, request: Request, submitted: Instant) -> Result<u64, Backpressure> {
        match &request {
            Request::Generate { prompt, max_new } if *max_new == 0 && !prompt.is_empty() => {
                let id = self.queue.reserve_id();
                self.metrics.requests += 1;
                self.push_timeline(Self::trivial_timeline(id));
                self.finished.push((id, Response::Generated { text: Vec::new() }));
                return Ok(id);
            }
            Request::Score { prompt, continuation }
                if continuation.is_empty() && !prompt.is_empty() =>
            {
                let id = self.queue.reserve_id();
                self.metrics.requests += 1;
                self.push_timeline(Self::trivial_timeline(id));
                self.finished.push((id, Response::Scored { logprob: 0.0 }));
                return Ok(id);
            }
            _ => {}
        }
        let res = self.queue.push(request, submitted, self.backend.ctx_len());
        if let Err(bp) = &res {
            self.metrics.rejections.count(bp);
        }
        res
    }

    /// True while anything is queued, running, or waiting to be drained.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty()
            || self.running.iter().any(|s| !s.dead)
            || !self.finished.is_empty()
    }

    /// Responses completed since the last drain, as `(request id,
    /// response)` pairs in completion order.
    pub fn drain_finished(&mut self) -> Vec<(u64, Response)> {
        std::mem::take(&mut self.finished)
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// The recorded timeline of a finished request, if still retained
    /// (the per-run timeline buffer is capped). Scans newest-first so a
    /// reused id resolves to its latest lifecycle.
    pub fn timeline_for(&self, rid: u64) -> Option<RequestTimeline> {
        self.metrics.timelines.iter().rev().find(|t| t.rid == rid).cloned()
    }

    /// Requests waiting for admission.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Sequences admitted and not yet retired (running + preempted).
    pub fn in_flight(&self) -> usize {
        self.running.iter().filter(|s| !s.dead).count()
    }

    /// Final metrics (backend counters folded in).
    pub fn into_metrics(mut self) -> ServerMetrics {
        self.refresh_stats();
        self.metrics
    }

    /// One scheduler iteration; returns the number of sequences stepped.
    ///
    /// Every phase runs under a tracing span (`sweep`/`resume`/`admit`/
    /// `plan`/`preempt`/`exec`/`apply_logits`/`refresh`, all children of
    /// `sched_step`), so an enabled trace attributes scheduler wall time
    /// across the pipeline. Disabled tracing costs one atomic load per
    /// span site.
    pub fn step(&mut self) -> usize {
        let _step = crate::span!("sched_step");
        {
            let _sp = crate::span!("sweep");
            self.sweep_dead();
        }
        {
            let _sp = crate::span!("resume");
            self.resume_preempted();
        }
        {
            let _sp = crate::span!("admit");
            self.admit();
        }
        let items = {
            let _sp = crate::span!("plan");
            self.plan_items()
        };
        let items = {
            let _sp = crate::span!("preempt");
            self.preempt_for_pages(items)
        };
        if items.is_empty() {
            self.refresh_stats();
            return 0;
        }
        self.metrics.sched_steps += 1;
        self.metrics.seqs_per_step.record(items.len() as f64);
        for &(i, take) in &items {
            // a surviving item is a prefill chunk iff its sequence still
            // has more than one pending token (the plan-time criterion,
            // re-evaluated here so dropped/shrunk items are not counted)
            let s = &self.running[i];
            if s.feed_end() - s.fed > 1 {
                self.metrics.prefill_chunks += 1;
                self.metrics.prefill_tokens += take;
                self.running[i].timeline.mark(Mark::PrefillChunk);
            }
        }
        let calls: Vec<(SeqId, &[i32])> = items
            .iter()
            .map(|&(i, take)| {
                let s = &self.running[i];
                let sid = match s.slot {
                    CacheSlot::Active(sid) => sid,
                    _ => unreachable!("planned item must be active"),
                };
                (sid, &s.tokens[s.fed..s.fed + take])
            })
            .collect();
        let stepped = {
            let _sp = crate::span!("exec");
            self.backend.step_ragged(&calls)
        };
        drop(calls);
        match stepped {
            Ok(logits) => {
                {
                    let _sp = crate::span!("apply_logits");
                    self.apply_logits(&items, &logits);
                }
                self.refresh_stats();
                items.len()
            }
            Err(e) => {
                // a failed ragged step (e.g. an arena race this scheduler
                // mis-estimated) leaves its members with skewed per-layer
                // rows: evict them so nothing serves misaligned K/V
                let message = e.to_string();
                for &(i, _) in &items {
                    self.fail_seq(i, &message);
                }
                self.refresh_stats();
                0
            }
        }
    }

    // ---- step phases ----

    fn sweep_dead(&mut self) {
        self.running.retain(|s| !s.dead);
    }

    /// Resume preempted sequences in priority order. Strict order — if the
    /// highest-priority parked sequence does not fit yet, younger ones
    /// wait behind it rather than starving it.
    fn resume_preempted(&mut self) {
        for i in 0..self.running.len() {
            if self.running[i].dead {
                continue;
            }
            let pages = match &self.running[i].slot {
                CacheSlot::Spilled(sp) => sp.pages(),
                _ => continue,
            };
            if let Some(free) = self.backend.free_pages() {
                if pages > free {
                    break;
                }
            }
            let slot = std::mem::replace(&mut self.running[i].slot, CacheSlot::Parked);
            let CacheSlot::Spilled(sp) = slot else {
                unreachable!("checked above");
            };
            match self.backend.resume_seq(sp) {
                Ok(sid) => {
                    self.running[i].slot = CacheSlot::Active(sid);
                    self.metrics.resumes += 1;
                    self.running[i].timeline.mark(Mark::Resume);
                }
                Err(sp) => {
                    // the free-page reading and the restore disagreed —
                    // re-park untouched and stop resuming this step
                    self.running[i].slot = CacheSlot::Spilled(sp);
                    break;
                }
            }
        }
    }

    /// Admit queued requests while the seq budget, token budget and page
    /// watermark allow. Requests whose KV footprint can never fit the
    /// arena are rejected here (the queue cannot know the page geometry).
    fn admit(&mut self) {
        loop {
            if self.in_flight() >= self.opts.max_batch {
                return;
            }
            let (need, max_rows) = match self.queue.front() {
                Some(q) => (q.need, q.need.saturating_sub(1).max(1)),
                None => return,
            };
            if self.tokens_in_flight + need > self.opts.max_tokens_in_flight {
                return;
            }
            if let Some(cap) = self.backend.page_capacity() {
                let need_pages = self.backend.pages_for(0, max_rows);
                if need_pages > cap {
                    // rejections never count as served requests, whether
                    // refused at submit() or deferred to admission
                    let q = self.queue.pop().expect("front checked");
                    let bp = Backpressure::ArenaTooSmall { need_pages, capacity: cap };
                    self.metrics.rejections.count(&bp);
                    self.finished.push((q.id, Response::Rejected { reason: bp.to_string() }));
                    continue;
                }
            }
            if let Some(free) = self.backend.free_pages() {
                // headroom gate: admitting straight into a dry arena would
                // only churn spills — wait until the first chunk fits
                let first = self.opts.prefill_chunk.min(max_rows);
                if self.backend.pages_for(0, first) > free {
                    return;
                }
            }
            let q = self.queue.pop().expect("front checked");
            self.metrics.queue_wait.record(elapsed_ms(q.submitted));
            // anchor the timeline at the recorded submit instant so queue
            // time is attributed even though the timeline is built here
            let base_ns =
                span::now_ns().saturating_sub(q.submitted.elapsed().as_nanos() as u64);
            let mut timeline = RequestTimeline::with_base(q.id, base_ns);
            timeline.mark(Mark::Admit);
            let (kind, tokens) = match q.request {
                Request::Generate { prompt, max_new } => {
                    let tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
                    (Kind::Gen { prompt_len: tokens.len(), max_new }, tokens)
                }
                Request::Score { prompt, continuation } => {
                    let mut tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
                    let prompt_len = tokens.len();
                    tokens.extend(continuation.iter().map(|&b| b as i32));
                    (Kind::Score { prompt_len, logprob: 0.0 }, tokens)
                }
            };
            // claim cap: at least one token must still be fed to produce
            // logits, and a Score needs every row from prompt_len-1 on
            // fed live (claimed rows produce no logits)
            let cap = match &kind {
                Kind::Gen { .. } => tokens.len().saturating_sub(1),
                Kind::Score { prompt_len, .. } => prompt_len.saturating_sub(1),
            };
            let (sid, claimed) = self.backend.begin_seq_prefixed(&tokens, cap);
            if claimed > 0 {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_tokens += claimed;
            }
            self.tokens_in_flight += q.need;
            self.running.push(RunSeq {
                rid: q.id,
                kind,
                tokens,
                fed: claimed,
                slot: CacheSlot::Active(sid),
                need: q.need,
                submitted: q.submitted,
                first_token: false,
                dead: false,
                timeline,
            });
        }
    }

    /// Form the step batch: `(running index, tokens to feed)` pairs.
    /// Decode steps (one pending token) always join; prompts still being
    /// fed draw chunks from a shared `prefill_chunk` budget in priority
    /// order.
    fn plan_items(&self) -> Vec<(usize, usize)> {
        let mut items = Vec::new();
        let mut prefill_budget = self.opts.prefill_chunk;
        for (i, s) in self.running.iter().enumerate() {
            if s.dead || !matches!(s.slot, CacheSlot::Active(_)) {
                continue;
            }
            let pend = s.feed_end().saturating_sub(s.fed);
            if pend == 0 {
                continue;
            }
            if pend == 1 {
                items.push((i, 1));
            } else if prefill_budget > 0 {
                let take = pend.min(prefill_budget);
                prefill_budget -= take;
                items.push((i, take));
            }
        }
        items
    }

    /// Make the planned step fit the arena: spill the lowest-priority
    /// active sequences (newest admissions first) until the appends fit,
    /// shrinking the last surviving chunk if even a lone sequence cannot
    /// feed its full chunk.
    fn preempt_for_pages(&mut self, mut items: Vec<(usize, usize)>) -> Vec<(usize, usize)> {
        if self.backend.page_capacity().is_none() {
            return items;
        }
        loop {
            if items.is_empty() {
                return items;
            }
            let free = self.backend.free_pages().unwrap_or(usize::MAX);
            let needed: usize = items
                .iter()
                .map(|&(i, take)| self.backend.pages_for(self.running[i].fed, take))
                .sum();
            if needed <= free {
                return items;
            }
            let victim = self
                .running
                .iter()
                .rposition(|s| !s.dead && matches!(s.slot, CacheSlot::Active(_)));
            match victim {
                Some(v) if v != items[0].0 => {
                    self.preempt_one(v);
                    items.retain(|&(i, _)| i != v);
                }
                _ => {
                    // only the top sequence is left: shrink its chunk to
                    // whatever the arena can take this step
                    let (i, take) = items[0];
                    let rows = self.running[i].fed;
                    let mut fit = 0usize;
                    for t in (1..=take).rev() {
                        if self.backend.pages_for(rows, t) <= free {
                            fit = t;
                            break;
                        }
                    }
                    if fit > 0 {
                        items[0] = (i, fit);
                    } else {
                        // the arena cannot hold even one more token of the
                        // only runnable sequence
                        self.fail_seq(i, "kv arena too small for a single step");
                        items.clear();
                    }
                    return items;
                }
            }
        }
    }

    fn preempt_one(&mut self, i: usize) {
        let slot = std::mem::replace(&mut self.running[i].slot, CacheSlot::Parked);
        match slot {
            CacheSlot::Active(sid) => {
                match self.backend.preempt_seq(sid, self.opts.quantize_spill) {
                    Ok(sp) => {
                        self.running[i].slot = CacheSlot::Spilled(sp);
                        self.metrics.preemptions += 1;
                        self.running[i].timeline.mark(Mark::Preempt);
                    }
                    Err(e) => self.fail_seq(i, &format!("kv spill failed: {e}")),
                }
            }
            other => self.running[i].slot = other,
        }
    }

    /// Advance every stepped sequence from its logits rows: sample the
    /// next token (Gen) or accumulate forced-token logprobs (Score), and
    /// retire whatever completed.
    fn apply_logits(&mut self, items: &[(usize, usize)], logits: &Mat) {
        let mut done: Vec<usize> = Vec::new();
        let mut publish_prompt: Vec<usize> = Vec::new();
        let mut row0 = 0usize;
        for &(i, take) in items {
            let s = &mut self.running[i];
            let fed_before = s.fed;
            s.fed += take;
            match &mut s.kind {
                Kind::Gen { prompt_len, max_new } => {
                    if s.fed == s.tokens.len() {
                        // the prefix is fully fed: the last row predicts the
                        // next token
                        let t = argmax_logit(logits.row(row0 + take - 1));
                        if !s.first_token {
                            s.first_token = true;
                            self.metrics.ttft.record(elapsed_ms(s.submitted));
                            s.timeline.mark(Mark::FirstToken);
                            // the whole prompt is cached now: publish it
                            // so concurrent admissions can claim it while
                            // this sequence is still decoding
                            publish_prompt.push(i);
                        }
                        s.tokens.push(t);
                        s.timeline.mark(Mark::DecodeStep);
                        self.metrics.tokens_out += 1;
                        if s.tokens.len() - *prompt_len >= *max_new {
                            done.push(i);
                        }
                    }
                }
                Kind::Score { prompt_len, logprob } => {
                    for r in 0..take {
                        let p = fed_before + r; // absolute position of this row
                        if p + 1 < *prompt_len {
                            continue; // still inside the prompt
                        }
                        let row = logits.row(row0 + r);
                        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                        let lse: f32 =
                            row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
                        let tok = s.tokens[p + 1] as usize;
                        *logprob += (row[tok] - lse) as f64;
                        self.metrics.tokens_out += 1;
                        if !s.first_token {
                            s.first_token = true;
                            self.metrics.ttft.record(elapsed_ms(s.submitted));
                            s.timeline.mark(Mark::FirstToken);
                        }
                    }
                    if s.fed == s.tokens.len() - 1 {
                        done.push(i);
                    }
                }
            }
            row0 += take;
        }
        for i in publish_prompt {
            let (sid, p) = match (&self.running[i].kind, &self.running[i].slot) {
                (Kind::Gen { prompt_len, .. }, CacheSlot::Active(sid)) => (*sid, *prompt_len),
                _ => continue,
            };
            self.backend.publish_seq(sid, &self.running[i].tokens[..p]);
        }
        for i in done {
            self.finish_seq(i);
        }
    }

    /// Retire a completed sequence: free its pages now, deliver its
    /// response, release its token budget. Removal from `running` happens
    /// at the next sweep so in-step indices stay valid.
    fn finish_seq(&mut self, i: usize) {
        if self.running[i].dead {
            return;
        }
        let slot = std::mem::replace(&mut self.running[i].slot, CacheSlot::Parked);
        if let CacheSlot::Active(sid) = slot {
            // publish the fed prefix before retiring so the departing
            // sequence's pages survive as a (cold) shared prefix instead
            // of being freed — a follow-up turn claims them back
            let fed = self.running[i].fed;
            self.backend.publish_seq(sid, &self.running[i].tokens[..fed]);
            self.backend.retire_seq(sid);
        }
        let s = &mut self.running[i];
        s.dead = true;
        self.tokens_in_flight -= s.need;
        let resp = match &s.kind {
            Kind::Gen { prompt_len, .. } => Response::Generated {
                text: s.tokens[*prompt_len..].iter().map(|&t| t.clamp(0, 255) as u8).collect(),
            },
            Kind::Score { logprob, .. } => Response::Scored { logprob: *logprob },
        };
        self.metrics.requests += 1;
        self.metrics.latency.record(elapsed_ms(s.submitted));
        s.timeline.mark(Mark::Finish);
        let timeline = std::mem::take(&mut s.timeline);
        let rid = s.rid;
        self.push_timeline(timeline);
        self.finished.push((rid, resp));
    }

    /// Fail a sequence with a structured error response (freeing its
    /// pages and budget like a normal retirement).
    fn fail_seq(&mut self, i: usize, message: &str) {
        if self.running[i].dead {
            return;
        }
        let slot = std::mem::replace(&mut self.running[i].slot, CacheSlot::Parked);
        if let CacheSlot::Active(sid) = slot {
            self.backend.retire_seq(sid);
        }
        let s = &mut self.running[i];
        s.dead = true;
        self.tokens_in_flight -= s.need;
        self.metrics.requests += 1;
        self.metrics.latency.record(elapsed_ms(s.submitted));
        s.timeline.mark(Mark::Finish);
        let timeline = std::mem::take(&mut s.timeline);
        let rid = s.rid;
        self.push_timeline(timeline);
        self.finished.push((rid, Response::Error { message: message.to_string() }));
    }

    fn refresh_stats(&mut self) {
        let _sp = crate::span!("refresh");
        self.metrics.kv_cache = self.backend.kv_stats();
        self.metrics.decode = self.backend.stream_stats();
        self.metrics.shards = self.backend.sharded_stats();
        self.metrics.spec = self.backend.speculative_stats();
    }

    /// Timeline for a request answered inline at submit (no admission).
    fn trivial_timeline(rid: u64) -> RequestTimeline {
        let mut tl = RequestTimeline::new(rid);
        tl.mark(Mark::Finish);
        tl
    }

    /// Retain a finished request's timeline, bounded so a very long run
    /// cannot grow the metrics without limit.
    fn push_timeline(&mut self, timeline: RequestTimeline) {
        const MAX_TIMELINES: usize = 16_384;
        if self.metrics.timelines.len() < MAX_TIMELINES {
            self.metrics.timelines.push(timeline);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{Kv, KvCacheOpts, PagedKvCache};

    /// Model-free backend over a *real* paged cache (so page pressure,
    /// spill and restore are the genuine article): the next token after
    /// `t` is always `(t + 1) % 256`, encoded as a one-hot logit row.
    struct MockBackend {
        seq_len: usize,
        cache: PagedKvCache,
    }

    const MOCK_W: usize = 4;

    impl MockBackend {
        fn new(seq_len: usize, page_rows: usize, max_pages: usize) -> MockBackend {
            let opts = KvCacheOpts { page_rows, max_pages, ..Default::default() };
            MockBackend { seq_len, cache: PagedKvCache::new(1, MOCK_W, opts) }
        }

        fn shared(seq_len: usize, page_rows: usize, max_pages: usize) -> MockBackend {
            let opts =
                KvCacheOpts { page_rows, max_pages, prefix_share: true, ..Default::default() };
            MockBackend { seq_len, cache: PagedKvCache::new(1, MOCK_W, opts) }
        }
    }

    impl SeqBackend for MockBackend {
        fn ctx_len(&self) -> usize {
            self.seq_len
        }

        fn begin_seq(&mut self) -> SeqId {
            self.cache.new_seq()
        }

        fn begin_seq_prefixed(&mut self, tokens: &[i32], max_rows: usize) -> (SeqId, usize) {
            self.cache.new_seq_shared(tokens, max_rows)
        }

        fn publish_seq(&mut self, sid: SeqId, tokens: &[i32]) {
            self.cache.publish_prefix(sid, tokens);
        }

        fn step_ragged(&mut self, items: &[(SeqId, &[i32])]) -> Result<Mat> {
            let total: usize = items.iter().map(|it| it.1.len()).sum();
            let mut out = Mat::zeros(total, 256);
            let mut row = 0usize;
            for &(sid, toks) in items {
                for &t in toks {
                    self.cache.append(sid, 0, Kv::K, &[t as f32; MOCK_W])?;
                    self.cache.append(sid, 0, Kv::V, &[0.0; MOCK_W])?;
                    *out.at_mut(row, ((t as usize) + 1) % 256) = 5.0;
                    row += 1;
                }
            }
            Ok(out)
        }

        fn retire_seq(&mut self, sid: SeqId) {
            self.cache.evict(sid);
        }

        fn preempt_seq(&mut self, sid: SeqId, quantize: bool) -> Result<SpilledSeq> {
            self.cache.spill(sid, quantize)
        }

        fn resume_seq(&mut self, sp: SpilledSeq) -> std::result::Result<SeqId, SpilledSeq> {
            self.cache.restore(sp)
        }

        fn free_pages(&self) -> Option<usize> {
            self.cache.free_pages()
        }

        fn page_capacity(&self) -> Option<usize> {
            self.cache.page_capacity()
        }

        fn pages_for(&self, rows: usize, n_new: usize) -> usize {
            self.cache.pages_needed(rows, n_new)
        }

        fn kv_stats(&self) -> Option<KvCacheStats> {
            Some(self.cache.stats())
        }

        fn stream_stats(&self) -> Option<DecodeStats> {
            None
        }
    }

    fn run_to_completion<B: SeqBackend>(
        sched: &mut ContinuousScheduler<B>,
        max_steps: usize,
    ) -> Vec<(u64, Response)> {
        let mut out = Vec::new();
        for _ in 0..max_steps {
            if !sched.has_work() {
                break;
            }
            sched.step();
            out.extend(sched.drain_finished());
        }
        assert!(!sched.has_work(), "scheduler did not converge in {max_steps} steps");
        out
    }

    /// Expected mock generation: bytes counting up from the prompt tail.
    fn counting_text(last: u8, n: usize) -> Vec<u8> {
        (1..=n).map(|k| ((last as usize + k) % 256) as u8).collect()
    }

    #[test]
    fn short_requests_finish_while_a_long_one_is_running() {
        // THE continuous-batching property: a short request admitted after
        // a long one completes long before it — no lockstep convoy
        let mut sched = ContinuousScheduler::new(
            MockBackend::new(256, 4, 0),
            ContinuousOpts { prefill_chunk: 4, ..Default::default() },
        );
        let now = Instant::now();
        let long = sched
            .submit(Request::Generate { prompt: vec![10; 3], max_new: 40 }, now)
            .unwrap();
        let short = sched
            .submit(Request::Generate { prompt: vec![99; 2], max_new: 3 }, now)
            .unwrap();
        let mut order = Vec::new();
        for _ in 0..200 {
            if !sched.has_work() {
                break;
            }
            sched.step();
            for (rid, resp) in sched.drain_finished() {
                order.push((rid, resp));
            }
        }
        assert_eq!(order.len(), 2);
        assert_eq!(order[0].0, short, "short request must finish first");
        assert_eq!(order[1].0, long);
        match &order[0].1 {
            Response::Generated { text } => assert_eq!(text, &counting_text(99, 3)),
            other => panic!("unexpected {other:?}"),
        }
        match &order[1].1 {
            Response::Generated { text } => assert_eq!(text, &counting_text(10, 40)),
            other => panic!("unexpected {other:?}"),
        }
        let m = sched.metrics();
        assert_eq!(m.requests, 2);
        assert_eq!(m.tokens_out, 43);
        assert_eq!(m.ttft.count(), 2);
        assert!(m.sched_steps >= 40, "long request runs one decode per step");
        // both sequences shared step batches
        assert!(m.seqs_per_step.quantile(1.0) >= 2.0);
    }

    #[test]
    fn chunked_prefill_interleaves_with_decode() {
        // prompt of 20 with a 4-token chunk budget: the prefill takes ≥ 5
        // steps, and a decoding sequence keeps stepping throughout
        let mut sched = ContinuousScheduler::new(
            MockBackend::new(256, 4, 0),
            ContinuousOpts { prefill_chunk: 4, ..Default::default() },
        );
        let now = Instant::now();
        let quick = sched
            .submit(Request::Generate { prompt: vec![7; 2], max_new: 8 }, now)
            .unwrap();
        let chunky = sched
            .submit(Request::Generate { prompt: vec![50; 20], max_new: 2 }, now)
            .unwrap();
        let done = run_to_completion(&mut sched, 100);
        assert_eq!(done.len(), 2);
        let m = sched.metrics();
        assert!(
            m.prefill_chunks >= 5,
            "20-token prompt at chunk 4 needs >= 5 chunks, got {}",
            m.prefill_chunks
        );
        assert!(m.prefill_tokens >= 20, "the whole prompt is fed through chunks");
        for (rid, resp) in &done {
            match resp {
                Response::Generated { text } if *rid == quick => {
                    assert_eq!(text, &counting_text(7, 8))
                }
                Response::Generated { text } if *rid == chunky => {
                    assert_eq!(text, &counting_text(50, 2))
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn token_budget_defers_admission() {
        let mut sched = ContinuousScheduler::new(
            MockBackend::new(256, 4, 0),
            ContinuousOpts { max_tokens_in_flight: 12, ..Default::default() },
        );
        let now = Instant::now();
        // 10 tokens in flight — the second request (8 tokens) must wait
        sched.submit(Request::Generate { prompt: vec![1; 4], max_new: 6 }, now).unwrap();
        sched.submit(Request::Generate { prompt: vec![2; 4], max_new: 4 }, now).unwrap();
        sched.step();
        assert_eq!(sched.in_flight(), 1, "budget admits only the first request");
        assert_eq!(sched.queue_depth(), 1);
        let done = run_to_completion(&mut sched, 100);
        assert_eq!(done.len(), 2, "deferred request completes after budget frees");
        assert!(sched.metrics().queue_wait.count() >= 2);
    }

    #[test]
    fn page_pressure_preempts_and_resumes() {
        // arena of 16 pages (page_rows 2, 2 streams): each sequence peaks
        // at 16 pages, so two of them cannot coexist — the younger one
        // must spill and still finish correctly after resuming
        let mut sched = ContinuousScheduler::new(
            MockBackend::new(256, 2, 16),
            ContinuousOpts { prefill_chunk: 4, ..Default::default() },
        );
        let now = Instant::now();
        let a = sched.submit(Request::Generate { prompt: vec![5; 4], max_new: 12 }, now).unwrap();
        let b = sched.submit(Request::Generate { prompt: vec![9; 4], max_new: 12 }, now).unwrap();
        let done = run_to_completion(&mut sched, 300);
        assert_eq!(done.len(), 2);
        for (rid, resp) in &done {
            assert!(*rid == a || *rid == b);
            let last = if *rid == a { 5 } else { 9 };
            match resp {
                Response::Generated { text } => assert_eq!(text, &counting_text(last, 12)),
                other => panic!("unexpected {other:?}"),
            }
        }
        let m = sched.metrics();
        assert!(m.preemptions >= 1, "tight arena must force a preemption");
        assert!(m.resumes >= 1, "preempted sequence must resume");
        let kv = m.kv_cache.expect("mock reports cache stats");
        assert!(kv.pages_spilled > 0 && kv.pages_restored > 0);
        assert_eq!(kv.pages_in_use, 0, "retirement returns every page");
    }

    #[test]
    fn infeasible_requests_are_rejected_with_structure() {
        let mut sched =
            ContinuousScheduler::new(MockBackend::new(64, 2, 6), ContinuousOpts::default());
        let now = Instant::now();
        // context overflow at the door
        let err = sched
            .submit(Request::Generate { prompt: vec![1; 60], max_new: 30 }, now)
            .unwrap_err();
        assert!(matches!(err, Backpressure::ContextOverflow { .. }));
        // empty prompt at the door
        let err = sched
            .submit(Request::Generate { prompt: Vec::new(), max_new: 4 }, now)
            .unwrap_err();
        assert_eq!(err, Backpressure::EmptyPrompt);
        // arena too small: needs more pages than the whole arena — deferred
        // rejection with a structured response
        let rid = sched
            .submit(Request::Generate { prompt: vec![1; 10], max_new: 20 }, now)
            .unwrap();
        sched.step();
        let done = sched.drain_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, rid);
        match &done[0].1 {
            Response::Rejected { reason } => {
                assert!(reason.contains("kv pages"), "{reason}")
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(sched.metrics().rejections.total(), 3);
    }

    #[test]
    fn trivial_requests_answer_without_stepping() {
        let mut sched =
            ContinuousScheduler::new(MockBackend::new(64, 4, 0), ContinuousOpts::default());
        let now = Instant::now();
        let a = sched.submit(Request::Generate { prompt: vec![3; 2], max_new: 0 }, now).unwrap();
        let b = sched
            .submit(Request::Score { prompt: vec![3; 2], continuation: Vec::new() }, now)
            .unwrap();
        let done = sched.drain_finished();
        assert_eq!(done.len(), 2);
        assert!(matches!(
            done.iter().find(|d| d.0 == a).map(|d| &d.1),
            Some(Response::Generated { .. })
        ));
        assert!(matches!(
            done.iter().find(|d| d.0 == b).map(|d| &d.1),
            Some(Response::Scored { .. })
        ));
        assert!(!sched.has_work());
    }

    #[test]
    fn timelines_record_request_lifecycle() {
        let mut sched = ContinuousScheduler::new(
            MockBackend::new(256, 4, 0),
            ContinuousOpts { prefill_chunk: 4, ..Default::default() },
        );
        let now = Instant::now();
        let rid =
            sched.submit(Request::Generate { prompt: vec![8; 10], max_new: 3 }, now).unwrap();
        let done = run_to_completion(&mut sched, 100);
        assert_eq!(done.len(), 1);
        let m = sched.metrics();
        assert_eq!(m.timelines.len(), 1);
        let t = &m.timelines[0];
        assert_eq!(t.rid, rid);
        assert_eq!(t.count(Mark::Admit), 1);
        assert!(
            t.count(Mark::PrefillChunk) >= 2,
            "10-token prompt at chunk 4 feeds over several chunks, got {}",
            t.count(Mark::PrefillChunk)
        );
        assert_eq!(t.count(Mark::FirstToken), 1);
        assert_eq!(t.count(Mark::DecodeStep), 3, "one decode stamp per emitted token");
        assert_eq!(t.count(Mark::Finish), 1);
        // stamps are monotone and the breakdown is total-preserving
        assert!(t.first(Mark::Admit) <= t.first(Mark::FirstToken));
        assert!(t.first(Mark::FirstToken) <= t.first(Mark::Finish));
        let b = t.breakdown();
        assert_eq!(b.queue_ns + b.prefill_ns + b.decode_ns, b.total_ns);
        // the snapshot surfaces the timeline attribution summaries
        let snap = m.snapshot();
        assert_eq!(snap.counter("timelines_recorded_total"), 1);
        assert!(snap.has("request_prefill_ms"));
    }

    #[test]
    fn trivial_and_preempted_requests_still_get_timelines() {
        // preemption scenario (same shape as page_pressure test)
        let mut sched = ContinuousScheduler::new(
            MockBackend::new(256, 2, 16),
            ContinuousOpts { prefill_chunk: 4, ..Default::default() },
        );
        let now = Instant::now();
        sched.submit(Request::Generate { prompt: vec![5; 4], max_new: 12 }, now).unwrap();
        sched.submit(Request::Generate { prompt: vec![9; 4], max_new: 12 }, now).unwrap();
        let trivial =
            sched.submit(Request::Generate { prompt: vec![1; 2], max_new: 0 }, now).unwrap();
        let done = run_to_completion(&mut sched, 300);
        assert_eq!(done.len(), 3);
        let m = sched.metrics();
        assert_eq!(m.timelines.len(), 3);
        let preempted: usize =
            m.timelines.iter().map(|t| t.count(Mark::Preempt)).sum();
        let resumed: usize = m.timelines.iter().map(|t| t.count(Mark::Resume)).sum();
        assert!(preempted >= 1, "tight arena stamps a preempt mark");
        assert!(resumed >= 1, "resume is stamped too");
        let tv = m.timelines.iter().find(|t| t.rid == trivial).unwrap();
        assert_eq!(tv.count(Mark::Finish), 1);
        assert_eq!(tv.count(Mark::Admit), 0, "trivial requests never admit");
    }

    #[test]
    fn shared_prefix_admission_skips_cached_prompt_tokens() {
        // same prompt twice, sequentially: the second admission claims
        // the prefix the first one published at retirement and feeds only
        // the final prompt token — identical output, almost no prefill
        let mut sched = ContinuousScheduler::new(
            MockBackend::shared(256, 4, 0),
            ContinuousOpts { prefill_chunk: 16, ..Default::default() },
        );
        let now = Instant::now();
        let prompt = vec![42u8; 12];
        sched.submit(Request::Generate { prompt: prompt.clone(), max_new: 2 }, now).unwrap();
        let done = run_to_completion(&mut sched, 100);
        assert_eq!(done.len(), 1);
        let first_prefill = sched.metrics().prefill_tokens;
        assert_eq!(first_prefill, 12, "cold cache prefills the whole prompt");
        sched.submit(Request::Generate { prompt, max_new: 2 }, now).unwrap();
        let done = run_to_completion(&mut sched, 100);
        assert_eq!(done.len(), 1);
        match &done[0].1 {
            Response::Generated { text } => assert_eq!(text, &counting_text(42, 2)),
            other => panic!("unexpected {other:?}"),
        }
        let m = sched.metrics();
        assert_eq!(m.prefix_hits, 1, "second admission hits the shared prefix");
        assert_eq!(m.prefix_tokens, 11, "claim caps at prompt_len - 1");
        assert_eq!(
            m.prefill_tokens, first_prefill,
            "the claimed admission feeds one pending token — no prefill chunk at all"
        );
        let kv = m.kv_cache.expect("mock reports cache stats");
        assert!(kv.prefix_hits >= 1 && kv.prefix_hit_rows >= 11);
        assert!(kv.cow_splits >= 1, "the 3-token tail of the cap splits mid-page");
    }

    #[test]
    fn score_requests_accumulate_over_chunks() {
        let mut sched = ContinuousScheduler::new(
            MockBackend::new(256, 4, 0),
            ContinuousOpts { prefill_chunk: 3, ..Default::default() },
        );
        let now = Instant::now();
        // continuation that exactly follows the mock's counting rule: each
        // forced token is the argmax, so its logprob is the one-hot lse gap
        let prompt = vec![20u8; 5];
        let continuation: Vec<u8> = counting_text(20, 4);
        let rid = sched.submit(Request::Score { prompt, continuation }, now).unwrap();
        let done = run_to_completion(&mut sched, 50);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, rid);
        let Response::Scored { logprob } = &done[0].1 else {
            panic!("expected score, got {:?}", done[0].1);
        };
        // per-token logprob of the one-hot row: 5 - ln(e^5 + 255)
        let per = 5.0 - ((5f64).exp() + 255.0).ln();
        assert!((logprob - 4.0 * per).abs() < 1e-4, "{logprob} vs {}", 4.0 * per);
        assert_eq!(sched.metrics().tokens_out, 4);
    }
}
