//! Continuous-batching scheduler: admission control, chunked prefill, and
//! KV-page preemption on top of the paged cache.
//!
//! The lockstep loop in [`crate::coordinator::server`] drains a batch and
//! runs it to completion before admitting anything else, so one long
//! generation stalls every short request behind it. This subsystem
//! replaces that with *continuous batching*: a fresh step batch is formed
//! every iteration, sequences join and leave per token, and the paged KV
//! arena — not the batch boundary — is the unit of resource accounting.
//!
//! - [`queue::RequestQueue`] is the admission-controlled front door:
//!   bounded depth, a token budget over everything in flight, and
//!   structural checks (context fit, non-empty prompt). Refusals are
//!   structured [`queue::Backpressure`] errors, surfaced to clients as
//!   `Response::Rejected` — load shedding a client can reason about.
//! - [`scheduler::ContinuousScheduler`] owns the loop: each
//!   [`scheduler::ContinuousScheduler::step`] retires finished sequences
//!   (freeing their KV pages immediately), resumes preempted sequences,
//!   admits queued requests the moment pages and token budget allow, and
//!   runs **one ragged forward** (`eval::native_fwd::forward_ragged`)
//!   mixing one-token decode steps with bounded *prefill chunks* — long
//!   prompts are fed `prefill_chunk` tokens per step, so a prefill never
//!   monopolizes a step.
//! - **Preemption**: when the arena runs out of pages, the
//!   lowest-priority (most recently admitted) sequence is parked via
//!   [`crate::kvcache::PagedKvCache::spill`] — *quantize-to-spill*
//!   compresses its pages through the existing `KvQuantizer` instead of
//!   dropping and recomputing them — and resumed when pages free up.
//!   With f32 pages, preempt + resume is bit-exact
//!   (`tests/continuous_parity.rs`).
//!
//! The scheduler is generic over [`scheduler::SeqBackend`] — implemented
//! by `coordinator::server::CachedNativeBackend` (dense or
//! streamed-compressed weights) and by a mock in the unit tests.
//! `coordinator::server::start_continuous` runs it on the server thread
//! behind the unchanged `ServerHandle::submit` interface;
//! `glvq serve --continuous` exposes it on the CLI.

pub mod queue;
pub mod scheduler;

pub use queue::{Backpressure, QueueOpts, RejectionCounts, RequestQueue};
pub use scheduler::{ContinuousOpts, ContinuousScheduler, SeqBackend};
