//! Admission-controlled request queue — the continuous scheduler's front
//! door.
//!
//! Requests that can *never* run (prompt + output exceeding the model
//! context, empty prompts, a single request bigger than the whole
//! in-flight token budget) and requests arriving while the bounded queue
//! is full are refused **at submission** with a structured
//! [`Backpressure`] error instead of being dropped or queued forever —
//! the client sees exactly why and can shed or retry. Everything else
//! waits in FIFO order; the scheduler pops entries as token budget and KV
//! pages free up.

use std::collections::VecDeque;
use std::fmt;
use std::time::Instant;

use crate::coordinator::server::Request;

/// Why a request was refused at the door. Carried to clients as
/// `Response::Rejected { reason }`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backpressure {
    /// The bounded queue is at capacity: retry later or shed load.
    QueueFull {
        /// requests currently waiting
        depth: usize,
        /// configured queue bound
        limit: usize,
    },
    /// This request alone exceeds the in-flight token budget — it could
    /// never be admitted, even against an idle server.
    BudgetExceeded {
        /// tokens the request needs (prompt + output)
        need: usize,
        /// configured `max_tokens_in_flight`
        budget: usize,
    },
    /// Prompt + requested output cannot fit the model context.
    ContextOverflow {
        /// tokens the request needs (prompt + output)
        need: usize,
        /// model context length
        seq_len: usize,
    },
    /// Continuous mode schedules against cached prompt positions and
    /// requires a non-empty prompt.
    EmptyPrompt,
    /// The request's KV footprint exceeds the whole page arena — it could
    /// never run to completion, even alone.
    ArenaTooSmall {
        /// pages the request would eventually hold
        need_pages: usize,
        /// hard arena capacity
        capacity: usize,
    },
}

impl fmt::Display for Backpressure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Backpressure::QueueFull { depth, limit } => {
                write!(f, "queue full ({depth}/{limit} requests waiting)")
            }
            Backpressure::BudgetExceeded { need, budget } => {
                write!(f, "request needs {need} tokens, in-flight budget is {budget}")
            }
            Backpressure::ContextOverflow { need, seq_len } => {
                write!(f, "request needs {need} tokens, model context is {seq_len}")
            }
            Backpressure::EmptyPrompt => {
                write!(f, "continuous mode requires a non-empty prompt")
            }
            Backpressure::ArenaTooSmall { need_pages, capacity } => {
                write!(f, "request needs {need_pages} kv pages, arena capacity is {capacity}")
            }
        }
    }
}

impl Backpressure {
    /// Stable short key naming the variant — the label value of the
    /// per-reason `rejections_total` Prometheus family and the field name
    /// in [`RejectionCounts`].
    pub fn key(&self) -> &'static str {
        match self {
            Backpressure::QueueFull { .. } => "queue_full",
            Backpressure::BudgetExceeded { .. } => "budget",
            Backpressure::ContextOverflow { .. } => "context_overflow",
            Backpressure::EmptyPrompt => "empty_prompt",
            Backpressure::ArenaTooSmall { .. } => "arena_too_small",
        }
    }
}

/// Per-variant rejection tally — one counter per [`Backpressure`] reason
/// instead of a single aggregate. The distinction matters operationally:
/// `queue_full` means the replica is saturated (a router should re-route
/// or shed), while `context_overflow` / `empty_prompt` / `budget` mean
/// the *request* is infeasible and would be refused by every replica.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RejectionCounts {
    pub queue_full: usize,
    pub budget: usize,
    pub context_overflow: usize,
    pub empty_prompt: usize,
    pub arena_too_small: usize,
}

impl RejectionCounts {
    /// Tally one refusal under its variant.
    pub fn count(&mut self, bp: &Backpressure) {
        match bp {
            Backpressure::QueueFull { .. } => self.queue_full += 1,
            Backpressure::BudgetExceeded { .. } => self.budget += 1,
            Backpressure::ContextOverflow { .. } => self.context_overflow += 1,
            Backpressure::EmptyPrompt => self.empty_prompt += 1,
            Backpressure::ArenaTooSmall { .. } => self.arena_too_small += 1,
        }
    }

    /// Total refusals across all variants (the pre-breakdown aggregate).
    pub fn total(&self) -> usize {
        self.queue_full
            + self.budget
            + self.context_overflow
            + self.empty_prompt
            + self.arena_too_small
    }

    /// `(variant key, count)` pairs in a fixed order, for metric export.
    pub fn breakdown(&self) -> [(&'static str, usize); 5] {
        [
            ("queue_full", self.queue_full),
            ("budget", self.budget),
            ("context_overflow", self.context_overflow),
            ("empty_prompt", self.empty_prompt),
            ("arena_too_small", self.arena_too_small),
        ]
    }
}

/// Tokens a request will occupy end to end: prompt plus everything it
/// emits (generated tokens) or forces (scored continuation). This is the
/// unit of the in-flight budget and of context-fit checks.
pub fn token_need(request: &Request) -> usize {
    match request {
        Request::Generate { prompt, max_new } => prompt.len() + max_new,
        Request::Score { prompt, continuation } => prompt.len() + continuation.len(),
    }
}

/// Queue construction options.
#[derive(Clone, Copy, Debug)]
pub struct QueueOpts {
    /// max requests waiting for admission before [`Backpressure::QueueFull`]
    pub max_depth: usize,
    /// token budget across all admitted (running + preempted) requests;
    /// also the per-request ceiling (see [`Backpressure::BudgetExceeded`])
    pub max_tokens_in_flight: usize,
}

impl Default for QueueOpts {
    fn default() -> Self {
        QueueOpts { max_depth: 256, max_tokens_in_flight: 4096 }
    }
}

/// One admitted-but-not-yet-running request.
pub struct Queued {
    /// scheduler-assigned request id (stable through the response)
    pub id: u64,
    pub request: Request,
    /// submission time, for queue-wait and time-to-first-token metrics
    pub submitted: Instant,
    /// cached [`token_need`] of `request`
    pub need: usize,
}

/// Bounded FIFO of requests that passed the structural admission checks.
pub struct RequestQueue {
    opts: QueueOpts,
    pending: VecDeque<Queued>,
    next_id: u64,
}

impl RequestQueue {
    pub fn new(opts: QueueOpts) -> RequestQueue {
        RequestQueue { opts, pending: VecDeque::new(), next_id: 0 }
    }

    /// Requests currently waiting.
    pub fn depth(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// The configured limits.
    pub fn opts(&self) -> QueueOpts {
        self.opts
    }

    /// Admit a request to the waiting line, or refuse it with the exact
    /// reason. `seq_len` is the model context the request must fit.
    pub fn push(
        &mut self,
        request: Request,
        submitted: Instant,
        seq_len: usize,
    ) -> Result<u64, Backpressure> {
        let prompt_len = match &request {
            Request::Generate { prompt, .. } | Request::Score { prompt, .. } => prompt.len(),
        };
        if prompt_len == 0 {
            return Err(Backpressure::EmptyPrompt);
        }
        let need = token_need(&request);
        // a request's final token is never fed into the cache (a Gen's
        // last sample and a Score's last continuation token only need
        // logits at the position before them), so it fits iff its other
        // `need - 1` tokens fit the position table — the same bound the
        // lockstep loop enforces implicitly
        if need > seq_len + 1 {
            return Err(Backpressure::ContextOverflow { need, seq_len });
        }
        if need > self.opts.max_tokens_in_flight {
            return Err(Backpressure::BudgetExceeded {
                need,
                budget: self.opts.max_tokens_in_flight,
            });
        }
        if self.pending.len() >= self.opts.max_depth {
            return Err(Backpressure::QueueFull {
                depth: self.pending.len(),
                limit: self.opts.max_depth,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back(Queued { id, request, submitted, need });
        Ok(id)
    }

    /// Reserve the next request id without queueing anything — used for
    /// requests answered at submission (e.g. `max_new == 0`).
    pub fn reserve_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// The request next in line, if any.
    pub fn front(&self) -> Option<&Queued> {
        self.pending.front()
    }

    /// Pop the request next in line.
    pub fn pop(&mut self) -> Option<Queued> {
        self.pending.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(prompt: usize, max_new: usize) -> Request {
        Request::Generate { prompt: vec![b'a'; prompt], max_new }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut q = RequestQueue::new(QueueOpts::default());
        let a = q.push(gen(3, 4), Instant::now(), 64).unwrap();
        let b = q.push(gen(5, 2), Instant::now(), 64).unwrap();
        assert_ne!(a, b);
        assert_eq!(q.depth(), 2);
        let first = q.pop().unwrap();
        assert_eq!(first.id, a);
        assert_eq!(first.need, 7);
        assert_eq!(q.pop().unwrap().id, b);
        assert!(q.pop().is_none());
    }

    #[test]
    fn structural_rejections() {
        let mut q = RequestQueue::new(QueueOpts { max_depth: 8, max_tokens_in_flight: 32 });
        // empty prompt
        assert_eq!(q.push(gen(0, 4), Instant::now(), 64), Err(Backpressure::EmptyPrompt));
        // context overflow: prompt + output > seq_len
        assert_eq!(
            q.push(gen(30, 40), Instant::now(), 64),
            Err(Backpressure::ContextOverflow { need: 70, seq_len: 64 })
        );
        // single request above the whole in-flight budget
        assert_eq!(
            q.push(gen(30, 10), Instant::now(), 64),
            Err(Backpressure::BudgetExceeded { need: 40, budget: 32 })
        );
        // score requests account prompt + continuation
        let score = Request::Score { prompt: vec![b'a'; 3], continuation: vec![b'b'; 4] };
        assert_eq!(token_need(&score), 7);
        assert!(q.push(score, Instant::now(), 64).is_ok());
        assert_eq!(q.depth(), 1, "rejected requests never enter the queue");
    }

    #[test]
    fn bounded_depth_backpressure() {
        let mut q = RequestQueue::new(QueueOpts { max_depth: 2, max_tokens_in_flight: 1024 });
        q.push(gen(2, 2), Instant::now(), 64).unwrap();
        q.push(gen(2, 2), Instant::now(), 64).unwrap();
        let err = q.push(gen(2, 2), Instant::now(), 64).unwrap_err();
        assert_eq!(err, Backpressure::QueueFull { depth: 2, limit: 2 });
        assert!(err.to_string().contains("queue full"));
        // popping frees a slot
        q.pop().unwrap();
        assert!(q.push(gen(2, 2), Instant::now(), 64).is_ok());
    }

    #[test]
    fn rejection_counts_tally_per_variant() {
        let mut c = RejectionCounts::default();
        c.count(&Backpressure::QueueFull { depth: 1, limit: 1 });
        c.count(&Backpressure::QueueFull { depth: 2, limit: 2 });
        c.count(&Backpressure::EmptyPrompt);
        c.count(&Backpressure::ArenaTooSmall { need_pages: 9, capacity: 4 });
        assert_eq!(c.queue_full, 2);
        assert_eq!(c.empty_prompt, 1);
        assert_eq!(c.arena_too_small, 1);
        assert_eq!(c.total(), 4);
        let by_key: std::collections::BTreeMap<_, _> = c.breakdown().into_iter().collect();
        assert_eq!(by_key["queue_full"], 2);
        assert_eq!(by_key["budget"], 0);
        assert_eq!(c.breakdown().len(), 5, "every variant exports a counter");
        // keys match Backpressure::key
        assert_eq!(Backpressure::EmptyPrompt.key(), "empty_prompt");
        assert_eq!(Backpressure::BudgetExceeded { need: 1, budget: 0 }.key(), "budget");
    }

    #[test]
    fn queue_properties_under_random_interleavings() {
        // FIFO ordering, exact depth() accounting, and id uniqueness
        // (push + reserve_id) must survive arbitrary push/pop/reject
        // interleavings — the queue is instantiated once per replica, so
        // these are cluster-wide invariants, not single-server ones.
        use std::collections::{BTreeSet, VecDeque};
        crate::util::proptest::proptest(64, |rig| {
            let max_depth = rig.usize_in(1, 8);
            let budget = rig.usize_in(8, 64);
            let seq_len = 64usize;
            let mut q = RequestQueue::new(QueueOpts { max_depth, max_tokens_in_flight: budget });
            let mut expect: VecDeque<(u64, usize)> = VecDeque::new();
            let mut seen: BTreeSet<u64> = BTreeSet::new();
            for _ in 0..rig.usize_in(1, 200) {
                match rig.usize_in(0, 3) {
                    0 | 1 => {
                        let prompt = rig.usize_in(0, 80);
                        let max_new = rig.usize_in(0, 40);
                        let req = Request::Generate { prompt: vec![b'x'; prompt], max_new };
                        let need = token_need(&req);
                        match q.push(req, Instant::now(), seq_len) {
                            Ok(id) => {
                                assert!(prompt > 0 && need <= seq_len + 1 && need <= budget);
                                assert!(expect.len() < max_depth);
                                assert!(seen.insert(id), "duplicate id {id}");
                                expect.push_back((id, need));
                            }
                            Err(bp) => match bp {
                                Backpressure::EmptyPrompt => assert_eq!(prompt, 0),
                                Backpressure::ContextOverflow { .. } => {
                                    assert!(need > seq_len + 1)
                                }
                                Backpressure::BudgetExceeded { .. } => assert!(need > budget),
                                Backpressure::QueueFull { .. } => {
                                    assert_eq!(expect.len(), max_depth)
                                }
                                Backpressure::ArenaTooSmall { .. } => {
                                    panic!("queue never checks the arena")
                                }
                            },
                        }
                    }
                    2 => match (q.pop(), expect.pop_front()) {
                        (None, None) => {}
                        (Some(g), Some((id, need))) => {
                            assert_eq!(g.id, id, "FIFO order violated");
                            assert_eq!(g.need, need, "cached need diverged");
                        }
                        (g, w) => panic!("pop mismatch: got {:?} want {w:?}", g.map(|x| x.id)),
                    },
                    _ => {
                        let id = q.reserve_id();
                        assert!(seen.insert(id), "reserved id {id} reused");
                    }
                }
                assert_eq!(q.depth(), expect.len(), "depth accounting diverged");
                assert_eq!(q.is_empty(), expect.is_empty());
            }
            // drain: the survivors leave in exact submission order
            while let Some((id, _)) = expect.pop_front() {
                assert_eq!(q.pop().unwrap().id, id);
            }
            assert!(q.pop().is_none());
        });
    }

    #[test]
    fn backpressure_messages_are_structured() {
        let cases: Vec<(Backpressure, &str)> = vec![
            (Backpressure::QueueFull { depth: 9, limit: 9 }, "9/9"),
            (Backpressure::BudgetExceeded { need: 10, budget: 5 }, "budget is 5"),
            (Backpressure::ContextOverflow { need: 99, seq_len: 64 }, "context is 64"),
            (Backpressure::EmptyPrompt, "non-empty prompt"),
            (Backpressure::ArenaTooSmall { need_pages: 40, capacity: 16 }, "capacity is 16"),
        ];
        for (bp, frag) in cases {
            assert!(bp.to_string().contains(frag), "{bp} missing {frag}");
        }
    }
}
