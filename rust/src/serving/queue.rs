//! Admission-controlled request queue — the continuous scheduler's front
//! door.
//!
//! Requests that can *never* run (prompt + output exceeding the model
//! context, empty prompts, a single request bigger than the whole
//! in-flight token budget) and requests arriving while the bounded queue
//! is full are refused **at submission** with a structured
//! [`Backpressure`] error instead of being dropped or queued forever —
//! the client sees exactly why and can shed or retry. Everything else
//! waits in FIFO order; the scheduler pops entries as token budget and KV
//! pages free up.

use std::collections::VecDeque;
use std::fmt;
use std::time::Instant;

use crate::coordinator::server::Request;

/// Why a request was refused at the door. Carried to clients as
/// `Response::Rejected { reason }`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backpressure {
    /// The bounded queue is at capacity: retry later or shed load.
    QueueFull {
        /// requests currently waiting
        depth: usize,
        /// configured queue bound
        limit: usize,
    },
    /// This request alone exceeds the in-flight token budget — it could
    /// never be admitted, even against an idle server.
    BudgetExceeded {
        /// tokens the request needs (prompt + output)
        need: usize,
        /// configured `max_tokens_in_flight`
        budget: usize,
    },
    /// Prompt + requested output cannot fit the model context.
    ContextOverflow {
        /// tokens the request needs (prompt + output)
        need: usize,
        /// model context length
        seq_len: usize,
    },
    /// Continuous mode schedules against cached prompt positions and
    /// requires a non-empty prompt.
    EmptyPrompt,
    /// The request's KV footprint exceeds the whole page arena — it could
    /// never run to completion, even alone.
    ArenaTooSmall {
        /// pages the request would eventually hold
        need_pages: usize,
        /// hard arena capacity
        capacity: usize,
    },
}

impl fmt::Display for Backpressure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Backpressure::QueueFull { depth, limit } => {
                write!(f, "queue full ({depth}/{limit} requests waiting)")
            }
            Backpressure::BudgetExceeded { need, budget } => {
                write!(f, "request needs {need} tokens, in-flight budget is {budget}")
            }
            Backpressure::ContextOverflow { need, seq_len } => {
                write!(f, "request needs {need} tokens, model context is {seq_len}")
            }
            Backpressure::EmptyPrompt => {
                write!(f, "continuous mode requires a non-empty prompt")
            }
            Backpressure::ArenaTooSmall { need_pages, capacity } => {
                write!(f, "request needs {need_pages} kv pages, arena capacity is {capacity}")
            }
        }
    }
}

/// Tokens a request will occupy end to end: prompt plus everything it
/// emits (generated tokens) or forces (scored continuation). This is the
/// unit of the in-flight budget and of context-fit checks.
pub fn token_need(request: &Request) -> usize {
    match request {
        Request::Generate { prompt, max_new } => prompt.len() + max_new,
        Request::Score { prompt, continuation } => prompt.len() + continuation.len(),
    }
}

/// Queue construction options.
#[derive(Clone, Copy, Debug)]
pub struct QueueOpts {
    /// max requests waiting for admission before [`Backpressure::QueueFull`]
    pub max_depth: usize,
    /// token budget across all admitted (running + preempted) requests;
    /// also the per-request ceiling (see [`Backpressure::BudgetExceeded`])
    pub max_tokens_in_flight: usize,
}

impl Default for QueueOpts {
    fn default() -> Self {
        QueueOpts { max_depth: 256, max_tokens_in_flight: 4096 }
    }
}

/// One admitted-but-not-yet-running request.
pub struct Queued {
    /// scheduler-assigned request id (stable through the response)
    pub id: u64,
    pub request: Request,
    /// submission time, for queue-wait and time-to-first-token metrics
    pub submitted: Instant,
    /// cached [`token_need`] of `request`
    pub need: usize,
}

/// Bounded FIFO of requests that passed the structural admission checks.
pub struct RequestQueue {
    opts: QueueOpts,
    pending: VecDeque<Queued>,
    next_id: u64,
}

impl RequestQueue {
    pub fn new(opts: QueueOpts) -> RequestQueue {
        RequestQueue { opts, pending: VecDeque::new(), next_id: 0 }
    }

    /// Requests currently waiting.
    pub fn depth(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// The configured limits.
    pub fn opts(&self) -> QueueOpts {
        self.opts
    }

    /// Admit a request to the waiting line, or refuse it with the exact
    /// reason. `seq_len` is the model context the request must fit.
    pub fn push(
        &mut self,
        request: Request,
        submitted: Instant,
        seq_len: usize,
    ) -> Result<u64, Backpressure> {
        let prompt_len = match &request {
            Request::Generate { prompt, .. } | Request::Score { prompt, .. } => prompt.len(),
        };
        if prompt_len == 0 {
            return Err(Backpressure::EmptyPrompt);
        }
        let need = token_need(&request);
        // a request's final token is never fed into the cache (a Gen's
        // last sample and a Score's last continuation token only need
        // logits at the position before them), so it fits iff its other
        // `need - 1` tokens fit the position table — the same bound the
        // lockstep loop enforces implicitly
        if need > seq_len + 1 {
            return Err(Backpressure::ContextOverflow { need, seq_len });
        }
        if need > self.opts.max_tokens_in_flight {
            return Err(Backpressure::BudgetExceeded {
                need,
                budget: self.opts.max_tokens_in_flight,
            });
        }
        if self.pending.len() >= self.opts.max_depth {
            return Err(Backpressure::QueueFull {
                depth: self.pending.len(),
                limit: self.opts.max_depth,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back(Queued { id, request, submitted, need });
        Ok(id)
    }

    /// Reserve the next request id without queueing anything — used for
    /// requests answered at submission (e.g. `max_new == 0`).
    pub fn reserve_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// The request next in line, if any.
    pub fn front(&self) -> Option<&Queued> {
        self.pending.front()
    }

    /// Pop the request next in line.
    pub fn pop(&mut self) -> Option<Queued> {
        self.pending.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(prompt: usize, max_new: usize) -> Request {
        Request::Generate { prompt: vec![b'a'; prompt], max_new }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut q = RequestQueue::new(QueueOpts::default());
        let a = q.push(gen(3, 4), Instant::now(), 64).unwrap();
        let b = q.push(gen(5, 2), Instant::now(), 64).unwrap();
        assert_ne!(a, b);
        assert_eq!(q.depth(), 2);
        let first = q.pop().unwrap();
        assert_eq!(first.id, a);
        assert_eq!(first.need, 7);
        assert_eq!(q.pop().unwrap().id, b);
        assert!(q.pop().is_none());
    }

    #[test]
    fn structural_rejections() {
        let mut q = RequestQueue::new(QueueOpts { max_depth: 8, max_tokens_in_flight: 32 });
        // empty prompt
        assert_eq!(q.push(gen(0, 4), Instant::now(), 64), Err(Backpressure::EmptyPrompt));
        // context overflow: prompt + output > seq_len
        assert_eq!(
            q.push(gen(30, 40), Instant::now(), 64),
            Err(Backpressure::ContextOverflow { need: 70, seq_len: 64 })
        );
        // single request above the whole in-flight budget
        assert_eq!(
            q.push(gen(30, 10), Instant::now(), 64),
            Err(Backpressure::BudgetExceeded { need: 40, budget: 32 })
        );
        // score requests account prompt + continuation
        let score = Request::Score { prompt: vec![b'a'; 3], continuation: vec![b'b'; 4] };
        assert_eq!(token_need(&score), 7);
        assert!(q.push(score, Instant::now(), 64).is_ok());
        assert_eq!(q.depth(), 1, "rejected requests never enter the queue");
    }

    #[test]
    fn bounded_depth_backpressure() {
        let mut q = RequestQueue::new(QueueOpts { max_depth: 2, max_tokens_in_flight: 1024 });
        q.push(gen(2, 2), Instant::now(), 64).unwrap();
        q.push(gen(2, 2), Instant::now(), 64).unwrap();
        let err = q.push(gen(2, 2), Instant::now(), 64).unwrap_err();
        assert_eq!(err, Backpressure::QueueFull { depth: 2, limit: 2 });
        assert!(err.to_string().contains("queue full"));
        // popping frees a slot
        q.pop().unwrap();
        assert!(q.push(gen(2, 2), Instant::now(), 64).is_ok());
    }

    #[test]
    fn backpressure_messages_are_structured() {
        let cases: Vec<(Backpressure, &str)> = vec![
            (Backpressure::QueueFull { depth: 9, limit: 9 }, "9/9"),
            (Backpressure::BudgetExceeded { need: 10, budget: 5 }, "budget is 5"),
            (Backpressure::ContextOverflow { need: 99, seq_len: 64 }, "context is 64"),
            (Backpressure::EmptyPrompt, "non-empty prompt"),
            (Backpressure::ArenaTooSmall { need_pages: 40, capacity: 16 }, "capacity is 16"),
        ];
        for (bp, frag) in cases {
            assert!(bp.to_string().contains(frag), "{bp} missing {frag}");
        }
    }
}
