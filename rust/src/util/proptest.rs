//! Mini property-testing harness (proptest is not in the vendored crate
//! set). Seeded, reproducible, with failure reporting that prints the
//! offending case index + seed so a failure can be replayed exactly.
//!
//! Usage:
//! ```ignore
//! proptest(100, |rig| {
//!     let n = rig.usize_in(1, 64);
//!     let xs = rig.vec_f32(n, -1.0, 1.0);
//!     check(roundtrip(&xs) == xs, "roundtrip");
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handle.
pub struct Rig {
    pub rng: Rng,
    pub case: usize,
}

impl Rig {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal_f32() * scale).collect()
    }
}

/// Run `cases` random cases of `body`. Panics with case/seed on failure
/// (body is expected to assert!/panic! on property violation).
pub fn proptest<F: FnMut(&mut Rig)>(cases: usize, mut body: F) {
    proptest_seeded(0xC0FFEE, cases, &mut body)
}

pub fn proptest_seeded<F: FnMut(&mut Rig)>(seed: u64, cases: usize, body: &mut F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rig = Rig { rng: Rng::new(case_seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rig)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        proptest(50, |rig| {
            let a = rig.usize_in(0, 100);
            let b = rig.usize_in(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failing_case() {
        let r = std::panic::catch_unwind(|| {
            proptest(50, |rig| {
                let n = rig.usize_in(0, 100);
                assert!(n < 95, "n={n}");
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string>".into());
        assert!(msg.contains("property failed at case"), "{msg}");
    }

    #[test]
    fn generators_in_bounds() {
        proptest(100, |rig| {
            let x = rig.f32_in(-2.0, 3.0);
            assert!((-2.0..=3.0).contains(&x));
            let n = rig.usize_in(3, 7);
            assert!((3..=7).contains(&n));
            let v = rig.vec_f32(n, 0.0, 1.0);
            assert_eq!(v.len(), n);
        });
    }
}
