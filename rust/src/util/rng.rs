//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core, with the
//! distribution helpers the rest of the crate needs (uniform, normal,
//! categorical). Reproducibility is a hard requirement for the experiment
//! harness, so all randomness in the crate flows through this module.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-thread / per-group RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * self.f64();
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Student-t with `nu` degrees of freedom — used by the synthetic weight
    /// generators to produce the heavy-tailed distributions the paper's
    /// companding targets.
    pub fn student_t(&mut self, nu: f64) -> f64 {
        // t = Z / sqrt(ChiSq_nu / nu); ChiSq via sum of squared normals is
        // fine for small integer nu, otherwise use the gamma-free ratio form.
        let z = self.normal();
        let mut chi = 0.0;
        let k = nu.round().max(1.0) as usize;
        for _ in 0..k {
            let n = self.normal();
            chi += n * n;
        }
        z / (chi / k as f64).sqrt()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill with standard normals scaled by `scale`.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn student_t_heavier_tailed_than_normal() {
        let mut r = Rng::new(6);
        let n = 30_000;
        let kurt = |xs: &[f64]| {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - m).powi(4)).sum::<f64>() / xs.len() as f64 / (v * v)
        };
        let tn: Vec<f64> = (0..n).map(|_| r.student_t(4.0)).collect();
        let nn: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        assert!(kurt(&tn) > kurt(&nn) + 0.5, "{} vs {}", kurt(&tn), kurt(&nn));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0, 3.0];
        let mut c1 = 0;
        for _ in 0..40_000 {
            if r.categorical(&w) == 1 {
                c1 += 1;
            }
        }
        let frac = c1 as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
