//! Minimal JSON codec (parser + writer) for the artifact manifest, run
//! configs and experiment reports.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bool, null). Numbers are kept as f64 — sufficient for shapes,
//! hyperparameters and metrics. No external deps (serde is not vendored).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so serialization
/// is deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---------------- builders ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
    }

    // ---------------- parse ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let b = text.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------------- serialize ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Bool(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip_preserves_value() {
        let src = r#"{"models":{"s":{"params":[{"name":"emb","shape":[256,128],"quantizable":false}]}},"version":1}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let v = Json::Str("π → \"q\"\t∎".into());
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
            let m = Json::parse(&text).unwrap();
            assert_eq!(m.get("version").as_usize(), Some(1));
            assert!(m.get("glvq").as_obj().is_some());
        }
    }
}
