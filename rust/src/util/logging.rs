//! Leveled stderr logger + scoped wall-clock timers.
//!
//! Level is process-global, set once from the CLI (`-v`, `-q`, or
//! `GLVQ_LOG=debug`). Deliberately tiny: no formatting machinery beyond
//! `format!`, no timestamps on quiet levels. Debug-level lines can carry
//! a monotonic elapsed-time prefix ([`set_timestamps`], or
//! `GLVQ_LOG_TS=1`), and every emitted line can be routed through a
//! capture hook ([`set_hook`]) so tests can assert on log output.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static TIMESTAMPS: AtomicBool = AtomicBool::new(false);

/// Capture hook: receives `(level, formatted_line)` for every line that
/// passes the level filter, *instead of* stderr.
pub type LogHook = Arc<dyn Fn(Level, &str) + Send + Sync>;

fn hook_slot() -> &'static Mutex<Option<LogHook>> {
    static HOOK: OnceLock<Mutex<Option<LogHook>>> = OnceLock::new();
    HOOK.get_or_init(|| Mutex::new(None))
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Prefix Debug-level lines with monotonic elapsed seconds since the
/// first log call (`[DEBUG +1.234s]`).
pub fn set_timestamps(on: bool) {
    TIMESTAMPS.store(on, Ordering::Relaxed);
}

/// Install (`Some`) or remove (`None`) the capture hook. While installed,
/// log lines go to the hook instead of stderr — used by tests to capture
/// output.
pub fn set_hook(hook: Option<LogHook>) {
    *hook_slot().lock().unwrap() = hook;
}

/// Configure the level from `GLVQ_LOG` (error|warn|info|debug). Unknown
/// values leave the level unchanged and emit a warning, rather than
/// silently mapping to Info. `GLVQ_LOG_TS=1` additionally enables
/// Debug-level elapsed timestamps.
pub fn level_from_env() {
    if let Ok(v) = std::env::var("GLVQ_LOG") {
        match v.as_str() {
            "error" => set_level(Level::Error),
            "warn" => set_level(Level::Warn),
            "info" => set_level(Level::Info),
            "debug" => set_level(Level::Debug),
            other => log(
                Level::Warn,
                &format!("unknown GLVQ_LOG value {other:?} (expected error|warn|info|debug); keeping current level"),
            ),
        }
    }
    if let Ok(v) = std::env::var("GLVQ_LOG_TS") {
        set_timestamps(v != "0" && !v.is_empty());
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

fn log_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

pub fn log(l: Level, msg: &str) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let line = if l == Level::Debug && TIMESTAMPS.load(Ordering::Relaxed) {
        format!("[{tag} +{:.3}s] {msg}", log_epoch().elapsed().as_secs_f64())
    } else {
        format!("[{tag}] {msg}")
    };
    let hook = hook_slot().lock().unwrap().clone();
    match hook {
        Some(h) => h(l, &line),
        None => eprintln!("{line}"),
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($t)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($t)*)) };
}

#[macro_export]
macro_rules! debuglog {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($t)*)) };
}

/// RAII wall-clock timer: logs at Debug on drop.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: &str) -> Timer {
        Timer { label: label.to_string(), start: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        log(Level::Debug, &format!("{}: {:.1} ms", self.label, self.elapsed_ms()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Level, timestamps and the hook are process-global; serialize the
    // tests that mutate them so parallel test threads don't interleave.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn level_ordering() {
        let _l = test_lock();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn timer_measures_time() {
        let t = Timer::new("t");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn hook_captures_formatted_lines() {
        let _l = test_lock();
        let captured: Arc<Mutex<Vec<(Level, String)>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = captured.clone();
        set_hook(Some(Arc::new(move |l, line: &str| {
            sink.lock().unwrap().push((l, line.to_string()));
        })));
        log(Level::Warn, "hook-test-alpha");
        log(Level::Error, "hook-test-beta");
        set_hook(None);
        // the hook is process-global and tests run in parallel: filter to
        // this test's own lines instead of asserting on totals
        let got = captured.lock().unwrap();
        assert!(got
            .iter()
            .any(|(l, s)| *l == Level::Warn && s == "[WARN ] hook-test-alpha"));
        assert!(got
            .iter()
            .any(|(l, s)| *l == Level::Error && s == "[ERROR] hook-test-beta"));
    }

    #[test]
    fn debug_timestamps_prefix_elapsed_seconds() {
        let _l = test_lock();
        let captured: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = captured.clone();
        set_hook(Some(Arc::new(move |_, line: &str| {
            sink.lock().unwrap().push(line.to_string());
        })));
        set_level(Level::Debug);
        set_timestamps(true);
        log(Level::Debug, "ts-test-line");
        // timestamps apply to Debug lines only
        log(Level::Info, "ts-test-info");
        set_timestamps(false);
        set_level(Level::Info);
        set_hook(None);
        let got = captured.lock().unwrap();
        let dbg = got.iter().find(|s| s.ends_with("ts-test-line")).unwrap();
        assert!(dbg.starts_with("[DEBUG +"), "{dbg}");
        assert!(dbg.contains("s] "), "{dbg}");
        let info = got.iter().find(|s| s.ends_with("ts-test-info")).unwrap();
        assert!(info.starts_with("[INFO ] "), "{info}");
    }

    #[test]
    fn unknown_env_value_warns_and_keeps_level() {
        let _l = test_lock();
        let before = LEVEL.load(Ordering::Relaxed);
        let captured: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = captured.clone();
        set_hook(Some(Arc::new(move |_, line: &str| {
            sink.lock().unwrap().push(line.to_string());
        })));
        std::env::set_var("GLVQ_LOG", "verbose");
        level_from_env();
        std::env::remove_var("GLVQ_LOG");
        set_hook(None);
        assert_eq!(LEVEL.load(Ordering::Relaxed), before, "unknown value must not change level");
        let got = captured.lock().unwrap();
        assert!(
            got.iter().any(|s| s.contains("unknown GLVQ_LOG value \"verbose\"")),
            "{got:?}"
        );
    }

    #[test]
    fn known_env_values_set_the_level() {
        let _l = test_lock();
        let before = LEVEL.load(Ordering::Relaxed);
        std::env::set_var("GLVQ_LOG", "warn");
        level_from_env();
        std::env::remove_var("GLVQ_LOG");
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        LEVEL.store(before, Ordering::Relaxed);
    }
}
