//! Leveled stderr logger + scoped wall-clock timers.
//!
//! Level is process-global, set once from the CLI (`-v`, `-q`, or
//! `GLVQ_LOG=debug`). Deliberately tiny: no formatting machinery beyond
//! `format!`, no timestamps on quiet levels.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    if let Ok(v) = std::env::var("GLVQ_LOG") {
        set_level(match v.as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        });
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, msg: &str) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($t)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($t)*)) };
}

#[macro_export]
macro_rules! debuglog {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($t)*)) };
}

/// RAII wall-clock timer: logs at Debug on drop.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: &str) -> Timer {
        Timer { label: label.to_string(), start: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        log(Level::Debug, &format!("{}: {:.1} ms", self.label, self.elapsed_ms()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn timer_measures_time() {
        let t = Timer::new("t");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
