//! Foundational utilities: deterministic PRNG, minimal JSON codec, logging,
//! and a small property-testing harness.
//!
//! These exist because the build is fully offline against a fixed vendored
//! crate set (no serde / rand / proptest available); each is a deliberate,
//! tested substrate rather than a stub.

pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
