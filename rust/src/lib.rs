//! # GLVQ — Grouped Lattice Vector Quantization for low-bit LLM compression
//!
//! Production-quality reproduction of *"Learning Grouped Lattice Vector
//! Quantizers for Low-Bit LLM Compression"* (NeurIPS 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the coordinator: quantization pipeline (Alg. 1 at
//!   model scope), salience-determined bit allocation, baselines, streaming
//!   decode runtime, batched serving, evaluation harness, CLI.
//! - **L2/L1 (python/, build-time only)** — JAX transformer + Pallas kernels,
//!   AOT-lowered to HLO text under `artifacts/`, loaded at runtime through
//!   the PJRT C API ([`runtime`]).
//!
//! Serving runs directly from compressed weights: the batched
//! multi-threaded [`coordinator::decode_stream::StreamingMatmul`] engine
//! decodes each group-panel once per batch and never materializes a full
//! dequantized layer; the [`shard`] subsystem spreads that decode over
//! persistent tensor-parallel workers partitioned along quantized group
//! boundaries, bit-identical to single-shard execution at any shard
//! count. Decode steps are O(T) per token through the paged,
//! optionally GLVQ-quantized KV cache in [`kvcache`] (prefill once, then
//! incremental one-token attention against cached K/V). Under heavy mixed
//! traffic the [`serving`] continuous-batching scheduler replaces the
//! lockstep batch boundary: admission-controlled queueing, chunked
//! prefill, per-token batch membership, and KV-page preemption with
//! quantize-to-spill. The [`cluster`] subsystem scales past one engine:
//! pipeline-parallel stage execution over the layer plan (bit-identical
//! at any stage count, composable with sharding) and a replicated-engine
//! router with draining and per-replica labeled metrics.
//!
//! Layout follows DESIGN.md §4; every public item is documented and every
//! module carries unit tests. The repo-root docs are the entry points:
//! `ARCHITECTURE.md` (module map + paper-section index) and `FORMAT.md`
//! (the byte-level `.glvq` container specification).

// Portable SIMD for the fused decode-GEMM kernels (kernels::fused),
// nightly-only behind the `simd` cargo feature; the scalar fused path is
// always compiled and remains the default.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod util;
pub mod obs;
pub mod linalg;
pub mod tensor;
pub mod lattice;
pub mod compand;
pub mod entropy;
pub mod quant;
pub mod kvcache;
pub mod data;
pub mod model;
pub mod salience;
pub mod glvq;
pub mod baselines;
pub mod runtime;
pub mod kernels;
pub mod coordinator;
pub mod serving;
pub mod shard;
pub mod spec;
pub mod cluster;
pub mod eval;
pub mod exp;
pub mod bench_support;
pub mod config;
