//! N-dimensional f32 tensor + the `.gten` binary container used to persist
//! model weights between pipeline stages (train → quantize → eval).
//!
//! Format (little-endian):
//!   magic "GTEN" | u32 version | u32 n_entries
//!   per entry: u32 name_len | name utf8 | u32 ndim | u64 dims... | f32 data...
//! A u32 CRC32 of everything after the magic trails the file.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;

/// Row-major nd tensor (f32).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// View a rank-2 tensor as a Mat (copies).
    pub fn to_mat(&self) -> Mat {
        assert_eq!(self.ndim(), 2, "to_mat on rank-{} tensor", self.ndim());
        Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    pub fn from_mat(m: &Mat) -> Tensor {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }
}

/// Named tensor collection with deterministic (sorted) iteration order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TensorStore {
    pub entries: BTreeMap<String, Tensor>,
}

const MAGIC: &[u8; 4] = b"GTEN";
const VERSION: u32 = 1;

impl TensorStore {
    pub fn new() -> TensorStore {
        TensorStore { entries: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.entries.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut body: Vec<u8> = Vec::new();
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, t) in &self.entries {
            let nb = name.as_bytes();
            body.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            body.extend_from_slice(nb);
            body.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                body.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &v in &t.data {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = crc32(&body);
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&body)?;
        f.write_all(&crc.to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TensorStore> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut buf)?;
        if buf.len() < 12 || &buf[..4] != MAGIC {
            bail!("{}: not a GTEN file", path.display());
        }
        let body = &buf[4..buf.len() - 4];
        let stored_crc = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            bail!("{}: CRC mismatch (corrupted)", path.display());
        }
        let mut pos = 0usize;
        let rd_u32 = |b: &[u8], p: &mut usize| -> Result<u32> {
            if *p + 4 > b.len() {
                bail!("truncated");
            }
            let v = u32::from_le_bytes(b[*p..*p + 4].try_into().unwrap());
            *p += 4;
            Ok(v)
        };
        let version = rd_u32(body, &mut pos)?;
        if version != VERSION {
            bail!("unsupported GTEN version {version}");
        }
        let n = rd_u32(body, &mut pos)? as usize;
        let mut store = TensorStore::new();
        for _ in 0..n {
            let name_len = rd_u32(body, &mut pos)? as usize;
            if pos + name_len > body.len() {
                bail!("truncated name");
            }
            let name = std::str::from_utf8(&body[pos..pos + name_len])?.to_string();
            pos += name_len;
            let ndim = rd_u32(body, &mut pos)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                if pos + 8 > body.len() {
                    bail!("truncated dims");
                }
                shape.push(u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap()) as usize);
                pos += 8;
            }
            let numel: usize = shape.iter().product();
            if pos + numel * 4 > body.len() {
                bail!("truncated data for {name}");
            }
            let mut data = Vec::with_capacity(numel);
            for i in 0..numel {
                data.push(f32::from_le_bytes(
                    body[pos + i * 4..pos + i * 4 + 4].try_into().unwrap(),
                ));
            }
            pos += numel * 4;
            store.entries.insert(name, Tensor { shape, data });
        }
        Ok(store)
    }

    /// Total payload bytes (f32 count * 4).
    pub fn payload_bytes(&self) -> usize {
        self.entries.values().map(|t| t.numel() * 4).sum()
    }
}

fn crc32_table() -> &'static [u32; 256] {
    static mut TABLE: [u32; 256] = [0; 256];
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| unsafe {
        for i in 0..256u32 {
            let mut c = i;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            TABLE[i as usize] = c;
        }
    });
    unsafe { &*std::ptr::addr_of!(TABLE) }
}

/// Incremental CRC-32 (IEEE 802.3, reflected) — lets readers verify a
/// container checksum while streaming instead of buffering the whole file.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFFFFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let table = crc32_table();
        let mut crc = self.state;
        for &b in data {
            crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
        }
        self.state = crc;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFFFFFF
    }
}

/// CRC-32 (IEEE 802.3, reflected) of a whole buffer.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn crc32_known_vector() {
        // CRC32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_incremental_matches_one_shot() {
        let data: Vec<u8> = (0..997u32).map(|i| (i * 31 % 251) as u8).collect();
        for split in [0usize, 1, 13, 500, 996, 997] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finalize(), crc32(&data), "split {split}");
        }
    }

    #[test]
    fn tensor_mat_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = Tensor::from_mat(&m);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.to_mat(), m);
    }

    #[test]
    fn store_save_load_roundtrip() {
        let mut rng = Rng::new(3);
        let mut store = TensorStore::new();
        store.insert("emb", Tensor::from_vec(&[4, 8], (0..32).map(|i| i as f32).collect()));
        let mut big = vec![0.0f32; 1000];
        rng.fill_normal(&mut big, 0.3);
        store.insert("00.attn.wq", Tensor::from_vec(&[10, 100], big));
        store.insert("scalar-ish", Tensor::from_vec(&[1], vec![7.5]));

        let dir = std::env::temp_dir().join(format!("gten_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.gten");
        store.save(&path).unwrap();
        let loaded = TensorStore::load(&path).unwrap();
        assert_eq!(store, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_file_is_rejected() {
        let mut store = TensorStore::new();
        store.insert("w", Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        let dir = std::env::temp_dir().join(format!("gten_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.gten");
        store.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(TensorStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn names_sorted_deterministically() {
        let mut s = TensorStore::new();
        s.insert("z", Tensor::zeros(&[1]));
        s.insert("a", Tensor::zeros(&[1]));
        assert_eq!(s.names(), vec!["a".to_string(), "z".to_string()]);
    }
}
