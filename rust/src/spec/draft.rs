//! Draft-view builder: a second, much smaller quantized view of the
//! *same* weights.
//!
//! The target container stores per-group variable-rate codes; the draft
//! is a fixed-rate 2-bit re-quantization of whatever weights are already
//! loaded, built at serve time in one pass. It reuses the KV cache's
//! page recipe ([`crate::kvcache::KvQuantizer`]: mu-law companding into a
//! scaled-identity lattice) and stores the result as ordinary
//! [`QuantizedGroup`]s inside a [`QuantizedModel`], so the streaming
//! decode engine serves it with zero new decode paths — the draft is
//! just another container as far as `StreamingMatmul` is concerned.
//!
//! The view is derived state: it is never serialized (its groups carry
//! the `"kv-glvq"` method tag, which the on-disk format does not map),
//! and `glvq info --container` reports its bytes as *overhead* on top of
//! the stored container, with the effective bits/weight including it.

use anyhow::{Context, Result};

use crate::kvcache::KvQuantizer;
use crate::linalg::Mat;
use crate::model::ModelConfig;
use crate::quant::format::{QuantizedModel, QuantizedTensor};
use crate::tensor::TensorStore;

/// Fixed code width of the draft view. 2 bits is the smallest rate at
/// which greedy draft argmaxes still track the target often enough to
/// pay for themselves (the accept-rate trajectory in `bench_spec`
/// watches exactly this).
pub const DRAFT_BITS: u8 = 2;

/// Rows per draft group: one group spans the full input width, so a
/// streamed panel decode touches exactly one side-info record.
const DRAFT_GROUP_ROWS: usize = 32;

/// A fixed-rate low-bit view of the target weights, plus its size
/// accounting for the `info` report.
pub struct DraftView {
    /// the draft weights, keyed by the same tensor names as the target
    pub model: QuantizedModel,
    /// stored code bytes of the draft view
    pub payload_bytes: usize,
    /// side-info bytes (scales, companding, lattice bases)
    pub side_bytes: usize,
}

impl DraftView {
    /// Total in-memory overhead of keeping the draft alongside the
    /// target (codes + side info).
    pub fn total_bytes(&self) -> usize {
        self.payload_bytes + self.side_bytes
    }
}

/// Cut one streaming-orientation matrix into [`DRAFT_GROUP_ROWS`]-row
/// fixed-rate groups.
fn quantize_mat(quant: &KvQuantizer, name: String, w: &Mat) -> QuantizedTensor {
    let mut groups = Vec::new();
    let mut r0 = 0;
    while r0 < w.rows {
        let rows = DRAFT_GROUP_ROWS.min(w.rows - r0);
        let chunk = &w.data[r0 * w.cols..(r0 + rows) * w.cols];
        groups.push((r0, 0, quant.quantize_page(chunk, rows, w.cols)));
        r0 += rows;
    }
    QuantizedTensor { name, rows: w.rows, cols: w.cols, groups }
}

/// Re-quantize every quantizable parameter of `store` into the 2-bit
/// draft view. Weights are transposed into the streaming-matmul
/// orientation (rows = output features) and cut into
/// [`DRAFT_GROUP_ROWS`]-row groups, exactly the shape
/// `StreamingMatmul` panels over.
pub fn build_draft_view(cfg: &ModelConfig, store: &TensorStore) -> Result<DraftView> {
    let _sp = crate::span!("spec_build_draft");
    let quant = KvQuantizer { bits: DRAFT_BITS, lattice_dim: 8, entropy: false };
    let mut tensors = Vec::new();
    for spec in cfg.param_specs() {
        if !spec.quantizable {
            continue;
        }
        let w = store
            .get(&spec.name)
            .with_context(|| format!("draft view: missing tensor {}", spec.name))?
            .to_mat();
        // store layout is (n_in × n_out); quantized tensors hold Wᵀ so a
        // row-panel decode yields contiguous output features
        let wt = w.transpose();
        tensors.push(quantize_mat(&quant, spec.name, &wt));
    }
    let model = QuantizedModel { tensors };
    let (payload_bytes, side_bytes) = model.size_bytes();
    Ok(DraftView { model, payload_bytes, side_bytes })
}

/// Build the draft view straight from a loaded container — what `glvq
/// info --container` uses to report the serve-time overhead of
/// `--speculate` without needing the original checkpoint. Each stored
/// tensor (already in streaming orientation) is dequantized and
/// re-encoded at [`DRAFT_BITS`].
pub fn draft_view_of_container(qm: &QuantizedModel) -> DraftView {
    let _sp = crate::span!("spec_build_draft");
    let quant = KvQuantizer { bits: DRAFT_BITS, lattice_dim: 8, entropy: false };
    let tensors = qm
        .tensors
        .iter()
        .map(|t| quantize_mat(&quant, t.name.clone(), &t.dequantize()))
        .collect();
    let model = QuantizedModel { tensors };
    let (payload_bytes, side_bytes) = model.size_bytes();
    DraftView { model, payload_bytes, side_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, CONFIG_S};

    #[test]
    fn draft_covers_every_quantizable_tensor_at_two_bits() {
        let cfg = CONFIG_S;
        let store = init_params(&cfg, 7);
        let draft = build_draft_view(&cfg, &store).unwrap();
        let names = cfg.quantizable_names();
        assert_eq!(draft.model.tensors.len(), names.len());
        for name in &names {
            let qt = draft.model.get(name).expect("tensor present in draft");
            for (_, _, g) in &qt.groups {
                assert_eq!(g.bits, DRAFT_BITS);
            }
            // orientation: rows = output features of the transposed weight
            let spec = cfg
                .param_specs()
                .into_iter()
                .find(|s| &s.name == name)
                .unwrap();
            assert_eq!(qt.rows, spec.shape[1]);
            assert_eq!(qt.cols, spec.shape[0]);
        }
        assert!(draft.payload_bytes > 0);
        assert!(draft.side_bytes > 0);
        // a 2-bit view must come in way under the f32 weights
        let dense_bytes: usize = names
            .iter()
            .map(|n| {
                let t = draft.model.get(n).unwrap();
                t.rows * t.cols * 4
            })
            .sum();
        assert!(draft.total_bytes() < dense_bytes / 4);
    }

    #[test]
    fn container_draft_matches_store_draft_shapes() {
        let cfg = CONFIG_S;
        let store = init_params(&cfg, 11);
        let d1 = build_draft_view(&cfg, &store).unwrap();
        // re-encoding any container (here: the draft itself) keeps the
        // tensor inventory and streaming orientation
        let d2 = draft_view_of_container(&d1.model);
        assert_eq!(d2.model.tensors.len(), d1.model.tensors.len());
        for (a, b) in d1.model.tensors.iter().zip(&d2.model.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
        assert!(d2.total_bytes() > 0);
    }

    #[test]
    fn draft_dequantizes_within_lattice_step() {
        let cfg = CONFIG_S;
        let store = init_params(&cfg, 3);
        let draft = build_draft_view(&cfg, &store).unwrap();
        let w = store.get("out").unwrap().to_mat().transpose();
        let dq = draft.model.get("out").unwrap().dequantize();
        assert_eq!(dq.rows, w.rows);
        assert_eq!(dq.cols, w.cols);
        // coarse but bounded: 2-bit mu-law reconstruction stays within
        // the page max-abs (sanity that orientation and scaling line up)
        let maxabs = w.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in w.data.iter().zip(dq.data.iter()) {
            assert!((a - b).abs() <= maxabs, "reconstruction blew past the page scale");
        }
    }
}
