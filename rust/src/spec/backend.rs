//! Draft/verify serving backend: speculative decode behind the standard
//! serving traits.
//!
//! [`SpeculativeBackend`] wraps a [`CachedNativeBackend`] (the *target*)
//! plus a 2-bit [`DraftView`] of the same weights. A decode step runs as
//! a **round**:
//!
//! 1. `spec_draft` — sync the draft's own KV stream to the sequence
//!    history, then greedily draft `k` tokens through the draft view
//!    (cheap: 2-bit streamed decode, one token at a time).
//! 2. `spec_verify` — feed the step token plus all `k` drafted tokens to
//!    the target in **one** ragged forward. `forward_ragged` yields one
//!    logits row per fed token and is bit-identical under any chunking,
//!    so row *i* is exactly what a token-at-a-time target decode would
//!    have produced.
//! 3. `spec_rollback` — accept the longest prefix of drafted tokens
//!    whose target argmax matches the draft's choice, and
//!    [`crate::kvcache::PagedKvCache::truncate_seq`] the rejected rows
//!    back off both caches. Accepted rows are *queued*: subsequent
//!    1-token steps that feed the queued token are answered from the
//!    queue with no forward at all — that amortization is the speedup.
//!
//! Greedy argmax acceptance makes the whole scheme exact: every logits
//! row the caller sees is a target row, so generated text is
//! bit-identical to target-only decode (`tests/spec_parity.rs`). A fed
//! token that does *not* match the queue (a Score continuation, or any
//! non-greedy caller) invalidates the queued tail and rolls the caches
//! back — degradation, never divergence.
//!
//! Preemption composes: before the target sequence is spilled, queued
//! (uncommitted) rows are rolled back so the parked pages hold exactly
//! the tokens the scheduler fed; the sequence history is parked under a
//! [`crate::kvcache::SpilledSeq`] tag and re-attached on resume, and the
//! draft KV stream is simply dropped and lazily rebuilt (it is derived
//! state, like the draft weights themselves).

use std::collections::{BTreeMap, VecDeque};

use anyhow::Result;

use crate::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use crate::coordinator::server::{CachedNativeBackend, LmBackend};
use crate::eval::native_fwd::{self, argmax_logit, StreamedLinear};
use crate::kvcache::{KvCacheOpts, KvCacheStats, PagedKvCache, SeqId, SpilledSeq};
use crate::linalg::Mat;
use crate::serving::SeqBackend;
use crate::shard::ShardStat;

use super::draft::{build_draft_view, DraftView};
use super::SpecStats;

/// Per-sequence speculative state, indexed by the target [`SeqId`] slot.
struct SpecSeq {
    /// this sequence's stream in the draft KV cache
    draft_sid: SeqId,
    /// tokens the caller has fed (and the target cache has committed,
    /// beyond the queued tail)
    history: Vec<i32>,
    /// committed rows in the draft KV cache (≤ `history.len()` between
    /// rounds, `history.len() + k_eff` right after a draft phase)
    draft_rows: usize,
    /// verified-but-not-yet-requested tokens, oldest first
    queued_tokens: VecDeque<i32>,
    /// the target logits row answering each queued token
    queued_rows: VecDeque<Vec<f32>>,
}

/// Lockstep recognition entry (mirrors the wrapped backend's own).
struct LiveSeq {
    tokens: Vec<i32>,
    id: SeqId,
}

/// How each step item is answered (planned in phase 0 of a step).
enum Plan {
    /// answered from the verified queue — no forward rows at all
    Queue(Vec<f32>),
    /// fed to the target: `expand` tokens, of which the trailing `k_eff`
    /// are drafted (0 = plain passthrough, e.g. a prefill chunk)
    Forward { expand: Vec<i32>, k_eff: usize },
}

/// Speculative decoding wrapper around a [`CachedNativeBackend`].
/// Implements both [`LmBackend`] (lockstep loop) and [`SeqBackend`]
/// (continuous loop); `glvq serve --speculate k` constructs one.
pub struct SpeculativeBackend {
    target: CachedNativeBackend,
    k: usize,
    draft: DraftView,
    draft_engine: StreamingMatmul,
    draft_cache: PagedKvCache,
    draft_stats: DecodeStats,
    states: Vec<Option<SpecSeq>>,
    /// histories of preempted sequences, keyed by the spill tag
    parked: BTreeMap<u64, Vec<i32>>,
    next_tag: u64,
    stats: SpecStats,
    live: Vec<LiveSeq>,
}

impl SpeculativeBackend {
    /// Wrap `target`, building the 2-bit draft view from its tensor
    /// store. `k` is the number of tokens drafted per round (clamped to
    /// at least 1). The draft keeps its own unbounded f32 KV cache —
    /// derived state that preemption drops and rebuilds.
    pub fn new(target: CachedNativeBackend, k: usize) -> Result<SpeculativeBackend> {
        let cfg = target.config();
        let draft = build_draft_view(&cfg, target.tensor_store())?;
        Ok(SpeculativeBackend {
            draft,
            draft_engine: StreamingMatmul::new(16, 1),
            draft_cache: PagedKvCache::new(cfg.n_layer, cfg.d_model, KvCacheOpts::default()),
            draft_stats: DecodeStats::default(),
            states: Vec::new(),
            parked: BTreeMap::new(),
            next_tag: 1,
            stats: SpecStats::default(),
            live: Vec::new(),
            k: k.max(1),
            target,
        })
    }

    /// Cumulative draft/verify counters.
    pub fn spec_counters(&self) -> SpecStats {
        self.stats
    }

    /// The draft view (for size reporting).
    pub fn draft_view(&self) -> &DraftView {
        &self.draft
    }

    fn insert_state(&mut self, sid: SeqId, st: SpecSeq) {
        let i = sid.index();
        if self.states.len() <= i {
            self.states.resize_with(i + 1, || None);
        }
        self.states[i] = Some(st);
    }

    /// One ragged forward through the **draft** view: streamed 2-bit
    /// weights over the shared tensor store, into the draft's KV cache.
    fn draft_forward(&mut self, sid: SeqId, tokens: &[i32]) -> Result<Mat> {
        let cfg = self.target.config();
        let store = self.target.tensor_store();
        let mut lin = StreamedLinear {
            qm: &self.draft.model,
            store,
            engine: &self.draft_engine,
            stats: DecodeStats::default(),
        };
        let out = native_fwd::forward_ragged(
            &cfg,
            store,
            &mut lin,
            &mut self.draft_cache,
            &[sid],
            &[tokens],
        );
        self.draft_stats.merge(&lin.stats);
        out
    }

    /// The speculative step: answer queue hits without a forward, expand
    /// decode steps into draft+verify rounds, pass prefill chunks
    /// through, and return exactly one logits row per fed token (the
    /// scheduler's `step_ragged` contract).
    fn step_spec(&mut self, items: &[(SeqId, &[i32])]) -> Result<Mat> {
        let cfg = self.target.config();
        let seq_len = cfg.seq_len;
        let vocab = cfg.vocab;

        // ---- phase 0: classify items; settle queues ----
        // `budget` bounds drafted-row appends by the pages actually free
        // right now, so a round never overcommits an arena the scheduler
        // only budgeted one token for. Queue-mismatch rollbacks below
        // only *free* pages, so the snapshot is conservative.
        let mut budget = self.target.free_pages();
        let mut plans: Vec<Plan> = Vec::with_capacity(items.len());
        for (sid, fed) in items {
            let si = sid.index();
            let st = self.states[si].as_mut().expect("stepped sequence has speculative state");
            if !st.queued_tokens.is_empty() {
                if fed.len() == 1 && st.queued_tokens.front() == Some(&fed[0]) {
                    st.queued_tokens.pop_front();
                    let row = st.queued_rows.pop_front().expect("queued rows parallel tokens");
                    st.history.push(fed[0]);
                    plans.push(Plan::Queue(row));
                    continue;
                }
                // non-greedy feed: the verified tail is for a path not
                // taken — drop it and roll both caches back to history
                let n_q = st.queued_tokens.len();
                st.queued_tokens.clear();
                st.queued_rows.clear();
                let base = st.history.len();
                let draft_sid = st.draft_sid;
                let dr = st.draft_rows.min(base);
                let roll_draft = dr < st.draft_rows;
                st.draft_rows = dr;
                {
                    let _sp = crate::span!("spec_rollback");
                    self.target.truncate(*sid, base)?;
                    if roll_draft {
                        self.draft_cache.truncate_seq(draft_sid, dr)?;
                    }
                }
                self.stats.rollback_rows += n_q as u64;
            }
            let st = self.states[si].as_ref().expect("still present");
            let base = st.history.len();
            if fed.len() != 1 {
                // prefill chunk (or re-fed window): pass through as-is
                if let Some(b) = budget.as_mut() {
                    *b = b.saturating_sub(self.target.pages_for(base, fed.len()));
                }
                plans.push(Plan::Forward { expand: fed.to_vec(), k_eff: 0 });
                continue;
            }
            // decode step: plan a round, clamped by context and pages
            let mut k_eff = self.k.min(seq_len.saturating_sub(base + 1));
            if let Some(b) = budget.as_mut() {
                while k_eff > 0 && self.target.pages_for(base, 1 + k_eff) > *b {
                    k_eff -= 1;
                }
                *b = b.saturating_sub(self.target.pages_for(base, 1 + k_eff));
            }
            plans.push(Plan::Forward { expand: vec![fed[0]], k_eff });
        }

        // ---- phase 1: draft k tokens per round through the 2-bit view ----
        for (idx, (sid, _)) in items.iter().enumerate() {
            let k_eff = match &plans[idx] {
                Plan::Forward { k_eff, .. } if *k_eff > 0 => *k_eff,
                _ => continue,
            };
            let _sp = crate::span!("spec_draft");
            let (draft_sid, feed) = {
                let st = self.states[sid.index()].as_ref().expect("present");
                // lazy sync: everything the draft stream is missing, plus
                // the step token itself
                let mut feed = st.history[st.draft_rows..].to_vec();
                if let Plan::Forward { expand, .. } = &plans[idx] {
                    feed.push(expand[0]);
                }
                (st.draft_sid, feed)
            };
            let logits = self.draft_forward(draft_sid, &feed)?;
            let mut d = argmax_logit(logits.row(logits.rows - 1));
            let mut drafted = vec![d];
            for _ in 1..k_eff {
                let lg = self.draft_forward(draft_sid, &[d])?;
                d = argmax_logit(lg.row(lg.rows - 1));
                drafted.push(d);
            }
            let st = self.states[sid.index()].as_mut().expect("present");
            st.draft_rows = st.history.len() + k_eff;
            if let Plan::Forward { expand, .. } = &mut plans[idx] {
                expand.extend_from_slice(&drafted);
            }
        }

        // ---- phase 2: one ragged target forward verifies everything ----
        let any_round =
            plans.iter().any(|p| matches!(p, Plan::Forward { k_eff, .. } if *k_eff > 0));
        let fwd: Vec<(SeqId, &[i32])> = items
            .iter()
            .zip(&plans)
            .filter_map(|((sid, _), plan)| match plan {
                Plan::Forward { expand, .. } => Some((*sid, expand.as_slice())),
                Plan::Queue(_) => None,
            })
            .collect();
        let out = if fwd.is_empty() {
            None
        } else {
            let _sp = any_round.then(|| crate::span!("spec_verify"));
            let m = self.target.step_ragged(&fwd)?;
            if any_round {
                self.stats.verify_calls += 1;
            }
            Some(m)
        };

        // ---- phase 3: accept, roll back rejects, assemble the result ----
        let total: usize = items.iter().map(|(_, fed)| fed.len()).sum();
        let mut result = Mat::zeros(total, vocab);
        let mut src = 0usize;
        let mut dst = 0usize;
        for (idx, (sid, fed)) in items.iter().enumerate() {
            match &plans[idx] {
                Plan::Queue(row) => {
                    result.data[dst * vocab..(dst + 1) * vocab].copy_from_slice(row);
                    dst += 1;
                }
                Plan::Forward { expand, k_eff } => {
                    let out = out.as_ref().expect("forward ran for forward plans");
                    if *k_eff == 0 {
                        for r in 0..expand.len() {
                            result.data[(dst + r) * vocab..(dst + r + 1) * vocab]
                                .copy_from_slice(out.row(src + r));
                        }
                        let st = self.states[sid.index()].as_mut().expect("present");
                        st.history.extend_from_slice(fed);
                        src += expand.len();
                        dst += fed.len();
                        continue;
                    }
                    // accept the longest prefix where the target's greedy
                    // choice equals the drafted token — row src+i answers
                    // expand[i], so acceptance is exact argmax parity
                    let mut a = 0usize;
                    while a < *k_eff && argmax_logit(out.row(src + a)) == expand[a + 1] {
                        a += 1;
                    }
                    let (base, draft_sid, old_dr) = {
                        let st = self.states[sid.index()].as_ref().expect("present");
                        (st.history.len(), st.draft_sid, st.draft_rows)
                    };
                    let keep = base + 1 + a;
                    let dr = old_dr.min(keep);
                    if a < *k_eff {
                        let _sp = crate::span!("spec_rollback");
                        self.target.truncate(*sid, keep)?;
                        if dr < old_dr {
                            self.draft_cache.truncate_seq(draft_sid, dr)?;
                        }
                    }
                    self.stats.rounds += 1;
                    self.stats.drafted += *k_eff as u64;
                    self.stats.accepted += a as u64;
                    self.stats.rollback_rows += (*k_eff - a) as u64;
                    let st = self.states[sid.index()].as_mut().expect("present");
                    st.draft_rows = dr;
                    st.history.push(expand[0]);
                    for i in 1..=a {
                        st.queued_tokens.push_back(expand[i]);
                        st.queued_rows.push_back(out.row(src + i).to_vec());
                    }
                    result.data[dst * vocab..(dst + 1) * vocab].copy_from_slice(out.row(src));
                    src += 1 + k_eff;
                    dst += 1;
                }
            }
        }
        Ok(result)
    }
}

impl SeqBackend for SpeculativeBackend {
    fn ctx_len(&self) -> usize {
        self.target.ctx_len()
    }

    fn begin_seq(&mut self) -> SeqId {
        let sid = self.target.begin_seq();
        let draft_sid = self.draft_cache.new_seq();
        self.insert_state(
            sid,
            SpecSeq {
                draft_sid,
                history: Vec::new(),
                draft_rows: 0,
                queued_tokens: VecDeque::new(),
                queued_rows: VecDeque::new(),
            },
        );
        sid
    }

    fn begin_seq_prefixed(&mut self, tokens: &[i32], max_rows: usize) -> (SeqId, usize) {
        let (sid, claimed) = self.target.begin_seq_prefixed(tokens, max_rows);
        let draft_sid = self.draft_cache.new_seq();
        self.insert_state(
            sid,
            SpecSeq {
                draft_sid,
                // claimed rows are committed history the caller will
                // never feed; the draft stream syncs to them lazily
                history: tokens[..claimed].to_vec(),
                draft_rows: 0,
                queued_tokens: VecDeque::new(),
                queued_rows: VecDeque::new(),
            },
        );
        (sid, claimed)
    }

    fn publish_seq(&mut self, sid: SeqId, tokens: &[i32]) {
        self.target.publish_seq(sid, tokens);
    }

    fn step_ragged(&mut self, items: &[(SeqId, &[i32])]) -> Result<Mat> {
        self.step_spec(items)
    }

    fn retire_seq(&mut self, sid: SeqId) {
        if let Some(st) = self.states.get_mut(sid.index()).and_then(|s| s.take()) {
            self.draft_cache.evict(st.draft_sid);
        }
        self.target.retire_seq(sid);
    }

    fn preempt_seq(&mut self, sid: SeqId, quantize: bool) -> Result<SpilledSeq> {
        // the spilled pages must hold exactly the tokens the scheduler
        // fed, so the queued (verified-but-unrequested) tail rolls back
        // before the spill; it is re-drafted cheaply after resume
        let (base, n_q) = {
            let st =
                self.states[sid.index()].as_mut().expect("preempted sequence has state");
            let n_q = st.queued_tokens.len();
            st.queued_tokens.clear();
            st.queued_rows.clear();
            (st.history.len(), n_q)
        };
        if n_q > 0 {
            let _sp = crate::span!("spec_rollback");
            self.target.truncate(sid, base)?;
            self.stats.rollback_rows += n_q as u64;
        }
        let mut sp = self.target.preempt_seq(sid, quantize)?;
        let st = self.states[sid.index()].take().expect("state present");
        self.draft_cache.evict(st.draft_sid);
        let tag = self.next_tag;
        self.next_tag += 1;
        sp.set_tag(tag);
        self.parked.insert(tag, st.history);
        Ok(sp)
    }

    fn resume_seq(&mut self, sp: SpilledSeq) -> std::result::Result<SeqId, SpilledSeq> {
        let tag = sp.tag();
        match self.target.resume_seq(sp) {
            Ok(sid) => {
                let history = self.parked.remove(&tag).unwrap_or_default();
                let draft_sid = self.draft_cache.new_seq();
                self.insert_state(
                    sid,
                    SpecSeq {
                        draft_sid,
                        history,
                        draft_rows: 0,
                        queued_tokens: VecDeque::new(),
                        queued_rows: VecDeque::new(),
                    },
                );
                Ok(sid)
            }
            // the parked history stays for the scheduler's retry
            Err(sp) => Err(sp),
        }
    }

    fn free_pages(&self) -> Option<usize> {
        self.target.free_pages()
    }

    fn page_capacity(&self) -> Option<usize> {
        self.target.page_capacity()
    }

    fn pages_for(&self, rows: usize, n_new: usize) -> usize {
        self.target.pages_for(rows, n_new)
    }

    fn kv_stats(&self) -> Option<KvCacheStats> {
        self.target.kv_stats()
    }

    fn stream_stats(&self) -> Option<DecodeStats> {
        // the draft always streams its 2-bit view, even over a dense
        // target — fold both decode streams into one report
        let mut s = self.target.stream_stats().unwrap_or_default();
        s.merge(&self.draft_stats);
        Some(s)
    }

    fn sharded_stats(&self) -> Option<Vec<ShardStat>> {
        self.target.sharded_stats()
    }

    fn speculative_stats(&self) -> Option<SpecStats> {
        Some(self.stats)
    }
}

impl LmBackend for SpeculativeBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        Ok(self.logits_last_batch(&[tokens])?.remove(0))
    }

    /// Lockstep recognition, mirroring the wrapped backend's: an
    /// extend-by-one prefix becomes a speculative decode step, anything
    /// else (re-)prefills a window — all through [`Self::step_spec`], so
    /// lockstep serving drafts exactly like continuous serving.
    fn logits_last_batch(&mut self, prefixes: &[&[i32]]) -> Result<Vec<Vec<f32>>> {
        let t_max = self.target.config().seq_len;
        let mut out: Vec<Option<Vec<f32>>> = vec![None; prefixes.len()];

        let mut claimed = vec![false; self.live.len()];
        let mut dead = vec![false; self.live.len()];
        let mut steps: Vec<(usize, usize)> = Vec::new();
        let mut stepping = vec![false; prefixes.len()];
        for (pi, p) in prefixes.iter().enumerate() {
            let n = p.len();
            if n == 0 {
                continue;
            }
            let matched = self.live.iter().enumerate().find(|(li, s)| {
                !claimed[*li] && s.tokens.len() + 1 == n && s.tokens[..] == p[..n - 1]
            });
            if let Some((li, _)) = matched {
                claimed[li] = true;
                if n > t_max {
                    // outgrew the position table — sliding-window regime
                    dead[li] = true;
                } else {
                    steps.push((pi, li));
                    stepping[pi] = true;
                }
            }
        }
        if dead.iter().any(|&d| d) {
            let mut remap = vec![0usize; self.live.len()];
            let mut kept = 0usize;
            let mut to_retire = Vec::new();
            for (li, slot) in remap.iter_mut().enumerate() {
                *slot = kept;
                if dead[li] {
                    to_retire.push(self.live[li].id);
                } else {
                    kept += 1;
                }
            }
            for id in to_retire {
                self.retire_seq(id);
            }
            let mut idx = 0;
            self.live.retain(|_| {
                let keep = !dead[idx];
                idx += 1;
                keep
            });
            for s in steps.iter_mut() {
                s.1 = remap[s.1];
            }
        }

        // unmatched prefixes (re-)prefill through the speculative step
        for (pi, p) in prefixes.iter().enumerate() {
            if stepping[pi] {
                continue;
            }
            let window: &[i32] = if p.is_empty() {
                &[0]
            } else if p.len() > t_max {
                &p[p.len() - t_max..]
            } else {
                p
            };
            let (sid, claimed_rows) =
                self.begin_seq_prefixed(window, window.len().saturating_sub(1));
            let fed = &window[claimed_rows..];
            let logits = match self.step_spec(&[(sid, fed)]) {
                Ok(l) => l.row(l.rows - 1).to_vec(),
                Err(e) => {
                    self.retire_seq(sid);
                    return Err(e);
                }
            };
            if p.is_empty() || p.len() > t_max {
                // transient window: the cache cannot extend it next step
                self.retire_seq(sid);
            } else {
                self.live.push(LiveSeq { tokens: p.to_vec(), id: sid });
            }
            out[pi] = Some(logits);
        }

        // one speculative step batch advances all recognized sequences
        if !steps.is_empty() {
            let last: Vec<i32> =
                steps.iter().map(|&(pi, _)| *prefixes[pi].last().unwrap()).collect();
            let items: Vec<(SeqId, &[i32])> = steps
                .iter()
                .enumerate()
                .map(|(si, &(_, li))| (self.live[li].id, std::slice::from_ref(&last[si])))
                .collect();
            let logits = match self.step_spec(&items) {
                Ok(l) => l,
                Err(e) => {
                    // a failed step leaves skewed per-layer rows: evict
                    // the stepping sequences so a retry re-prefills
                    let mut bad = vec![false; self.live.len()];
                    let mut ids = Vec::new();
                    for &(_, li) in &steps {
                        bad[li] = true;
                        ids.push(self.live[li].id);
                    }
                    for id in ids {
                        self.retire_seq(id);
                    }
                    let mut idx = 0;
                    self.live.retain(|_| {
                        let keep = !bad[idx];
                        idx += 1;
                        keep
                    });
                    return Err(e);
                }
            };
            for (si, &(pi, li)) in steps.iter().enumerate() {
                self.live[li].tokens.push(last[si]);
                out[pi] = Some(logits.row(si).to_vec());
            }
        }

        Ok(out.into_iter().map(|o| o.expect("every prefix answered")).collect())
    }

    fn seq_len(&self) -> usize {
        self.target.config().seq_len
    }

    fn vocab(&self) -> usize {
        self.target.config().vocab
    }

    fn decode_stats(&self) -> Option<DecodeStats> {
        self.stream_stats()
    }

    fn end_batch(&mut self) {
        let live = std::mem::take(&mut self.live);
        for s in live {
            self.publish_seq(s.id, &s.tokens);
            self.retire_seq(s.id);
        }
    }

    fn cache_stats(&self) -> Option<KvCacheStats> {
        self.target.cache_stats()
    }

    fn shard_stats(&self) -> Option<Vec<ShardStat>> {
        self.target.shard_stats()
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        Some(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ModelConfig};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "spec-test",
            vocab: 256,
            d_model: 32,
            n_layer: 1,
            n_head: 2,
            d_ff: 64,
            seq_len: 32,
            batch_train: 2,
            batch_eval: 2,
        }
    }

    fn dense_backend(cfg: &ModelConfig) -> CachedNativeBackend {
        CachedNativeBackend::dense(*cfg, init_params(cfg, 0), KvCacheOpts::default())
    }

    /// Greedy continuation through plain SeqBackend stepping.
    fn greedy<B: SeqBackend>(backend: &mut B, prompt: &[i32], n_new: usize) -> Vec<i32> {
        let sid = backend.begin_seq();
        let first = backend.step_ragged(&[(sid, prompt)]).unwrap();
        let mut toks = vec![argmax_logit(first.row(first.rows - 1))];
        for _ in 1..n_new {
            let t = *toks.last().unwrap();
            let lg = backend.step_ragged(&[(sid, &[t])]).unwrap();
            toks.push(argmax_logit(lg.row(lg.rows - 1)));
        }
        backend.retire_seq(sid);
        toks
    }

    #[test]
    fn speculative_greedy_decode_matches_target_only() {
        let cfg = tiny_cfg();
        let prompt: Vec<i32> = vec![5, 9, 2, 14];
        let want = greedy(&mut dense_backend(&cfg), &prompt, 12);
        for k in [1usize, 2, 4, 8] {
            let mut spec = SpeculativeBackend::new(dense_backend(&cfg), k).unwrap();
            let got = greedy(&mut spec, &prompt, 12);
            assert_eq!(got, want, "speculative (k={k}) diverged from target-only");
            let s = spec.spec_counters();
            assert!(s.rounds > 0, "k={k} never ran a round");
            assert!(s.drafted >= s.accepted);
        }
    }

    #[test]
    fn queue_mismatch_rolls_back_and_recovers() {
        let cfg = tiny_cfg();
        let mut spec = SpeculativeBackend::new(dense_backend(&cfg), 4).unwrap();
        let sid = spec.begin_seq();
        let first = spec.step_ragged(&[(sid, &[1, 2, 3][..])]).unwrap();
        let g1 = argmax_logit(first.row(first.rows - 1));
        // step the greedy token (fills the queue), then deliberately feed
        // a non-greedy token: the queued tail must roll back, and the
        // row must still equal the target's
        let r1 = spec.step_ragged(&[(sid, &[g1][..])]).unwrap();
        let wrong = (argmax_logit(r1.row(0)) + 1) % cfg.vocab as i32;
        let r2 = spec.step_ragged(&[(sid, &[wrong][..])]).unwrap();
        spec.retire_seq(sid);

        let mut target = dense_backend(&cfg);
        let tid = target.begin_seq();
        target.step_ragged(&[(tid, &[1, 2, 3][..])]).unwrap();
        let t1 = target.step_ragged(&[(tid, &[g1][..])]).unwrap();
        let t2 = target.step_ragged(&[(tid, &[wrong][..])]).unwrap();
        target.retire_seq(tid);
        assert_eq!(r1.row(0), t1.row(0));
        assert_eq!(r2.row(0), t2.row(0));
    }

    #[test]
    fn lockstep_interface_matches_wrapped_backend() {
        let cfg = tiny_cfg();
        let mut plain = dense_backend(&cfg);
        let mut spec = SpeculativeBackend::new(dense_backend(&cfg), 4).unwrap();
        let mut a: Vec<i32> = vec![7, 3];
        let mut b = a.clone();
        for _ in 0..10 {
            let ra = plain.logits_last(&a).unwrap();
            let rb = LmBackend::logits_last(&mut spec, &b).unwrap();
            let ta = argmax_logit(&ra);
            let tb = argmax_logit(&rb);
            assert_eq!(ta, tb);
            a.push(ta);
            b.push(tb);
        }
        plain.end_batch();
        LmBackend::end_batch(&mut spec);
        assert_eq!(a, b);
    }
}
