//! Self-speculative decoding from the variable-rate GLVQ container.
//!
//! The paper's rate/accuracy trade-off gives multiple views of the *same*
//! weights at different bit-rates. This subsystem cashes that in for
//! wall-clock speed: [`draft::build_draft_view`] re-quantizes the already
//! loaded target weights into a tiny fixed-rate 2-bit lattice view (the
//! same scaled-identity-lattice recipe the KV cache uses to retire cold
//! pages), and [`SpeculativeBackend`] drafts `k` tokens greedily through
//! that view, then verifies all of them in **one** ragged target forward
//! — `forward_ragged` is exactly the verify primitive, because it
//! produces one logits row per fed token.
//!
//! Acceptance is exact, not approximate: generation is greedy
//! (`argmax_logit`), so a drafted token is accepted iff the target's
//! argmax at the same position produces the *identical* token id.
//! Accepted output is therefore bit-identical to target-only decode
//! (`tests/spec_parity.rs`), and the accepted-token rate becomes a
//! quality metric tying back to the paper's rate/accuracy trade-off:
//! a draft view that tracks the target closely accepts more.
//!
//! Rejected positions roll back through
//! [`crate::kvcache::PagedKvCache::truncate_seq`] — a page-granular trim
//! that composes with prefix sharing (a shared page is never freed or
//! written by rollback, only this sequence's reference to it goes).
//!
//! The wrapper implements both serving traits
//! ([`crate::serving::SeqBackend`] and
//! [`crate::coordinator::server::LmBackend`]), so the lockstep *and*
//! continuous loops run it unchanged; `glvq serve --speculate k` switches
//! it on. The draft/verify/rollback phases run under `spec_draft` /
//! `spec_verify` / `spec_rollback` tracing spans, and [`SpecStats`]
//! surfaces the accept rate in the server report.

pub mod backend;
pub mod draft;

pub use backend::SpeculativeBackend;
pub use draft::{build_draft_view, draft_view_of_container, DraftView, DRAFT_BITS};

/// Cumulative draft/verify counters for the speculative decode loop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// tokens proposed by the draft view
    pub drafted: u64,
    /// drafted tokens the target verified (greedy-argmax equality)
    pub accepted: u64,
    /// draft→verify rounds run
    pub rounds: u64,
    /// batched target verify forwards issued
    pub verify_calls: u64,
    /// KV rows rolled back off rejected draft positions
    pub rollback_rows: u64,
}

impl SpecStats {
    /// Fraction of drafted tokens the target accepted (0 when nothing
    /// has been drafted yet).
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Fold another counter snapshot into this one.
    pub fn merge(&mut self, other: &SpecStats) {
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.rounds += other.rounds;
        self.verify_calls += other.verify_calls;
        self.rollback_rows += other.rollback_rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_rate_handles_empty_and_partial() {
        let mut s = SpecStats::default();
        assert_eq!(s.accept_rate(), 0.0);
        s.drafted = 8;
        s.accepted = 6;
        assert!((s.accept_rate() - 0.75).abs() < 1e-12);
        let mut t = SpecStats { drafted: 2, accepted: 2, rounds: 1, ..Default::default() };
        t.merge(&s);
        assert_eq!(t.drafted, 10);
        assert_eq!(t.accepted, 8);
        assert_eq!(t.rounds, 1);
    }
}
