//! Tensor-parallel sharded execution over quantized group boundaries.
//!
//! The paper's decode reduces to independent per-group matrix-vector
//! products, which means a [`crate::quant::format::QuantizedTensor`]
//! partitions **losslessly** along its group grid — grouped-lattice
//! weights are a natural sharding unit in a way dense checkpoints are
//! not. This subsystem turns that into an execution strategy:
//!
//! - [`plan`] assigns whole groups to shards along group-aligned
//!   boundaries (`QuantizedTensor::{col,row}_split_points`), balanced by
//!   true stored payload bytes — never splitting a lattice group or an
//!   rANS chunk;
//! - [`exec`] runs N persistent worker threads, each owning its shard's
//!   decode scratch and rANS decode tables, and reduces their partial
//!   products deterministically (concat for output-dim splits, canonical
//!   ordered sum for input-dim splits) so sharded output is
//!   **bit-identical** to the single-engine path at any shard count.
//!
//! Serving plugs in through [`ShardedLinear`] (a
//! [`crate::eval::native_fwd::LinearOp`]): the layer-plan walk is
//! unchanged, only the operator behind each linear node switches. The
//! CLI exposes it as `glvq serve --shards N` (composing with
//! `--threads`, `--kv-cache` and `--continuous`); `tests/shard_parity.rs`
//! holds the bit-identity proofs and `benches/bench_shard.rs` the
//! speedup acceptance.

pub mod exec;
pub mod plan;

pub use exec::{imbalance, ShardOpts, ShardStat, ShardedLinear, ShardedMatmul};
pub use plan::{balanced_contiguous, ShardPlan, SplitAxis, TensorShardPlan};
