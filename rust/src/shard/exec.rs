//! Tensor-parallel sharded execution of quantized linears.
//!
//! [`ShardedMatmul`] owns N **persistent** worker threads, one per shard.
//! Each worker holds, for the lifetime of the executor:
//!
//! - its shard's group assignment from the [`super::ShardPlan`];
//! - its own decode scratch (a single-thread [`StreamingMatmul`] whose
//!   `parallel_map` runs inline — no per-call thread spawn);
//! - its own expanded rANS decode tables, built **once** per tensor on
//!   first touch and reused for every subsequent batch (the single-engine
//!   path rebuilds them every call), upgraded in place with fused
//!   code→vector LUTs once the tensor crosses the warm-call threshold.
//!
//! A `matmul` call broadcasts the activation batch to every worker,
//! gathers their per-panel partial-product slabs, and reduces them in
//! the canonical (group, panel) order of
//! [`crate::coordinator::decode_stream::merge_slabs`]. For an output-dim
//! (row) partition the shard slabs occupy disjoint output rows and the
//! reduce is a concat; for an input-dim (column) partition it is an
//! ordered segment sum. Because the order depends only on the tensor's
//! group grid — never on the shard count — the result is **bit-identical**
//! to [`StreamingMatmul::matmul`] on one engine, for any shard count
//! (`tests/shard_parity.rs`).
//!
//! [`ShardedLinear`] plugs the executor into the layer-plan walk
//! ([`crate::eval::plan::walk`]) as a [`LinearOp`], which is all the
//! serving backends need to run every forward tensor-parallel.

use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::decode_stream::{
    attach_luts, kernel_tables, merge_slabs, DecodeStats, PanelSlab, StreamingMatmul,
};
use crate::eval::native_fwd::{DenseLinear, LinearOp};
use crate::kernels::{GroupTables, LUT_WARM_CALLS};
use crate::linalg::{Mat, MatView};
use crate::quant::format::QuantizedModel;
use crate::tensor::TensorStore;

use super::plan::ShardPlan;

/// Sharded-execution options.
#[derive(Clone, Copy, Debug)]
pub struct ShardOpts {
    /// number of persistent shard workers
    pub shards: usize,
    /// rows per streamed decode panel (as [`StreamingMatmul`])
    pub panel_rows: usize,
    /// decode threads *inside* each shard worker (1 = inline decode; the
    /// CLI maps `--threads T --shards N` to `T / N`, so total thread
    /// count composes)
    pub threads_per_shard: usize,
}

impl Default for ShardOpts {
    fn default() -> Self {
        ShardOpts { shards: 2, panel_rows: 16, threads_per_shard: 1 }
    }
}

/// Per-shard cumulative counters, surfaced through `ServerMetrics` for
/// the imbalance report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardStat {
    /// matmul jobs this shard has executed
    pub jobs: usize,
    /// code payload bytes decoded (true stored bytes)
    pub code_bytes: usize,
    /// total decode traffic (code + side info; activations are charged
    /// once by the coordinator, not per shard)
    pub total_bytes: usize,
    /// decoded weight elements produced
    pub weights_decoded: usize,
    /// wall time this shard spent decoding, nanoseconds
    pub busy_ns: u64,
}

/// Busy-time imbalance across shards: max/mean (1.0 = perfectly even,
/// 0.0 when no work ran).
pub fn imbalance(stats: &[ShardStat]) -> f64 {
    if stats.is_empty() {
        return 0.0;
    }
    let total: u64 = stats.iter().map(|s| s.busy_ns).sum();
    if total == 0 {
        return 0.0;
    }
    let mean = total as f64 / stats.len() as f64;
    let max = stats.iter().map(|s| s.busy_ns).max().unwrap_or(0) as f64;
    max / mean
}

enum Job {
    Matmul { tensor: usize, x: Arc<Mat>, reply: mpsc::Sender<ShardReply> },
    Stop,
}

struct ShardReply {
    shard: usize,
    slabs: Vec<PanelSlab>,
    stats: DecodeStats,
    busy_ns: u64,
}

/// The persistent worker body: owns this shard's scratch + decode-table
/// cache, answers matmul jobs until `Stop`.
fn worker_loop(
    shard: usize,
    qm: Arc<QuantizedModel>,
    plan: Arc<ShardPlan>,
    engine: StreamingMatmul,
    rx: mpsc::Receiver<Job>,
) {
    // decode tables per tensor, expanded once for the owned groups only;
    // the touch counter upgrades hot tensors with fused code→vector LUTs
    // once they cross the warm threshold (same policy as the engine cache)
    let mut tables: Vec<Option<(usize, Vec<GroupTables>)>> =
        (0..qm.tensors.len()).map(|_| None).collect();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Matmul { tensor, x, reply } => {
                // the span brackets the same region busy_ns measures, so
                // shard-busy trace bars line up with the imbalance report
                let _sp = crate::span!("shard_busy");
                let t0 = Instant::now();
                let qt = &qm.tensors[tensor];
                let owned = &plan.tensors[tensor].owners[shard];
                if tables[tensor].is_none() {
                    tables[tensor] = Some((0, kernel_tables(qt, owned)));
                }
                let (touches, tb) = tables[tensor].as_mut().expect("tables just built");
                *touches += 1;
                if *touches == LUT_WARM_CALLS {
                    attach_luts(qt, owned, tb);
                }
                let mut stats = DecodeStats::default();
                let slabs = engine.panel_slabs(qt, owned, tb, MatView::of(&x), &mut stats);
                let busy_ns = t0.elapsed().as_nanos() as u64;
                // a dropped receiver just means the coordinator gave up on
                // this call; the worker stays alive for the next job
                let _ = reply.send(ShardReply { shard, slabs, stats, busy_ns });
            }
            Job::Stop => break,
        }
    }
}

/// Tensor-parallel decode-matmul executor over a shared quantized
/// container (see module docs). `matmul` is `&self`, so one executor can
/// be shared across layers and serving steps; shutdown is automatic on
/// drop.
pub struct ShardedMatmul {
    qm: Arc<QuantizedModel>,
    plan: Arc<ShardPlan>,
    opts: ShardOpts,
    index: BTreeMap<String, usize>,
    senders: Vec<mpsc::Sender<Job>>,
    joins: Vec<std::thread::JoinHandle<()>>,
    stats: Mutex<Vec<ShardStat>>,
}

impl ShardedMatmul {
    /// Plan the container and start the persistent shard workers.
    pub fn new(qm: Arc<QuantizedModel>, opts: ShardOpts) -> ShardedMatmul {
        let opts = ShardOpts {
            shards: opts.shards.max(1),
            panel_rows: opts.panel_rows.max(1),
            threads_per_shard: opts.threads_per_shard.max(1),
        };
        let plan = Arc::new(ShardPlan::build(&qm, opts.shards));
        let index: BTreeMap<String, usize> =
            qm.tensors.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        let mut senders = Vec::with_capacity(opts.shards);
        let mut joins = Vec::with_capacity(opts.shards);
        for shard in 0..opts.shards {
            let (tx, rx) = mpsc::channel::<Job>();
            let qm_c = Arc::clone(&qm);
            let plan_c = Arc::clone(&plan);
            let engine = StreamingMatmul::new(opts.panel_rows, opts.threads_per_shard);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("glvq-shard-{shard}"))
                    .spawn(move || worker_loop(shard, qm_c, plan_c, engine, rx))
                    .expect("spawn shard worker"),
            );
            senders.push(tx);
        }
        ShardedMatmul {
            qm,
            plan,
            opts,
            index,
            senders,
            joins,
            stats: Mutex::new(vec![ShardStat::default(); opts.shards]),
        }
    }

    pub fn shards(&self) -> usize {
        self.opts.shards
    }

    pub fn opts(&self) -> ShardOpts {
        self.opts
    }

    /// The shared container this executor serves from.
    pub fn model(&self) -> &QuantizedModel {
        &self.qm
    }

    /// The group partition the workers execute.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Container index of a tensor by name, if present.
    pub fn tensor_index(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Per-shard cumulative counters (cheap copy).
    pub fn shard_stats(&self) -> Vec<ShardStat> {
        self.stats.lock().expect("shard stats poisoned").clone()
    }

    /// `y = x · decode(tensor)ᵀ` executed across all shard workers.
    /// Output and [`DecodeStats`] are bit-identical to
    /// [`StreamingMatmul::matmul`] over the same tensor (tested), at any
    /// shard count.
    pub fn matmul(&self, tensor: usize, x: &Mat, y: &mut Mat, stats: &mut DecodeStats) {
        let _sp = crate::span!("shard_matmul");
        let qt = &self.qm.tensors[tensor];
        let batch = x.rows;
        assert_eq!(x.cols, qt.cols, "{}: x cols {} != n_in {}", qt.name, x.cols, qt.cols);
        assert_eq!((y.rows, y.cols), (batch, qt.rows), "{}: bad output shape", qt.name);
        y.data.fill(0.0);
        stats.act_bytes += (x.data.len() + y.data.len()) * 4;

        // broadcast the batch, gather one reply per shard
        let xa = Arc::new(x.clone());
        let (tx, rx) = mpsc::channel::<ShardReply>();
        for s in &self.senders {
            s.send(Job::Matmul { tensor, x: Arc::clone(&xa), reply: tx.clone() })
                .expect("shard worker hung up");
        }
        drop(tx);
        let mut replies: Vec<ShardReply> = rx.iter().collect();
        assert_eq!(replies.len(), self.opts.shards, "{}: lost a shard reply", qt.name);
        replies.sort_by_key(|r| r.shard);

        {
            let mut per = self.stats.lock().expect("shard stats poisoned");
            for r in &replies {
                stats.merge(&r.stats);
                let p = &mut per[r.shard];
                p.jobs += 1;
                p.code_bytes += r.stats.code_bytes;
                p.total_bytes += r.stats.code_bytes + r.stats.side_bytes;
                p.weights_decoded += r.stats.weights_decoded;
                p.busy_ns += r.busy_ns;
            }
        }

        // deterministic reduce: every shard's slabs fold in the canonical
        // (group, panel) order, independent of the shard partition
        let mut slabs: Vec<PanelSlab> =
            replies.into_iter().flat_map(|r| r.slabs).collect();
        slabs.sort_by_key(|s| (s.gi, s.r));
        merge_slabs(qt, &slabs, y);
    }
}

impl Drop for ShardedMatmul {
    fn drop(&mut self) {
        for s in &self.senders {
            let _ = s.send(Job::Stop);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// [`LinearOp`] over the sharded executor: quantized tensors run
/// tensor-parallel, anything absent from the container falls back to the
/// dense store — the drop-in sharded counterpart of
/// [`crate::eval::native_fwd::StreamedLinear`].
pub struct ShardedLinear<'a> {
    pub exec: &'a ShardedMatmul,
    pub store: &'a TensorStore,
    pub stats: DecodeStats,
}

impl LinearOp for ShardedLinear<'_> {
    fn apply(&mut self, name: &str, x: &Mat) -> Result<Mat> {
        match self.exec.tensor_index(name) {
            Some(ti) => {
                let mut y = Mat::zeros(x.rows, self.exec.model().tensors[ti].rows);
                self.exec.matmul(ti, x, &mut y, &mut self.stats);
                Ok(y)
            }
            None => DenseLinear { store: self.store }.apply(name, x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::config::GlvqConfig;
    use crate::glvq::optimizer::GlvqGroupQuantizer;
    use crate::quant::format::QuantizedTensor;
    use crate::quant::traits::GroupQuantizer;
    use crate::util::rng::Rng;

    fn quantized_model(method: &str, seed: u64, entropy: bool) -> QuantizedModel {
        let mut rng = Rng::new(seed);
        let mut tensors = Vec::new();
        for (ti, (rows, cols)) in [(32usize, 64usize), (48, 32)].iter().enumerate() {
            let wt = Mat::random_normal(*rows, *cols, 0.05, &mut rng);
            let x = Mat::random_normal(32, 16, 1.0, &mut rng);
            let mut groups = Vec::new();
            for gi in 0..cols / 32 {
                let panel = wt.slice(0, *rows, gi * 32, (gi + 1) * 32);
                let mut qg = match method {
                    "glvq" => {
                        let mut cfg = GlvqConfig::default();
                        cfg.lattice_dim = 8;
                        cfg.group_size = 32;
                        cfg.iters = 3;
                        GlvqGroupQuantizer::new(cfg).quantize(&panel, &x, 2)
                    }
                    _ => RtnQuantizer.quantize(&panel, &x, 2),
                };
                if entropy {
                    qg.codes = qg.codes.to_entropy(qg.cols * 4, 4);
                }
                groups.push((0usize, gi * 32, qg));
            }
            tensors.push(QuantizedTensor {
                name: format!("t{ti}"),
                rows: *rows,
                cols: *cols,
                groups,
            });
        }
        QuantizedModel { tensors }
    }

    #[test]
    fn sharded_matmul_is_bit_identical_to_single_engine_any_shard_count() {
        for entropy in [false, true] {
            for method in ["rtn", "glvq"] {
                let qm = quantized_model(method, 5, entropy);
                let reference = StreamingMatmul::new(8, 2);
                for shards in [1usize, 2, 4] {
                    let exec = ShardedMatmul::new(
                        Arc::new(qm.clone()),
                        ShardOpts { shards, panel_rows: 8, threads_per_shard: 1 },
                    );
                    for (ti, qt) in qm.tensors.iter().enumerate() {
                        let mut rng = Rng::new(7 + ti as u64);
                        for batch in [1usize, 3] {
                            let x = Mat::random_normal(batch, qt.cols, 1.0, &mut rng);
                            let mut want = Mat::zeros(batch, qt.rows);
                            let mut sw = DecodeStats::default();
                            reference.matmul(qt, &x, &mut want, &mut sw);
                            let mut got = Mat::zeros(batch, qt.rows);
                            let mut sg = DecodeStats::default();
                            exec.matmul(ti, &x, &mut got, &mut sg);
                            assert_eq!(
                                got.data, want.data,
                                "{method} entropy={entropy} shards={shards} t{ti} b{batch}"
                            );
                            assert_eq!(sg, sw, "stats drifted at shards={shards}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn shard_stats_accumulate_and_balance() {
        let qm = quantized_model("rtn", 9, true);
        let exec = ShardedMatmul::new(
            Arc::new(qm.clone()),
            ShardOpts { shards: 2, panel_rows: 8, threads_per_shard: 1 },
        );
        let mut rng = Rng::new(11);
        let x = Mat::random_normal(2, qm.tensors[0].cols, 1.0, &mut rng);
        let mut y = Mat::zeros(2, qm.tensors[0].rows);
        let mut st = DecodeStats::default();
        for _ in 0..3 {
            exec.matmul(0, &x, &mut y, &mut st);
        }
        let per = exec.shard_stats();
        assert_eq!(per.len(), 2);
        for (i, p) in per.iter().enumerate() {
            assert_eq!(p.jobs, 3, "shard {i}");
            assert!(p.weights_decoded > 0, "shard {i} decoded nothing");
        }
        // both shards own one of the two equal groups → equal decode work
        assert_eq!(per[0].weights_decoded, per[1].weights_decoded);
        let imb = imbalance(&per);
        assert!(imb >= 1.0, "imbalance {imb}");
        // per-shard code bytes sum to the engine-level total
        assert_eq!(
            per.iter().map(|p| p.code_bytes).sum::<usize>(),
            st.code_bytes
        );
    }

    #[test]
    fn sharded_linear_falls_back_to_dense_for_unquantized_names() {
        use crate::model::{init_params, CONFIG_S};
        let cfg = CONFIG_S;
        let store = init_params(&cfg, 3);
        let qm = quantized_model("rtn", 13, false);
        let exec = ShardedMatmul::new(Arc::new(qm), ShardOpts::default());
        let mut lin = ShardedLinear { exec: &exec, store: &store, stats: DecodeStats::default() };
        // "emb" is not in the container → dense fallback must serve it
        let mut rng = Rng::new(4);
        let x = Mat::random_normal(2, cfg.vocab, 1.0, &mut rng);
        let y = lin.apply("emb", &x).unwrap();
        assert_eq!((y.rows, y.cols), (2, cfg.d_model));
    }

    #[test]
    fn imbalance_of_empty_and_even() {
        assert_eq!(imbalance(&[]), 0.0);
        let even = vec![ShardStat { busy_ns: 100, ..Default::default() }; 4];
        assert!((imbalance(&even) - 1.0).abs() < 1e-12);
        let skew = vec![
            ShardStat { busy_ns: 300, ..Default::default() },
            ShardStat { busy_ns: 100, ..Default::default() },
        ];
        assert!((imbalance(&skew) - 1.5).abs() < 1e-12);
    }
}
