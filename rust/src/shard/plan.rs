//! Shard planning: partition every quantized tensor along its group
//! boundaries into per-shard ownership lists.
//!
//! The unit of assignment is the [`crate::quant::traits::QuantizedGroup`]
//! — never a slice of one — so a shard's payload is always a set of
//! whole lattice groups / rANS chunk streams
//! ([`crate::quant::format::QuantizedTensor::col_split_points`] is the
//! boundary lattice the planner picks from). For the pipeline's standard
//! layout (full-row column groups) the partition follows the **input
//! dimension** (row-parallel in Megatron terms: every shard computes a
//! full-width partial output that the coordinator reduces); tensors
//! grouped along rows partition the **output dimension** (column-parallel:
//! shard outputs occupy disjoint rows and the reduce degenerates to a
//! concat). Either way the reduce runs in the canonical (group, panel)
//! order of [`crate::coordinator::decode_stream::merge_slabs`], so the
//! result is bit-identical to the single-engine path at any shard count.
//!
//! Assignment is deterministic: contiguous cell runs balanced by true
//! stored payload bytes (compressed size for entropy payloads), so a
//! tensor whose groups compress unevenly still spreads decode work
//! evenly.

use crate::quant::format::{QuantizedModel, QuantizedTensor};

/// Which axis a tensor's partition follows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitAxis {
    /// column (input-dim) split: shards produce overlapping-support
    /// partials that the coordinator sums in canonical group order
    Cols,
    /// row (output-dim) split: shard outputs occupy disjoint output rows;
    /// the reduce is a concat
    Rows,
    /// no non-trivial group-aligned boundary on either axis: groups are
    /// assigned directly (still whole groups, still canonical-order
    /// reduce)
    Groups,
}

/// One tensor's shard assignment.
#[derive(Clone, Debug)]
pub struct TensorShardPlan {
    /// group indices owned by each shard (ascending within a shard);
    /// disjoint and jointly complete over `qt.groups`
    pub owners: Vec<Vec<usize>>,
    /// payload bytes each shard owns (the balance target)
    pub owned_bytes: Vec<usize>,
    pub axis: SplitAxis,
}

/// The whole model's shard assignment, one entry per tensor of the
/// container it was built from.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub shards: usize,
    pub tensors: Vec<TensorShardPlan>,
}

/// Split `weights` into `shards` contiguous runs with near-equal sums:
/// run `k` ends at the smallest prefix reaching `total·(k+1)/shards`.
/// Deterministic; later runs may be empty when cells are few or skewed.
/// Also the balancing core of `cluster::PipelinePlan`, which feeds it
/// per-layer payload bytes instead of per-cell bytes.
pub fn balanced_contiguous(weights: &[usize], shards: usize) -> Vec<(usize, usize)> {
    let total: usize = weights.iter().sum();
    let n = weights.len();
    let mut runs = Vec::with_capacity(shards);
    let mut start = 0usize;
    let mut acc = 0usize;
    for k in 0..shards {
        let target = (total as u128 * (k as u128 + 1) / shards as u128) as usize;
        let mut end = start;
        while end < n && (acc < target || target == 0) {
            // leave at least one cell per remaining shard when possible
            if n - end <= shards - 1 - k {
                break;
            }
            acc += weights[end];
            end += 1;
            if acc >= target {
                break;
            }
        }
        if k == shards - 1 {
            end = n;
        }
        runs.push((start, end));
        start = end;
    }
    runs
}

impl TensorShardPlan {
    /// Partition one tensor. Cells are the spans between adjacent
    /// group-aligned split points on the chosen axis; each cell's groups
    /// stay together, and cells are distributed as contiguous balanced
    /// runs.
    pub fn build(qt: &QuantizedTensor, shards: usize) -> TensorShardPlan {
        let shards = shards.max(1);
        let col_pts = qt.col_split_points();
        let row_pts = qt.row_split_points();
        let (axis, cells): (SplitAxis, Vec<Vec<usize>>) = if col_pts.len() > 2 {
            (SplitAxis::Cols, cells_on_axis(qt, &col_pts, |(_, c0, _)| *c0))
        } else if row_pts.len() > 2 {
            (SplitAxis::Rows, cells_on_axis(qt, &row_pts, |(r0, _, _)| *r0))
        } else {
            (SplitAxis::Groups, (0..qt.groups.len()).map(|gi| vec![gi]).collect())
        };
        let weights: Vec<usize> = cells
            .iter()
            .map(|c| c.iter().map(|&gi| qt.groups[gi].2.codes.payload_bytes()).sum())
            .collect();
        let runs = balanced_contiguous(&weights, shards);
        let mut owners = Vec::with_capacity(shards);
        let mut owned_bytes = Vec::with_capacity(shards);
        for &(a, b) in &runs {
            let mut groups: Vec<usize> = cells[a..b].iter().flatten().copied().collect();
            groups.sort_unstable();
            owned_bytes.push(groups.iter().map(|&gi| qt.groups[gi].2.codes.payload_bytes()).sum());
            owners.push(groups);
        }
        TensorShardPlan { owners, owned_bytes, axis }
    }
}

/// Group indices per cell, cells ordered by the axis split points.
fn cells_on_axis<F>(qt: &QuantizedTensor, pts: &[usize], key: F) -> Vec<Vec<usize>>
where
    F: Fn(&(usize, usize, crate::quant::traits::QuantizedGroup)) -> usize,
{
    let mut cells: Vec<Vec<usize>> = vec![Vec::new(); pts.len() - 1];
    for (gi, g) in qt.groups.iter().enumerate() {
        let k = key(g);
        // the cell whose [pts[i], pts[i+1]) span contains the group start
        let ci = match pts.binary_search(&k) {
            Ok(i) => i.min(pts.len() - 2),
            Err(i) => i - 1,
        };
        cells[ci].push(gi);
    }
    cells
}

impl ShardPlan {
    /// Plan every tensor of a container for `shards`-way execution.
    pub fn build(qm: &QuantizedModel, shards: usize) -> ShardPlan {
        ShardPlan {
            shards: shards.max(1),
            tensors: qm.tensors.iter().map(|t| TensorShardPlan::build(t, shards.max(1))).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{code_range, PackedCodes};
    use crate::quant::traits::{QuantizedGroup, SideInfo};

    fn column_tensor(n_groups: usize) -> QuantizedTensor {
        let (lo, hi) = code_range(2);
        let codes: Vec<i32> = (0..64).map(|i| (i % (hi - lo + 1)) + lo).collect();
        let groups = (0..n_groups)
            .map(|gi| {
                (
                    0usize,
                    gi * 8,
                    QuantizedGroup {
                        method: "rtn",
                        bits: 2,
                        rows: 8,
                        cols: 8,
                        codes: PackedCodes::pack(&codes, 2).into(),
                        side: SideInfo::Uniform { scale: 0.1, zero: 0.0 },
                    },
                )
            })
            .collect();
        QuantizedTensor { name: "t".into(), rows: 8, cols: n_groups * 8, groups }
    }

    #[test]
    fn owners_are_disjoint_and_complete() {
        for shards in [1usize, 2, 3, 4, 7] {
            let qt = column_tensor(6);
            let plan = TensorShardPlan::build(&qt, shards);
            assert_eq!(plan.owners.len(), shards);
            assert_eq!(plan.axis, SplitAxis::Cols);
            let mut all: Vec<usize> = plan.owners.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..6).collect::<Vec<_>>(), "shards={shards}");
        }
    }

    #[test]
    fn balance_tracks_payload_bytes() {
        let qt = column_tensor(8);
        let plan = TensorShardPlan::build(&qt, 4);
        // equal-size groups, 4 shards → 2 groups each
        for (s, o) in plan.owners.iter().enumerate() {
            assert_eq!(o.len(), 2, "shard {s} owns {o:?}");
        }
        let total: usize = plan.owned_bytes.iter().sum();
        assert_eq!(total, qt.payload_bytes());
    }

    #[test]
    fn more_shards_than_cells_leaves_spare_shards_empty() {
        let qt = column_tensor(2);
        let plan = TensorShardPlan::build(&qt, 4);
        let owned: usize = plan.owners.iter().map(|o| o.len()).sum();
        assert_eq!(owned, 2);
        assert!(plan.owners.iter().filter(|o| o.is_empty()).count() >= 2);
    }

    #[test]
    fn single_full_width_group_falls_back_to_group_axis() {
        let (lo, hi) = code_range(2);
        let codes: Vec<i32> = (0..64).map(|i| (i % (hi - lo + 1)) + lo).collect();
        let qt = QuantizedTensor {
            name: "one".into(),
            rows: 8,
            cols: 8,
            groups: vec![(
                0,
                0,
                QuantizedGroup {
                    method: "rtn",
                    bits: 2,
                    rows: 8,
                    cols: 8,
                    codes: PackedCodes::pack(&codes, 2).into(),
                    side: SideInfo::Uniform { scale: 0.1, zero: 0.0 },
                },
            )],
        };
        let plan = TensorShardPlan::build(&qt, 3);
        assert_eq!(plan.axis, SplitAxis::Groups);
        assert_eq!(plan.owners.iter().map(|o| o.len()).sum::<usize>(), 1);
    }

    #[test]
    fn row_grouped_tensor_splits_rows() {
        let (lo, hi) = code_range(2);
        let codes: Vec<i32> = (0..64).map(|i| (i % (hi - lo + 1)) + lo).collect();
        let mk = || QuantizedGroup {
            method: "rtn",
            bits: 2,
            rows: 8,
            cols: 8,
            codes: PackedCodes::pack(&codes, 2).into(),
            side: SideInfo::Uniform { scale: 0.1, zero: 0.0 },
        };
        let qt = QuantizedTensor {
            name: "rows".into(),
            rows: 16,
            cols: 8,
            groups: vec![(0, 0, mk()), (8, 0, mk())],
        };
        let plan = TensorShardPlan::build(&qt, 2);
        assert_eq!(plan.axis, SplitAxis::Rows);
        assert_eq!(plan.owners[0], vec![0]);
        assert_eq!(plan.owners[1], vec![1]);
    }

    #[test]
    fn balanced_contiguous_is_deterministic_and_covers() {
        let w = [5usize, 1, 1, 1, 5, 1, 1, 1];
        let runs = balanced_contiguous(&w, 3);
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0].0, 0);
        assert_eq!(runs.last().unwrap().1, w.len());
        for pair in runs.windows(2) {
            assert_eq!(pair[0].1, pair[1].0, "runs not contiguous");
        }
        assert_eq!(runs, balanced_contiguous(&w, 3));
    }
}
