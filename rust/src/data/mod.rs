//! Data substrate: the synthetic corpus that substitutes for
//! WikiText-2 / C4 / RedPajama (DESIGN.md §3), the byte tokenizer, and
//! deterministic batch / calibration samplers.

pub mod batches;
pub mod corpus;
pub mod tokenizer;
