//! Synthetic language corpus generator.
//!
//! The corpus must make the paper's evaluation *meaningful* on a small
//! trained transformer, so it has learnable structure at several ranges:
//!
//! - a fixed word vocabulary built from syllables (local byte structure),
//! - an SVO grammar with agreement-like co-occurrence (mid-range),
//! - bracketed asides `( … )` / `[ … ]` whose closer type must match the
//!   opener across a long span (long-range — the ARC-C probe),
//! - entity repetition: paragraph-level named entities that recur
//!   (induction — the Winogrande probe),
//! - two *mixes* with different word/grammar statistics standing in for the
//!   paper's two eval sets (Wikitext2 → `Mix::Wiki`, C4 → `Mix::Web`).
//!
//! Generation is fully deterministic in the seed.

use crate::util::rng::Rng;

/// Which evaluation distribution to emulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Mix {
    /// longer sentences, heavier entity reuse, nested brackets
    Wiki,
    /// shorter, noisier: numbers, stray punctuation, fewer repeats
    Web,
}

impl Mix {
    pub fn name(&self) -> &'static str {
        match self {
            Mix::Wiki => "wiki",
            Mix::Web => "web",
        }
    }
}

const SYLLABLES: &[&str] = &[
    "ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du", "ka", "ke", "ki", "ko", "ku",
    "la", "le", "li", "lo", "lu", "ma", "me", "mi", "mo", "mu", "na", "ne", "ni", "no", "nu",
    "ra", "re", "ri", "ro", "ru", "sa", "se", "si", "so", "su", "ta", "te", "ti", "to", "tu",
    "va", "ve", "vi", "vo", "vu", "za", "ze", "zi", "zo", "zu",
];

/// Deterministic word list shared by both mixes; nouns/verbs/adjectives are
/// disjoint slices so grammar induces real co-occurrence statistics.
pub struct Vocabulary {
    pub nouns: Vec<String>,
    pub verbs: Vec<String>,
    pub adjectives: Vec<String>,
    pub entities: Vec<String>,
}

impl Vocabulary {
    pub fn build(seed: u64) -> Vocabulary {
        let mut rng = Rng::new(seed ^ 0x5EED_F00D);
        let mut word = |syl: usize| -> String {
            let mut s = String::new();
            for _ in 0..syl {
                s.push_str(SYLLABLES[rng.below(SYLLABLES.len())]);
            }
            s
        };
        let nouns = (0..120).map(|_| word(2)).collect();
        let verbs = (0..60).map(|_| word(2)).collect();
        let adjectives = (0..60).map(|_| word(3)).collect();
        // entities are Capitalized 2-syllable words
        let entities = (0..40)
            .map(|_| {
                let w = word(2);
                let mut c = w.chars();
                match c.next() {
                    Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
                    None => w,
                }
            })
            .collect();
        Vocabulary { nouns, verbs, adjectives, entities }
    }
}

/// Corpus generator state.
pub struct Corpus {
    pub mix: Mix,
    vocab: Vocabulary,
    rng: Rng,
    /// zipf-ish sampling weights over nouns (frequent-bigram probe relies
    /// on a skewed distribution)
    noun_weights: Vec<f64>,
    verb_weights: Vec<f64>,
}

impl Corpus {
    pub fn new(mix: Mix, seed: u64) -> Corpus {
        let vocab = Vocabulary::build(1); // shared vocab across seeds/mixes
        let zipf = |n: usize| -> Vec<f64> { (0..n).map(|i| 1.0 / (i as f64 + 1.5)).collect() };
        Corpus {
            mix,
            noun_weights: zipf(vocab.nouns.len()),
            verb_weights: zipf(vocab.verbs.len()),
            vocab,
            rng: Rng::new(seed),
        }
    }

    pub fn vocab(&self) -> &Vocabulary {
        &self.vocab
    }

    fn noun(&mut self) -> String {
        let i = self.rng.categorical(&self.noun_weights);
        self.vocab.nouns[i].clone()
    }

    fn verb(&mut self) -> String {
        let i = self.rng.categorical(&self.verb_weights);
        self.vocab.verbs[i].clone()
    }

    fn adjective(&mut self) -> String {
        let i = self.rng.below(self.vocab.adjectives.len());
        self.vocab.adjectives[i].clone()
    }

    /// One sentence, optionally referencing paragraph entities.
    fn sentence(&mut self, entities: &[String]) -> String {
        let mut parts: Vec<String> = Vec::new();
        let subject = if !entities.is_empty() && self.rng.f64() < 0.55 {
            entities[self.rng.below(entities.len())].clone()
        } else {
            format!("the {}", self.noun())
        };
        parts.push(subject);
        parts.push(self.verb());
        if self.rng.f64() < 0.7 {
            let adj = if self.rng.f64() < 0.4 { format!("{} ", self.adjective()) } else { String::new() };
            parts.push(format!("the {}{}", adj, self.noun()));
        }
        // bracketed aside with type-matching closer (long-range dependency)
        let aside_p = match self.mix {
            Mix::Wiki => 0.35,
            Mix::Web => 0.15,
        };
        if self.rng.f64() < aside_p {
            let (open, close) = if self.rng.f64() < 0.5 { ('(', ')') } else { ('[', ']') };
            let inner = format!("{} {} {}", self.noun(), self.verb(), self.noun());
            parts.push(format!("{open}{inner}{close}"));
        }
        if self.mix == Mix::Web && self.rng.f64() < 0.25 {
            parts.push(format!("{}", self.rng.below(1000)));
        }
        let mut s = parts.join(" ");
        s.push_str(if self.mix == Mix::Web && self.rng.f64() < 0.2 { "!" } else { "." });
        s
    }

    /// One paragraph: picks 1-3 entities that recur across its sentences —
    /// the induction signal.
    pub fn paragraph(&mut self) -> String {
        let n_entities = match self.mix {
            Mix::Wiki => 1 + self.rng.below(3),
            Mix::Web => self.rng.below(2),
        };
        let entities: Vec<String> = (0..n_entities)
            .map(|_| self.vocab.entities[self.rng.below(self.vocab.entities.len())].clone())
            .collect();
        let n_sent = match self.mix {
            Mix::Wiki => 4 + self.rng.below(5),
            Mix::Web => 2 + self.rng.below(3),
        };
        let mut out = String::new();
        for i in 0..n_sent {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(&self.sentence(&entities));
        }
        out.push('\n');
        out
    }

    /// Generate at least `n_bytes` of corpus text (byte == token).
    pub fn generate(&mut self, n_bytes: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n_bytes + 256);
        while out.len() < n_bytes {
            out.extend_from_slice(self.paragraph().as_bytes());
        }
        out.truncate(n_bytes);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = Corpus::new(Mix::Wiki, 7).generate(4096);
        let b = Corpus::new(Mix::Wiki, 7).generate(4096);
        assert_eq!(a, b);
        let c = Corpus::new(Mix::Wiki, 8).generate(4096);
        assert_ne!(a, c);
    }

    #[test]
    fn mixes_have_different_statistics() {
        let wiki = Corpus::new(Mix::Wiki, 1).generate(60_000);
        let web = Corpus::new(Mix::Web, 1).generate(60_000);
        let digits = |v: &[u8]| v.iter().filter(|b| b.is_ascii_digit()).count() as f64 / v.len() as f64;
        assert!(digits(&web) > digits(&wiki) * 2.0, "web should carry more digits");
        let brackets = |v: &[u8]| v.iter().filter(|&&b| b == b'(' || b == b'[').count() as f64 / v.len() as f64;
        assert!(brackets(&wiki) > brackets(&web), "wiki should carry more brackets");
    }

    #[test]
    fn ascii_only_and_brackets_balanced() {
        let text = Corpus::new(Mix::Wiki, 3).generate(50_000);
        assert!(text.iter().all(|&b| b.is_ascii()));
        // brackets balance within the untruncated portion
        let upto = text.iter().rposition(|&b| b == b'\n').unwrap_or(0);
        let mut depth_round = 0i64;
        let mut depth_square = 0i64;
        for &b in &text[..upto] {
            match b {
                b'(' => depth_round += 1,
                b')' => depth_round -= 1,
                b'[' => depth_square += 1,
                b']' => depth_square -= 1,
                _ => {}
            }
            assert!(depth_round >= 0 && depth_square >= 0);
        }
        assert_eq!(depth_round, 0);
        assert_eq!(depth_square, 0);
    }

    #[test]
    fn entities_recur_within_paragraphs() {
        let mut c = Corpus::new(Mix::Wiki, 5);
        let mut repeats = 0;
        for _ in 0..50 {
            let p = c.paragraph();
            for e in &c.vocab().entities.clone() {
                let count = p.matches(e.as_str()).count();
                if count >= 2 {
                    repeats += 1;
                    break;
                }
            }
        }
        assert!(repeats > 10, "entity repetition too rare: {repeats}/50");
    }

    #[test]
    fn vocabulary_is_stable() {
        let a = Vocabulary::build(1);
        let b = Vocabulary::build(1);
        assert_eq!(a.nouns, b.nouns);
        assert_eq!(a.entities, b.entities);
        assert!(a.nouns.len() >= 100);
    }
}
