//! Byte-level tokenizer (vocab = 256). Trivial by design — the model's
//! vocabulary axis matches the paper's setup structurally (token ids feed an
//! embedding table) without dragging in BPE training.

pub const VOCAB: usize = 256;

/// Encode text to token ids.
pub fn encode(text: &[u8]) -> Vec<i32> {
    text.iter().map(|&b| b as i32).collect()
}

/// Decode token ids to bytes (lossy for out-of-range ids → '?').
pub fn decode(tokens: &[i32]) -> Vec<u8> {
    tokens
        .iter()
        .map(|&t| if (0..256).contains(&t) { t as u8 } else { b'?' })
        .collect()
}

pub fn decode_string(tokens: &[i32]) -> String {
    String::from_utf8_lossy(&decode(tokens)).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let text = b"the kama vove (riko tesu) 42.";
        assert_eq!(decode(&encode(text)), text.to_vec());
    }

    #[test]
    fn out_of_range_replaced() {
        assert_eq!(decode(&[65, 300, -1]), vec![b'A', b'?', b'?']);
    }
}
