//! Deterministic batch iteration over a token stream + the calibration
//! sampler that feeds per-layer activation capture (paper: 4M RedPajama
//! tokens → here a seed-controlled token budget, swept in Table 11).

use crate::util::rng::Rng;

/// (x, y) next-token batches: x = tokens[p..p+T], y = tokens[p+1..p+T+1].
pub struct BatchIter<'a> {
    tokens: &'a [i32],
    batch: usize,
    seq: usize,
    rng: Rng,
    /// when false, walk windows sequentially (eval); when true, sample
    /// random offsets (training)
    random: bool,
    cursor: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(tokens: &'a [i32], batch: usize, seq: usize, seed: u64, random: bool) -> Self {
        assert!(tokens.len() > seq + 1, "token stream too short");
        BatchIter { tokens, batch, seq, rng: Rng::new(seed), random, cursor: 0 }
    }

    /// Number of full sequential batches available (eval mode).
    pub fn n_sequential_batches(&self) -> usize {
        (self.tokens.len() - 1) / self.seq / self.batch
    }

    /// Next batch; returns flattened row-major (batch*seq) x and y, or None
    /// when a sequential pass is exhausted.
    pub fn next_batch(&mut self) -> Option<(Vec<i32>, Vec<i32>)> {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = if self.random {
                self.rng.below(self.tokens.len() - self.seq - 1)
            } else {
                let s = self.cursor;
                if s + self.seq + 1 > self.tokens.len() {
                    return None;
                }
                self.cursor += self.seq;
                s
            };
            x.extend_from_slice(&self.tokens[start..start + self.seq]);
            y.extend_from_slice(&self.tokens[start + 1..start + self.seq + 1]);
        }
        Some((x, y))
    }

    pub fn reset(&mut self) {
        self.cursor = 0;
    }
}

/// Sample `n` calibration sequences of length `seq` at random offsets.
/// Returns row-major (n × seq) token ids — the model runs these to capture
/// per-layer input activations for the quantizers.
pub fn sample_calibration(tokens: &[i32], n: usize, seq: usize, seed: u64) -> Vec<i32> {
    assert!(tokens.len() > seq + 1);
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n * seq);
    for _ in 0..n {
        let start = rng.below(tokens.len() - seq - 1);
        out.extend_from_slice(&tokens[start..start + seq]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize) -> Vec<i32> {
        (0..n).map(|i| (i % 251) as i32).collect()
    }

    #[test]
    fn sequential_pass_covers_stream_without_overlap() {
        let t = toks(1000);
        let mut it = BatchIter::new(&t, 2, 10, 0, false);
        let mut seen = Vec::new();
        while let Some((x, _)) = it.next_batch() {
            seen.extend(x);
        }
        // windows advance by seq => x values are the stream prefix in order
        assert!(seen.len() >= 900);
        for (i, &v) in seen.iter().enumerate() {
            assert_eq!(v, t[i]);
        }
    }

    #[test]
    fn y_is_x_shifted_by_one() {
        let t = toks(500);
        let mut it = BatchIter::new(&t, 3, 7, 1, true);
        let (x, y) = it.next_batch().unwrap();
        for row in 0..3 {
            for j in 0..6 {
                assert_eq!(x[row * 7 + j + 1], y[row * 7 + j]);
            }
        }
    }

    #[test]
    fn random_mode_is_deterministic_in_seed() {
        let t = toks(5000);
        let a = BatchIter::new(&t, 4, 16, 9, true).next_batch().unwrap();
        let b = BatchIter::new(&t, 4, 16, 9, true).next_batch().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn calibration_shapes_and_determinism() {
        let t = toks(10_000);
        let c1 = sample_calibration(&t, 8, 32, 5);
        let c2 = sample_calibration(&t, 8, 32, 5);
        assert_eq!(c1.len(), 8 * 32);
        assert_eq!(c1, c2);
        assert_ne!(c1, sample_calibration(&t, 8, 32, 6));
    }
}
