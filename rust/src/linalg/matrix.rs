//! Row-major f32 matrix with the operations the quantizers need.
//!
//! `matmul` is cache-blocked + micro-kerneled (see `bench_support` and
//! EXPERIMENTS.md §Perf for measurements); everything else favours clarity.

use crate::util::rng::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn random_normal(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, scale);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// C = A @ B. Blocked ikj loop with an 8-wide inner kernel; this is the
    /// native hot path for calibration products and reconstruction errors.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut c = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut c);
        c
    }

    /// y = A @ x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    pub fn add(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x - y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Mat {
        let data = self.data.iter().map(|x| x * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// self += s * b (axpy).
    pub fn axpy(&mut self, s: f32, b: &Mat) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (x, y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn frob_dist(&self, b: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            .sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    /// Extract a sub-matrix of rows [r0, r1) and cols [c0, c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write `block` into self at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Mat) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for i in 0..block.rows {
            let dst = &mut self.row_mut(r0 + i)[c0..c0 + block.cols];
            dst.copy_from_slice(block.row(i));
        }
    }
}

/// Borrowed row-major matrix view — the shape of [`Mat`] without the
/// ownership. The streaming/fused decode engines take activations as a
/// `MatView` so the batch-1 hot path can pass a bare `&[f32]` without
/// cloning it into a fresh `Mat` first.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> MatView<'a> {
    /// View an owned matrix.
    pub fn of(m: &'a Mat) -> MatView<'a> {
        MatView { rows: m.rows, cols: m.cols, data: &m.data }
    }

    /// View a borrowed slice as a (rows × cols) matrix.
    pub fn from_slice(rows: usize, cols: usize, data: &'a [f32]) -> MatView<'a> {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        MatView { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

/// C = A @ B into a preallocated C (zeroed by caller or overwritten here).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    c.data.fill(0.0);
    let (n, k, m) = (a.rows, a.cols, b.cols);
    // i-k-j ordering: stream B rows, accumulate into C row; unrolled by 8.
    const KB: usize = 64;
    for k0 in (0..k).step_by(KB) {
        let kmax = (k0 + KB).min(k);
        for i in 0..n {
            let arow = a.row(i);
            let crow = &mut c.data[i * m..(i + 1) * m];
            for kk in k0..kmax {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * m..(kk + 1) * m];
                let chunks = m / 8;
                for t in 0..chunks {
                    let j = t * 8;
                    crow[j] += aik * brow[j];
                    crow[j + 1] += aik * brow[j + 1];
                    crow[j + 2] += aik * brow[j + 2];
                    crow[j + 3] += aik * brow[j + 3];
                    crow[j + 4] += aik * brow[j + 4];
                    crow[j + 5] += aik * brow[j + 5];
                    crow[j + 6] += aik * brow[j + 6];
                    crow[j + 7] += aik * brow[j + 7];
                }
                for j in chunks * 8..m {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        proptest(30, |rig| {
            let (n, k, m) = (rig.usize_in(1, 40), rig.usize_in(1, 40), rig.usize_in(1, 40));
            let a = Mat::from_vec(n, k, rig.vec_normal(n * k, 1.0));
            let b = Mat::from_vec(k, m, rig.vec_normal(k * m, 1.0));
            let fast = a.matmul(&b);
            let slow = naive_matmul(&a, &b);
            assert!(fast.frob_dist(&slow) < 1e-3 * (1.0 + slow.frob_norm()));
        });
    }

    #[test]
    fn identity_is_neutral() {
        proptest(20, |rig| {
            let n = rig.usize_in(1, 24);
            let a = Mat::from_vec(n, n, rig.vec_normal(n * n, 1.0));
            let i = Mat::eye(n);
            assert!(a.matmul(&i).frob_dist(&a) < 1e-5);
            assert!(i.matmul(&a).frob_dist(&a) < 1e-5);
        });
    }

    #[test]
    fn transpose_involution_and_shape() {
        proptest(20, |rig| {
            let (n, m) = (rig.usize_in(1, 50), rig.usize_in(1, 50));
            let a = Mat::from_vec(n, m, rig.vec_normal(n * m, 1.0));
            let t = a.transpose();
            assert_eq!((t.rows, t.cols), (m, n));
            assert_eq!(t.transpose(), a);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        proptest(20, |rig| {
            let (n, m) = (rig.usize_in(1, 30), rig.usize_in(1, 30));
            let a = Mat::from_vec(n, m, rig.vec_normal(n * m, 1.0));
            let x = rig.vec_normal(m, 1.0);
            let xm = Mat::from_vec(m, 1, x.clone());
            let want = a.matmul(&xm);
            let got = a.matvec(&x);
            for i in 0..n {
                assert!((got[i] - want.at(i, 0)).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn slice_and_set_block_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Mat::random_normal(10, 8, 1.0, &mut rng);
        let b = a.slice(2, 7, 1, 5);
        assert_eq!((b.rows, b.cols), (5, 4));
        let mut c = Mat::zeros(10, 8);
        c.set_block(2, 1, &b);
        assert_eq!(c.at(3, 2), a.at(3, 2));
        assert_eq!(c.at(0, 0), 0.0);
    }

    #[test]
    fn axpy_and_norms() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = Mat::zeros(2, 2);
        b.axpy(2.0, &a);
        assert_eq!(b.data, vec![2.0, 4.0, 6.0, 8.0]);
        assert!((a.frob_norm() - (30.0f32).sqrt()).abs() < 1e-6);
        assert_eq!(a.max_abs(), 4.0);
    }
}
