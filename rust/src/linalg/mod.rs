//! Dense linear algebra substrate (no BLAS/LAPACK in the vendored set).
//!
//! Everything GLVQ needs: a row-major [`Mat`] with blocked matmul, LU/Cholesky
//! decompositions ([`decomp`]), LLL lattice basis reduction ([`lll`], used by
//! the Appendix-A Babai error-bound property tests), power-iteration spectral
//! estimates and clamping ([`spectral`]), and weight statistics ([`stats`]).
//!
//! The PJRT path never sees these — they serve the rust-native optimizer,
//! the baselines, and the places where XLA 0.5.1 cannot go (matrix inverse
//! lowers to a typed-FFI custom call it rejects, so `G^{-1}` is always
//! produced here and fed *into* the graphs).

pub mod decomp;
pub mod lll;
pub mod matrix;
pub mod spectral;
pub mod stats;

pub use matrix::{Mat, MatView};
