//! Spectral estimates via power iteration + the spectral clamp the GLVQ
//! optimizer applies after every G-update ("Spectral normalization is
//! applied after each update to constrain the singular values of G within
//! a stable range [σ_min, σ_max]").
//!
//! For the small d×d generation matrices (d ≤ 32) power iteration on GᵀG is
//! accurate and allocation-light; the clamp rescales G when σ_max or σ_min
//! leaves the band (a practical surrogate for full SVD projection that
//! preserves lattice shape — documented deviation: the paper does not
//! specify the projection operator).

use super::decomp::{inverse, DecompError};
use super::matrix::Mat;

/// Largest singular value of A (power iteration on AᵀA).
pub fn sigma_max(a: &Mat, iters: usize) -> f32 {
    let at = a.transpose();
    let n = a.cols;
    let mut v = vec![1.0f32; n];
    let mut norm = (n as f32).sqrt();
    for x in v.iter_mut() {
        *x /= norm;
    }
    let mut lam = 0.0f32;
    for _ in 0..iters {
        let w = at.matvec(&a.matvec(&v)); // AᵀA v
        norm = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm < 1e-20 {
            return 0.0;
        }
        v = w.iter().map(|x| x / norm).collect();
        lam = norm;
    }
    lam.sqrt()
}

/// Smallest singular value via power iteration on (AᵀA)^{-1}.
pub fn sigma_min(a: &Mat, iters: usize) -> Result<f32, DecompError> {
    let ata = a.transpose().matmul(a);
    let inv = inverse(&ata)?;
    let s = sigma_max_sym(&inv, iters);
    Ok(if s > 0.0 { 1.0 / s.sqrt() } else { 0.0 })
}

/// Largest eigenvalue of a symmetric PSD matrix.
fn sigma_max_sym(a: &Mat, iters: usize) -> f32 {
    let n = a.cols;
    let mut v = vec![1.0f32; n];
    let mut lam = 0.0f32;
    for _ in 0..iters {
        let w = a.matvec(&v);
        let norm = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm < 1e-20 {
            return 0.0;
        }
        v = w.iter().map(|x| x / norm).collect();
        lam = norm;
    }
    lam
}

/// Clamp the singular values of G into [smin, smax] by global rescaling:
/// if σ_max(G) > smax, scale down; if σ_min(G) < smin (and G nonsingular),
/// blend toward a scaled identity to lift the bottom of the spectrum.
pub fn spectral_clamp(g: &Mat, smin: f32, smax: f32) -> Mat {
    let mut out = g.clone();
    let sm = sigma_max(&out, 30);
    if sm > smax && sm > 0.0 {
        out = out.scale(smax / sm);
    }
    let smn = sigma_min(&out, 30).unwrap_or(0.0);
    if smn < smin {
        // lift: G <- G + eps * I scaled to restore conditioning
        let n = out.rows;
        let lift = smin - smn;
        for i in 0..n {
            let s = if out.at(i, i) >= 0.0 { 1.0 } else { -1.0 };
            *out.at_mut(i, i) += s * lift;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    #[test]
    fn sigma_max_of_diagonal() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, -5.0, 0.0, 0.0, 0.0, 1.0]);
        assert!((sigma_max(&a, 100) - 5.0).abs() < 1e-3);
        assert!((sigma_min(&a, 100).unwrap() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn sigma_max_upper_bounds_matvec_gain() {
        proptest(20, |rig| {
            let n = rig.usize_in(2, 16);
            let a = Mat::from_vec(n, n, rig.vec_normal(n * n, 1.0));
            let s = sigma_max(&a, 200);
            for _ in 0..5 {
                let x = rig.vec_normal(n, 1.0);
                let xn: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
                let y = a.matvec(&x);
                let yn: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
                assert!(yn <= s * xn * 1.01 + 1e-4, "gain {} > sigma {}", yn / xn, s);
            }
        });
    }

    #[test]
    fn clamp_enforces_band() {
        proptest(15, |rig| {
            let n = rig.usize_in(2, 12);
            let mut a = Mat::from_vec(n, n, rig.vec_normal(n * n, 0.5));
            for i in 0..n {
                *a.at_mut(i, i) += 1.0;
            }
            let c = spectral_clamp(&a, 0.05, 1.5);
            assert!(sigma_max(&c, 100) <= 1.5 * 1.05);
            assert!(sigma_min(&c, 100).unwrap_or(0.0) >= 0.05 * 0.5);
        });
    }
}
