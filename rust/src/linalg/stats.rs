//! Weight/activation statistics: moments, kurtosis (drives the μ-law init,
//! paper Eq. 12), quantiles, and the KL-divergence surrogate used by the
//! salience-determined bit allocation (paper Eq. 3).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// Sample excess-free kurtosis (normal → 3). The paper's μ init uses the
/// plain kurtosis κ_g: μ_g⁰ = 100 tanh(κ_g / 10).
pub fn kurtosis(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 3.0;
    }
    let m = mean(xs);
    let n = xs.len() as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n;
    if var < 1e-24 {
        return 3.0;
    }
    let m4 = xs.iter().map(|&x| (x as f64 - m).powi(4)).sum::<f64>() / n;
    m4 / (var * var)
}

/// q-th quantile (0..=1) by sorting a copy. Linear interpolation.
pub fn quantile(xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = (pos - lo as f64) as f32;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Dynamic range proxy: max|x| / (p50|x| + eps). Large for outlier-heavy
/// groups — one of the salience signals.
pub fn dynamic_range(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let abss: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let med = quantile(&abss, 0.5) as f64;
    let max = abss.iter().fold(0.0f32, |a, &b| a.max(b)) as f64;
    max / (med + 1e-12)
}

/// KL divergence between two empirical distributions given by histograms of
/// the same binning. Inputs are raw samples; we bin jointly over their
/// combined range. This is the D_KL(WX || ŴX) surrogate in SDBA (Eq. 3).
pub fn kl_divergence(p_samples: &[f32], q_samples: &[f32], bins: usize) -> f64 {
    assert!(bins >= 2);
    if p_samples.is_empty() || q_samples.is_empty() {
        return 0.0;
    }
    let lo = p_samples
        .iter()
        .chain(q_samples)
        .fold(f32::INFINITY, |a, &b| a.min(b));
    let hi = p_samples
        .iter()
        .chain(q_samples)
        .fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    if !(hi > lo) {
        return 0.0;
    }
    let width = (hi - lo) / bins as f32;
    let mut hp = vec![0.0f64; bins];
    let mut hq = vec![0.0f64; bins];
    for &x in p_samples {
        let b = (((x - lo) / width) as usize).min(bins - 1);
        hp[b] += 1.0;
    }
    for &x in q_samples {
        let b = (((x - lo) / width) as usize).min(bins - 1);
        hq[b] += 1.0;
    }
    // Laplace smoothing keeps the divergence finite
    let np = p_samples.len() as f64 + bins as f64;
    let nq = q_samples.len() as f64 + bins as f64;
    let mut kl = 0.0;
    for b in 0..bins {
        let p = (hp[b] + 1.0) / np;
        let q = (hq[b] + 1.0) / nq;
        kl += p * (p / q).ln();
    }
    kl.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn moments_of_constant() {
        let xs = vec![2.0f32; 100];
        assert!((mean(&xs) - 2.0).abs() < 1e-9);
        assert!(variance(&xs) < 1e-9);
        assert_eq!(kurtosis(&xs), 3.0); // degenerate → normal default
    }

    #[test]
    fn kurtosis_normal_near_three_and_t_heavier() {
        let mut rng = Rng::new(11);
        let normal: Vec<f32> = (0..40_000).map(|_| rng.normal_f32()).collect();
        let heavy: Vec<f32> = (0..40_000).map(|_| rng.student_t(4.0) as f32).collect();
        let kn = kurtosis(&normal);
        let kh = kurtosis(&heavy);
        assert!((kn - 3.0).abs() < 0.25, "kn={kn}");
        assert!(kh > kn + 0.5, "kh={kh} kn={kn}");
    }

    #[test]
    fn quantiles_of_linear_ramp() {
        let xs: Vec<f32> = (0..=100).map(|i| i as f32).collect();
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert!((quantile(&xs, 0.5) - 50.0).abs() < 1e-5);
        assert!((quantile(&xs, 0.25) - 25.0).abs() < 1e-5);
    }

    #[test]
    fn kl_zero_for_identical_and_positive_for_shifted() {
        let mut rng = Rng::new(5);
        let a: Vec<f32> = (0..20_000).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 2.0).collect();
        let same = kl_divergence(&a, &a, 64);
        let diff = kl_divergence(&a, &b, 64);
        assert!(same < 0.01, "same={same}");
        assert!(diff > 0.3, "diff={diff}");
    }

    #[test]
    fn dynamic_range_flags_outliers() {
        let mut xs = vec![0.01f32; 1000];
        let clean = dynamic_range(&xs);
        xs[0] = 5.0;
        let dirty = dynamic_range(&xs);
        assert!(dirty > clean * 50.0);
    }
}
