//! Matrix decompositions: LU (partial pivoting) solve/inverse/det, Cholesky,
//! and modified Gram-Schmidt — the pieces GLVQ needs for `G^{-1}`,
//! covariance-based lattice initialization (paper Eq. 8 context) and the
//! Appendix-A error-bound machinery.

use super::matrix::Mat;

#[derive(Debug)]
pub enum DecompError {
    Singular,
    NotPositiveDefinite,
    NotSquare,
}

impl std::fmt::Display for DecompError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecompError::Singular => write!(f, "matrix is singular"),
            DecompError::NotPositiveDefinite => write!(f, "matrix is not positive definite"),
            DecompError::NotSquare => write!(f, "matrix is not square"),
        }
    }
}

impl std::error::Error for DecompError {}

/// LU decomposition with partial pivoting. Stores combined L\U plus the
/// permutation; all downstream solves reuse the single factorization.
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    sign: f32,
}

impl Lu {
    pub fn new(a: &Mat) -> Result<Lu, DecompError> {
        if a.rows != a.cols {
            return Err(DecompError::NotSquare);
        }
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0f32;
        for k in 0..n {
            // pivot
            let mut p = k;
            let mut maxv = lu.at(k, k).abs();
            for i in k + 1..n {
                let v = lu.at(i, k).abs();
                if v > maxv {
                    maxv = v;
                    p = i;
                }
            }
            if maxv < 1e-12 {
                return Err(DecompError::Singular);
            }
            if p != k {
                for j in 0..n {
                    let t = lu.at(k, j);
                    *lu.at_mut(k, j) = lu.at(p, j);
                    *lu.at_mut(p, j) = t;
                }
                piv.swap(k, p);
                sign = -sign;
            }
            let pivot = lu.at(k, k);
            for i in k + 1..n {
                let f = lu.at(i, k) / pivot;
                *lu.at_mut(i, k) = f;
                for j in k + 1..n {
                    *lu.at_mut(i, j) -= f * lu.at(k, j);
                }
            }
        }
        Ok(Lu { lu, piv, sign })
    }

    pub fn det(&self) -> f32 {
        let n = self.lu.rows;
        let mut d = self.sign;
        for i in 0..n {
            d *= self.lu.at(i, i);
        }
        d
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f32]) -> Vec<f32> {
        let n = self.lu.rows;
        assert_eq!(b.len(), n);
        let mut x: Vec<f32> = (0..n).map(|i| b[self.piv[i]]).collect();
        // forward substitution (unit lower)
        for i in 0..n {
            for j in 0..i {
                x[i] -= self.lu.at(i, j) * x[j];
            }
        }
        // back substitution
        for i in (0..n).rev() {
            for j in i + 1..n {
                x[i] -= self.lu.at(i, j) * x[j];
            }
            x[i] /= self.lu.at(i, i);
        }
        x
    }

    pub fn inverse(&self) -> Mat {
        let n = self.lu.rows;
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0f32; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            for i in 0..n {
                *inv.at_mut(i, j) = col[i];
            }
            e[j] = 0.0;
        }
        inv
    }
}

/// Convenience: A^{-1} via LU.
pub fn inverse(a: &Mat) -> Result<Mat, DecompError> {
    Ok(Lu::new(a)?.inverse())
}

/// Cholesky factor L (lower-triangular, A = L Lᵀ). Used to initialize the
/// lattice basis from the group covariance (paper: "initialized using the
/// Cholesky decomposition of the group's covariance matrix").
pub fn cholesky(a: &Mat) -> Result<Mat, DecompError> {
    if a.rows != a.cols {
        return Err(DecompError::NotSquare);
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return Err(DecompError::NotPositiveDefinite);
                }
                *l.at_mut(i, i) = s.sqrt();
            } else {
                *l.at_mut(i, j) = s / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// Modified Gram-Schmidt on the *columns* of B. Returns (B*, mu) where B*'s
/// columns are orthogonal and `mu[j][i]` (j < i) are the projection
/// coefficients — exactly the quantities in the Appendix-A Babai bound.
pub fn gram_schmidt(b: &Mat) -> (Mat, Mat) {
    let n = b.cols;
    let mut bs = b.clone();
    let mut mu = Mat::eye(n);
    for i in 0..n {
        for j in 0..i {
            let bj: Vec<f32> = bs.col(j);
            let bi: Vec<f32> = bs.col(i);
            let den: f32 = bj.iter().map(|x| x * x).sum();
            let num: f32 = bi.iter().zip(&bj).map(|(x, y)| x * y).sum();
            let m = if den > 0.0 { num / den } else { 0.0 };
            *mu.at_mut(j, i) = m;
            for r in 0..bs.rows {
                let v = bs.at(r, i) - m * bs.at(r, j);
                *bs.at_mut(r, i) = v;
            }
        }
    }
    (bs, mu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    fn well_conditioned(n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::random_normal(n, n, 0.1, rng);
        for i in 0..n {
            *a.at_mut(i, i) += 1.0;
        }
        a
    }

    #[test]
    fn lu_solve_recovers_known_solution() {
        proptest(30, |rig| {
            let n = rig.usize_in(1, 24);
            let a = well_conditioned(n, &mut rig.rng);
            let x_true = rig.vec_normal(n, 1.0);
            let b = a.matvec(&x_true);
            let x = Lu::new(&a).unwrap().solve(&b);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-3, "i={i} {x:?} vs {x_true:?}");
            }
        });
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        proptest(30, |rig| {
            let n = rig.usize_in(1, 32);
            let a = well_conditioned(n, &mut rig.rng);
            let inv = inverse(&a).unwrap();
            let prod = a.matmul(&inv);
            assert!(prod.frob_dist(&Mat::eye(n)) < 1e-3, "n={n}");
        });
    }

    #[test]
    fn singular_matrix_is_rejected() {
        let mut a = Mat::zeros(3, 3);
        *a.at_mut(0, 0) = 1.0;
        *a.at_mut(1, 1) = 1.0; // rank 2
        assert!(matches!(Lu::new(&a), Err(DecompError::Singular)));
    }

    #[test]
    fn det_of_diagonal_and_permutation() {
        let a = Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, 3.0]);
        assert!((Lu::new(&a).unwrap().det() - 6.0).abs() < 1e-5);
        let p = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        assert!((Lu::new(&p).unwrap().det() + 1.0).abs() < 1e-5);
    }

    #[test]
    fn cholesky_reconstructs_spd_matrix() {
        proptest(30, |rig| {
            let n = rig.usize_in(1, 16);
            let b = Mat::random_normal(n, n, 1.0, &mut rig.rng);
            let mut a = b.matmul(&b.transpose()); // SPD-ish
            for i in 0..n {
                *a.at_mut(i, i) += 0.5;
            }
            let l = cholesky(&a).unwrap();
            let rec = l.matmul(&l.transpose());
            assert!(rec.frob_dist(&a) < 1e-2 * (1.0 + a.frob_norm()));
            // lower-triangular
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l.at(i, j), 0.0);
                }
            }
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(matches!(cholesky(&a), Err(DecompError::NotPositiveDefinite)));
    }

    #[test]
    fn gram_schmidt_orthogonalizes_columns() {
        proptest(20, |rig| {
            let n = rig.usize_in(2, 10);
            let b = well_conditioned(n, &mut rig.rng);
            let (bs, mu) = gram_schmidt(&b);
            // orthogonality
            for i in 0..n {
                for j in 0..i {
                    let dot: f32 = bs.col(i).iter().zip(bs.col(j).iter()).map(|(x, y)| x * y).sum();
                    let ni: f32 = bs.col(i).iter().map(|x| x * x).sum::<f32>().sqrt();
                    let nj: f32 = bs.col(j).iter().map(|x| x * x).sum::<f32>().sqrt();
                    assert!(dot.abs() < 1e-2 * (ni * nj + 1e-6), "i={i} j={j}");
                }
            }
            // reconstruction: b_i = b*_i + sum_{j<i} mu[j,i] b*_j
            for i in 0..n {
                for r in 0..n {
                    let mut v = bs.at(r, i);
                    for j in 0..i {
                        v += mu.at(j, i) * bs.at(r, j);
                    }
                    assert!((v - b.at(r, i)).abs() < 1e-3);
                }
            }
        });
    }
}
