//! LLL (Lenstra–Lenstra–Lovász) lattice basis reduction.
//!
//! Used to (a) precondition learned generation matrices before Babai
//! rounding when requested, and (b) drive the Appendix-A property test: for
//! an LLL-reduced basis with δ = 3/4, all Gram-Schmidt coefficients satisfy
//! |μ_{j,i}| ≤ 1/2, which yields the paper's closed-form Babai error bound
//! (Eq. 25). We verify the bound holds empirically for every reduced basis.

use super::decomp::gram_schmidt;
use super::matrix::Mat;

/// LLL-reduce the columns of `b` in place semantics (returns a new Mat).
/// `delta` ∈ (1/4, 1]; 3/4 is the classic choice used by Appendix A.
pub fn lll_reduce(b: &Mat, delta: f32) -> Mat {
    let n = b.cols;
    let mut basis = b.clone();
    if n <= 1 {
        return basis;
    }
    let mut k = 1usize;
    let mut guard = 0usize;
    let guard_max = 10_000 + 100 * n * n;
    while k < n && guard < guard_max {
        guard += 1;
        // size-reduce column k against all previous columns
        for j in (0..k).rev() {
            let (bs, mu) = gram_schmidt(&basis);
            let _ = bs;
            let m = mu.at(j, k);
            if m.abs() > 0.5 {
                let q = m.round();
                for r in 0..basis.rows {
                    let v = basis.at(r, k) - q * basis.at(r, j);
                    *basis.at_mut(r, k) = v;
                }
            }
        }
        // Lovász condition
        let (bs, mu) = gram_schmidt(&basis);
        let norm2 = |j: usize| -> f32 { bs.col(j).iter().map(|x| x * x).sum() };
        let mukk = mu.at(k - 1, k);
        if norm2(k) >= (delta - mukk * mukk) * norm2(k - 1) {
            k += 1;
        } else {
            for r in 0..basis.rows {
                let t = basis.at(r, k);
                *basis.at_mut(r, k) = basis.at(r, k - 1);
                *basis.at_mut(r, k - 1) = t;
            }
            k = k.max(2) - 1;
        }
    }
    basis
}

/// Check the LLL size-reduction property: |mu_{j,i}| <= 1/2 for all j < i.
pub fn is_size_reduced(b: &Mat, tol: f32) -> bool {
    let (_, mu) = gram_schmidt(b);
    for i in 0..b.cols {
        for j in 0..i {
            if mu.at(j, i).abs() > 0.5 + tol {
                return false;
            }
        }
    }
    true
}

/// The Appendix-A Babai error bound (Eq. 25) for basis B:
/// ||e|| <= 1/2 sqrt( sum_j (1 + (n-j)/2)^2 ||b*_j||^2 )   (1-indexed j)
pub fn babai_error_bound(b: &Mat) -> f32 {
    let (bs, _) = gram_schmidt(b);
    let n = b.cols;
    let mut total = 0.0f32;
    for j in 0..n {
        let nj: f32 = bs.col(j).iter().map(|x| x * x).sum();
        let factor = 1.0 + (n - 1 - j) as f32 / 2.0;
        total += factor * factor * nj;
    }
    0.5 * total.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    fn random_basis(n: usize, rig: &mut crate::util::proptest::Rig) -> Mat {
        // start near identity then shear it to create skewed bases
        let mut b = Mat::eye(n);
        for _ in 0..3 {
            let i = rig.usize_in(0, n - 1);
            let j = rig.usize_in(0, n - 1);
            if i != j {
                let s = rig.f32_in(-3.0, 3.0);
                for r in 0..n {
                    let v = b.at(r, i) + s * b.at(r, j);
                    *b.at_mut(r, i) = v;
                }
            }
        }
        b
    }

    #[test]
    fn reduction_yields_size_reduced_basis() {
        proptest(25, |rig| {
            let n = rig.usize_in(2, 8);
            let b = random_basis(n, rig);
            let red = lll_reduce(&b, 0.75);
            assert!(is_size_reduced(&red, 1e-3));
        });
    }

    #[test]
    fn reduction_preserves_lattice_determinant() {
        use crate::linalg::decomp::Lu;
        proptest(25, |rig| {
            let n = rig.usize_in(2, 6);
            let b = random_basis(n, rig);
            let red = lll_reduce(&b, 0.75);
            let d0 = Lu::new(&b).map(|l| l.det().abs()).unwrap_or(0.0);
            let d1 = Lu::new(&red).map(|l| l.det().abs()).unwrap_or(0.0);
            assert!((d0 - d1).abs() < 1e-2 * (1.0 + d0), "{d0} vs {d1}");
        });
    }

    /// Appendix A, verified as a property: for LLL-reduced bases, the Babai
    /// rounding error never exceeds the closed-form bound.
    #[test]
    fn babai_bound_holds_on_reduced_bases() {
        proptest(40, |rig| {
            let n = rig.usize_in(2, 8);
            let b = random_basis(n, rig);
            let red = lll_reduce(&b, 0.75);
            let bound = babai_error_bound(&red);
            let inv = match crate::linalg::decomp::inverse(&red) {
                Ok(i) => i,
                Err(_) => return,
            };
            for _ in 0..8 {
                let t = rig.vec_normal(n, 2.0);
                // Babai: c = round(B^{-1} t), v = B c, e = t - v
                let x = inv.matvec(&t);
                let c: Vec<f32> = x.iter().map(|v| v.round()).collect();
                let v = red.matvec(&c);
                let err: f32 = t
                    .iter()
                    .zip(&v)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    .sqrt();
                assert!(err <= bound * (1.0 + 1e-3) + 1e-4, "err={err} bound={bound} n={n}");
            }
        });
    }
}
