//! Unified telemetry: span tracing, metrics registry, per-request
//! timelines.
//!
//! Three pillars, one subsystem (ARCHITECTURE.md §Observability):
//!
//! - [`span`] — RAII tracing spans over per-thread stacks. Instrumented
//!   through the stack: continuous-scheduler step phases
//!   (`serving::scheduler`), streaming panel decode and rANS table builds
//!   (`coordinator::decode_stream`, `entropy::stream`), shard worker jobs
//!   (`shard::exec`), KV-cache quantize/spill/restore (`kvcache::paged`),
//!   and the model forward (`eval::native_fwd`). Disabled tracing costs
//!   one atomic load per site.
//! - [`registry`] — typed counters/gauges/summaries frozen into a
//!   [`MetricsSnapshot`] that renders as the human `report()` line,
//!   structured JSON, or Prometheus text — all from the same data.
//! - [`timeline`] — per-request lifecycle stamps giving TTFT attribution
//!   (queue vs prefill vs decode) per request, not just in aggregate.
//!
//! [`chrome_trace_json`] fuses drained spans and request timelines into
//! one Chrome trace-event document (load in `chrome://tracing` or
//! Perfetto); `glvq serve --trace-out` and the serving bench write it.

pub mod registry;
pub mod span;
pub mod timeline;

pub use registry::{MetricValue, MetricsSnapshot, Registry};
pub use span::{FinishedSpan, SpanGuard, StageStat};
pub use timeline::{Breakdown, Mark, RequestTimeline};

use crate::util::json::Json;

/// Open a tracing span; the span closes when the returned guard drops.
///
/// ```
/// fn stage() {
///     let _sp = glvq::span!("stage");
///     // ... traced work ...
/// }
/// ```
///
/// Bind the guard to a named `_`-prefixed variable — a bare `let _ =`
/// would drop it immediately. When tracing is disabled
/// ([`obs::span::set_enabled`](span::set_enabled)) the cost is one atomic
/// load.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::span::guard($name)
    };
}

/// Assemble a complete Chrome trace-event JSON document from drained
/// spans and per-request timelines. Spans appear under their recording
/// thread's track; each request gets a named virtual track.
pub fn chrome_trace_json(spans: &[FinishedSpan], timelines: &[RequestTimeline]) -> Json {
    let mut events: Vec<Json> = spans.iter().map(span::trace_event).collect();
    events.extend(timeline::trace_events(timelines));
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_trace_document_shape() {
        let spans = vec![FinishedSpan {
            name: "stage",
            tid: 1,
            start_ns: 1_000,
            dur_ns: 5_000,
            self_ns: 5_000,
            depth: 0,
        }];
        let mut tl = RequestTimeline::new(3);
        tl.mark(Mark::Finish);
        let doc = chrome_trace_json(&spans, &[tl]);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed, doc);
        let events = parsed.get("traceEvents").as_arr().unwrap();
        assert!(events.len() >= 2);
        let first = &events[0];
        assert_eq!(first.get("ph").as_str(), Some("X"));
        assert_eq!(first.get("name").as_str(), Some("stage"));
        assert_eq!(first.get("ts").as_f64(), Some(1.0));
        assert_eq!(first.get("dur").as_f64(), Some(5.0));
    }
}
