//! Per-request timelines: submit → admit → prefill → first token →
//! decode steps → preempt/resume → finish.
//!
//! The continuous scheduler stamps one [`RequestTimeline`] per request at
//! each lifecycle transition. Timestamps are nanoseconds relative to the
//! request's own submit instant, with the submit instant itself anchored
//! on the process-global trace epoch ([`crate::obs::span::now_ns`]) — so
//! timelines compose with scheduler spans on one time axis in the Chrome
//! trace export, each request rendered as its own virtual track.
//!
//! [`RequestTimeline::breakdown`] splits time-to-first-token into its
//! queue / prefill components (and the remainder into decode), which is
//! what turns a single opaque TTFT histogram into an attribution: *where*
//! did the p95 request wait?

use crate::obs::span::now_ns;
use crate::util::json::Json;

/// Lifecycle transition stamped into a timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mark {
    /// request entered the scheduler queue (always at offset 0)
    Submit,
    /// admission control moved it into the running set
    Admit,
    /// one chunk of prompt prefill was fed
    PrefillChunk,
    /// first output token emitted / first score chunk accumulated
    FirstToken,
    /// one decode step advanced this request
    DecodeStep,
    /// KV pages spilled out of the arena under page pressure
    Preempt,
    /// spilled request restored into the arena
    Resume,
    /// response sent (success or failure)
    Finish,
}

impl Mark {
    pub fn name(self) -> &'static str {
        match self {
            Mark::Submit => "submit",
            Mark::Admit => "admit",
            Mark::PrefillChunk => "prefill_chunk",
            Mark::FirstToken => "first_token",
            Mark::DecodeStep => "decode_step",
            Mark::Preempt => "preempt",
            Mark::Resume => "resume",
            Mark::Finish => "finish",
        }
    }
}

/// Queue/prefill/decode attribution derived from a timeline (all ns).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Breakdown {
    /// submit → admission
    pub queue_ns: u64,
    /// admission → first token
    pub prefill_ns: u64,
    /// first token → finish
    pub decode_ns: u64,
    /// submit → finish
    pub total_ns: u64,
}

/// Events are capped per request so a pathological run cannot grow a
/// timeline without bound; `Finish` is always recorded.
const MAX_EVENTS: usize = 4096;

/// One request's recorded lifecycle.
#[derive(Clone, Debug)]
pub struct RequestTimeline {
    /// scheduler request id
    pub rid: u64,
    /// submit instant, ns since the process trace epoch
    pub base_ns: u64,
    events: Vec<(Mark, u64)>,
    truncated: usize,
}

impl Default for RequestTimeline {
    /// Empty placeholder (no events) — what `mem::take` leaves behind when
    /// a finished timeline moves into the metrics. Never exported.
    fn default() -> RequestTimeline {
        RequestTimeline { rid: 0, base_ns: 0, events: Vec::new(), truncated: 0 }
    }
}

impl RequestTimeline {
    /// Start a timeline at the request's submit instant.
    pub fn new(rid: u64) -> RequestTimeline {
        Self::with_base(rid, now_ns())
    }

    /// Start a timeline whose submit instant is `base_ns` on the trace
    /// epoch — used when the submit instant predates timeline creation
    /// (e.g. the scheduler builds the timeline at admission from the
    /// queued request's recorded submit time).
    pub fn with_base(rid: u64, base_ns: u64) -> RequestTimeline {
        RequestTimeline { rid, base_ns, events: vec![(Mark::Submit, 0)], truncated: 0 }
    }

    /// Stamp `m` at the current instant.
    pub fn mark(&mut self, m: Mark) {
        if self.events.len() >= MAX_EVENTS && m != Mark::Finish {
            self.truncated += 1;
            return;
        }
        self.events.push((m, now_ns().saturating_sub(self.base_ns)));
    }

    /// All recorded `(mark, ns_since_submit)` events, in stamp order.
    pub fn events(&self) -> &[(Mark, u64)] {
        &self.events
    }

    /// Events dropped by the per-request cap.
    pub fn truncated(&self) -> usize {
        self.truncated
    }

    /// Offset of the first occurrence of `m`, if stamped.
    pub fn first(&self, m: Mark) -> Option<u64> {
        self.events.iter().find(|(e, _)| *e == m).map(|(_, t)| *t)
    }

    /// Number of occurrences of `m`.
    pub fn count(&self, m: Mark) -> usize {
        self.events.iter().filter(|(e, _)| *e == m).count()
    }

    /// Split the request's wall time into queue / prefill / decode.
    /// Requests that never reached a stage attribute the remainder to the
    /// last stage they did reach.
    pub fn breakdown(&self) -> Breakdown {
        let total_ns = self
            .first(Mark::Finish)
            .or_else(|| self.events.last().map(|(_, t)| *t))
            .unwrap_or(0);
        let admit = self.first(Mark::Admit).unwrap_or(total_ns).min(total_ns);
        // Clamp into [admit, total] so the three parts always sum to
        // exactly total, even on degenerate mark orders.
        let first_tok = self.first(Mark::FirstToken).unwrap_or(total_ns).clamp(admit, total_ns);
        Breakdown {
            queue_ns: admit,
            prefill_ns: first_tok - admit,
            decode_ns: total_ns - first_tok,
            total_ns,
        }
    }

    /// JSON form: rid, absolute base, breakdown and the raw event list.
    pub fn to_json(&self) -> Json {
        let b = self.breakdown();
        Json::obj(vec![
            ("rid", Json::num(self.rid as f64)),
            ("base_us", Json::num(self.base_ns as f64 / 1e3)),
            ("queue_ms", Json::num(b.queue_ns as f64 / 1e6)),
            ("prefill_ms", Json::num(b.prefill_ns as f64 / 1e6)),
            ("decode_ms", Json::num(b.decode_ns as f64 / 1e6)),
            ("total_ms", Json::num(b.total_ns as f64 / 1e6)),
            ("decode_steps", Json::num(self.count(Mark::DecodeStep) as f64)),
            ("preemptions", Json::num(self.count(Mark::Preempt) as f64)),
            (
                "events",
                Json::arr(self.events.iter().map(|(m, t)| {
                    Json::obj(vec![
                        ("mark", Json::str(m.name())),
                        ("ms", Json::num(*t as f64 / 1e6)),
                    ])
                })),
            ),
        ])
    }
}

/// Virtual-track base so request tracks sort after real thread tracks in
/// trace viewers.
const REQ_TID_BASE: u64 = 1_000_000;

/// Chrome trace events for a set of request timelines: one named virtual
/// track per request carrying `queue`/`prefill`/`decode` phase bars and
/// instant markers for preempt/resume.
pub fn trace_events(timelines: &[RequestTimeline]) -> Vec<Json> {
    let mut out = Vec::new();
    for t in timelines {
        let tid = REQ_TID_BASE + t.rid;
        let tidj = || Json::num(tid as f64);
        out.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", tidj()),
            ("args", Json::obj(vec![("name", Json::str(&format!("req-{}", t.rid)))])),
        ]));
        let b = t.breakdown();
        let base_us = t.base_ns as f64 / 1e3;
        let phases = [
            ("queue", 0u64, b.queue_ns),
            ("prefill", b.queue_ns, b.prefill_ns),
            ("decode", b.queue_ns + b.prefill_ns, b.decode_ns),
        ];
        for (name, off_ns, dur_ns) in phases {
            if dur_ns == 0 {
                continue;
            }
            out.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("cat", Json::str("request")),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", tidj()),
                ("ts", Json::num(base_us + off_ns as f64 / 1e3)),
                ("dur", Json::num(dur_ns as f64 / 1e3)),
            ]));
        }
        for (m, off_ns) in t.events() {
            if !matches!(*m, Mark::Preempt | Mark::Resume) {
                continue;
            }
            out.push(Json::obj(vec![
                ("name", Json::str(m.name())),
                ("cat", Json::str("request")),
                ("ph", Json::str("i")),
                ("s", Json::str("t")),
                ("pid", Json::num(1.0)),
                ("tid", tidj()),
                ("ts", Json::num(base_us + *off_ns as f64 / 1e3)),
            ]));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manual(rid: u64, marks: &[(Mark, u64)]) -> RequestTimeline {
        let mut t = RequestTimeline::new(rid);
        t.events = vec![(Mark::Submit, 0)];
        t.events.extend_from_slice(marks);
        t
    }

    #[test]
    fn breakdown_attributes_queue_prefill_decode() {
        let t = manual(
            1,
            &[
                (Mark::Admit, 10),
                (Mark::PrefillChunk, 12),
                (Mark::FirstToken, 30),
                (Mark::DecodeStep, 40),
                (Mark::Finish, 100),
            ],
        );
        let b = t.breakdown();
        assert_eq!(b, Breakdown { queue_ns: 10, prefill_ns: 20, decode_ns: 70, total_ns: 100 });
        assert_eq!(t.count(Mark::DecodeStep), 1);
        assert_eq!(t.first(Mark::Admit), Some(10));
    }

    #[test]
    fn breakdown_handles_requests_that_never_started() {
        // rejected before admission: everything is queue time
        let t = manual(2, &[(Mark::Finish, 50)]);
        let b = t.breakdown();
        assert_eq!(b, Breakdown { queue_ns: 50, prefill_ns: 0, decode_ns: 0, total_ns: 50 });
    }

    #[test]
    fn breakdown_parts_sum_to_total_on_degenerate_orders() {
        // Marks stamped out of lifecycle order (FirstToken before Admit,
        // marks after Finish) must still split exactly.
        let t = manual(
            4,
            &[(Mark::FirstToken, 30), (Mark::Finish, 58), (Mark::Admit, 60)],
        );
        let b = t.breakdown();
        assert_eq!(b.queue_ns + b.prefill_ns + b.decode_ns, b.total_ns);
        assert_eq!(b.total_ns, 58);
    }

    #[test]
    fn mark_caps_events_but_always_records_finish() {
        let mut t = RequestTimeline::new(3);
        for _ in 0..(MAX_EVENTS * 2) {
            t.mark(Mark::DecodeStep);
        }
        t.mark(Mark::Finish);
        assert!(t.events().len() <= MAX_EVENTS + 1);
        assert!(t.truncated() > 0);
        assert_eq!(t.count(Mark::Finish), 1);
    }

    #[test]
    fn trace_events_emit_named_track_and_phases() {
        let t = manual(
            7,
            &[
                (Mark::Admit, 10),
                (Mark::FirstToken, 30),
                (Mark::Preempt, 35),
                (Mark::Resume, 60),
                (Mark::Finish, 100),
            ],
        );
        let evs = trace_events(&[t]);
        let names: Vec<&str> =
            evs.iter().filter_map(|e| e.get("name").as_str()).collect();
        assert!(names.contains(&"thread_name"));
        assert!(names.contains(&"queue"));
        assert!(names.contains(&"prefill"));
        assert!(names.contains(&"decode"));
        assert!(names.contains(&"preempt"));
        assert!(names.contains(&"resume"));
        // all events sit on the request's virtual track
        for e in &evs {
            assert_eq!(e.get("tid").as_f64(), Some((REQ_TID_BASE + 7) as f64));
        }
    }

    #[test]
    fn timeline_json_round_trips() {
        let t = manual(5, &[(Mark::Admit, 10), (Mark::FirstToken, 30), (Mark::Finish, 90)]);
        let j = t.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
        assert_eq!(parsed.get("rid").as_f64(), Some(5.0));
        assert_eq!(parsed.get("decode_steps").as_f64(), Some(0.0));
    }
}
