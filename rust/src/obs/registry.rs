//! Typed metrics registry and its snapshot/export formats.
//!
//! Subsystems (server backends, the continuous scheduler, the KV cache,
//! shard workers) register named counters, gauges and summaries into a
//! [`Registry`]; `finish()` freezes it into a [`MetricsSnapshot`] that
//! exports three ways:
//!
//! - [`MetricsSnapshot::to_json`] — structured JSON through
//!   [`crate::util::json`], merged into bench trajectories so serving runs
//!   and benches share one schema (see FORMAT.md §metrics JSON);
//! - [`MetricsSnapshot::to_prometheus`] — Prometheus text exposition
//!   (`# TYPE` + samples, summaries with `quantile` labels and
//!   `_sum`/`_count`), written by `glvq serve --metrics-out`;
//! - the human one-line `report()` string, rendered by
//!   `coordinator::metrics::ServerMetrics` from the same snapshot so all
//!   three views can never disagree.
//!
//! Names are snake_case and already Prometheus-safe; the text exposition
//! prefixes them with `glvq_`. Registration order is preserved in every
//! export.

use crate::util::json::Json;

/// A single metric observation frozen into a snapshot.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    /// monotonically accumulated count (events, tokens, bytes)
    Counter(u64),
    /// instantaneous level (occupancy, ratios, rates)
    Gauge(f64),
    /// distribution digest: selected quantiles plus stream sum and count
    Summary { quantiles: Vec<(f64, f64)>, sum: f64, count: u64 },
}

/// Builder: subsystems push named metrics, `finish()` yields the snapshot.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    entries: Vec<(String, MetricValue)>,
}

/// Build the stored entry key for a labeled metric: `name{k="v",...}` in
/// the given label order, values escaped per the Prometheus text format
/// (backslash, double quote, newline). With no labels this is just `name`.
pub fn labeled_name(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut s = String::from(name);
    s.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
        s.push_str(k);
        s.push_str("=\"");
        s.push_str(&escaped);
        s.push('"');
    }
    s.push('}');
    s
}

/// The family a (possibly labeled) entry name belongs to: everything
/// before the label set.
fn family_of(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&mut self, name: &str, value: u64) {
        self.entries.push((name.to_string(), MetricValue::Counter(value)));
    }

    pub fn gauge(&mut self, name: &str, value: f64) {
        self.entries.push((name.to_string(), MetricValue::Gauge(value)));
    }

    /// Register one sample of a labeled counter family, e.g.
    /// `counter_with("rejections_total", &[("reason", "queue_full")], n)`.
    /// Samples of the same family share one `# TYPE` line in the
    /// Prometheus exposition; each label set is its own entry.
    pub fn counter_with(&mut self, name: &str, labels: &[(&str, &str)], value: u64) {
        self.entries.push((labeled_name(name, labels), MetricValue::Counter(value)));
    }

    /// Register one sample of a labeled gauge family (see [`Registry::counter_with`]).
    pub fn gauge_with(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.entries.push((labeled_name(name, labels), MetricValue::Gauge(value)));
    }

    /// Register a distribution summary. `quantiles` are `(q, value)` pairs
    /// with `q` in [0, 1]; `sum`/`count` describe the full stream.
    pub fn summary(&mut self, name: &str, quantiles: Vec<(f64, f64)>, sum: f64, count: u64) {
        self.entries.push((name.to_string(), MetricValue::Summary { quantiles, sum, count }));
    }

    pub fn finish(self) -> MetricsSnapshot {
        MetricsSnapshot { entries: self.entries }
    }
}

/// Immutable point-in-time view of every registered metric.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    entries: Vec<(String, MetricValue)>,
}

/// Render an f64 the way `util::json` does: integral values without a
/// decimal point. Keeps Prometheus samples and JSON numerals consistent.
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl MetricsSnapshot {
    pub fn entries(&self) -> &[(String, MetricValue)] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    pub fn has(&self, name: &str) -> bool {
        self.get(name).is_some()
    }

    /// Counter value, 0 when absent or a different type.
    pub fn counter(&self, name: &str) -> u64 {
        match self.get(name) {
            Some(MetricValue::Counter(c)) => *c,
            _ => 0,
        }
    }

    /// One sample of a labeled counter family, 0 when absent.
    pub fn counter_labeled(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        self.counter(&labeled_name(name, labels))
    }

    /// Sum of every counter sample in `family` — the plain entry plus all
    /// labeled `family{...}` entries. This is how aggregate views (the
    /// human report line, cluster rollups) read a per-label breakdown.
    pub fn counter_family(&self, family: &str) -> u64 {
        self.entries
            .iter()
            .filter(|(n, _)| family_of(n) == family)
            .map(|(_, v)| match v {
                MetricValue::Counter(c) => *c,
                _ => 0,
            })
            .sum()
    }

    /// Gauge value, 0.0 when absent or a different type.
    pub fn gauge(&self, name: &str) -> f64 {
        match self.get(name) {
            Some(MetricValue::Gauge(g)) => *g,
            _ => 0.0,
        }
    }

    /// Value of the summary quantile nearest `q` (0.0 when absent).
    pub fn quantile(&self, name: &str, q: f64) -> f64 {
        match self.get(name) {
            Some(MetricValue::Summary { quantiles, .. }) if !quantiles.is_empty() => {
                let mut best = quantiles[0];
                for &(qq, v) in quantiles {
                    if (qq - q).abs() < (best.0 - q).abs() {
                        best = (qq, v);
                    }
                }
                best.1
            }
            _ => 0.0,
        }
    }

    /// Stream count of a summary (0 when absent).
    pub fn summary_count(&self, name: &str) -> u64 {
        match self.get(name) {
            Some(MetricValue::Summary { count, .. }) => *count,
            _ => 0,
        }
    }

    /// Stream sum of a summary (0.0 when absent).
    pub fn summary_sum(&self, name: &str) -> f64 {
        match self.get(name) {
            Some(MetricValue::Summary { sum, .. }) => *sum,
            _ => 0.0,
        }
    }

    /// Structured JSON export: counters and gauges as numbers, summaries
    /// as `{count, sum, q50, q95, ...}` objects. Key order is the
    /// serializer's (sorted); registration order is not part of the schema.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj(vec![]);
        for (name, v) in &self.entries {
            let jv = match v {
                MetricValue::Counter(c) => Json::num(*c as f64),
                MetricValue::Gauge(g) => Json::num(*g),
                MetricValue::Summary { quantiles, sum, count } => {
                    let mut o = Json::obj(vec![
                        ("count", Json::num(*count as f64)),
                        ("sum", Json::num(*sum)),
                    ]);
                    for (q, qv) in quantiles {
                        o.set(&format!("q{}", fmt_f64(q * 100.0)), Json::num(*qv));
                    }
                    o
                }
            };
            root.set(name, jv);
        }
        root
    }

    /// Prometheus text exposition: one `# TYPE` line per metric *family*
    /// followed by its samples — labeled samples of the same family (e.g.
    /// `rejections_total{reason="queue_full"}`) share a single
    /// declaration; summaries expand to `quantile`-labelled samples plus
    /// `_sum` and `_count`.
    pub fn to_prometheus(&self) -> String {
        use std::collections::BTreeSet;
        let mut out = String::new();
        let mut typed: BTreeSet<String> = BTreeSet::new();
        for (name, v) in &self.entries {
            let n = format!("glvq_{name}");
            let fam = format!("glvq_{}", family_of(name));
            let mut declare = |out: &mut String, kind: &str| {
                if typed.insert(fam.clone()) {
                    out.push_str(&format!("# TYPE {fam} {kind}\n"));
                }
            };
            match v {
                MetricValue::Counter(c) => {
                    declare(&mut out, "counter");
                    out.push_str(&format!("{n} {c}\n"));
                }
                MetricValue::Gauge(g) => {
                    declare(&mut out, "gauge");
                    out.push_str(&format!("{n} {}\n", fmt_f64(*g)));
                }
                MetricValue::Summary { quantiles, sum, count } => {
                    declare(&mut out, "summary");
                    for (q, qv) in quantiles {
                        out.push_str(&format!(
                            "{n}{{quantile=\"{}\"}} {}\n",
                            fmt_f64(*q),
                            fmt_f64(*qv)
                        ));
                    }
                    out.push_str(&format!("{n}_sum {}\n{n}_count {count}\n", fmt_f64(*sum)));
                }
            }
        }
        out
    }
}

/// Parse the inside of a label set (`k="v",k2="v2"`, no braces) into
/// pairs, honoring backslash escapes inside values. Errors on malformed
/// pairs and on duplicate label names within the set.
fn parse_label_pairs(s: &str) -> Result<Vec<(String, String)>, &'static str> {
    let mut pairs: Vec<(String, String)> = Vec::new();
    let mut rest = s;
    while !rest.is_empty() {
        let eq = rest.find('=').ok_or("label missing '='")?;
        let key = &rest[..eq];
        let key_ok = !key.is_empty()
            && key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
            && !key.chars().next().unwrap().is_ascii_digit();
        if !key_ok {
            return Err("invalid label name");
        }
        if pairs.iter().any(|(k, _)| k == key) {
            return Err("duplicate label name in one sample");
        }
        rest = &rest[eq + 1..];
        if !rest.starts_with('"') {
            return Err("label value not quoted");
        }
        rest = &rest[1..];
        let mut val = String::new();
        let mut end = None;
        let mut escaped = false;
        for (i, c) in rest.char_indices() {
            if escaped {
                escaped = false;
                val.push(c);
                continue;
            }
            match c {
                '\\' => escaped = true,
                '"' => {
                    end = Some(i);
                    break;
                }
                _ => val.push(c),
            }
        }
        let end = end.ok_or("unterminated label value")?;
        rest = &rest[end + 1..];
        pairs.push((key.to_string(), val));
        if let Some(r) = rest.strip_prefix(',') {
            if r.is_empty() {
                return Err("trailing comma in label set");
            }
            rest = r;
        } else if !rest.is_empty() {
            return Err("expected ',' between labels");
        }
    }
    Ok(pairs)
}

/// Structural check of a Prometheus text exposition: every `# TYPE` line
/// names a valid type and never re-declares a family as a different type,
/// every sample line parses as `name[{labels}] value` with well-formed
/// label pairs (no duplicate label names, quoted values), no two samples
/// share the same name + label set, and every sample belongs to a
/// declared metric family (allowing the summary `_sum`/`_count`
/// suffixes). Used by the export golden tests and the CI artifact check.
pub fn validate_prometheus(text: &str) -> Result<(), String> {
    use std::collections::{BTreeMap, BTreeSet};
    let mut declared: BTreeMap<String, String> = BTreeMap::new();
    let mut samples: BTreeSet<String> = BTreeSet::new();
    for (i, line) in text.lines().enumerate() {
        let err = |msg: &str| Err(format!("line {}: {msg}: {line:?}", i + 1));
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let parts: Vec<&str> = rest.split_whitespace().collect();
            if parts.first() == Some(&"TYPE") {
                if parts.len() != 3 {
                    return err("malformed # TYPE line");
                }
                let ok =
                    matches!(parts[2], "counter" | "gauge" | "summary" | "histogram" | "untyped");
                if !ok {
                    return err("unknown metric type");
                }
                if let Some(prev) = declared.insert(parts[1].to_string(), parts[2].to_string()) {
                    if prev != parts[2] {
                        return err("family re-declared with a different type");
                    }
                }
            }
            continue; // other comments (# HELP ...) are fine
        }
        let (name_part, value_part) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => return err("sample line missing value"),
        };
        if value_part.parse::<f64>().is_err() {
            return err("sample value is not a number");
        }
        let base = match name_part.split_once('{') {
            Some((b, labels)) => {
                let inner = match labels.strip_suffix('}') {
                    Some(inner) => inner,
                    None => return err("unterminated label set"),
                };
                if let Err(e) = parse_label_pairs(inner) {
                    return err(e);
                }
                b
            }
            None => name_part,
        };
        if base.is_empty()
            || !base
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || base.chars().next().unwrap().is_ascii_digit()
        {
            return err("invalid metric name");
        }
        if !samples.insert(name_part.to_string()) {
            return err("duplicate sample (same name and label set)");
        }
        let family = base
            .strip_suffix("_sum")
            .filter(|f| declared.contains_key(*f))
            .or_else(|| base.strip_suffix("_count").filter(|f| declared.contains_key(*f)))
            .unwrap_or(base);
        if !declared.contains_key(family) {
            return err("sample without a preceding # TYPE declaration");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MetricsSnapshot {
        let mut r = Registry::new();
        r.counter("requests_total", 7);
        r.gauge("tokens_per_sec", 123.5);
        r.summary(
            "request_latency_ms",
            vec![(0.5, 12.0), (0.95, 20.25), (0.99, 31.0)],
            140.5,
            7,
        );
        r.finish()
    }

    #[test]
    fn lookups_by_name_and_type() {
        let s = sample();
        assert_eq!(s.counter("requests_total"), 7);
        assert_eq!(s.gauge("tokens_per_sec"), 123.5);
        assert_eq!(s.quantile("request_latency_ms", 0.95), 20.25);
        assert_eq!(s.summary_count("request_latency_ms"), 7);
        assert_eq!(s.summary_sum("request_latency_ms"), 140.5);
        assert_eq!(s.counter("missing"), 0);
        assert!(!s.has("missing"));
        assert_eq!(s.entries().len(), 3);
    }

    #[test]
    fn json_export_round_trips_through_util_json() {
        let j = sample().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
        assert_eq!(parsed.get("requests_total").as_f64(), Some(7.0));
        assert_eq!(
            parsed.get("request_latency_ms").get("q95").as_f64(),
            Some(20.25)
        );
        assert_eq!(parsed.get("request_latency_ms").get("count").as_f64(), Some(7.0));
    }

    #[test]
    fn prometheus_export_is_well_formed() {
        let text = sample().to_prometheus();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("# TYPE glvq_requests_total counter\n"));
        assert!(text.contains("glvq_requests_total 7\n"));
        assert!(text.contains("# TYPE glvq_request_latency_ms summary\n"));
        assert!(text.contains("glvq_request_latency_ms{quantile=\"0.5\"} 12\n"));
        assert!(text.contains("glvq_request_latency_ms_sum 140.5\n"));
        assert!(text.contains("glvq_request_latency_ms_count 7\n"));
    }

    #[test]
    fn prometheus_validator_rejects_malformed_text() {
        assert!(validate_prometheus("# TYPE glvq_x banana\nglvq_x 1\n").is_err());
        assert!(validate_prometheus("glvq_unregistered 1\n").is_err());
        assert!(validate_prometheus("# TYPE glvq_x counter\nglvq_x notanumber\n").is_err());
        assert!(validate_prometheus("# TYPE glvq_x counter\nglvq_x\n").is_err());
    }

    #[test]
    fn labeled_samples_share_one_family_declaration() {
        let mut r = Registry::new();
        r.counter_with("rejections_total", &[("reason", "queue_full")], 2);
        r.counter_with("rejections_total", &[("reason", "budget")], 1);
        r.gauge_with("replica_tokens_per_sec", &[("replica", "0")], 10.5);
        r.gauge_with("replica_tokens_per_sec", &[("replica", "1")], 12.0);
        let s = r.finish();
        assert_eq!(s.counter_labeled("rejections_total", &[("reason", "queue_full")]), 2);
        assert_eq!(s.counter_labeled("rejections_total", &[("reason", "missing")]), 0);
        assert_eq!(s.counter_family("rejections_total"), 3);
        let text = s.to_prometheus();
        validate_prometheus(&text).unwrap();
        assert_eq!(text.matches("# TYPE glvq_rejections_total counter").count(), 1);
        assert!(text.contains("glvq_rejections_total{reason=\"queue_full\"} 2\n"), "{text}");
        assert!(text.contains("glvq_rejections_total{reason=\"budget\"} 1\n"), "{text}");
        assert_eq!(text.matches("# TYPE glvq_replica_tokens_per_sec gauge").count(), 1);
        assert!(text.contains("glvq_replica_tokens_per_sec{replica=\"1\"} 12\n"), "{text}");
    }

    #[test]
    fn label_values_are_escaped_and_reparse() {
        let mut r = Registry::new();
        r.counter_with("weird_total", &[("k", "a\"b\\c")], 1);
        let text = r.finish().to_prometheus();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("glvq_weird_total{k=\"a\\\"b\\\\c\"} 1\n"), "{text}");
    }

    #[test]
    fn validator_checks_labeled_families() {
        // duplicate label name within one sample
        assert!(validate_prometheus("# TYPE glvq_x counter\nglvq_x{a=\"1\",a=\"2\"} 1\n").is_err());
        // unquoted label value
        assert!(validate_prometheus("# TYPE glvq_x counter\nglvq_x{a=1} 1\n").is_err());
        // unterminated label set / value
        assert!(validate_prometheus("# TYPE glvq_x counter\nglvq_x{a=\"1\" 1\n").is_err());
        assert!(validate_prometheus("# TYPE glvq_x counter\nglvq_x{a=\"1} 1\n").is_err());
        // family re-declared with a different type
        assert!(
            validate_prometheus("# TYPE glvq_x counter\nglvq_x 1\n# TYPE glvq_x gauge\n").is_err()
        );
        // re-declaring with the same type is tolerated
        assert!(validate_prometheus(
            "# TYPE glvq_x counter\nglvq_x{a=\"1\"} 1\n# TYPE glvq_x counter\nglvq_x{a=\"2\"} 2\n"
        )
        .is_ok());
        // duplicate sample: same name and label set
        assert!(validate_prometheus(
            "# TYPE glvq_x counter\nglvq_x{a=\"1\"} 1\nglvq_x{a=\"1\"} 2\n"
        )
        .is_err());
        // distinct label values are fine
        assert!(validate_prometheus(
            "# TYPE glvq_x counter\nglvq_x{a=\"1\"} 1\nglvq_x{a=\"2\"} 2\n"
        )
        .is_ok());
        // labeled sample of an undeclared family
        assert!(validate_prometheus("glvq_y{a=\"1\"} 1\n").is_err());
    }
}
