//! Allocation-frugal RAII span tracing.
//!
//! A span is opened with the [`crate::span!`] macro and closed when the
//! returned guard drops. Spans nest through a per-thread frame stack, so a
//! guard must be dropped in LIFO order on the thread that opened it (the
//! natural behaviour of `let _g = crate::span!("stage");` scoping). Each
//! finished span records wall duration *and* self time (duration minus the
//! time spent inside child spans), which is what makes the aggregate
//! attribution in [`summarize`] meaningful: a parent whose children cover
//! its interval has near-zero self time.
//!
//! Cost discipline:
//!
//! - Tracing is off by default. A disabled `crate::span!` is one relaxed
//!   atomic load returning an inert guard — cheap enough to leave in the
//!   scheduler's per-step hot path (the serving bench asserts this).
//! - An enabled span does no heap allocation on open (the frame stack
//!   reuses its backing storage) and one `Vec` push on close into a buffer
//!   owned by the recording thread.
//!
//! Buffers from every thread — including worker threads that have since
//! exited — are collected by [`drain`], which returns the finished spans
//! ordered per thread. Timestamps are nanoseconds since a process-global
//! monotonic epoch shared with [`crate::obs::timeline`], so scheduler
//! spans and per-request timelines land on one common time axis in the
//! Chrome trace export ([`crate::obs::chrome_trace_json`]).

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span recording on or off process-wide. Guards opened while
/// disabled stay inert even if recording is enabled before they drop.
pub fn set_enabled(on: bool) {
    if on {
        // Pin the epoch before the first span so timestamps stay small.
        epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is span recording currently enabled?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-global monotonic trace epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// One closed span, as recorded by a dropped guard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FinishedSpan {
    /// static stage label passed to `crate::span!`
    pub name: &'static str,
    /// recording thread (small dense ids assigned per thread, not OS tids)
    pub tid: u64,
    /// open timestamp, ns since the trace epoch
    pub start_ns: u64,
    /// wall duration, ns
    pub dur_ns: u64,
    /// duration minus time covered by child spans, ns
    pub self_ns: u64,
    /// nesting depth at open time (0 = top level on its thread)
    pub depth: u32,
}

struct Frame {
    name: &'static str,
    start_ns: u64,
    child_ns: u64,
}

#[derive(Default)]
struct ThreadBuf {
    spans: Mutex<Vec<FinishedSpan>>,
}

fn buf_registry() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static REG: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

struct ThreadState {
    tid: u64,
    stack: Vec<Frame>,
    buf: Arc<ThreadBuf>,
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TLS: RefCell<Option<ThreadState>> = const { RefCell::new(None) };
}

fn with_state<R>(f: impl FnOnce(&mut ThreadState) -> R) -> R {
    TLS.with(|cell| {
        let mut slot = cell.borrow_mut();
        let st = slot.get_or_insert_with(|| {
            let buf = Arc::new(ThreadBuf::default());
            buf_registry().lock().unwrap().push(buf.clone());
            ThreadState {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                stack: Vec::with_capacity(8),
                buf,
            }
        });
        f(st)
    })
}

/// RAII guard returned by [`guard`] / the `crate::span!` macro. Closes the
/// span on drop. Must drop on the opening thread, in LIFO order.
pub struct SpanGuard {
    active: bool,
}

/// Open a span named `name`. Prefer the `crate::span!` macro at call
/// sites. When tracing is disabled this is one atomic load.
#[inline]
pub fn guard(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { active: false };
    }
    let start_ns = now_ns();
    with_state(|st| st.stack.push(Frame { name, start_ns, child_ns: 0 }));
    SpanGuard { active: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end_ns = now_ns();
        with_state(|st| {
            let Some(f) = st.stack.pop() else { return };
            let dur_ns = end_ns.saturating_sub(f.start_ns);
            let self_ns = dur_ns.saturating_sub(f.child_ns);
            if let Some(parent) = st.stack.last_mut() {
                parent.child_ns += dur_ns;
            }
            let span = FinishedSpan {
                name: f.name,
                tid: st.tid,
                start_ns: f.start_ns,
                dur_ns,
                self_ns,
                depth: st.stack.len() as u32,
            };
            st.buf.spans.lock().unwrap().push(span);
        });
    }
}

/// Take every finished span recorded so far, across all threads (live and
/// exited), sorted by `(tid, start_ns)` with parents before their
/// children. Buffers of exited threads are released.
pub fn drain() -> Vec<FinishedSpan> {
    let mut out = Vec::new();
    {
        let mut reg = buf_registry().lock().unwrap();
        reg.retain(|buf| {
            out.append(&mut buf.spans.lock().unwrap());
            // strong_count 1 means the owning thread's TLS is gone
            Arc::strong_count(buf) > 1
        });
    }
    out.sort_by_key(|s| (s.tid, s.start_ns, std::cmp::Reverse(s.dur_ns)));
    out
}

/// Per-stage aggregate over a set of finished spans.
#[derive(Clone, Debug)]
pub struct StageStat {
    pub name: &'static str,
    pub count: usize,
    pub total_ms: f64,
    pub self_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// Aggregate spans by stage name: count, total, self time and duration
/// quantiles, sorted by descending total time.
pub fn summarize(spans: &[FinishedSpan]) -> Vec<StageStat> {
    use std::collections::BTreeMap;
    let mut by_name: BTreeMap<&'static str, Vec<u64>> = BTreeMap::new();
    let mut self_by_name: BTreeMap<&'static str, u64> = BTreeMap::new();
    for s in spans {
        by_name.entry(s.name).or_default().push(s.dur_ns);
        *self_by_name.entry(s.name).or_default() += s.self_ns;
    }
    let mut out = Vec::with_capacity(by_name.len());
    for (name, mut durs) in by_name {
        durs.sort_unstable();
        let q = |q: f64| durs[(q * (durs.len() - 1) as f64).round() as usize] as f64 / 1e6;
        out.push(StageStat {
            name,
            count: durs.len(),
            total_ms: durs.iter().sum::<u64>() as f64 / 1e6,
            self_ms: self_by_name[name] as f64 / 1e6,
            p50_ms: q(0.5),
            p95_ms: q(0.95),
        });
    }
    out.sort_by(|a, b| b.total_ms.partial_cmp(&a.total_ms).unwrap());
    out
}

/// Render stage aggregates as an aligned text table (one line per stage).
pub fn render_summary(stats: &[StageStat]) -> String {
    let mut out = String::from(
        "stage                       count   total_ms    self_ms     p50_ms     p95_ms\n",
    );
    for s in stats {
        out.push_str(&format!(
            "{:<26} {:>6} {:>10.2} {:>10.2} {:>10.3} {:>10.3}\n",
            s.name, s.count, s.total_ms, s.self_ms, s.p50_ms, s.p95_ms
        ));
    }
    out
}

/// One Chrome trace-event (`ph:"X"` complete event) for a finished span.
pub fn trace_event(s: &FinishedSpan) -> Json {
    Json::obj(vec![
        ("name", Json::str(s.name)),
        ("cat", Json::str("glvq")),
        ("ph", Json::str("X")),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(s.tid as f64)),
        ("ts", Json::num(s.start_ns as f64 / 1e3)),
        ("dur", Json::num(s.dur_ns as f64 / 1e3)),
        (
            "args",
            Json::obj(vec![
                ("self_us", Json::num(s.self_ns as f64 / 1e3)),
                ("depth", Json::num(s.depth as f64)),
            ]),
        ),
    ])
}

/// Check that spans form a proper forest per thread: on each thread,
/// every span is either disjoint from or fully contained in an earlier
/// still-open span, and its recorded depth matches the nesting level.
/// Input must be `drain()`-ordered. Used by the export golden tests.
pub fn validate_nesting(spans: &[FinishedSpan]) -> Result<(), String> {
    // (tid, end_ns) stack of currently-open ancestors
    let mut open: Vec<(u64, u64)> = Vec::new();
    for s in spans {
        let end = s.start_ns + s.dur_ns;
        while let Some(&(tid, anc_end)) = open.last() {
            if tid != s.tid || s.start_ns >= anc_end {
                open.pop();
            } else {
                break;
            }
        }
        if let Some(&(_, anc_end)) = open.last() {
            if end > anc_end {
                return Err(format!(
                    "span {} [{}, {}) overlaps ancestor ending at {}",
                    s.name, s.start_ns, end, anc_end
                ));
            }
        }
        if s.depth as usize != open.len() {
            return Err(format!(
                "span {} recorded depth {} but has {} open ancestors",
                s.name,
                s.depth,
                open.len()
            ));
        }
        if s.self_ns > s.dur_ns {
            return Err(format!("span {} self_ns exceeds dur_ns", s.name));
        }
        open.push((s.tid, end));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Spans and drain() are process-global; serialize the tests that
    // enable recording so one test's drain cannot swallow another's spans.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn spin_ns(ns: u64) {
        let t0 = now_ns();
        while now_ns() - t0 < ns {
            std::hint::spin_loop();
        }
    }

    #[test]
    fn disabled_guard_records_nothing() {
        let _l = test_lock();
        set_enabled(false);
        {
            let _g = crate::span!("never");
        }
        assert!(!drain().iter().any(|s| s.name == "never"));
    }

    #[test]
    fn nesting_and_self_time_attribution() {
        let _l = test_lock();
        set_enabled(true);
        {
            let _p = crate::span!("span_test_parent");
            spin_ns(200_000);
            {
                let _c = crate::span!("span_test_child");
                spin_ns(200_000);
            }
        }
        set_enabled(false);
        let spans = drain();
        let parent = spans.iter().find(|s| s.name == "span_test_parent").unwrap();
        let child = spans.iter().find(|s| s.name == "span_test_child").unwrap();
        assert_eq!(parent.tid, child.tid);
        assert!(child.start_ns >= parent.start_ns);
        assert!(child.start_ns + child.dur_ns <= parent.start_ns + parent.dur_ns);
        assert_eq!(child.depth, parent.depth + 1);
        // parent self time excludes the child's interval
        assert_eq!(parent.self_ns, parent.dur_ns - child.dur_ns);
        assert!(parent.self_ns >= 150_000, "self_ns={}", parent.self_ns);
    }

    #[test]
    fn drain_collects_spans_from_exited_threads() {
        let _l = test_lock();
        set_enabled(true);
        std::thread::spawn(|| {
            let _g = crate::span!("span_test_worker");
        })
        .join()
        .unwrap();
        set_enabled(false);
        let spans = drain();
        assert!(spans.iter().any(|s| s.name == "span_test_worker"));
    }

    #[test]
    fn summarize_counts_and_totals() {
        let spans = vec![
            FinishedSpan { name: "a", tid: 1, start_ns: 0, dur_ns: 10, self_ns: 4, depth: 0 },
            FinishedSpan { name: "b", tid: 1, start_ns: 2, dur_ns: 6, self_ns: 6, depth: 1 },
            FinishedSpan { name: "a", tid: 2, start_ns: 0, dur_ns: 30, self_ns: 30, depth: 0 },
        ];
        let stats = summarize(&spans);
        let a = stats.iter().find(|s| s.name == "a").unwrap();
        assert_eq!(a.count, 2);
        assert!((a.total_ms - 40e-6).abs() < 1e-12);
        assert!((a.self_ms - 34e-6).abs() < 1e-12);
        assert!(!render_summary(&stats).is_empty());
    }

    #[test]
    fn validate_nesting_rejects_overlap() {
        let bad = vec![
            FinishedSpan { name: "a", tid: 1, start_ns: 0, dur_ns: 10, self_ns: 10, depth: 0 },
            FinishedSpan { name: "b", tid: 1, start_ns: 5, dur_ns: 10, self_ns: 10, depth: 1 },
        ];
        assert!(validate_nesting(&bad).is_err());
        let good = vec![
            FinishedSpan { name: "a", tid: 1, start_ns: 0, dur_ns: 10, self_ns: 4, depth: 0 },
            FinishedSpan { name: "b", tid: 1, start_ns: 2, dur_ns: 6, self_ns: 6, depth: 1 },
            FinishedSpan { name: "c", tid: 2, start_ns: 1, dur_ns: 3, self_ns: 3, depth: 0 },
        ];
        assert!(validate_nesting(&good).is_ok());
    }
}
