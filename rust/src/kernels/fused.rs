//! The fused decode–GEMM micro-kernel.
//!
//! One pass over the packed payload per panel: codes are expanded
//! (through the code→vector table when one is attached, else through the
//! per-family decoder) into an L1-resident tile of at most
//! [`crate::kernels::tile::fused_tile_rows`] rows, and each decoded row
//! is FMA'd against every activation row while still cache-hot. There is
//! no panel-sized f32 slab and no second sweep — the scratch round-trip
//! the two-pass path pays is gone.
//!
//! **Bit-exactness.** The scalar kernel reproduces the slab path
//! bit-for-bit: decoded values come from the same decoder (the LUT bakes
//! its entries with it), and the accumulation is the same c-ascending
//! scalar dot per output element. `DecodeStats` are charged identically
//! — including `peak_decoded`, which stays panel-granular for parity
//! even though the fused tile residency is smaller (see ARCHITECTURE
//! "Fused kernels"). The SIMD reduction (`simd = true`, compiled under
//! `--features simd`) folds 8 lanes and may differ in the last ulps;
//! it is never enabled by default.

use crate::coordinator::decode_stream::{decode_codes, DecodeStats, UnstreamableDecode};
use crate::kernels::tile::fused_tile_rows;
use crate::kernels::{GroupTables, KernelScratch};
use crate::linalg::matrix::MatView;
use crate::quant::traits::{CodePayload, QuantizedGroup};

/// Decode-and-multiply one panel of `g` (group-local rows
/// `[r, r + rows)`, absolute activation columns starting at `c0`) into a
/// partial-product slab `slab[b·rows + i] = Σ_c ŵ[r+i][c] · x[b][c0+c]`
/// — the same contract as the slab path's `panel_slab`, produced in one
/// fused pass. Errors with [`UnstreamableDecode`] only if a
/// non-streamable family was misrouted here; the caller falls back to
/// the slab path (which carries the dense whole-group fallback).
pub fn fused_panel_slab(
    g: &QuantizedGroup,
    c0: usize,
    r: usize,
    rows: usize,
    tables: &GroupTables,
    x: MatView<'_>,
    scratch: &mut KernelScratch,
    stats: &mut DecodeStats,
    simd: bool,
) -> Result<Vec<f32>, UnstreamableDecode> {
    let (n, batch) = (g.cols, x.rows);
    let count = rows * n;
    let mut slab = vec![0.0f32; batch * rows];
    if count == 0 {
        return Ok(slab);
    }
    let bits = g.codes.bits();
    // a table decodes this group only if it was built for the same code
    // width and the row length is whole blocks
    let lut = tables.lut.as_deref().filter(|t| t.bits == bits && t.dim > 0 && n % t.dim == 0);

    let KernelScratch { codes_buf, rans_scratch, row_codes, row_buf, .. } = scratch;

    // rANS payloads decode chunk-granularly: materialize the whole
    // panel's codes once (panels snap to whole chunks upstream), exactly
    // as the slab path does, so the charged traffic stays identical.
    // Fixed payloads are bit-addressable and unpack tile-granularly below.
    let panel_codes = matches!(g.codes, CodePayload::Rans(_));
    if panel_codes {
        codes_buf.resize(count, 0);
        match (&g.codes, tables.rans.as_ref()) {
            (CodePayload::Rans(rc), Some(t)) => {
                rc.decode_range_with(r * n, &mut codes_buf[..count], t, rans_scratch)
            }
            _ => g.codes.unpack_range_into(r * n, &mut codes_buf[..count]),
        }
    }
    stats.code_bytes += g.codes.range_payload_bytes(r * n, count);

    let tile_rows = fused_tile_rows(n, batch).min(rows);
    row_buf.resize(tile_rows * n, 0.0);
    if !panel_codes && lut.is_none() {
        row_codes.resize(n, 0);
    }

    let mut t0 = 0usize;
    while t0 < rows {
        let tr = tile_rows.min(rows - t0);
        // ---- decode `tr` rows into the L1-resident tile ----
        for i in 0..tr {
            let dst = &mut row_buf[i * n..(i + 1) * n];
            if let Some(t) = lut {
                let dim = t.dim;
                if panel_codes {
                    let codes = &codes_buf[(t0 + i) * n..(t0 + i + 1) * n];
                    for (k, blk) in dst.chunks_exact_mut(dim).enumerate() {
                        let idx = t.index_of_codes(&codes[k * dim..(k + 1) * dim]);
                        blk.copy_from_slice(t.entry(idx));
                    }
                } else if let CodePayload::Fixed(p) = &g.codes {
                    // table index read straight from the packed bit stream
                    let base = (r + t0 + i) * n;
                    for (k, blk) in dst.chunks_exact_mut(dim).enumerate() {
                        let idx = p.read_code_run(base + k * dim, dim) as usize;
                        blk.copy_from_slice(t.entry(idx));
                    }
                }
            } else {
                let codes: &[i32] = if panel_codes {
                    &codes_buf[(t0 + i) * n..(t0 + i + 1) * n]
                } else {
                    // tile-granular unpack: only this row's codes are ever
                    // materialized
                    g.codes.unpack_range_into((r + t0 + i) * n, &mut row_codes[..n]);
                    &row_codes[..n]
                };
                decode_codes(&g.side, bits, codes, dst)?;
            }
        }
        // ---- FMA the tile into the slab while it is cache-hot ----
        for b in 0..batch {
            let xr = &x.row(b)[c0..c0 + n];
            for i in 0..tr {
                let w = &row_buf[i * n..(i + 1) * n];
                slab[b * rows + t0 + i] = dot(w, xr, simd);
            }
        }
        t0 += tr;
    }

    stats.weights_decoded += count;
    // panel-granular for parity with the slab path's accounting; the
    // true fused residency is the (smaller) tile
    stats.peak_decoded = stats.peak_decoded.max(count);
    stats.macs += batch * count;
    Ok(slab)
}

/// Dot product of one decoded weight row against one activation row.
/// Scalar: c-ascending `acc += w·x`, matching the slab path exactly.
/// SIMD (opt-in): 8-lane vertical accumulate + horizontal fold.
#[inline]
fn dot(w: &[f32], x: &[f32], simd: bool) -> f32 {
    #[cfg(feature = "simd")]
    if simd {
        return dot_simd(w, x);
    }
    #[cfg(not(feature = "simd"))]
    let _ = simd;
    let mut acc = 0.0f32;
    for (a, v) in w.iter().zip(x.iter()) {
        acc += a * v;
    }
    acc
}

#[cfg(feature = "simd")]
#[inline]
fn dot_simd(w: &[f32], x: &[f32]) -> f32 {
    use std::simd::prelude::*;
    const LANES: usize = 8;
    let n = w.len().min(x.len());
    let chunks = n / LANES;
    let mut acc = f32x8::splat(0.0);
    for t in 0..chunks {
        let a = f32x8::from_slice(&w[t * LANES..]);
        let b = f32x8::from_slice(&x[t * LANES..]);
        acc += a * b;
    }
    let mut s = acc.reduce_sum();
    for j in chunks * LANES..n {
        s += w[j] * x[j];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_dot_is_plain_ascending_accumulation() {
        let w = [1.0f32, 2.0, 3.0, 4.0];
        let x = [0.5f32, -1.0, 2.0, 0.25];
        let mut want = 0.0f32;
        for i in 0..4 {
            want += w[i] * x[i];
        }
        assert_eq!(dot(&w, &x, false), want);
    }

    #[cfg(feature = "simd")]
    #[test]
    fn simd_dot_matches_scalar_within_tolerance() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        for n in [1usize, 7, 8, 9, 64, 127, 512] {
            let w: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let a = dot(&w, &x, false);
            let b = dot(&w, &x, true);
            assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs()), "n={n}: {a} vs {b}");
        }
    }
}
