//! Cache-blocking geometry for the fused decode–GEMM kernel.
//!
//! The fused kernel decodes a tile of weight rows into a scratch buffer
//! and immediately FMAs it against every activation row, so the decoded
//! weights are consumed while still cache-hot and never round-trip
//! through memory. This module picks the tile height: the decoded tile
//! itself must stay inside (a conservative share of) L1d, and tile +
//! activation panel together inside L2, for any group width.
//!
//! Sizes are deliberately static: the repo targets portable scalar/SIMD
//! Rust, and 32 KiB L1d / 256 KiB-plus L2 per core is the floor of every
//! deployment target. Halving the budgets leaves room for the code
//! stream, output slab and stack traffic sharing the same caches.

/// Decoded-tile budget inside L1d (half of a 32 KiB L1d).
pub const L1_TILE_BYTES: usize = 16 * 1024;

/// Decoded tile + activation panel budget inside L2 (conservative share
/// of a 256 KiB L2).
pub const L2_TILE_BYTES: usize = 192 * 1024;

/// Rows of decoded weights the fused kernel materializes per tile for a
/// group `n_cols` wide under an activation batch of `batch` rows.
/// Always ≥ 1 (a single row may exceed the L1 share for very wide
/// groups; it still streams row-at-a-time, the minimum possible
/// residency).
pub fn fused_tile_rows(n_cols: usize, batch: usize) -> usize {
    let row_bytes = n_cols.max(1) * std::mem::size_of::<f32>();
    let l1_rows = L1_TILE_BYTES / row_bytes;
    // keep the x rows this tile is multiplied against co-resident in L2
    let act_bytes = batch.max(1).saturating_mul(row_bytes);
    let l2_rows = L2_TILE_BYTES.saturating_sub(act_bytes) / row_bytes;
    l1_rows.min(l2_rows).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_always_at_least_one_row() {
        for n in [1usize, 8, 64, 512, 4096, 1 << 16] {
            for batch in [1usize, 16, 256] {
                assert!(fused_tile_rows(n, batch) >= 1, "n={n} batch={batch}");
            }
        }
    }

    #[test]
    fn tile_respects_l1_budget_when_a_row_fits() {
        for n in [8usize, 64, 128, 512, 2048] {
            let rows = fused_tile_rows(n, 1);
            if n * 4 <= L1_TILE_BYTES {
                assert!(rows * n * 4 <= L1_TILE_BYTES, "n={n} rows={rows}");
            } else {
                assert_eq!(rows, 1, "oversized rows must stream one at a time");
            }
        }
    }

    #[test]
    fn bigger_batches_shrink_the_tile_not_the_floor() {
        let wide = fused_tile_rows(512, 1);
        let batched = fused_tile_rows(512, 64);
        assert!(batched <= wide);
        assert!(batched >= 1);
    }

    #[test]
    fn tile_monotone_in_group_width() {
        let mut prev = usize::MAX;
        for n in [8usize, 32, 128, 512, 2048] {
            let rows = fused_tile_rows(n, 4);
            assert!(rows <= prev, "tile rows must not grow with group width");
            prev = rows;
        }
    }
}
