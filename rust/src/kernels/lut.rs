//! Lookup-table decode for low-bit fixed-rate lattice families.
//!
//! A d-dimensional block quantized at b bits has only (2^b)^d distinct
//! code vectors; at 2–3 bits and d ≤ 8 that is at most 2^16 blocks. A
//! [`LutTable`] enumerates every one of them through the *same* decoder
//! the slab path uses — generation matrix, μ-law inverse and scale baked
//! in — so fused execution replaces the per-block matvec + `exp` with a
//! direct-indexed load (QuIP#-style fast codebook decode; see PAPERS.md).
//! Because entries come from [`decode_codes`], LUT decode is bit-identical
//! to direct decode by construction.
//!
//! The table index of a block is its packed-payload bit pattern: field j
//! (the offset code `z_j − lo`) occupies bits `[j·b, (j+1)·b)`, exactly
//! the order [`crate::quant::pack::PackedCodes`] stores them, so fixed
//! payloads address the table straight from the code stream
//! (`PackedCodes::read_code_run`) without materializing integer codes.
//!
//! [`decode_codes`]: crate::coordinator::decode_stream

use crate::lattice::{code_space, unrank_codes};
use crate::quant::pack::code_range;
use crate::quant::traits::{CodePayload, QuantizedGroup, SideInfo};

/// Tables are capped at 2^16 entries (bits · d ≤ 16): a 2-bit d=8 or
/// 3-bit d=4 family fits; wider families fall back to direct fused
/// decode. Keeps any single table ≤ 2 MiB of f32 entries.
pub const MAX_LUT_INDEX_BITS: usize = 16;

/// Direct-indexed code→decoded-vector table for one group's side info.
pub struct LutTable {
    /// block dimensionality d
    pub dim: usize,
    /// code width the index fields are read at
    pub bits: u8,
    /// `(2^bits)^dim · dim` decoded weights, entry-major: entry i holds
    /// the decoded block whose packed bit pattern equals i
    pub entries: Vec<f32>,
}

impl LutTable {
    /// Build the table for an eligible side-info family (see
    /// [`lut_block_dim`]); `None` if the family is ineligible or its
    /// decoder refuses (cannot happen for eligible families).
    pub fn build(side: &SideInfo, bits: u8) -> Option<LutTable> {
        let _sp = crate::span!("lut_build");
        let dim = lut_block_dim(side, bits)?;
        let n_entries = code_space(bits, dim)?;
        let mut entries = vec![0.0f32; n_entries * dim];
        let mut codes = vec![0i32; dim];
        for idx in 0..n_entries {
            unrank_codes(idx, bits, &mut codes);
            let out = &mut entries[idx * dim..(idx + 1) * dim];
            crate::coordinator::decode_stream::decode_codes(side, bits, &codes, out).ok()?;
        }
        Some(LutTable { dim, bits, entries })
    }

    /// Decoded block for a table index.
    #[inline]
    pub fn entry(&self, idx: usize) -> &[f32] {
        &self.entries[idx * self.dim..(idx + 1) * self.dim]
    }

    /// Table index of a block of signed codes (the rANS path, where codes
    /// are already materialized): `Σ_j (z_j − lo) << (j·bits)`.
    #[inline]
    pub fn index_of_codes(&self, codes: &[i32]) -> usize {
        let lo = code_range(self.bits).0;
        let b = self.bits as usize;
        let mut idx = 0usize;
        for (j, &c) in codes.iter().enumerate() {
            idx |= ((c - lo) as usize) << (j * b);
        }
        idx
    }

    /// Resident bytes of the entry storage.
    pub fn bytes(&self) -> usize {
        self.entries.len() * std::mem::size_of::<f32>()
    }
}

/// `Some(d)` when this family gets a code→vector table: fixed-rate
/// lattice families (learned or rotated) of block dim ≥ 2 whose index
/// width `bits · d` fits [`MAX_LUT_INDEX_BITS`]. Uniform (d = 1) gains
/// nothing from a table; codebook/trellis/binary are not streamable at
/// all and never reach the fused path.
pub fn lut_block_dim(side: &SideInfo, bits: u8) -> Option<usize> {
    let dim = match side {
        SideInfo::Lattice { d, .. } | SideInfo::RotatedLattice { d, .. } => *d,
        _ => return None,
    };
    if dim < 2 || (bits as usize) * dim > MAX_LUT_INDEX_BITS {
        return None;
    }
    Some(dim)
}

/// Entry-storage bytes a table for this family would occupy (admission
/// check for the engine cache budget, before paying the build).
pub fn lut_bytes_estimate(side: &SideInfo, bits: u8) -> Option<usize> {
    let dim = lut_block_dim(side, bits)?;
    let n = code_space(bits, dim)?;
    Some(n * dim * std::mem::size_of::<f32>())
}

/// Content fingerprint of everything a [`LutTable`] depends on — the
/// side-info floats, code width and shape — so the engine cache detects
/// a different tensor reusing a cached (name, group) key and rebuilds
/// instead of serving stale entries. FNV-1a over the exact float bits.
pub fn group_fingerprint(g: &QuantizedGroup) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    fn mix(h: &mut u64, bytes: &[u8]) {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(FNV_PRIME);
        }
    }
    let mut h = FNV_OFFSET;
    mix(&mut h, g.method.as_bytes());
    for v in [g.rows as u64, g.cols as u64, g.bits as u64, g.codes.bits() as u64] {
        mix(&mut h, &v.to_le_bytes());
    }
    match &g.side {
        SideInfo::Lattice { d, g: gm, mu, scale } => {
            mix(&mut h, &(*d as u64).to_le_bytes());
            for f in gm {
                mix(&mut h, &f.to_bits().to_le_bytes());
            }
            mix(&mut h, &mu.to_bits().to_le_bytes());
            mix(&mut h, &scale.to_bits().to_le_bytes());
        }
        SideInfo::RotatedLattice { d, scale, sign_seed } => {
            mix(&mut h, &(*d as u64).to_le_bytes());
            mix(&mut h, &scale.to_bits().to_le_bytes());
            mix(&mut h, &sign_seed.to_le_bytes());
        }
        // non-lattice families never build tables; shape + bits suffice
        _ => {}
    }
    if let CodePayload::Fixed(p) = &g.codes {
        mix(&mut h, &(p.n as u64).to_le_bytes());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::PackedCodes;
    use crate::util::rng::Rng;

    fn lattice_side(d: usize, seed: u64) -> SideInfo {
        let mut rng = Rng::new(seed);
        let mut g = vec![0.0f32; d * d];
        for (i, v) in g.iter_mut().enumerate() {
            *v = 0.15 * rng.normal_f32() + if i % (d + 1) == 0 { 0.4 } else { 0.0 };
        }
        SideInfo::Lattice { d, g, mu: 87.0, scale: 0.031 }
    }

    #[test]
    fn eligibility_matrix() {
        assert_eq!(lut_block_dim(&lattice_side(8, 1), 2), Some(8)); // 16 index bits
        assert_eq!(lut_block_dim(&lattice_side(4, 1), 3), Some(4)); // 12 index bits
        assert_eq!(lut_block_dim(&lattice_side(8, 1), 3), None); // 24 bits: too wide
        assert_eq!(lut_block_dim(&lattice_side(1, 1), 2), None); // scalar blocks
        assert_eq!(lut_block_dim(&SideInfo::Uniform { scale: 1.0, zero: 0.0 }, 2), None);
        assert_eq!(
            lut_block_dim(&SideInfo::RotatedLattice { d: 8, scale: 0.5, sign_seed: 3 }, 2),
            Some(8)
        );
    }

    #[test]
    fn table_entries_match_direct_decode_bitwise() {
        for (d, bits) in [(4usize, 2u8), (4, 3), (8, 2)] {
            let side = lattice_side(d, 7 + d as u64);
            let t = LutTable::build(&side, bits).expect("eligible");
            assert_eq!(t.entries.len(), code_space(bits, d).unwrap() * d);
            let (lo, hi) = code_range(bits);
            let mut rng = Rng::new(11);
            for _ in 0..200 {
                let codes: Vec<i32> =
                    (0..d).map(|_| rng.below((hi - lo + 1) as usize) as i32 + lo).collect();
                let mut want = vec![0.0f32; d];
                crate::coordinator::decode_stream::decode_codes(&side, bits, &codes, &mut want)
                    .unwrap();
                let got = t.entry(t.index_of_codes(&codes));
                assert_eq!(got, &want[..], "d={d} bits={bits} codes={codes:?}");
            }
        }
    }

    #[test]
    fn index_of_codes_matches_packed_bit_pattern() {
        // the identity the fixed-payload fast path relies on: the table
        // index of a block equals its raw packed-field run
        let (d, bits) = (8usize, 2u8);
        let (lo, hi) = code_range(bits);
        let mut rng = Rng::new(5);
        let codes: Vec<i32> =
            (0..4 * d).map(|_| rng.below((hi - lo + 1) as usize) as i32 + lo).collect();
        let packed = PackedCodes::pack(&codes, bits);
        let side = lattice_side(d, 2);
        let t = LutTable::build(&side, bits).unwrap();
        for blk in 0..4 {
            let want = t.index_of_codes(&codes[blk * d..(blk + 1) * d]);
            assert_eq!(packed.read_code_run(blk * d, d) as usize, want);
        }
    }

    #[test]
    fn fingerprint_tracks_side_info_content() {
        let g = |seed| QuantizedGroup {
            method: "glvq",
            bits: 2,
            rows: 8,
            cols: 16,
            codes: PackedCodes::pack(&vec![0i32; 128], 2).into(),
            side: lattice_side(8, seed),
        };
        let a = group_fingerprint(&g(1));
        assert_eq!(a, group_fingerprint(&g(1)), "fingerprint must be deterministic");
        assert_ne!(a, group_fingerprint(&g(2)), "different G must change the fingerprint");
    }

    #[test]
    fn bytes_estimate_matches_built_table() {
        let side = lattice_side(4, 3);
        let t = LutTable::build(&side, 3).unwrap();
        assert_eq!(lut_bytes_estimate(&side, 3), Some(t.bytes()));
    }
}
