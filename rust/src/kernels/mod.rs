//! Fused decode–GEMM kernel layer.
//!
//! The PR-2 slab path ([`crate::coordinator::decode_stream`]) runs every
//! hot matmul in two passes: decode a group-panel into an f32 scratch
//! slab, then multiply it — two sweeps over the panel and a scratch
//! round-trip per call. The GLVQ decoder is itself a tiny matvec
//! (ŵ = F⁻¹(G z) per d-block), so the decode folds into the GEMM tile:
//! [`fused::fused_panel_slab`] unpacks codes, expands them through the
//! per-group generation matrix + μ-law inverse (or a precomputed
//! code→vector table), and FMAs straight into the output accumulators in
//! one pass over the packed payload. Tiles are cache-blocked ([`tile`])
//! so the decoded weights never leave L1/L2 between decode and use.
//!
//! Three execution layers, selected per group at runtime:
//!
//! - **LUT fused** ([`lut`]): 2–3-bit fixed-rate lattice families index a
//!   direct table of all (2^bits)^d decoded blocks — generation matrix,
//!   μ-law expansion and scale baked in at build time, so the hot loop is
//!   a load + copy + FMA. Tables build once beside the rANS
//!   `DecodeTable`s and are cached per engine after a warm-up.
//! - **Direct fused** ([`fused`]): everything streamable that the table
//!   cannot cover decodes row-at-a-time into an L1-resident tile and
//!   multiplies immediately.
//! - **Slab fallback**: non-streamable families (trellis/binary/codebook)
//!   and [`ExecMode::Slab`] keep the original two-pass path, so shard /
//!   pipeline executors and `DecodeStats` accounting work unchanged.
//!
//! **Bit-exactness contract.** The scalar fused path preserves the slab
//! path's per-element multiply-accumulate order: logits and
//! `DecodeStats` are bit-identical to the slab path (tested in
//! `tests/fused_parity.rs`). The SIMD path (`--features simd`, runtime
//! opt-in via [`StreamingMatmul::with_simd`]/`GLVQ_SIMD=1`/`serve
//! --fused`) reorders the dot-product reduction into 8 lanes; it is
//! token-identical on the generation parity suites with elementwise
//! tolerance `|Δ| ≤ 1e-4 · (1 + |y|)`.
//!
//! [`StreamingMatmul::with_simd`]: crate::coordinator::decode_stream::StreamingMatmul::with_simd

pub mod fused;
pub mod lut;
pub mod tile;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

use crate::entropy::histogram::DecodeTable;

/// How [`crate::coordinator::decode_stream::StreamingMatmul`] executes
/// streamable group-panels. Non-streamable side-info families always take
/// the whole-group dense fallback regardless of mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Fused decode–GEMM for streamable families, slab/dense fallback
    /// elsewhere — the default; bit-identical to `Slab`.
    Auto,
    /// Fused wherever streamable (what `serve --fused` forces). Same
    /// dispatch as `Auto`; the explicit variant records operator intent
    /// and survives an environment that said `Slab`.
    Fused,
    /// The original two-pass decode-then-multiply slab path everywhere —
    /// the reference the fused paths are tested bit-identical against.
    Slab,
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Auto => "auto",
            ExecMode::Fused => "fused",
            ExecMode::Slab => "slab",
        }
    }
}

/// Engine-level LUT cache: tables build only after a (tensor, group) has
/// been decoded this many times through one engine, so one-shot calls
/// (quantization-time evals, tests) never pay a table build.
pub const LUT_WARM_CALLS: usize = 2;

/// Hard ceiling on the bytes of code→vector tables one engine caches.
pub const LUT_CACHE_BUDGET_BYTES: usize = 512 << 20;

// Process-wide overrides (set by the CLI before engines are built) layered
// over the environment: override > env > default. Engines snapshot the
// resolved values at construction.
static MODE_OVERRIDE: AtomicU8 = AtomicU8::new(0); // 0 unset, 1 auto, 2 fused, 3 slab
static SIMD_OVERRIDE: AtomicU8 = AtomicU8::new(0); // 0 unset, 1 on, 2 off

/// Force an execution mode for every engine constructed after this call
/// (`None` restores env/default resolution). `serve --fused` maps here.
pub fn set_mode_override(mode: Option<ExecMode>) {
    let v = match mode {
        None => 0,
        Some(ExecMode::Auto) => 1,
        Some(ExecMode::Fused) => 2,
        Some(ExecMode::Slab) => 3,
    };
    MODE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Execution mode for new engines: process override, else the
/// `GLVQ_FUSED` environment variable (`0`/`slab` → slab, `1`/`fused` →
/// fused), else [`ExecMode::Auto`].
pub fn resolve_mode() -> ExecMode {
    match MODE_OVERRIDE.load(Ordering::Relaxed) {
        1 => return ExecMode::Auto,
        2 => return ExecMode::Fused,
        3 => return ExecMode::Slab,
        _ => {}
    }
    match std::env::var("GLVQ_FUSED").ok().as_deref() {
        Some("0") | Some("slab") | Some("false") => ExecMode::Slab,
        Some("1") | Some("fused") | Some("true") => ExecMode::Fused,
        _ => ExecMode::Auto,
    }
}

/// Force SIMD lane reduction on/off for new engines (`None` restores
/// env/default). Only effective when built with `--features simd`.
pub fn set_simd_override(on: Option<bool>) {
    let v = match on {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    };
    SIMD_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Whether new engines use the SIMD dot reduction: requires the `simd`
/// feature, then process override, then `GLVQ_SIMD=1`. Default off even
/// when compiled in, so default-mode results stay bit-identical to the
/// scalar path under every feature configuration.
pub fn resolve_simd() -> bool {
    if !cfg!(feature = "simd") {
        return false;
    }
    match SIMD_OVERRIDE.load(Ordering::Relaxed) {
        1 => return true,
        2 => return false,
        _ => {}
    }
    matches!(std::env::var("GLVQ_SIMD").ok().as_deref(), Some("1") | Some("true"))
}

/// Kill switch for the code→vector decode tables (`GLVQ_LUT=0`): fused
/// execution then always decodes directly. Tables change nothing
/// numerically — entries are produced by the same decoder — so this is a
/// memory/debug knob, not a correctness one.
pub fn lut_enabled() -> bool {
    !matches!(std::env::var("GLVQ_LUT").ok().as_deref(), Some("0") | Some("false"))
}

/// Per-group decode acceleration structures, built once per batch (or
/// once per shard worker) and shared read-only across decode threads:
/// the rANS symbol table for entropy payloads plus, when the family is
/// eligible and warm, the fused kernel's code→vector table.
#[derive(Default)]
pub struct GroupTables {
    /// rANS decode table (entropy-coded payloads only)
    pub rans: Option<DecodeTable>,
    /// direct-indexed code→decoded-block table ([`lut::LutTable`])
    pub lut: Option<Arc<lut::LutTable>>,
}

/// Per-worker scratch buffers, reused across panels, groups and batches
/// (allocation-free steady state). One instance per decode worker, each
/// worker locking only its own slot.
#[derive(Default)]
pub struct KernelScratch {
    /// decoded integer codes for one panel
    pub codes_buf: Vec<i32>,
    /// decoded f32 weights for one panel (slab path)
    pub panel: Vec<f32>,
    /// lattice-decode scratch: codes as f32 blocks (+½) for the blocked
    /// matmul path (§Perf: scalar per-block loops → one (B×d)@(d×d) GEMM)
    pub zf: Vec<f32>,
    /// rANS chunk-decode scratch (reused across panels and groups)
    pub rans_scratch: Vec<i32>,
    /// fused path: one row of integer codes (tile-granular unpack)
    pub row_codes: Vec<i32>,
    /// fused path: the L1-resident decoded tile
    pub row_buf: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_override_wins_over_default() {
        // note: tests run in one process — restore the unset state so
        // other tests constructing engines see default resolution
        set_mode_override(Some(ExecMode::Slab));
        assert_eq!(resolve_mode(), ExecMode::Slab);
        set_mode_override(Some(ExecMode::Fused));
        assert_eq!(resolve_mode(), ExecMode::Fused);
        set_mode_override(None);
        assert_eq!(resolve_mode(), ExecMode::Auto);
    }

    #[test]
    fn simd_defaults_off_for_bit_exactness() {
        // default resolution (no override, no env) must be scalar under
        // every feature configuration — SIMD is strictly opt-in, so the
        // bit-exact oracle suites hold with and without `--features simd`.
        // (Deliberately does not flip the global override: tests share the
        // process, and a transient SIMD default would race the parity
        // suites. Mode overrides are safe to flip — every mode is
        // bit-identical — so the test above exercises that path.)
        let env_on = matches!(std::env::var("GLVQ_SIMD").ok().as_deref(), Some("1") | Some("true"));
        assert!(!resolve_simd() || env_on);
    }

    #[test]
    fn mode_names_are_stable() {
        assert_eq!(ExecMode::Auto.name(), "auto");
        assert_eq!(ExecMode::Fused.name(), "fused");
        assert_eq!(ExecMode::Slab.name(), "slab");
    }
}
