//! Radix index over token prefixes → shared arena pages.
//!
//! Each node covers exactly one page worth of tokens (`page_rows`) and
//! records the arena page ids holding that token range's K/V rows for
//! every (layer, K|V) stream. A path from a root to a node therefore
//! spells out a token prefix whose cached state can be claimed by a new
//! sequence instead of re-prefilled — the vLLM-style radix cache, here
//! over GLVQ-quantizable pages.
//!
//! The index owns **no** storage and performs **no** refcounting itself:
//! it stores page ids and per-node bookkeeping (`live` attachment counts
//! and LRU stamps), while [`super::paged::PagedKvCache`] moves the arena
//! refcounts in lockstep. Keeping the structure pure makes the
//! refcounting invariants auditable in one place
//! (`PagedKvCache::check_invariants`).
//!
//! Liveness is hierarchical by construction: sequences attach to every
//! node along their claimed path, so a node with live descendants is
//! itself live. Cold (live == 0) nodes are the only eviction candidates,
//! peeled leaf-first in LRU order.

/// One radix node: a page-aligned token range and its shared pages.
pub(super) struct PrefixNode {
    /// exactly `page_rows` tokens extending the parent's prefix
    pub key: Vec<i32>,
    /// arena page ids, stream-major (`2·layer + Kv::index()`)
    pub pages: Vec<usize>,
    /// child node ids (keys are distinct among siblings)
    pub children: Vec<usize>,
    /// `None` for a root node
    pub parent: Option<usize>,
    /// live sequences currently attached to this node's pages
    pub live: u32,
    /// logical LRU stamp (monotone tick, not wall time)
    pub last_used: u64,
}

/// The prefix index: a slab of nodes plus counters surfaced through
/// `KvCacheStats`.
pub(super) struct PrefixIndex {
    nodes: Vec<Option<PrefixNode>>,
    vacant: Vec<usize>,
    roots: Vec<usize>,
    tick: u64,
    /// prefix lookups attempted (one per shared sequence registration)
    pub lookups: usize,
    /// lookups that claimed at least one row
    pub hits: usize,
    /// rows claimed from shared pages (cumulative)
    pub hit_rows: usize,
    /// copy-on-write splits of a mid-page divergence (cumulative)
    pub cow_splits: usize,
    /// cold nodes evicted under page pressure (cumulative)
    pub evictions: usize,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex {
            nodes: Vec::new(),
            vacant: Vec::new(),
            roots: Vec::new(),
            tick: 0,
            lookups: 0,
            hits: 0,
            hit_rows: 0,
            cow_splits: 0,
            evictions: 0,
        }
    }

    pub fn node(&self, ni: usize) -> &PrefixNode {
        self.nodes[ni].as_ref().expect("live prefix node")
    }

    fn node_mut(&mut self, ni: usize) -> &mut PrefixNode {
        self.nodes[ni].as_mut().expect("live prefix node")
    }

    fn child_ids(&self, parent: Option<usize>) -> &[usize] {
        match parent {
            Some(p) => &self.node(p).children,
            None => &self.roots,
        }
    }

    /// Exact-key child lookup under `parent` (`None` = the roots).
    pub fn find_child(&self, parent: Option<usize>, key: &[i32]) -> Option<usize> {
        self.child_ids(parent).iter().copied().find(|&ni| self.node(ni).key == key)
    }

    /// Child of `parent` sharing the longest non-empty common prefix with
    /// `want`, for the copy-on-write split at a mid-page divergence.
    /// Returns `(node, common_len)`; `common_len ≤ want.len()`.
    pub fn best_partial(&self, parent: Option<usize>, want: &[i32]) -> Option<(usize, usize)> {
        let mut best = None;
        let mut best_m = 0usize;
        for &ni in self.child_ids(parent) {
            let key = &self.node(ni).key;
            let m = key.iter().zip(want).take_while(|(a, b)| a == b).count();
            if m > best_m {
                best = Some((ni, m));
                best_m = m;
            }
        }
        best
    }

    /// Insert a new node under `parent`. The caller has already taken the
    /// index's reference on every page.
    pub fn insert(&mut self, parent: Option<usize>, key: Vec<i32>, pages: Vec<usize>) -> usize {
        self.tick += 1;
        let node = PrefixNode {
            key,
            pages,
            children: Vec::new(),
            parent,
            live: 0,
            last_used: self.tick,
        };
        let ni = match self.vacant.pop() {
            Some(i) => {
                self.nodes[i] = Some(node);
                i
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        match parent {
            Some(p) => self.node_mut(p).children.push(ni),
            None => self.roots.push(ni),
        }
        ni
    }

    /// Detach a childless node from the tree and return it — the caller
    /// drops the index's page references.
    pub fn remove(&mut self, ni: usize) -> PrefixNode {
        let node = self.nodes[ni].take().expect("live prefix node");
        debug_assert!(node.children.is_empty(), "removing an interior prefix node");
        match node.parent {
            Some(p) => self.node_mut(p).children.retain(|&c| c != ni),
            None => self.roots.retain(|&c| c != ni),
        }
        self.vacant.push(ni);
        node
    }

    /// Record one live sequence attaching to this node.
    pub fn attach(&mut self, ni: usize) {
        self.tick += 1;
        let t = self.tick;
        let n = self.node_mut(ni);
        n.live += 1;
        n.last_used = t;
    }

    /// Drop one live attachment; true when the node went cold.
    pub fn detach(&mut self, ni: usize) -> bool {
        self.tick += 1;
        let t = self.tick;
        let n = self.node_mut(ni);
        debug_assert!(n.live > 0, "detach of a cold prefix node");
        n.live = n.live.saturating_sub(1);
        n.last_used = t;
        n.live == 0
    }

    /// Refresh a node's LRU stamp without attaching.
    pub fn touch(&mut self, ni: usize) {
        self.tick += 1;
        let t = self.tick;
        self.node_mut(ni).last_used = t;
    }

    /// Least-recently-used cold leaf — the only legal eviction victim.
    /// Cold interior nodes become leaves once their subtree is peeled.
    pub fn cold_lru_leaf(&self) -> Option<usize> {
        self.iter()
            .filter(|(_, n)| n.live == 0 && n.children.is_empty())
            .min_by_key(|(_, n)| n.last_used)
            .map(|(ni, _)| ni)
    }

    /// Arena pages held only by the index — reclaimable on demand, so
    /// they count as allocatable capacity for admission control.
    pub fn cold_pages(&self) -> usize {
        self.iter().filter(|(_, n)| n.live == 0).map(|(_, n)| n.pages.len()).sum()
    }

    /// Arena pages currently referenced by the index (cold or live).
    pub fn shared_pages(&self) -> usize {
        self.iter().map(|(_, n)| n.pages.len()).sum()
    }

    /// Live node count.
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    /// Slab capacity (for parallel bookkeeping arrays in audits).
    pub fn capacity(&self) -> usize {
        self.nodes.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (usize, &PrefixNode)> {
        self.nodes.iter().enumerate().filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
    }
}
