//! Quantized-page backend: compress retired KV pages with the paper's own
//! lattice + companding chain.
//!
//! A page is a `(page_rows × width)` f32 panel — exactly the shape of a
//! weight group, so it reuses the weight path end to end: group
//! normalization scale, kurtosis-driven μ-law companding
//! (`compand::MuLaw`, Eq. 12), a scaled-identity generation matrix from
//! `lattice::GenLattice`, encoding on the shifted half-integer grid
//! (`z = clamp(round(F_μ(w/s)/α − ½))`, the same convention as
//! `glvq::optimizer`), and `quant::pack` fixed-width payloads with
//! optional rANS entropy coding. Decoding is *not* reimplemented: pages
//! are stored as `quant::traits::QuantizedGroup` with
//! `SideInfo::Lattice`, so `dequantize_into` — the decoder every other
//! path in the crate uses and tests — reconstructs them.

use crate::compand::MuLaw;
use crate::entropy::stream::DEFAULT_LANES;
use crate::lattice::GenLattice;
use crate::quant::pack::{clamp_code, code_range, PackedCodes};
use crate::quant::traits::{CodePayload, QuantizedGroup, SideInfo};

/// Fast grouped-lattice page compressor (runs on the serving hot path, so
/// the generation matrix is fixed to a scaled identity instead of being
/// optimized per page — "GLVQ-lite", matching the fixed-lattice ablation).
#[derive(Clone, Copy, Debug)]
pub struct KvQuantizer {
    /// code width per element (1..=8)
    pub bits: u8,
    /// lattice sub-block length d; falls back to 1 when it does not
    /// divide the page width
    pub lattice_dim: usize,
    /// rANS entropy-code the packed codes (one chunk per page)
    pub entropy: bool,
}

impl KvQuantizer {
    /// Compress one full page (`rows × width`, row-major) into a
    /// [`QuantizedGroup`] whose `dequantize` reproduces the page within
    /// the lattice step (bounds pinned by the tests below).
    pub fn quantize_page(&self, data: &[f32], rows: usize, width: usize) -> QuantizedGroup {
        let _sp = crate::span!("kv_quantize_page");
        assert_eq!(data.len(), rows * width, "page shape mismatch");
        let bits = self.bits.clamp(1, 8);
        let d = if width % self.lattice_dim == 0 { self.lattice_dim } else { 1 };
        // group normalization: bring the page into [-1, 1]
        let scale = data.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-12);
        // kurtosis-driven companding init (Eq. 12)
        let comp = MuLaw::init_from_kurtosis(data);
        // scaled-identity lattice sized so the half-integer grid α(z+½)
        // spans the companded range edge to edge
        let (_, hi) = code_range(bits);
        let alpha = 1.0 / (hi as f32 + 0.5);
        let lat = GenLattice::scaled_identity(d, alpha);
        // encode on the shifted grid (diagonal G ⇒ Babai rounding is an
        // elementwise round): z = clamp(round(F_μ(w/s)/α − ½))
        let codes: Vec<i32> = data
            .iter()
            .map(|&w| clamp_code(comp.forward(w / scale) / alpha - 0.5, bits))
            .collect();
        let packed = PackedCodes::pack(&codes, bits);
        let payload: CodePayload = if self.entropy {
            CodePayload::Fixed(packed).to_entropy(rows * width, DEFAULT_LANES)
        } else {
            packed.into()
        };
        QuantizedGroup {
            method: "kv-glvq",
            bits,
            rows,
            cols: width,
            codes: payload,
            side: SideInfo::Lattice { d, g: lat.g.data, mu: comp.mu, scale },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{Kv, KvCacheOpts, PagedKvCache};
    use crate::util::rng::Rng;

    fn page(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * scale).collect()
    }

    /// max and rms reconstruction error of one page round-trip, as a
    /// fraction of the page's max-abs.
    fn roundtrip_err(bits: u8, seed: u64) -> (f32, f32) {
        let mut rng = Rng::new(seed);
        let data = page(&mut rng, 16 * 32, 0.7);
        let q = KvQuantizer { bits, lattice_dim: 8, entropy: false };
        let g = q.quantize_page(&data, 16, 32);
        let rec = g.dequantize();
        let mx = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let mut worst = 0.0f32;
        let mut sq = 0.0f64;
        for (a, b) in data.iter().zip(&rec.data) {
            let e = (a - b).abs();
            worst = worst.max(e);
            sq += (e as f64) * (e as f64);
        }
        let rms = (sq / data.len() as f64).sqrt() as f32;
        (worst / mx, rms / mx)
    }

    #[test]
    fn page_roundtrip_error_is_bounded() {
        // 8-bit pages: the half-integer grid step is 1/127.5 in companded
        // space; even after μ-law expansion the relative error stays tiny
        let (max8, rms8) = roundtrip_err(8, 3);
        assert!(max8 < 0.08, "8-bit max err {max8}");
        assert!(rms8 < 0.02, "8-bit rms err {rms8}");
        // 4-bit pages: coarser but still bounded well below the signal
        let (max4, rms4) = roundtrip_err(4, 4);
        assert!(max4 < 0.6, "4-bit max err {max4}");
        assert!(rms4 < 0.12, "4-bit rms err {rms4}");
        // more bits must not be worse
        assert!(rms8 < rms4);
    }

    #[test]
    fn entropy_payload_decodes_identically() {
        let mut rng = Rng::new(7);
        let data = page(&mut rng, 8 * 16, 0.3);
        let fixed = KvQuantizer { bits: 4, lattice_dim: 8, entropy: false };
        let rans = KvQuantizer { bits: 4, lattice_dim: 8, entropy: true };
        let a = fixed.quantize_page(&data, 8, 16);
        let b = rans.quantize_page(&data, 8, 16);
        assert!(b.codes.is_entropy());
        assert_eq!(
            a.dequantize().data,
            b.dequantize().data,
            "rANS page payload must be lossless"
        );
    }

    #[test]
    fn width_not_divisible_by_lattice_dim_falls_back_to_d1() {
        let mut rng = Rng::new(9);
        let data = page(&mut rng, 4 * 10, 0.5);
        let q = KvQuantizer { bits: 6, lattice_dim: 8, entropy: false };
        let g = q.quantize_page(&data, 4, 10);
        match &g.side {
            SideInfo::Lattice { d, .. } => assert_eq!(*d, 1),
            other => panic!("unexpected side info {other:?}"),
        }
        // still reconstructs
        let rec = g.dequantize();
        let mx = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in data.iter().zip(&rec.data) {
            assert!((a - b).abs() < 0.2 * mx);
        }
    }

    #[test]
    fn cache_quantizes_retired_pages_and_keeps_hot_tail_f32() {
        let opts = KvCacheOpts {
            page_rows: 4,
            quantize: true,
            kv_bits: 8,
            lattice_dim: 8,
            ..Default::default()
        };
        let width = 32;
        let mut c = PagedKvCache::new(1, width, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(11);
        let mut want: Vec<f32> = Vec::new();
        for _ in 0..10 {
            let r: Vec<f32> = (0..width).map(|_| rng.normal_f32()).collect();
            c.append(s, 0, Kv::K, &r).unwrap();
            want.extend_from_slice(&r);
        }
        let st = c.stats();
        // 10 rows over 4-row pages: two full pages retired, one hot tail
        assert_eq!(st.pages_quantized, 2);
        assert_eq!(st.hot_pages, 1);
        assert_eq!(st.pages_in_use, 3);
        assert!(st.quantized_payload_bytes > 0);
        // reads decode quantized pages (approximately) and pass the hot
        // tail through exactly
        let mut got: Vec<f32> = Vec::new();
        c.visit(s, 0, Kv::K, 10, |_, rows| got.extend_from_slice(rows));
        assert_eq!(got.len(), want.len());
        let quantized_elems = 8 * width; // the two retired pages
        let mx = want.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in want.iter().zip(&got).take(quantized_elems) {
            assert!((a - b).abs() < 0.1 * mx, "quantized page drifted: {a} vs {b}");
        }
        assert_eq!(
            &got[quantized_elems..],
            &want[quantized_elems..],
            "hot tail must stay bit-exact"
        );
        assert!(c.stats().decoded_bytes > 0);
        // quantized pages shrink the resident footprint below all-f32
        let f32_page_bytes = 4 * width * 4;
        assert!(c.bytes_in_use() < 3 * f32_page_bytes);
    }
}
