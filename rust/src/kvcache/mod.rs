//! Paged, GLVQ-quantized KV-cache runtime — quantized *state*, not just
//! quantized weights.
//!
//! A decode step without a KV cache re-runs attention over the whole
//! prefix, so serving cost grows O(T²) per sequence. This subsystem makes
//! decode O(T) and then applies the paper's own grouped-lattice machinery
//! to the cached K/V tensors:
//!
//! - [`paged::PagedKvCache`] holds per-layer K/V rows in fixed-size block
//!   pages drawn from one shared arena with a free-list allocator, so
//!   batched lockstep sequences of different lengths share storage and
//!   release it on eviction.
//! - [`quantized::KvQuantizer`] compresses retired (full) pages with the
//!   same lattice + μ-law companding chain the weight quantizer uses
//!   (scaled-identity generation matrix, half-integer grid, kurtosis-driven
//!   μ — see `quant::traits::SideInfo::Lattice`), optionally rANS
//!   entropy-coded. Only the hot tail page of each page table stays f32;
//!   attention reads decode quantized pages one at a time into a
//!   cache-owned scratch, mirroring `coordinator::decode_stream`'s
//!   bounded-working-set discipline.
//! - `eval::native_fwd::forward_incremental` drives the cache: one-token
//!   steps compute attention scores only for the new position against the
//!   cached prefix, bit-identical to the full recompute when pages stay
//!   f32 (tested in `tests/kvcache_parity.rs`).
//! - [`paged::PagedKvCache::spill`] / [`paged::PagedKvCache::restore`]
//!   move a whole sequence out of (and back into) the arena for scheduler
//!   preemption: bit-exact when pages stay f32, or compressed on the way
//!   out (*quantize-to-spill*) so a parked sequence costs a fraction of
//!   its hot footprint. [`paged::PagedKvCache::free_pages`] and the page
//!   watermark give admission control a direct occupancy signal.
//! - With [`KvCacheOpts::prefix_share`], arena pages are refcounted and a
//!   radix index over token prefixes lets new sequences **claim** the
//!   longest cached prefix of their prompt instead of re-prefilling it
//!   ([`paged::PagedKvCache::new_seq_shared`] /
//!   [`paged::PagedKvCache::publish_prefix`]): full-page matches attach
//!   by reference, mid-page divergences copy-on-write split, departed
//!   prefixes stay resident cold (LRU-evicted only under page pressure)
//!   and optionally retire through the lattice quantizer while cold
//!   (quantize-on-share).
//!
//! The serving integration lives in `coordinator::server::CachedNativeBackend`
//! (prefill once, then batched one-token lockstep steps) and surfaces
//! occupancy / quantization / decode-traffic counters through
//! [`KvCacheStats`] into `coordinator::metrics::ServerMetrics`.

pub mod paged;
mod prefix;
pub mod quantized;

pub use paged::{Kv, PagedKvCache, SeqId, SpilledSeq};
pub use quantized::KvQuantizer;

/// KV-cache construction options.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheOpts {
    /// positions per page (the fixed block size of the arena)
    pub page_rows: usize,
    /// compress retired pages with the grouped lattice quantizer
    pub quantize: bool,
    /// code width for quantized pages (1..=8 bits per element)
    pub kv_bits: u8,
    /// lattice sub-block length d (falls back to 1 when the model width is
    /// not divisible by it)
    pub lattice_dim: usize,
    /// rANS entropy-code the packed page codes (smaller resident bytes,
    /// same decoded values)
    pub entropy: bool,
    /// hard arena capacity in pages; 0 = grow on demand
    pub max_pages: usize,
    /// refcount pages and share token prefixes through the radix index
    /// (claim on registration, publish on completion)
    pub prefix_share: bool,
    /// re-encode cold shared prefix pages through the lattice quantizer
    /// once their last live sequence departs (quantize-on-share); later
    /// claims decode the `SideInfo::Lattice` representation, trading the
    /// bit-exact guarantee for a smaller resident cold cache
    pub quantize_shared: bool,
}

impl Default for KvCacheOpts {
    fn default() -> Self {
        KvCacheOpts {
            page_rows: 16,
            quantize: false,
            kv_bits: 4,
            lattice_dim: 8,
            entropy: false,
            max_pages: 0,
            prefix_share: false,
            quantize_shared: false,
        }
    }
}

/// Cache counters surfaced through `ServerMetrics` and the kvcache bench.
///
/// `pages_in_use` / `hot_pages` / `peak_pages` describe current occupancy;
/// the remaining fields are cumulative over the cache's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvCacheStats {
    /// pages currently allocated to some sequence (hot + quantized)
    pub pages_in_use: usize,
    /// high-water mark of `pages_in_use`
    pub peak_pages: usize,
    /// pages currently resident as raw f32 (the hot tails)
    pub hot_pages: usize,
    /// resident cache bytes right now: hot pages at f32 plus the
    /// compressed payloads of live quantized pages
    pub bytes_in_use: usize,
    /// pages retired through the lattice quantizer (cumulative)
    pub pages_quantized: usize,
    /// K/V rows appended (cumulative)
    pub appended_rows: usize,
    /// f32 bytes materialized from quantized pages on attention reads
    /// (cumulative)
    pub decoded_bytes: usize,
    /// compressed bytes (codes + side info) produced by page quantization
    /// (cumulative)
    pub quantized_payload_bytes: usize,
    /// pages moved out of the arena by sequence preemption (cumulative) —
    /// see [`PagedKvCache::spill`]
    pub pages_spilled: usize,
    /// spilled pages moved back into the arena on resume (cumulative) —
    /// see [`PagedKvCache::restore`]
    pub pages_restored: usize,
    /// arena pages currently referenced by the prefix index (cold or
    /// attached to live sequences)
    pub shared_pages: usize,
    /// prefix-index nodes currently resident
    pub shared_nodes: usize,
    /// shared-prefix lookups attempted (one per shared registration,
    /// cumulative)
    pub prefix_lookups: usize,
    /// lookups that claimed at least one cached row (cumulative)
    pub prefix_hits: usize,
    /// K/V positions claimed from shared pages instead of re-prefilled
    /// (cumulative)
    pub prefix_hit_rows: usize,
    /// copy-on-write splits at mid-page divergences (cumulative)
    pub cow_splits: usize,
    /// cold prefix nodes evicted under page pressure (cumulative)
    pub prefix_evictions: usize,
}
