//! Paged KV storage: fixed-size block pages in one shared arena with a
//! free-list allocator, plus per-sequence page tables.
//!
//! Every (sequence, layer, K|V) triple owns a page table: an ordered
//! list of page ids covering positions `[0, rows)`. Appends write into the
//! hot tail page; when a page fills it is *retired* — if quantization is
//! enabled the page is compressed through [`super::KvQuantizer`] and its
//! f32 buffer returns to a spare pool, so steady-state appends allocate
//! nothing. Eviction returns a sequence's pages to the free list, which is
//! how lockstep batches of different lengths share one arena.
//!
//! Reads go through [`PagedKvCache::visit`], which walks a table page by
//! page in position order. Quantized pages decode into a cache-owned
//! scratch one page at a time — the peak decoded working set is a single
//! page, the same bounded-materialization discipline as
//! `coordinator::decode_stream`.

use anyhow::{bail, Result};

use crate::linalg::Mat;
use crate::quant::traits::QuantizedGroup;

use super::quantized::KvQuantizer;
use super::{KvCacheOpts, KvCacheStats};

/// Which of the two per-layer tensors a page table tracks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kv {
    /// attention keys
    K,
    /// attention values
    V,
}

impl Kv {
    fn index(self) -> usize {
        match self {
            Kv::K => 0,
            Kv::V => 1,
        }
    }
}

/// Opaque handle to one cached sequence (stable until [`PagedKvCache::evict`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqId(usize);

/// One page's storage state.
enum PageSlot {
    /// unallocated (on the free list)
    Free,
    /// raw f32 rows (`page_rows × width`), the mutable hot form
    Hot(Vec<f32>),
    /// retired page compressed by the grouped lattice quantizer
    Quantized(QuantizedGroup),
}

/// The shared page store: slots + free list + spare f32 buffers.
struct PageArena {
    page_rows: usize,
    width: usize,
    slots: Vec<PageSlot>,
    free: Vec<usize>,
    /// f32 buffers from retired/freed pages, reused by later allocs
    spare: Vec<Vec<f32>>,
    max_pages: usize,
    hot_pages: usize,
    live_quantized_bytes: usize,
    peak_pages: usize,
}

impl PageArena {
    fn new(page_rows: usize, width: usize, max_pages: usize) -> PageArena {
        PageArena {
            page_rows,
            width,
            slots: Vec::new(),
            free: Vec::new(),
            spare: Vec::new(),
            max_pages,
            hot_pages: 0,
            live_quantized_bytes: 0,
            peak_pages: 0,
        }
    }

    fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    fn page_bytes(&self) -> usize {
        self.page_rows * self.width * 4
    }

    /// Claim an empty slot id: reuse a freed slot when possible, grow the
    /// arena otherwise (respecting `max_pages`).
    fn slot_id(&mut self) -> Result<usize> {
        match self.free.pop() {
            Some(id) => Ok(id),
            None => {
                if self.max_pages > 0 && self.slots.len() >= self.max_pages {
                    bail!("kv-cache arena exhausted ({} pages)", self.max_pages);
                }
                self.slots.push(PageSlot::Free);
                Ok(self.slots.len() - 1)
            }
        }
    }

    /// Allocate a zeroed hot page: reuse a freed slot (and a spare buffer)
    /// when possible, grow the arena otherwise.
    fn alloc(&mut self) -> Result<usize> {
        let id = self.slot_id()?;
        let buf = match self.spare.pop() {
            Some(mut b) => {
                b.fill(0.0);
                b
            }
            None => vec![0.0f32; self.page_rows * self.width],
        };
        self.slots[id] = PageSlot::Hot(buf);
        self.hot_pages += 1;
        self.peak_pages = self.peak_pages.max(self.in_use());
        Ok(id)
    }

    /// Install an existing f32 buffer (a spilled page coming home) into a
    /// fresh slot without zeroing it.
    fn adopt_hot(&mut self, buf: Vec<f32>) -> Result<usize> {
        let id = self.slot_id()?;
        self.slots[id] = PageSlot::Hot(buf);
        self.hot_pages += 1;
        self.peak_pages = self.peak_pages.max(self.in_use());
        Ok(id)
    }

    /// Install an already-compressed page into a fresh slot.
    fn adopt_quantized(&mut self, g: QuantizedGroup) -> Result<usize> {
        let id = self.slot_id()?;
        self.live_quantized_bytes += g.codes.payload_bytes() + g.side_bytes();
        self.slots[id] = PageSlot::Quantized(g);
        self.peak_pages = self.peak_pages.max(self.in_use());
        Ok(id)
    }

    /// Return a page to the free list (its f32 buffer goes to the spare
    /// pool; a quantized payload is dropped).
    fn free(&mut self, id: usize) {
        match std::mem::replace(&mut self.slots[id], PageSlot::Free) {
            PageSlot::Hot(buf) => {
                self.hot_pages -= 1;
                self.spare.push(buf);
            }
            PageSlot::Quantized(g) => {
                self.live_quantized_bytes -= g.codes.payload_bytes() + g.side_bytes();
            }
            PageSlot::Free => return,
        }
        self.free.push(id);
    }
}

/// Ordered page list for one (sequence, layer, K|V) stream.
#[derive(Default)]
struct PageTable {
    pages: Vec<usize>,
    rows: usize,
}

struct SeqSlot {
    /// index = `2·layer + Kv::index()`
    tables: Vec<PageTable>,
}

/// One page moved out of the arena by [`PagedKvCache::spill`].
#[derive(Debug)]
enum SpilledPage {
    /// bit-exact f32 rows (`page_rows × width`)
    Raw(Vec<f32>),
    /// lattice-compressed payload: pages that were already retired keep
    /// theirs; hot pages are compressed on spill when quantization is
    /// requested (quantize-to-spill)
    Coded(QuantizedGroup),
}

/// A preempted sequence's complete KV state, self-contained outside the
/// arena: every page of every (layer, K|V) stream plus the row counts
/// needed to rebuild the page tables. Produced by [`PagedKvCache::spill`],
/// consumed by [`PagedKvCache::restore`]. Holding one of these costs no
/// arena pages — that is the point: the scheduler parks low-priority
/// sequences here when the arena runs dry and resumes them later.
#[derive(Debug)]
pub struct SpilledSeq {
    /// per-(layer, K|V) stream in `2·layer + Kv::index()` order
    tables: Vec<(Vec<SpilledPage>, usize)>,
    /// arena pages this sequence occupied (and needs again to resume)
    pages: usize,
}

impl SpilledSeq {
    /// Arena pages [`PagedKvCache::restore`] will need.
    pub fn pages(&self) -> usize {
        self.pages
    }

    /// Cached positions per stream (every stream of a spilled sequence
    /// holds the same number of rows).
    pub fn rows(&self) -> usize {
        self.tables.first().map(|t| t.1).unwrap_or(0)
    }

    /// Resident bytes of the spilled payload: f32 pages at full width,
    /// compressed pages at codes + side info.
    pub fn bytes(&self) -> usize {
        self.tables
            .iter()
            .flat_map(|(pages, _)| pages.iter())
            .map(|p| match p {
                SpilledPage::Raw(buf) => buf.len() * 4,
                SpilledPage::Coded(g) => g.codes.payload_bytes() + g.side_bytes(),
            })
            .sum()
    }
}

/// The paged (optionally GLVQ-quantized) KV cache — see [`crate::kvcache`]
/// for the runtime story.
pub struct PagedKvCache {
    opts: KvCacheOpts,
    n_layer: usize,
    width: usize,
    arena: PageArena,
    seqs: Vec<Option<SeqSlot>>,
    quantizer: KvQuantizer,
    /// per-cache decode scratch (one page), reused across reads
    scratch: Mat,
    pages_quantized: usize,
    appended_rows: usize,
    decoded_bytes: usize,
    quantized_payload_bytes: usize,
    pages_spilled: usize,
    pages_restored: usize,
}

impl PagedKvCache {
    /// Create a cache for `n_layer` transformer layers of row width
    /// `width` (= `d_model`).
    pub fn new(n_layer: usize, width: usize, opts: KvCacheOpts) -> PagedKvCache {
        assert!(width > 0, "kv cache width must be positive");
        let opts = KvCacheOpts { page_rows: opts.page_rows.max(1), ..opts };
        let quantizer = KvQuantizer {
            bits: opts.kv_bits.clamp(1, 8),
            lattice_dim: opts.lattice_dim.max(1),
            entropy: opts.entropy,
        };
        PagedKvCache {
            arena: PageArena::new(opts.page_rows, width, opts.max_pages),
            scratch: Mat::zeros(opts.page_rows, width),
            opts,
            n_layer,
            width,
            seqs: Vec::new(),
            quantizer,
            pages_quantized: 0,
            appended_rows: 0,
            decoded_bytes: 0,
            quantized_payload_bytes: 0,
            pages_spilled: 0,
            pages_restored: 0,
        }
    }

    /// Register a new (empty) sequence, reusing a vacated slot when one
    /// exists.
    pub fn new_seq(&mut self) -> SeqId {
        let tables: Vec<PageTable> = (0..2 * self.n_layer).map(|_| PageTable::default()).collect();
        match self.seqs.iter().position(|s| s.is_none()) {
            Some(i) => {
                self.seqs[i] = Some(SeqSlot { tables });
                SeqId(i)
            }
            None => {
                self.seqs.push(Some(SeqSlot { tables }));
                SeqId(self.seqs.len() - 1)
            }
        }
    }

    /// Drop a sequence and return all of its pages to the free list.
    pub fn evict(&mut self, seq: SeqId) {
        if let Some(slot) = self.seqs.get_mut(seq.0).and_then(|s| s.take()) {
            for t in slot.tables {
                for pid in t.pages {
                    self.arena.free(pid);
                }
            }
        }
    }

    /// Cached positions for one (sequence, layer, K|V) stream.
    pub fn rows(&self, seq: SeqId, layer: usize, which: Kv) -> usize {
        self.seqs
            .get(seq.0)
            .and_then(|s| s.as_ref())
            .map(|s| s.tables[2 * layer + which.index()].rows)
            .unwrap_or(0)
    }

    /// Positions per page.
    pub fn page_rows(&self) -> usize {
        self.opts.page_rows
    }

    /// Row width (= `d_model`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Total slots ever allocated in the arena (free or not) — the arena's
    /// high-water capacity.
    pub fn arena_pages(&self) -> usize {
        self.arena.slots.len()
    }

    /// Resident cache bytes right now: hot pages at f32 plus the
    /// compressed payloads of live quantized pages.
    pub fn bytes_in_use(&self) -> usize {
        self.arena.hot_pages * self.arena.page_bytes() + self.arena.live_quantized_bytes
    }

    /// Current + cumulative counters (see [`KvCacheStats`]).
    pub fn stats(&self) -> KvCacheStats {
        KvCacheStats {
            pages_in_use: self.arena.in_use(),
            peak_pages: self.arena.peak_pages,
            hot_pages: self.arena.hot_pages,
            bytes_in_use: self.bytes_in_use(),
            pages_quantized: self.pages_quantized,
            appended_rows: self.appended_rows,
            decoded_bytes: self.decoded_bytes,
            quantized_payload_bytes: self.quantized_payload_bytes,
            pages_spilled: self.pages_spilled,
            pages_restored: self.pages_restored,
        }
    }

    /// Pages still allocatable before the arena cap is hit: free-list
    /// slots plus untapped growth headroom. `None` when the arena is
    /// unbounded (`max_pages == 0`). This is the scheduler's admission
    /// signal — occupancy read directly, not inferred from counters.
    pub fn free_pages(&self) -> Option<usize> {
        if self.opts.max_pages == 0 {
            None
        } else {
            Some(
                self.arena.free.len()
                    + self.opts.max_pages.saturating_sub(self.arena.slots.len()),
            )
        }
    }

    /// Hard arena capacity in pages (`None` = unbounded).
    pub fn page_capacity(&self) -> Option<usize> {
        if self.opts.max_pages == 0 {
            None
        } else {
            Some(self.opts.max_pages)
        }
    }

    /// High-water mark of pages simultaneously in use over the cache's
    /// lifetime.
    pub fn high_watermark(&self) -> usize {
        self.arena.peak_pages
    }

    /// Extra arena pages required to append `n_new` rows to **every**
    /// (layer, K|V) stream of a sequence currently holding `rows` rows —
    /// exact, because the incremental forward appends the same number of
    /// rows to all `2·n_layer` streams of a sequence.
    pub fn pages_needed(&self, rows: usize, n_new: usize) -> usize {
        let pr = self.opts.page_rows;
        2 * self.n_layer * ((rows + n_new).div_ceil(pr) - rows.div_ceil(pr))
    }

    /// Preempt a sequence: move every one of its pages out of the arena
    /// into a self-contained [`SpilledSeq`] and return all of its slots to
    /// the free list. Already-quantized pages keep their compressed
    /// payload; hot f32 pages are either moved out verbatim
    /// (`quantize = false`, bit-exact on [`PagedKvCache::restore`]) or
    /// compressed through the lattice quantizer on the way out
    /// (`quantize = true`, quantize-to-spill — smaller parked footprint at
    /// the documented KV reconstruction tolerance).
    pub fn spill(&mut self, seq: SeqId, quantize: bool) -> Result<SpilledSeq> {
        let _sp = crate::span!("kv_spill");
        let slot = match self.seqs.get_mut(seq.0).and_then(|s| s.take()) {
            Some(slot) => slot,
            None => bail!("spill of unknown kv sequence {seq:?}"),
        };
        let mut tables = Vec::with_capacity(slot.tables.len());
        let mut pages = 0usize;
        for t in slot.tables {
            let mut spilled = Vec::with_capacity(t.pages.len());
            for pid in t.pages {
                pages += 1;
                match std::mem::replace(&mut self.arena.slots[pid], PageSlot::Free) {
                    PageSlot::Hot(buf) => {
                        self.arena.hot_pages -= 1;
                        if quantize {
                            let g = self.quantizer.quantize_page(
                                &buf,
                                self.opts.page_rows,
                                self.width,
                            );
                            self.pages_quantized += 1;
                            self.quantized_payload_bytes +=
                                g.codes.payload_bytes() + g.side_bytes();
                            self.arena.spare.push(buf);
                            spilled.push(SpilledPage::Coded(g));
                        } else {
                            spilled.push(SpilledPage::Raw(buf));
                        }
                    }
                    PageSlot::Quantized(g) => {
                        self.arena.live_quantized_bytes -=
                            g.codes.payload_bytes() + g.side_bytes();
                        spilled.push(SpilledPage::Coded(g));
                    }
                    PageSlot::Free => unreachable!("page table points at a freed page"),
                }
                self.arena.free.push(pid);
            }
            tables.push((spilled, t.rows));
        }
        self.pages_spilled += pages;
        Ok(SpilledSeq { tables, pages })
    }

    /// Resume a spilled sequence: re-allocate its pages and rebuild its
    /// page tables under a fresh [`SeqId`]. Full compressed pages re-enter
    /// the arena still compressed (no decode cost); the partial tail page
    /// of each stream must accept future appends, so it comes back hot —
    /// decoded from its payload if it was spilled compressed. Capacity is
    /// checked up front: when the arena lacks the pages, the **untouched**
    /// [`SpilledSeq`] comes back in `Err`, so the caller retries after
    /// more evictions — a failed resume never destroys the parked KV
    /// state (it is the sequence's only copy).
    #[allow(clippy::result_large_err)]
    pub fn restore(&mut self, sp: SpilledSeq) -> std::result::Result<SeqId, SpilledSeq> {
        let _sp = crate::span!("kv_restore");
        if let Some(free) = self.free_pages() {
            if sp.pages > free {
                return Err(sp);
            }
        }
        let pr = self.opts.page_rows;
        let sid = self.new_seq();
        let pages = sp.pages;
        for (ti, (spilled, rows)) in sp.tables.into_iter().enumerate() {
            let n = spilled.len();
            for (i, page) in spilled.into_iter().enumerate() {
                let tail_partial = i + 1 == n && rows % pr != 0;
                // the capacity precheck reserves every slot these calls
                // claim, so allocation cannot fail below
                let pid = match page {
                    SpilledPage::Raw(buf) => {
                        self.arena.adopt_hot(buf).expect("precheck reserved pages")
                    }
                    SpilledPage::Coded(g) if !tail_partial => {
                        self.arena.adopt_quantized(g).expect("precheck reserved pages")
                    }
                    SpilledPage::Coded(g) => {
                        // appendable tail: decode back to a hot f32 page
                        let pid = self.arena.alloc().expect("precheck reserved pages");
                        g.dequantize_into(&mut self.scratch);
                        self.decoded_bytes += pr * self.width * 4;
                        match &mut self.arena.slots[pid] {
                            PageSlot::Hot(buf) => buf.copy_from_slice(&self.scratch.data),
                            _ => unreachable!("alloc returns a hot page"),
                        }
                        pid
                    }
                };
                self.seqs[sid.0].as_mut().expect("fresh sequence").tables[ti].pages.push(pid);
            }
            self.seqs[sid.0].as_mut().expect("fresh sequence").tables[ti].rows = rows;
        }
        self.pages_restored += pages;
        Ok(sid)
    }

    /// Append one position row. Fills the hot tail page, allocating a new
    /// page on crossing a boundary; a page that becomes full is retired
    /// (quantized) when the cache was built with `quantize = true`.
    pub fn append(&mut self, seq: SeqId, layer: usize, which: Kv, row: &[f32]) -> Result<()> {
        assert_eq!(row.len(), self.width, "kv row width mismatch");
        let page_rows = self.opts.page_rows;
        let ti = 2 * layer + which.index();
        let rows = match self.seqs.get(seq.0).and_then(|s| s.as_ref()) {
            Some(slot) => slot.tables[ti].rows,
            None => bail!("append to unknown kv sequence {seq:?}"),
        };
        let off = rows % page_rows;
        if off == 0 {
            let pid = self.arena.alloc()?;
            self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti].pages.push(pid);
        }
        let table = &mut self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti];
        let pid = *table.pages.last().expect("tail page exists");
        table.rows += 1;
        match &mut self.arena.slots[pid] {
            PageSlot::Hot(buf) => {
                buf[off * self.width..(off + 1) * self.width].copy_from_slice(row)
            }
            _ => unreachable!("tail page must be hot"),
        }
        self.appended_rows += 1;
        if off + 1 == page_rows && self.opts.quantize {
            self.retire(pid);
        }
        Ok(())
    }

    /// Compress a full hot page through the lattice quantizer and recycle
    /// its f32 buffer.
    fn retire(&mut self, pid: usize) {
        let buf = match std::mem::replace(&mut self.arena.slots[pid], PageSlot::Free) {
            PageSlot::Hot(buf) => buf,
            other => {
                self.arena.slots[pid] = other;
                return;
            }
        };
        self.arena.hot_pages -= 1;
        let group = self.quantizer.quantize_page(&buf, self.opts.page_rows, self.width);
        let bytes = group.codes.payload_bytes() + group.side_bytes();
        self.arena.spare.push(buf);
        self.arena.slots[pid] = PageSlot::Quantized(group);
        self.arena.live_quantized_bytes += bytes;
        self.pages_quantized += 1;
        self.quantized_payload_bytes += bytes;
    }

    /// Visit rows `[0, limit)` of one stream, page by page in position
    /// order. `f(pos0, rows)` receives the absolute position of the first
    /// row and a `(k × width)` row-major slice. Hot pages are passed
    /// through by reference; quantized pages decode into the cache-owned
    /// scratch first (one page at a time), charging
    /// [`KvCacheStats::decoded_bytes`].
    pub fn visit<F: FnMut(usize, &[f32])>(
        &mut self,
        seq: SeqId,
        layer: usize,
        which: Kv,
        limit: usize,
        mut f: F,
    ) {
        let page_rows = self.opts.page_rows;
        let width = self.width;
        let Some(slot) = self.seqs.get(seq.0).and_then(|s| s.as_ref()) else {
            return;
        };
        let table = &slot.tables[2 * layer + which.index()];
        let limit = limit.min(table.rows);
        for (pi, &pid) in table.pages.iter().enumerate() {
            let pos0 = pi * page_rows;
            if pos0 >= limit {
                break;
            }
            let take = page_rows.min(limit - pos0);
            match &self.arena.slots[pid] {
                PageSlot::Hot(buf) => f(pos0, &buf[..take * width]),
                PageSlot::Quantized(g) => {
                    g.dequantize_into(&mut self.scratch);
                    self.decoded_bytes += take * width * 4;
                    f(pos0, &self.scratch.data[..take * width]);
                }
                PageSlot::Free => unreachable!("page table points at a freed page"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_row(rng: &mut Rng, w: usize) -> Vec<f32> {
        (0..w).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn append_visit_roundtrip_f32() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut c = PagedKvCache::new(2, 8, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(1);
        let mut want: Vec<f32> = Vec::new();
        for _ in 0..11 {
            let r = rand_row(&mut rng, 8);
            c.append(s, 1, Kv::K, &r).unwrap();
            want.extend_from_slice(&r);
        }
        assert_eq!(c.rows(s, 1, Kv::K), 11);
        assert_eq!(c.rows(s, 1, Kv::V), 0);
        assert_eq!(c.rows(s, 0, Kv::K), 0);
        let mut got: Vec<f32> = Vec::new();
        let mut next = 0usize;
        c.visit(s, 1, Kv::K, 11, |pos0, rows| {
            assert_eq!(pos0, next);
            next += rows.len() / 8;
            got.extend_from_slice(rows);
        });
        assert_eq!(next, 11);
        assert_eq!(got, want, "f32 pages must round-trip exactly");
    }

    #[test]
    fn visit_respects_the_limit() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut c = PagedKvCache::new(1, 2, opts);
        let s = c.new_seq();
        for i in 0..10 {
            c.append(s, 0, Kv::V, &[i as f32, -(i as f32)]).unwrap();
        }
        let mut seen = Vec::new();
        c.visit(s, 0, Kv::V, 5, |pos0, rows| seen.push((pos0, rows.len() / 2)));
        assert_eq!(seen, vec![(0, 4), (4, 1)]);
        // limit beyond the stream clamps to the stored rows
        let mut total = 0;
        c.visit(s, 0, Kv::V, 99, |_, rows| total += rows.len() / 2);
        assert_eq!(total, 10);
    }

    #[test]
    fn eviction_returns_pages_to_the_free_list() {
        let opts = KvCacheOpts { page_rows: 2, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let a = c.new_seq();
        let b = c.new_seq();
        let r = vec![1.0f32; 4];
        for _ in 0..6 {
            c.append(a, 0, Kv::K, &r).unwrap();
            c.append(b, 0, Kv::V, &r).unwrap();
        }
        assert_eq!(c.stats().pages_in_use, 6);
        assert_eq!(c.stats().peak_pages, 6);
        let capacity = c.arena_pages();
        c.evict(a);
        assert_eq!(c.stats().pages_in_use, 3);
        // a fresh sequence reuses the freed pages without growing the arena
        let d = c.new_seq();
        for _ in 0..6 {
            c.append(d, 0, Kv::K, &r).unwrap();
        }
        assert_eq!(c.arena_pages(), capacity, "free list not reused");
        assert_eq!(c.stats().pages_in_use, 6);
        assert!(c.bytes_in_use() > 0);
    }

    #[test]
    fn arena_capacity_is_enforced() {
        let opts = KvCacheOpts { page_rows: 2, max_pages: 2, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let s = c.new_seq();
        let r = vec![0.5f32; 4];
        for _ in 0..2 {
            c.append(s, 0, Kv::K, &r).unwrap();
        }
        for _ in 0..2 {
            c.append(s, 0, Kv::V, &r).unwrap();
        }
        let err = c.append(s, 0, Kv::K, &r);
        assert!(err.is_err(), "third page must exceed max_pages = 2");
        // eviction frees capacity again
        c.evict(s);
        let s2 = c.new_seq();
        assert!(c.append(s2, 0, Kv::K, &r).is_ok());
    }

    #[test]
    fn free_pages_and_watermark_track_occupancy() {
        // bounded arena: free_pages counts free slots + growth headroom,
        // the watermark tracks the all-time peak — the scheduler reads
        // admission capacity directly instead of inferring it from stats
        let opts = KvCacheOpts { page_rows: 2, max_pages: 6, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        assert_eq!(c.free_pages(), Some(6));
        assert_eq!(c.page_capacity(), Some(6));
        assert_eq!(c.high_watermark(), 0);
        let s = c.new_seq();
        let r = vec![1.0f32; 4];
        for _ in 0..4 {
            c.append(s, 0, Kv::K, &r).unwrap(); // 2 pages
        }
        assert_eq!(c.free_pages(), Some(4));
        assert_eq!(c.high_watermark(), 2);
        c.evict(s);
        assert_eq!(c.free_pages(), Some(6), "eviction returns capacity");
        assert_eq!(c.high_watermark(), 2, "watermark is a high-water mark");
        // unbounded arena reports None (grow on demand)
        let unbounded = PagedKvCache::new(1, 4, KvCacheOpts::default());
        assert_eq!(unbounded.free_pages(), None);
        assert_eq!(unbounded.page_capacity(), None);
    }

    #[test]
    fn pages_needed_is_exact_across_boundaries() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let c = PagedKvCache::new(2, 8, opts); // 2 layers -> 4 streams
        assert_eq!(c.pages_needed(0, 1), 4, "first row opens one page per stream");
        assert_eq!(c.pages_needed(1, 1), 0, "mid-page appends are free");
        assert_eq!(c.pages_needed(4, 1), 4, "boundary crossing opens new pages");
        assert_eq!(c.pages_needed(2, 7), 8, "chunk spanning two boundaries");
        assert_eq!(c.pages_needed(3, 0), 0);
    }

    #[test]
    fn spill_restore_roundtrip_is_bit_exact_without_quantization() {
        let opts = KvCacheOpts { page_rows: 4, max_pages: 8, ..Default::default() };
        let mut c = PagedKvCache::new(1, 8, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(3);
        let mut want_k: Vec<f32> = Vec::new();
        let mut want_v: Vec<f32> = Vec::new();
        for _ in 0..10 {
            let rk = rand_row(&mut rng, 8);
            let rv = rand_row(&mut rng, 8);
            c.append(s, 0, Kv::K, &rk).unwrap();
            c.append(s, 0, Kv::V, &rv).unwrap();
            want_k.extend_from_slice(&rk);
            want_v.extend_from_slice(&rv);
        }
        assert_eq!(c.stats().pages_in_use, 6);
        let sp = c.spill(s, false).unwrap();
        assert_eq!(sp.pages(), 6);
        assert_eq!(sp.rows(), 10);
        assert!(sp.bytes() > 0);
        assert_eq!(c.stats().pages_in_use, 0, "spill frees every arena page");
        assert_eq!(c.stats().pages_spilled, 6);
        // the old handle is dead
        assert!(c.append(s, 0, Kv::K, &[0.0; 8]).is_err());

        let s2 = c.restore(sp).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 10);
        assert_eq!(c.stats().pages_restored, 6);
        let mut got = Vec::new();
        c.visit(s2, 0, Kv::K, 10, |_, rows| got.extend_from_slice(rows));
        assert_eq!(got, want_k, "f32 spill must restore K bit-exactly");
        got.clear();
        c.visit(s2, 0, Kv::V, 10, |_, rows| got.extend_from_slice(rows));
        assert_eq!(got, want_v, "f32 spill must restore V bit-exactly");
        // restored sequence keeps appending where it left off
        c.append(s2, 0, Kv::K, &[0.5; 8]).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 11);
    }

    #[test]
    fn quantized_spill_shrinks_and_restores_within_tolerance() {
        // wide pages so the per-page lattice side info (2d²+4 bytes) is
        // small next to the codes — the regime quantize-to-spill targets
        let opts = KvCacheOpts { page_rows: 8, kv_bits: 8, ..Default::default() };
        let mut c = PagedKvCache::new(1, 32, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(5);
        let mut want: Vec<f32> = Vec::new();
        for _ in 0..12 {
            let r = rand_row(&mut rng, 32);
            c.append(s, 0, Kv::K, &r).unwrap();
            want.extend_from_slice(&r);
        }
        let raw = c.spill(s, false).unwrap();
        let raw_bytes = raw.bytes();
        let s1 = c.restore(raw).unwrap();
        let sp = c.spill(s1, true).unwrap();
        assert!(
            sp.bytes() < raw_bytes / 2,
            "8-bit quantize-to-spill should at least halve the parked bytes ({} vs {raw_bytes})",
            sp.bytes()
        );
        let s2 = c.restore(sp).unwrap();
        let mut got = Vec::new();
        c.visit(s2, 0, Kv::K, 12, |_, rows| got.extend_from_slice(rows));
        let mx = want.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 0.1 * mx, "quantized spill drifted: {a} vs {b}");
        }
        // the restored tail is hot again and accepts appends
        c.append(s2, 0, Kv::K, &[0.25; 32]).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 13);
        assert!(c.stats().pages_quantized > 0);
    }

    #[test]
    fn restore_refuses_when_arena_is_full_and_leaves_it_untouched() {
        let opts = KvCacheOpts { page_rows: 2, max_pages: 4, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let a = c.new_seq();
        let r = vec![1.0f32; 4];
        for _ in 0..2 {
            c.append(a, 0, Kv::K, &r).unwrap();
            c.append(a, 0, Kv::V, &r).unwrap();
        }
        let sp = c.spill(a, false).unwrap();
        assert_eq!(sp.pages(), 2);
        // another sequence grabs most of the arena
        let b = c.new_seq();
        for _ in 0..4 {
            c.append(b, 0, Kv::K, &r).unwrap();
        }
        assert_eq!(c.free_pages(), Some(2));
        c.append(b, 0, Kv::V, &r).unwrap();
        assert_eq!(c.free_pages(), Some(1));
        let sp = match c.restore(sp) {
            Err(sp) => sp,
            Ok(_) => panic!("restore must refuse without enough free pages"),
        };
        assert_eq!(c.stats().pages_in_use, 3, "failed restore must not leak pages");
        assert_eq!(c.rows(b, 0, Kv::K), 8, "existing sequences untouched");
        // the refusal handed the state back intact: evict and retry
        assert_eq!(sp.pages(), 2);
        c.evict(b);
        let s2 = c.restore(sp).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 2, "retry after eviction restores the rows");
    }

    #[test]
    fn unknown_sequence_is_rejected_and_empty_visit_is_noop() {
        let mut c = PagedKvCache::new(1, 4, KvCacheOpts::default());
        let s = c.new_seq();
        c.evict(s);
        assert!(c.append(s, 0, Kv::K, &[0.0; 4]).is_err());
        let mut calls = 0;
        c.visit(s, 0, Kv::K, 10, |_, _| calls += 1);
        assert_eq!(calls, 0);
        assert_eq!(c.rows(s, 0, Kv::K), 0);
    }
}
