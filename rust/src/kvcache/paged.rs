//! Paged KV storage: fixed-size block pages in one shared arena with a
//! free-list allocator, plus per-sequence page tables.
//!
//! Every (sequence, layer, K|V) triple owns a page table: an ordered
//! list of page ids covering positions `[0, rows)`. Appends write into the
//! hot tail page; when a page fills it is *retired* — if quantization is
//! enabled the page is compressed through [`super::KvQuantizer`] and its
//! f32 buffer returns to a spare pool, so steady-state appends allocate
//! nothing. Eviction returns a sequence's pages to the free list, which is
//! how lockstep batches of different lengths share one arena.
//!
//! Reads go through [`PagedKvCache::visit`], which walks a table page by
//! page in position order. Quantized pages decode into a cache-owned
//! scratch one page at a time — the peak decoded working set is a single
//! page, the same bounded-materialization discipline as
//! `coordinator::decode_stream`.
//!
//! With `prefix_share` on, pages are **refcounted** and a radix index
//! over token prefixes ([`super::prefix`]) lets a new sequence claim the
//! longest cached prefix of its prompt instead of re-prefilling it:
//! full-page matches attach by reference, a mid-page divergence
//! copy-on-write splits the matched rows into fresh exclusive pages, and
//! prefixes whose last sequence departed stay resident as a *cold* cache
//! — evicted LRU only under page pressure, optionally re-encoded through
//! the lattice quantizer (quantize-on-share) while they wait.

use anyhow::{bail, Result};

use crate::linalg::Mat;
use crate::quant::traits::QuantizedGroup;

use super::prefix::PrefixIndex;
use super::quantized::KvQuantizer;
use super::{KvCacheOpts, KvCacheStats};

/// Which of the two per-layer tensors a page table tracks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kv {
    /// attention keys
    K,
    /// attention values
    V,
}

impl Kv {
    fn index(self) -> usize {
        match self {
            Kv::K => 0,
            Kv::V => 1,
        }
    }
}

/// Opaque handle to one cached sequence (stable until [`PagedKvCache::evict`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqId(usize);

impl SeqId {
    /// Dense slot index of this handle — usable as a key into caller-side
    /// per-sequence side tables. Slot indices are reused only after the
    /// sequence is evicted, mirroring the cache's own slot reuse.
    pub fn index(self) -> usize {
        self.0
    }
}

/// One page's storage state.
enum PageSlot {
    /// unallocated (on the free list)
    Free,
    /// raw f32 rows (`page_rows × width`), the mutable hot form
    Hot(Vec<f32>),
    /// retired page compressed by the grouped lattice quantizer
    Quantized(QuantizedGroup),
}

/// The shared page store: slots + free list + spare f32 buffers.
struct PageArena {
    page_rows: usize,
    width: usize,
    slots: Vec<PageSlot>,
    /// per-slot reference count: one per page-table entry plus one when
    /// the prefix index holds the page; 0 for free slots
    refs: Vec<u32>,
    free: Vec<usize>,
    /// f32 buffers from retired/freed pages, reused by later allocs
    spare: Vec<Vec<f32>>,
    max_pages: usize,
    hot_pages: usize,
    live_quantized_bytes: usize,
    peak_pages: usize,
}

impl PageArena {
    fn new(page_rows: usize, width: usize, max_pages: usize) -> PageArena {
        PageArena {
            page_rows,
            width,
            slots: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            spare: Vec::new(),
            max_pages,
            hot_pages: 0,
            live_quantized_bytes: 0,
            peak_pages: 0,
        }
    }

    fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    fn page_bytes(&self) -> usize {
        self.page_rows * self.width * 4
    }

    /// Claim an empty slot id: reuse a freed slot when possible, grow the
    /// arena otherwise (respecting `max_pages`).
    fn slot_id(&mut self) -> Result<usize> {
        match self.free.pop() {
            Some(id) => Ok(id),
            None => {
                if self.max_pages > 0 && self.slots.len() >= self.max_pages {
                    bail!("kv-cache arena exhausted ({} pages)", self.max_pages);
                }
                self.slots.push(PageSlot::Free);
                self.refs.push(0);
                Ok(self.slots.len() - 1)
            }
        }
    }

    /// Allocate a zeroed hot page: reuse a freed slot (and a spare buffer)
    /// when possible, grow the arena otherwise.
    fn alloc(&mut self) -> Result<usize> {
        let id = self.slot_id()?;
        let buf = match self.spare.pop() {
            Some(mut b) => {
                b.fill(0.0);
                b
            }
            None => vec![0.0f32; self.page_rows * self.width],
        };
        self.slots[id] = PageSlot::Hot(buf);
        self.refs[id] = 1;
        self.hot_pages += 1;
        self.peak_pages = self.peak_pages.max(self.in_use());
        Ok(id)
    }

    /// Install an existing f32 buffer (a spilled page coming home) into a
    /// fresh slot without zeroing it.
    fn adopt_hot(&mut self, buf: Vec<f32>) -> Result<usize> {
        let id = self.slot_id()?;
        self.slots[id] = PageSlot::Hot(buf);
        self.refs[id] = 1;
        self.hot_pages += 1;
        self.peak_pages = self.peak_pages.max(self.in_use());
        Ok(id)
    }

    /// Install an already-compressed page into a fresh slot.
    fn adopt_quantized(&mut self, g: QuantizedGroup) -> Result<usize> {
        let id = self.slot_id()?;
        self.live_quantized_bytes += g.codes.payload_bytes() + g.side_bytes();
        self.slots[id] = PageSlot::Quantized(g);
        self.refs[id] = 1;
        self.peak_pages = self.peak_pages.max(self.in_use());
        Ok(id)
    }

    /// Take one more reference on an allocated page (a sequence or the
    /// prefix index starting to share it).
    fn inc_ref(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "inc_ref of an unallocated page");
        self.refs[id] += 1;
    }

    /// Drop one reference; the slot is only released once the **last**
    /// reference goes — a finished sequence decrements shared pages, it
    /// does not free them. Returns true when the page was freed.
    fn dec_ref(&mut self, id: usize) -> bool {
        debug_assert!(self.refs[id] > 0, "dec_ref of an unreferenced page");
        self.refs[id] = self.refs[id].saturating_sub(1);
        if self.refs[id] > 0 {
            return false;
        }
        self.release(id);
        true
    }

    /// Return a page to the free list (its f32 buffer goes to the spare
    /// pool; a quantized payload is dropped). Only called at refcount
    /// zero.
    fn release(&mut self, id: usize) {
        match std::mem::replace(&mut self.slots[id], PageSlot::Free) {
            PageSlot::Hot(buf) => {
                self.hot_pages -= 1;
                self.spare.push(buf);
            }
            PageSlot::Quantized(g) => {
                self.live_quantized_bytes -= g.codes.payload_bytes() + g.side_bytes();
            }
            PageSlot::Free => return,
        }
        self.free.push(id);
    }
}

/// Ordered page list for one (sequence, layer, K|V) stream.
#[derive(Default)]
struct PageTable {
    pages: Vec<usize>,
    rows: usize,
}

struct SeqSlot {
    /// index = `2·layer + Kv::index()`
    tables: Vec<PageTable>,
    /// prefix-index nodes this sequence is attached to (claimed at
    /// registration or recorded when its pages were published)
    claimed: Vec<usize>,
}

/// One page moved out of the arena by [`PagedKvCache::spill`].
#[derive(Debug)]
enum SpilledPage {
    /// bit-exact f32 rows (`page_rows × width`)
    Raw(Vec<f32>),
    /// lattice-compressed payload: pages that were already retired keep
    /// theirs; hot pages are compressed on spill when quantization is
    /// requested (quantize-to-spill)
    Coded(QuantizedGroup),
}

/// A preempted sequence's complete KV state, self-contained outside the
/// arena: every page of every (layer, K|V) stream plus the row counts
/// needed to rebuild the page tables. Produced by [`PagedKvCache::spill`],
/// consumed by [`PagedKvCache::restore`]. Holding one of these costs no
/// arena pages — that is the point: the scheduler parks low-priority
/// sequences here when the arena runs dry and resumes them later.
#[derive(Debug)]
pub struct SpilledSeq {
    /// per-(layer, K|V) stream in `2·layer + Kv::index()` order
    tables: Vec<(Vec<SpilledPage>, usize)>,
    /// arena pages this sequence occupied (and needs again to resume)
    pages: usize,
    /// caller-owned correlation tag (0 until [`SpilledSeq::set_tag`])
    tag: u64,
}

impl SpilledSeq {
    /// Arena pages [`PagedKvCache::restore`] will need.
    pub fn pages(&self) -> usize {
        self.pages
    }

    /// Caller-owned correlation tag (0 until [`SpilledSeq::set_tag`]).
    pub fn tag(&self) -> u64 {
        self.tag
    }

    /// Attach a caller-owned correlation tag. The tag rides through
    /// spill → park → restore untouched, so a wrapping backend (e.g. the
    /// speculative decoder) can re-associate its own parked side state
    /// when the sequence comes back under a fresh [`SeqId`].
    pub fn set_tag(&mut self, tag: u64) {
        self.tag = tag;
    }

    /// Cached positions per stream (every stream of a spilled sequence
    /// holds the same number of rows).
    pub fn rows(&self) -> usize {
        self.tables.first().map(|t| t.1).unwrap_or(0)
    }

    /// Resident bytes of the spilled payload: f32 pages at full width,
    /// compressed pages at codes + side info.
    pub fn bytes(&self) -> usize {
        self.tables
            .iter()
            .flat_map(|(pages, _)| pages.iter())
            .map(|p| match p {
                SpilledPage::Raw(buf) => buf.len() * 4,
                SpilledPage::Coded(g) => g.codes.payload_bytes() + g.side_bytes(),
            })
            .sum()
    }
}

/// The paged (optionally GLVQ-quantized) KV cache — see [`crate::kvcache`]
/// for the runtime story.
pub struct PagedKvCache {
    opts: KvCacheOpts,
    n_layer: usize,
    width: usize,
    arena: PageArena,
    seqs: Vec<Option<SeqSlot>>,
    quantizer: KvQuantizer,
    prefix: PrefixIndex,
    /// per-cache decode scratch (one page), reused across reads
    scratch: Mat,
    pages_quantized: usize,
    appended_rows: usize,
    decoded_bytes: usize,
    quantized_payload_bytes: usize,
    pages_spilled: usize,
    pages_restored: usize,
}

impl PagedKvCache {
    /// Create a cache for `n_layer` transformer layers of row width
    /// `width` (= `d_model`).
    pub fn new(n_layer: usize, width: usize, opts: KvCacheOpts) -> PagedKvCache {
        assert!(width > 0, "kv cache width must be positive");
        let opts = KvCacheOpts { page_rows: opts.page_rows.max(1), ..opts };
        let quantizer = KvQuantizer {
            bits: opts.kv_bits.clamp(1, 8),
            lattice_dim: opts.lattice_dim.max(1),
            entropy: opts.entropy,
        };
        PagedKvCache {
            arena: PageArena::new(opts.page_rows, width, opts.max_pages),
            scratch: Mat::zeros(opts.page_rows, width),
            opts,
            n_layer,
            width,
            seqs: Vec::new(),
            quantizer,
            prefix: PrefixIndex::new(),
            pages_quantized: 0,
            appended_rows: 0,
            decoded_bytes: 0,
            quantized_payload_bytes: 0,
            pages_spilled: 0,
            pages_restored: 0,
        }
    }

    /// Register a new (empty) sequence, reusing a vacated slot when one
    /// exists.
    pub fn new_seq(&mut self) -> SeqId {
        let tables: Vec<PageTable> = (0..2 * self.n_layer).map(|_| PageTable::default()).collect();
        let slot = SeqSlot { tables, claimed: Vec::new() };
        match self.seqs.iter().position(|s| s.is_none()) {
            Some(i) => {
                self.seqs[i] = Some(slot);
                SeqId(i)
            }
            None => {
                self.seqs.push(Some(slot));
                SeqId(self.seqs.len() - 1)
            }
        }
    }

    /// Register a new sequence that **claims** the longest shared prefix
    /// of `tokens` from the radix index, up to `max_rows` positions.
    /// Matched full pages attach by reference (refcounted, zero copy);
    /// when the match ends mid-page — the prompt diverges inside a shared
    /// page, or `max_rows` caps the claim — the matched rows are
    /// copy-on-write split into fresh exclusive pages, so a shared page
    /// is never mutated. Returns the handle and the positions claimed;
    /// the caller prefills only `tokens[claimed..]`. Pass
    /// `tokens.len() - 1` as `max_rows` when logits for the final prompt
    /// position are still needed (at least one token must run forward).
    pub fn new_seq_shared(&mut self, tokens: &[i32], max_rows: usize) -> (SeqId, usize) {
        let sid = self.new_seq();
        if !self.opts.prefix_share {
            return (sid, 0);
        }
        let _sp = crate::span!("kv_prefix_claim");
        let pr = self.opts.page_rows;
        let cap = tokens.len().min(max_rows);
        self.prefix.lookups += 1;
        let mut parent: Option<usize> = None;
        let mut rows = 0usize;
        while rows + pr <= cap {
            let Some(ni) = self.prefix.find_child(parent, &tokens[rows..rows + pr]) else {
                break;
            };
            let pages = self.prefix.node(ni).pages.clone();
            for (ti, &pid) in pages.iter().enumerate() {
                self.arena.inc_ref(pid);
                let t = &mut self.seqs[sid.0].as_mut().expect("fresh sequence").tables[ti];
                t.pages.push(pid);
                t.rows += pr;
            }
            self.prefix.attach(ni);
            self.seqs[sid.0].as_mut().expect("fresh sequence").claimed.push(ni);
            rows += pr;
            parent = Some(ni);
        }
        // divergence (or the cap) inside the next page: CoW-split the
        // matched rows out of the shared page
        if rows < cap {
            if let Some((ni, m)) = self.prefix.best_partial(parent, &tokens[rows..cap]) {
                if self.cow_claim(sid, ni, m) {
                    self.prefix.cow_splits += 1;
                    self.prefix.touch(ni);
                    rows += m;
                }
            }
        }
        if rows > 0 {
            self.prefix.hits += 1;
            self.prefix.hit_rows += rows;
        }
        (sid, rows)
    }

    /// Copy the first `m` rows of every stream page of node `ni` into
    /// fresh exclusive pages appended to `sid`'s tables. The shared pages
    /// are read, never written. Claims nothing (false) when the arena
    /// cannot hold the `2·n_layer` new pages.
    fn cow_claim(&mut self, sid: SeqId, ni: usize, m: usize) -> bool {
        let pr = self.opts.page_rows;
        let need = 2 * self.n_layer;
        self.ensure_free(need);
        if let Some(free) = self.arena_free_now() {
            if free < need {
                return false;
            }
        }
        let pages = self.prefix.node(ni).pages.clone();
        let mut copies: Vec<Vec<f32>> = Vec::with_capacity(pages.len());
        for &pid in &pages {
            let mut buf = vec![0.0f32; pr * self.width];
            match &self.arena.slots[pid] {
                PageSlot::Hot(src) => {
                    buf[..m * self.width].copy_from_slice(&src[..m * self.width]);
                }
                PageSlot::Quantized(g) => {
                    g.dequantize_into(&mut self.scratch);
                    self.decoded_bytes += m * self.width * 4;
                    buf[..m * self.width].copy_from_slice(&self.scratch.data[..m * self.width]);
                }
                PageSlot::Free => unreachable!("prefix node points at a freed page"),
            }
            copies.push(buf);
        }
        for (ti, buf) in copies.into_iter().enumerate() {
            let pid = self.arena.adopt_hot(buf).expect("capacity checked above");
            let t = &mut self.seqs[sid.0].as_mut().expect("sequence exists").tables[ti];
            t.pages.push(pid);
            t.rows += m;
        }
        true
    }

    /// Publish the full pages of `tokens[..rows]` into the radix index so
    /// later sequences can claim them. Pages whose token range is already
    /// indexed are deduplicated — the sequence's private copies are
    /// swapped for the shared ones and freed. Idempotent, and a no-op
    /// unless the cache was built with `prefix_share`.
    pub fn publish_prefix(&mut self, seq: SeqId, tokens: &[i32]) {
        if !self.opts.prefix_share {
            return;
        }
        let _sp = crate::span!("kv_prefix_publish");
        let pr = self.opts.page_rows;
        let streams = 2 * self.n_layer;
        let Some(rows) = self.seqs.get(seq.0).and_then(|s| s.as_ref()).map(|s| s.tables[0].rows)
        else {
            return;
        };
        let full = tokens.len().min(rows) / pr;
        let mut parent: Option<usize> = None;
        for d in 0..full {
            let key = &tokens[d * pr..(d + 1) * pr];
            let mine: Vec<usize> = (0..streams)
                .map(|ti| self.seqs[seq.0].as_ref().expect("sequence checked").tables[ti].pages[d])
                .collect();
            let ni = match self.prefix.find_child(parent, key) {
                Some(ni) => {
                    let shared = self.prefix.node(ni).pages.clone();
                    if shared != mine {
                        // dedup: retarget the tables at the shared pages
                        // and free the private duplicates
                        for (ti, (&spid, &mpid)) in shared.iter().zip(&mine).enumerate() {
                            self.arena.inc_ref(spid);
                            self.seqs[seq.0].as_mut().expect("sequence checked").tables[ti]
                                .pages[d] = spid;
                            self.arena.dec_ref(mpid);
                        }
                    }
                    ni
                }
                None => {
                    for &pid in &mine {
                        self.arena.inc_ref(pid);
                    }
                    self.prefix.insert(parent, key.to_vec(), mine)
                }
            };
            let slot = self.seqs[seq.0].as_mut().expect("sequence checked");
            if slot.claimed.contains(&ni) {
                self.prefix.touch(ni);
            } else {
                slot.claimed.push(ni);
                self.prefix.attach(ni);
            }
            parent = Some(ni);
        }
    }

    /// Drop a sequence; shared pages are decremented (freed only when
    /// the last reference goes), and prefix nodes that went cold are
    /// optionally re-encoded through the quantizer (quantize-on-share).
    pub fn evict(&mut self, seq: SeqId) {
        if let Some(slot) = self.seqs.get_mut(seq.0).and_then(|s| s.take()) {
            for t in slot.tables {
                for pid in t.pages {
                    self.arena.dec_ref(pid);
                }
            }
            self.release_claims(slot.claimed);
        }
    }

    /// Drop a departing sequence's node attachments; a node whose last
    /// sequence left stays resident as a cold prefix, compressed through
    /// the lattice quantizer when `quantize_shared` is on — its pages are
    /// exclusively the index's at that point, so re-encoding cannot
    /// perturb any live reader.
    fn release_claims(&mut self, claimed: Vec<usize>) {
        for ni in claimed {
            if self.prefix.detach(ni) && self.opts.quantize_shared {
                let pages = self.prefix.node(ni).pages.clone();
                for pid in pages {
                    self.retire(pid);
                }
            }
        }
    }

    /// Evict cold (refcount-zero) shared prefix pages, least recently
    /// used first, until at least `want` pages are allocatable. The cold
    /// cache is opportunistic: it never shrinks schedulable capacity.
    fn ensure_free(&mut self, want: usize) {
        if self.opts.max_pages == 0 {
            return;
        }
        while self.arena.free.len() + self.opts.max_pages.saturating_sub(self.arena.slots.len())
            < want
        {
            if self.evict_cold_leaf().is_none() {
                return;
            }
        }
    }

    /// Remove the least-recently-used cold leaf node and free its pages;
    /// returns how many pages were reclaimed.
    fn evict_cold_leaf(&mut self) -> Option<usize> {
        let ni = self.prefix.cold_lru_leaf()?;
        let node = self.prefix.remove(ni);
        let n = node.pages.len();
        for pid in node.pages {
            self.arena.dec_ref(pid);
        }
        self.prefix.evictions += 1;
        Some(n)
    }

    /// Drop every cold shared prefix (pages held only by the index),
    /// returning the number of arena pages reclaimed. Exposed for tests
    /// and operational cache flushes.
    pub fn drop_cold_prefixes(&mut self) -> usize {
        let mut freed = 0;
        while let Some(n) = self.evict_cold_leaf() {
            freed += n;
        }
        freed
    }

    /// Allocatable pages before reclaiming any cold prefix (`None` =
    /// unbounded arena).
    fn arena_free_now(&self) -> Option<usize> {
        if self.opts.max_pages == 0 {
            None
        } else {
            Some(
                self.arena.free.len()
                    + self.opts.max_pages.saturating_sub(self.arena.slots.len()),
            )
        }
    }

    /// Cached positions for one (sequence, layer, K|V) stream.
    pub fn rows(&self, seq: SeqId, layer: usize, which: Kv) -> usize {
        self.seqs
            .get(seq.0)
            .and_then(|s| s.as_ref())
            .map(|s| s.tables[2 * layer + which.index()].rows)
            .unwrap_or(0)
    }

    /// Positions per page.
    pub fn page_rows(&self) -> usize {
        self.opts.page_rows
    }

    /// Row width (= `d_model`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Total slots ever allocated in the arena (free or not) — the arena's
    /// high-water capacity.
    pub fn arena_pages(&self) -> usize {
        self.arena.slots.len()
    }

    /// Resident cache bytes right now: hot pages at f32 plus the
    /// compressed payloads of live quantized pages.
    pub fn bytes_in_use(&self) -> usize {
        self.arena.hot_pages * self.arena.page_bytes() + self.arena.live_quantized_bytes
    }

    /// Current + cumulative counters (see [`KvCacheStats`]).
    pub fn stats(&self) -> KvCacheStats {
        KvCacheStats {
            pages_in_use: self.arena.in_use(),
            peak_pages: self.arena.peak_pages,
            hot_pages: self.arena.hot_pages,
            bytes_in_use: self.bytes_in_use(),
            pages_quantized: self.pages_quantized,
            appended_rows: self.appended_rows,
            decoded_bytes: self.decoded_bytes,
            quantized_payload_bytes: self.quantized_payload_bytes,
            pages_spilled: self.pages_spilled,
            pages_restored: self.pages_restored,
            shared_pages: self.prefix.shared_pages(),
            shared_nodes: self.prefix.node_count(),
            prefix_lookups: self.prefix.lookups,
            prefix_hits: self.prefix.hits,
            prefix_hit_rows: self.prefix.hit_rows,
            cow_splits: self.prefix.cow_splits,
            prefix_evictions: self.prefix.evictions,
        }
    }

    /// Pages still allocatable before the arena cap is hit: free-list
    /// slots plus untapped growth headroom, **plus** cold shared prefix
    /// pages (held only by the radix index), which are reclaimed LRU on
    /// demand. `None` when the arena is unbounded (`max_pages == 0`).
    /// This is the scheduler's admission signal — occupancy read
    /// directly, not inferred from counters.
    pub fn free_pages(&self) -> Option<usize> {
        self.arena_free_now().map(|free| free + self.prefix.cold_pages())
    }

    /// Hard arena capacity in pages (`None` = unbounded).
    pub fn page_capacity(&self) -> Option<usize> {
        if self.opts.max_pages == 0 {
            None
        } else {
            Some(self.opts.max_pages)
        }
    }

    /// High-water mark of pages simultaneously in use over the cache's
    /// lifetime.
    pub fn high_watermark(&self) -> usize {
        self.arena.peak_pages
    }

    /// Extra arena pages required to append `n_new` rows to **every**
    /// (layer, K|V) stream of a sequence currently holding `rows` rows —
    /// exact, because the incremental forward appends the same number of
    /// rows to all `2·n_layer` streams of a sequence.
    pub fn pages_needed(&self, rows: usize, n_new: usize) -> usize {
        let pr = self.opts.page_rows;
        2 * self.n_layer * ((rows + n_new).div_ceil(pr) - rows.div_ceil(pr))
    }

    /// Preempt a sequence: move every one of its pages out of the arena
    /// into a self-contained [`SpilledSeq`] and return all of its slots to
    /// the free list. Already-quantized pages keep their compressed
    /// payload; hot f32 pages are either moved out verbatim
    /// (`quantize = false`, bit-exact on [`PagedKvCache::restore`]) or
    /// compressed through the lattice quantizer on the way out
    /// (`quantize = true`, quantize-to-spill — smaller parked footprint at
    /// the documented KV reconstruction tolerance).
    pub fn spill(&mut self, seq: SeqId, quantize: bool) -> Result<SpilledSeq> {
        let _sp = crate::span!("kv_spill");
        let slot = match self.seqs.get_mut(seq.0).and_then(|s| s.take()) {
            Some(slot) => slot,
            None => bail!("spill of unknown kv sequence {seq:?}"),
        };
        let mut tables = Vec::with_capacity(slot.tables.len());
        let mut pages = 0usize;
        for t in slot.tables {
            let mut spilled = Vec::with_capacity(t.pages.len());
            for pid in t.pages {
                pages += 1;
                if self.arena.refs[pid] > 1 {
                    // shared with the prefix index or another sequence:
                    // snapshot a copy and drop only this sequence's
                    // reference — the resident page is never freed or
                    // re-encoded out from under its other readers
                    let page = match &self.arena.slots[pid] {
                        PageSlot::Hot(buf) => {
                            if quantize {
                                let g = self.quantizer.quantize_page(
                                    buf,
                                    self.opts.page_rows,
                                    self.width,
                                );
                                self.pages_quantized += 1;
                                self.quantized_payload_bytes +=
                                    g.codes.payload_bytes() + g.side_bytes();
                                SpilledPage::Coded(g)
                            } else {
                                SpilledPage::Raw(buf.clone())
                            }
                        }
                        PageSlot::Quantized(g) => SpilledPage::Coded(g.clone()),
                        PageSlot::Free => unreachable!("page table points at a freed page"),
                    };
                    self.arena.dec_ref(pid);
                    spilled.push(page);
                    continue;
                }
                match std::mem::replace(&mut self.arena.slots[pid], PageSlot::Free) {
                    PageSlot::Hot(buf) => {
                        self.arena.hot_pages -= 1;
                        if quantize {
                            let g = self.quantizer.quantize_page(
                                &buf,
                                self.opts.page_rows,
                                self.width,
                            );
                            self.pages_quantized += 1;
                            self.quantized_payload_bytes +=
                                g.codes.payload_bytes() + g.side_bytes();
                            self.arena.spare.push(buf);
                            spilled.push(SpilledPage::Coded(g));
                        } else {
                            spilled.push(SpilledPage::Raw(buf));
                        }
                    }
                    PageSlot::Quantized(g) => {
                        self.arena.live_quantized_bytes -=
                            g.codes.payload_bytes() + g.side_bytes();
                        spilled.push(SpilledPage::Coded(g));
                    }
                    PageSlot::Free => unreachable!("page table points at a freed page"),
                }
                self.arena.refs[pid] = 0;
                self.arena.free.push(pid);
            }
            tables.push((spilled, t.rows));
        }
        self.release_claims(slot.claimed);
        self.pages_spilled += pages;
        Ok(SpilledSeq { tables, pages, tag: 0 })
    }

    /// Resume a spilled sequence: re-allocate its pages and rebuild its
    /// page tables under a fresh [`SeqId`]. Full compressed pages re-enter
    /// the arena still compressed (no decode cost); the partial tail page
    /// of each stream must accept future appends, so it comes back hot —
    /// decoded from its payload if it was spilled compressed. Capacity is
    /// checked up front: when the arena lacks the pages, the **untouched**
    /// [`SpilledSeq`] comes back in `Err`, so the caller retries after
    /// more evictions — a failed resume never destroys the parked KV
    /// state (it is the sequence's only copy).
    #[allow(clippy::result_large_err)]
    pub fn restore(&mut self, sp: SpilledSeq) -> std::result::Result<SeqId, SpilledSeq> {
        let _sp = crate::span!("kv_restore");
        if let Some(free) = self.free_pages() {
            if sp.pages > free {
                return Err(sp);
            }
        }
        // the precheck counted cold shared pages as allocatable; make
        // them actually free before the infallible adopt calls below
        self.ensure_free(sp.pages);
        let pr = self.opts.page_rows;
        let sid = self.new_seq();
        let pages = sp.pages;
        for (ti, (spilled, rows)) in sp.tables.into_iter().enumerate() {
            let n = spilled.len();
            for (i, page) in spilled.into_iter().enumerate() {
                let tail_partial = i + 1 == n && rows % pr != 0;
                // the capacity precheck reserves every slot these calls
                // claim, so allocation cannot fail below
                let pid = match page {
                    SpilledPage::Raw(buf) => {
                        self.arena.adopt_hot(buf).expect("precheck reserved pages")
                    }
                    SpilledPage::Coded(g) if !tail_partial => {
                        self.arena.adopt_quantized(g).expect("precheck reserved pages")
                    }
                    SpilledPage::Coded(g) => {
                        // appendable tail: decode back to a hot f32 page
                        let pid = self.arena.alloc().expect("precheck reserved pages");
                        g.dequantize_into(&mut self.scratch);
                        self.decoded_bytes += pr * self.width * 4;
                        match &mut self.arena.slots[pid] {
                            PageSlot::Hot(buf) => buf.copy_from_slice(&self.scratch.data),
                            _ => unreachable!("alloc returns a hot page"),
                        }
                        pid
                    }
                };
                self.seqs[sid.0].as_mut().expect("fresh sequence").tables[ti].pages.push(pid);
            }
            self.seqs[sid.0].as_mut().expect("fresh sequence").tables[ti].rows = rows;
        }
        self.pages_restored += pages;
        Ok(sid)
    }

    /// Roll a sequence back to its first `rows` positions — the
    /// speculative decoder's rejection path. Page-granular trim that
    /// composes with prefix sharing: pages wholly past the new length
    /// drop **one** reference each, so a page shared with the radix
    /// index or another sequence is never freed or written by rollback —
    /// only this sequence's view of it goes. A partial tail page must
    /// accept future appends again, so it ends hot *and* exclusive: a
    /// shared tail is copy-on-write split into a fresh page (the shared
    /// original stays untouched), and an exclusively-owned retired tail
    /// decodes back to f32 in place. `rows` may equal the current length
    /// (no-op) but never exceed it.
    pub fn truncate_seq(&mut self, seq: SeqId, rows: usize) -> Result<()> {
        let _sp = crate::span!("kv_truncate");
        let cur = match self.seqs.get(seq.0).and_then(|s| s.as_ref()) {
            Some(slot) => slot.tables[0].rows,
            None => bail!("truncate of unknown kv sequence {seq:?}"),
        };
        if rows > cur {
            bail!("truncate_seq to {rows} rows but sequence holds only {cur}");
        }
        if rows == cur {
            return Ok(());
        }
        let pr = self.opts.page_rows;
        let keep = rows.div_ceil(pr);
        let tail = rows % pr;
        for ti in 0..2 * self.n_layer {
            // drop whole pages past the new length: one reference each,
            // never a write — a shared page survives for its other readers
            let dropped = {
                let t = &mut self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti];
                t.rows = rows;
                t.pages.split_off(keep)
            };
            for pid in dropped {
                self.arena.dec_ref(pid);
            }
            if tail == 0 {
                continue;
            }
            // rejected positions beyond `tail` inside the kept page stay
            // as stale storage; `rows` bounds every read and the next
            // append overwrites them in order
            let pid =
                self.seqs[seq.0].as_ref().expect("sequence checked above").tables[ti].pages
                    [keep - 1];
            if self.arena.refs[pid] > 1 {
                // shared tail: CoW-split the surviving rows out, exactly
                // like a mid-page prefix claim
                let mut buf = vec![0.0f32; pr * self.width];
                match &self.arena.slots[pid] {
                    PageSlot::Hot(src) => {
                        buf[..tail * self.width].copy_from_slice(&src[..tail * self.width]);
                    }
                    PageSlot::Quantized(g) => {
                        g.dequantize_into(&mut self.scratch);
                        self.decoded_bytes += tail * self.width * 4;
                        buf[..tail * self.width]
                            .copy_from_slice(&self.scratch.data[..tail * self.width]);
                    }
                    PageSlot::Free => unreachable!("page table points at a freed page"),
                }
                self.ensure_free(1);
                let npid = self.arena.adopt_hot(buf)?;
                self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti].pages
                    [keep - 1] = npid;
                self.arena.dec_ref(pid);
            } else if matches!(self.arena.slots[pid], PageSlot::Quantized(_)) {
                // exclusively-owned retired tail: decode back to an
                // appendable hot page in the same slot
                let g = match std::mem::replace(&mut self.arena.slots[pid], PageSlot::Free) {
                    PageSlot::Quantized(g) => g,
                    _ => unreachable!("matched quantized above"),
                };
                self.arena.live_quantized_bytes -= g.codes.payload_bytes() + g.side_bytes();
                g.dequantize_into(&mut self.scratch);
                self.decoded_bytes += pr * self.width * 4;
                let mut buf = match self.arena.spare.pop() {
                    Some(b) => b,
                    None => vec![0.0f32; pr * self.width],
                };
                buf.copy_from_slice(&self.scratch.data);
                self.arena.slots[pid] = PageSlot::Hot(buf);
                self.arena.hot_pages += 1;
            }
        }
        Ok(())
    }

    /// Append one position row. Fills the hot tail page, allocating a new
    /// page on crossing a boundary; a page that becomes full is retired
    /// (quantized) when the cache was built with `quantize = true`.
    pub fn append(&mut self, seq: SeqId, layer: usize, which: Kv, row: &[f32]) -> Result<()> {
        assert_eq!(row.len(), self.width, "kv row width mismatch");
        let page_rows = self.opts.page_rows;
        let ti = 2 * layer + which.index();
        let rows = match self.seqs.get(seq.0).and_then(|s| s.as_ref()) {
            Some(slot) => slot.tables[ti].rows,
            None => bail!("append to unknown kv sequence {seq:?}"),
        };
        let off = rows % page_rows;
        if off == 0 {
            self.ensure_free(1);
            let pid = self.arena.alloc()?;
            self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti].pages.push(pid);
        }
        let table = &mut self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti];
        let pid = *table.pages.last().expect("tail page exists");
        table.rows += 1;
        match &mut self.arena.slots[pid] {
            PageSlot::Hot(buf) => {
                buf[off * self.width..(off + 1) * self.width].copy_from_slice(row)
            }
            _ => unreachable!("tail page must be hot"),
        }
        self.appended_rows += 1;
        if off + 1 == page_rows && self.opts.quantize {
            self.retire(pid);
        }
        Ok(())
    }

    /// Compress a full hot page through the lattice quantizer and recycle
    /// its f32 buffer.
    fn retire(&mut self, pid: usize) {
        let buf = match std::mem::replace(&mut self.arena.slots[pid], PageSlot::Free) {
            PageSlot::Hot(buf) => buf,
            other => {
                self.arena.slots[pid] = other;
                return;
            }
        };
        self.arena.hot_pages -= 1;
        let group = self.quantizer.quantize_page(&buf, self.opts.page_rows, self.width);
        let bytes = group.codes.payload_bytes() + group.side_bytes();
        self.arena.spare.push(buf);
        self.arena.slots[pid] = PageSlot::Quantized(group);
        self.arena.live_quantized_bytes += bytes;
        self.pages_quantized += 1;
        self.quantized_payload_bytes += bytes;
    }

    /// Visit rows `[0, limit)` of one stream, page by page in position
    /// order. `f(pos0, rows)` receives the absolute position of the first
    /// row and a `(k × width)` row-major slice. Hot pages are passed
    /// through by reference; quantized pages decode into the cache-owned
    /// scratch first (one page at a time), charging
    /// [`KvCacheStats::decoded_bytes`].
    pub fn visit<F: FnMut(usize, &[f32])>(
        &mut self,
        seq: SeqId,
        layer: usize,
        which: Kv,
        limit: usize,
        mut f: F,
    ) {
        let page_rows = self.opts.page_rows;
        let width = self.width;
        let Some(slot) = self.seqs.get(seq.0).and_then(|s| s.as_ref()) else {
            return;
        };
        let table = &slot.tables[2 * layer + which.index()];
        let limit = limit.min(table.rows);
        for (pi, &pid) in table.pages.iter().enumerate() {
            let pos0 = pi * page_rows;
            if pos0 >= limit {
                break;
            }
            let take = page_rows.min(limit - pos0);
            match &self.arena.slots[pid] {
                PageSlot::Hot(buf) => f(pos0, &buf[..take * width]),
                PageSlot::Quantized(g) => {
                    g.dequantize_into(&mut self.scratch);
                    self.decoded_bytes += take * width * 4;
                    f(pos0, &self.scratch.data[..take * width]);
                }
                PageSlot::Free => unreachable!("page table points at a freed page"),
            }
        }
    }

    /// Structural audit for the property-test layer: every arena
    /// refcount equals the number of live page-table references plus
    /// index references, no refcount-zero page is reachable or still
    /// allocated, the free list is duplicate-free and complete, and node
    /// liveness matches the sequences' claim lists. Returns a
    /// description of the first violation.
    #[doc(hidden)]
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let n = self.arena.slots.len();
        let mut want = vec![0u32; n];
        for s in self.seqs.iter().flatten() {
            for t in &s.tables {
                for &pid in &t.pages {
                    want[pid] += 1;
                }
            }
        }
        let mut live = vec![0u32; self.prefix.capacity()];
        for s in self.seqs.iter().flatten() {
            for &ni in &s.claimed {
                live[ni] += 1;
            }
        }
        for (ni, node) in self.prefix.iter() {
            if node.live != live[ni] {
                return Err(format!(
                    "node {ni}: live {} != {} claiming sequences",
                    node.live, live[ni]
                ));
            }
            for &pid in &node.pages {
                want[pid] += 1;
            }
        }
        for (pid, &w) in want.iter().enumerate() {
            if self.arena.refs[pid] != w {
                return Err(format!(
                    "page {pid}: refcount {} != {} references",
                    self.arena.refs[pid], w
                ));
            }
            let is_free = matches!(self.arena.slots[pid], PageSlot::Free);
            if w == 0 && !is_free {
                return Err(format!("page {pid}: refcount zero but still allocated"));
            }
            if w > 0 && is_free {
                return Err(format!("page {pid}: referenced but freed"));
            }
        }
        let mut seen = vec![false; n];
        for &pid in &self.arena.free {
            if seen[pid] {
                return Err(format!("page {pid}: on the free list twice"));
            }
            seen[pid] = true;
            if !matches!(self.arena.slots[pid], PageSlot::Free) {
                return Err(format!("page {pid}: on the free list but not free"));
            }
            if self.arena.refs[pid] != 0 {
                return Err(format!(
                    "page {pid}: on the free list with refcount {}",
                    self.arena.refs[pid]
                ));
            }
        }
        let free_slots =
            (0..n).filter(|&p| matches!(self.arena.slots[p], PageSlot::Free)).count();
        if free_slots != self.arena.free.len() {
            return Err(format!(
                "{free_slots} free slots but {} free-list entries",
                self.arena.free.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_row(rng: &mut Rng, w: usize) -> Vec<f32> {
        (0..w).map(|_| rng.normal_f32()).collect()
    }

    fn share_opts(page_rows: usize, max_pages: usize) -> KvCacheOpts {
        KvCacheOpts { page_rows, prefix_share: true, max_pages, ..Default::default() }
    }

    /// Append `n` position rows (deterministic content, distinct per
    /// stream and position) to **every** (layer, K|V) stream.
    fn fill_all(c: &mut PagedKvCache, s: SeqId, n_layer: usize, w: usize, start: usize, n: usize) {
        for p in start..start + n {
            for l in 0..n_layer {
                for which in [Kv::K, Kv::V] {
                    let tag = (2 * l + which.index()) as f32;
                    let row: Vec<f32> =
                        (0..w).map(|j| p as f32 + 0.25 * tag + 0.01 * j as f32).collect();
                    c.append(s, l, which, &row).unwrap();
                }
            }
        }
    }

    #[test]
    fn append_visit_roundtrip_f32() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut c = PagedKvCache::new(2, 8, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(1);
        let mut want: Vec<f32> = Vec::new();
        for _ in 0..11 {
            let r = rand_row(&mut rng, 8);
            c.append(s, 1, Kv::K, &r).unwrap();
            want.extend_from_slice(&r);
        }
        assert_eq!(c.rows(s, 1, Kv::K), 11);
        assert_eq!(c.rows(s, 1, Kv::V), 0);
        assert_eq!(c.rows(s, 0, Kv::K), 0);
        let mut got: Vec<f32> = Vec::new();
        let mut next = 0usize;
        c.visit(s, 1, Kv::K, 11, |pos0, rows| {
            assert_eq!(pos0, next);
            next += rows.len() / 8;
            got.extend_from_slice(rows);
        });
        assert_eq!(next, 11);
        assert_eq!(got, want, "f32 pages must round-trip exactly");
    }

    #[test]
    fn visit_respects_the_limit() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut c = PagedKvCache::new(1, 2, opts);
        let s = c.new_seq();
        for i in 0..10 {
            c.append(s, 0, Kv::V, &[i as f32, -(i as f32)]).unwrap();
        }
        let mut seen = Vec::new();
        c.visit(s, 0, Kv::V, 5, |pos0, rows| seen.push((pos0, rows.len() / 2)));
        assert_eq!(seen, vec![(0, 4), (4, 1)]);
        // limit beyond the stream clamps to the stored rows
        let mut total = 0;
        c.visit(s, 0, Kv::V, 99, |_, rows| total += rows.len() / 2);
        assert_eq!(total, 10);
    }

    #[test]
    fn eviction_returns_pages_to_the_free_list() {
        let opts = KvCacheOpts { page_rows: 2, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let a = c.new_seq();
        let b = c.new_seq();
        let r = vec![1.0f32; 4];
        for _ in 0..6 {
            c.append(a, 0, Kv::K, &r).unwrap();
            c.append(b, 0, Kv::V, &r).unwrap();
        }
        assert_eq!(c.stats().pages_in_use, 6);
        assert_eq!(c.stats().peak_pages, 6);
        let capacity = c.arena_pages();
        c.evict(a);
        assert_eq!(c.stats().pages_in_use, 3);
        // a fresh sequence reuses the freed pages without growing the arena
        let d = c.new_seq();
        for _ in 0..6 {
            c.append(d, 0, Kv::K, &r).unwrap();
        }
        assert_eq!(c.arena_pages(), capacity, "free list not reused");
        assert_eq!(c.stats().pages_in_use, 6);
        assert!(c.bytes_in_use() > 0);
    }

    #[test]
    fn arena_capacity_is_enforced() {
        let opts = KvCacheOpts { page_rows: 2, max_pages: 2, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let s = c.new_seq();
        let r = vec![0.5f32; 4];
        for _ in 0..2 {
            c.append(s, 0, Kv::K, &r).unwrap();
        }
        for _ in 0..2 {
            c.append(s, 0, Kv::V, &r).unwrap();
        }
        let err = c.append(s, 0, Kv::K, &r);
        assert!(err.is_err(), "third page must exceed max_pages = 2");
        // eviction frees capacity again
        c.evict(s);
        let s2 = c.new_seq();
        assert!(c.append(s2, 0, Kv::K, &r).is_ok());
    }

    #[test]
    fn free_pages_and_watermark_track_occupancy() {
        // bounded arena: free_pages counts free slots + growth headroom,
        // the watermark tracks the all-time peak — the scheduler reads
        // admission capacity directly instead of inferring it from stats
        let opts = KvCacheOpts { page_rows: 2, max_pages: 6, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        assert_eq!(c.free_pages(), Some(6));
        assert_eq!(c.page_capacity(), Some(6));
        assert_eq!(c.high_watermark(), 0);
        let s = c.new_seq();
        let r = vec![1.0f32; 4];
        for _ in 0..4 {
            c.append(s, 0, Kv::K, &r).unwrap(); // 2 pages
        }
        assert_eq!(c.free_pages(), Some(4));
        assert_eq!(c.high_watermark(), 2);
        c.evict(s);
        assert_eq!(c.free_pages(), Some(6), "eviction returns capacity");
        assert_eq!(c.high_watermark(), 2, "watermark is a high-water mark");
        // unbounded arena reports None (grow on demand)
        let unbounded = PagedKvCache::new(1, 4, KvCacheOpts::default());
        assert_eq!(unbounded.free_pages(), None);
        assert_eq!(unbounded.page_capacity(), None);
    }

    #[test]
    fn pages_needed_is_exact_across_boundaries() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let c = PagedKvCache::new(2, 8, opts); // 2 layers -> 4 streams
        assert_eq!(c.pages_needed(0, 1), 4, "first row opens one page per stream");
        assert_eq!(c.pages_needed(1, 1), 0, "mid-page appends are free");
        assert_eq!(c.pages_needed(4, 1), 4, "boundary crossing opens new pages");
        assert_eq!(c.pages_needed(2, 7), 8, "chunk spanning two boundaries");
        assert_eq!(c.pages_needed(3, 0), 0);
    }

    #[test]
    fn spill_restore_roundtrip_is_bit_exact_without_quantization() {
        let opts = KvCacheOpts { page_rows: 4, max_pages: 8, ..Default::default() };
        let mut c = PagedKvCache::new(1, 8, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(3);
        let mut want_k: Vec<f32> = Vec::new();
        let mut want_v: Vec<f32> = Vec::new();
        for _ in 0..10 {
            let rk = rand_row(&mut rng, 8);
            let rv = rand_row(&mut rng, 8);
            c.append(s, 0, Kv::K, &rk).unwrap();
            c.append(s, 0, Kv::V, &rv).unwrap();
            want_k.extend_from_slice(&rk);
            want_v.extend_from_slice(&rv);
        }
        assert_eq!(c.stats().pages_in_use, 6);
        let sp = c.spill(s, false).unwrap();
        assert_eq!(sp.pages(), 6);
        assert_eq!(sp.rows(), 10);
        assert!(sp.bytes() > 0);
        assert_eq!(c.stats().pages_in_use, 0, "spill frees every arena page");
        assert_eq!(c.stats().pages_spilled, 6);
        // the old handle is dead
        assert!(c.append(s, 0, Kv::K, &[0.0; 8]).is_err());

        let s2 = c.restore(sp).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 10);
        assert_eq!(c.stats().pages_restored, 6);
        let mut got = Vec::new();
        c.visit(s2, 0, Kv::K, 10, |_, rows| got.extend_from_slice(rows));
        assert_eq!(got, want_k, "f32 spill must restore K bit-exactly");
        got.clear();
        c.visit(s2, 0, Kv::V, 10, |_, rows| got.extend_from_slice(rows));
        assert_eq!(got, want_v, "f32 spill must restore V bit-exactly");
        // restored sequence keeps appending where it left off
        c.append(s2, 0, Kv::K, &[0.5; 8]).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 11);
    }

    #[test]
    fn quantized_spill_shrinks_and_restores_within_tolerance() {
        // wide pages so the per-page lattice side info (2d²+4 bytes) is
        // small next to the codes — the regime quantize-to-spill targets
        let opts = KvCacheOpts { page_rows: 8, kv_bits: 8, ..Default::default() };
        let mut c = PagedKvCache::new(1, 32, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(5);
        let mut want: Vec<f32> = Vec::new();
        for _ in 0..12 {
            let r = rand_row(&mut rng, 32);
            c.append(s, 0, Kv::K, &r).unwrap();
            want.extend_from_slice(&r);
        }
        let raw = c.spill(s, false).unwrap();
        let raw_bytes = raw.bytes();
        let s1 = c.restore(raw).unwrap();
        let sp = c.spill(s1, true).unwrap();
        assert!(
            sp.bytes() < raw_bytes / 2,
            "8-bit quantize-to-spill should at least halve the parked bytes ({} vs {raw_bytes})",
            sp.bytes()
        );
        let s2 = c.restore(sp).unwrap();
        let mut got = Vec::new();
        c.visit(s2, 0, Kv::K, 12, |_, rows| got.extend_from_slice(rows));
        let mx = want.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 0.1 * mx, "quantized spill drifted: {a} vs {b}");
        }
        // the restored tail is hot again and accepts appends
        c.append(s2, 0, Kv::K, &[0.25; 32]).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 13);
        assert!(c.stats().pages_quantized > 0);
    }

    #[test]
    fn restore_refuses_when_arena_is_full_and_leaves_it_untouched() {
        let opts = KvCacheOpts { page_rows: 2, max_pages: 4, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let a = c.new_seq();
        let r = vec![1.0f32; 4];
        for _ in 0..2 {
            c.append(a, 0, Kv::K, &r).unwrap();
            c.append(a, 0, Kv::V, &r).unwrap();
        }
        let sp = c.spill(a, false).unwrap();
        assert_eq!(sp.pages(), 2);
        // another sequence grabs most of the arena
        let b = c.new_seq();
        for _ in 0..4 {
            c.append(b, 0, Kv::K, &r).unwrap();
        }
        assert_eq!(c.free_pages(), Some(2));
        c.append(b, 0, Kv::V, &r).unwrap();
        assert_eq!(c.free_pages(), Some(1));
        let sp = match c.restore(sp) {
            Err(sp) => sp,
            Ok(_) => panic!("restore must refuse without enough free pages"),
        };
        assert_eq!(c.stats().pages_in_use, 3, "failed restore must not leak pages");
        assert_eq!(c.rows(b, 0, Kv::K), 8, "existing sequences untouched");
        // the refusal handed the state back intact: evict and retry
        assert_eq!(sp.pages(), 2);
        c.evict(b);
        let s2 = c.restore(sp).unwrap();
        assert_eq!(c.rows(s2, 0, Kv::K), 2, "retry after eviction restores the rows");
    }

    #[test]
    fn unknown_sequence_is_rejected_and_empty_visit_is_noop() {
        let mut c = PagedKvCache::new(1, 4, KvCacheOpts::default());
        let s = c.new_seq();
        c.evict(s);
        assert!(c.append(s, 0, Kv::K, &[0.0; 4]).is_err());
        let mut calls = 0;
        c.visit(s, 0, Kv::K, 10, |_, _| calls += 1);
        assert_eq!(calls, 0);
        assert_eq!(c.rows(s, 0, Kv::K), 0);
    }

    #[test]
    fn shared_prefix_claim_attaches_published_pages() {
        let mut c = PagedKvCache::new(2, 4, share_opts(2, 0));
        let a = c.new_seq();
        fill_all(&mut c, a, 2, 4, 0, 6); // 3 full pages per stream
        let toks: Vec<i32> = (0..6).collect();
        c.publish_prefix(a, &toks);
        c.check_invariants().unwrap();
        let before = c.stats().pages_in_use;
        // a second sequence with the same prompt claims every full page
        let (b, claimed) = c.new_seq_shared(&toks, toks.len());
        assert_eq!(claimed, 6);
        assert_eq!(c.rows(b, 1, Kv::V), 6);
        assert_eq!(c.stats().pages_in_use, before, "a full claim allocates nothing");
        assert_eq!(c.stats().prefix_hits, 1);
        assert_eq!(c.stats().prefix_hit_rows, 6);
        let mut got = Vec::new();
        c.visit(b, 0, Kv::K, 6, |_, rows| got.extend_from_slice(rows));
        let mut want = Vec::new();
        c.visit(a, 0, Kv::K, 6, |_, rows| want.extend_from_slice(rows));
        assert_eq!(got, want, "claimed pages read back bit-exactly");
        c.check_invariants().unwrap();
    }

    #[test]
    fn finished_sequences_decrement_shared_pages_instead_of_freeing() {
        // regression: eviction used to return every table page to the
        // free list unconditionally — with two sequences sharing prefix
        // pages, the first eviction corrupted the survivor's reads and
        // the second double-freed the pages
        let mut c = PagedKvCache::new(1, 4, share_opts(2, 0));
        let a = c.new_seq();
        fill_all(&mut c, a, 1, 4, 0, 4);
        let toks: Vec<i32> = (0..4).collect();
        c.publish_prefix(a, &toks);
        let (b, claimed) = c.new_seq_shared(&toks, 4);
        assert_eq!(claimed, 4);
        c.evict(a);
        // b still reads the shared pages after a's eviction
        let mut rows_seen = 0;
        c.visit(b, 0, Kv::K, 4, |_, r| rows_seen += r.len() / 4);
        assert_eq!(rows_seen, 4);
        c.check_invariants().unwrap();
        c.evict(b);
        c.check_invariants().unwrap();
        // the prefix stays resident (cold) exactly once; flushing frees
        // each page a single time
        assert_eq!(c.stats().shared_pages, 4);
        assert_eq!(c.stats().pages_in_use, 4);
        let freed = c.drop_cold_prefixes();
        assert_eq!(freed, 4);
        assert_eq!(c.stats().pages_in_use, 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn cow_split_copies_and_never_mutates_the_shared_page() {
        let mut c = PagedKvCache::new(1, 4, share_opts(4, 0));
        let a = c.new_seq();
        fill_all(&mut c, a, 1, 4, 0, 4); // exactly one full page per stream
        let toks: Vec<i32> = vec![1, 2, 3, 4];
        c.publish_prefix(a, &toks);
        let mut shared_before = Vec::new();
        c.visit(a, 0, Kv::K, 4, |_, r| shared_before.extend_from_slice(r));
        // b diverges at the third token: CoW-claims the 2 matching rows
        let div = vec![1, 2, 9, 9];
        let (b, claimed) = c.new_seq_shared(&div, 4);
        assert_eq!(claimed, 2);
        assert_eq!(c.stats().cow_splits, 1);
        // b's copy holds the matched rows and keeps growing independently
        fill_all(&mut c, b, 1, 4, 90, 2);
        let mut b_rows = Vec::new();
        c.visit(b, 0, Kv::K, 4, |_, r| b_rows.extend_from_slice(r));
        assert_eq!(&b_rows[..2 * 4], &shared_before[..2 * 4]);
        assert_ne!(&b_rows[2 * 4..], &shared_before[2 * 4..]);
        // the shared page itself is untouched
        let mut shared_after = Vec::new();
        c.visit(a, 0, Kv::K, 4, |_, r| shared_after.extend_from_slice(r));
        assert_eq!(shared_after, shared_before);
        c.check_invariants().unwrap();
    }

    #[test]
    fn cold_prefixes_are_reclaimed_under_page_pressure() {
        // arena of 4 pages holding one cold shared prefix (2 pages)
        let mut c = PagedKvCache::new(1, 4, share_opts(2, 4));
        let a = c.new_seq();
        fill_all(&mut c, a, 1, 4, 0, 2); // one full page per stream
        let toks: Vec<i32> = vec![7, 8];
        c.publish_prefix(a, &toks);
        c.evict(a); // the prefix goes cold but stays resident
        assert_eq!(c.stats().pages_in_use, 2);
        assert_eq!(c.free_pages(), Some(4), "cold pages count as allocatable");
        // a new sequence needs the whole arena: the cold prefix is evicted
        let b = c.new_seq();
        fill_all(&mut c, b, 1, 4, 0, 4);
        assert_eq!(c.stats().prefix_evictions, 1);
        assert_eq!(c.stats().shared_pages, 0);
        assert_eq!(c.stats().pages_in_use, 4);
        c.check_invariants().unwrap();
        // evict-then-reinsert round-trips: publish again, claim again
        let toks2: Vec<i32> = (0..4).collect();
        c.publish_prefix(b, &toks2);
        let (d, claimed) = c.new_seq_shared(&toks2, 4);
        assert_eq!(claimed, 4);
        assert_eq!(c.rows(d, 0, Kv::K), 4);
        c.check_invariants().unwrap();
    }

    #[test]
    fn quantize_on_share_retires_cold_prefix_pages() {
        let opts = KvCacheOpts {
            page_rows: 8,
            prefix_share: true,
            quantize_shared: true,
            kv_bits: 8,
            ..Default::default()
        };
        let mut c = PagedKvCache::new(1, 32, opts);
        let a = c.new_seq();
        let mut rng = Rng::new(9);
        let mut want: Vec<f32> = Vec::new();
        for _ in 0..8 {
            let rk = rand_row(&mut rng, 32);
            let rv = rand_row(&mut rng, 32);
            c.append(a, 0, Kv::K, &rk).unwrap();
            c.append(a, 0, Kv::V, &rv).unwrap();
            want.extend_from_slice(&rk);
        }
        let toks: Vec<i32> = (0..8).collect();
        c.publish_prefix(a, &toks);
        assert_eq!(c.stats().pages_quantized, 0, "pages stay hot while a reader is live");
        c.evict(a);
        assert_eq!(c.stats().pages_quantized, 2, "cold shared pages retire via the quantizer");
        // a later claim decodes the lattice representation within tolerance
        let (b, claimed) = c.new_seq_shared(&toks, 8);
        assert_eq!(claimed, 8);
        let mut got = Vec::new();
        c.visit(b, 0, Kv::K, 8, |_, r| got.extend_from_slice(r));
        let mx = want.iter().fold(0.0f32, |acc, &v| acc.max(v.abs()));
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() < 0.1 * mx, "quantized shared page drifted: {x} vs {y}");
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn spill_of_a_shared_sequence_copies_instead_of_freeing() {
        let mut c = PagedKvCache::new(1, 4, share_opts(2, 0));
        let a = c.new_seq();
        fill_all(&mut c, a, 1, 4, 0, 4);
        let toks: Vec<i32> = (0..4).collect();
        c.publish_prefix(a, &toks);
        let (b, claimed) = c.new_seq_shared(&toks, 4);
        assert_eq!(claimed, 4);
        let pages_before = c.stats().pages_in_use;
        // spilling b snapshots the shared pages; a and the index keep
        // reading the originals
        let sp = c.spill(b, false).unwrap();
        assert_eq!(sp.pages(), 4);
        assert_eq!(c.stats().pages_in_use, pages_before, "shared pages stay resident");
        let mut rows_seen = 0;
        c.visit(a, 0, Kv::K, 4, |_, r| rows_seen += r.len() / 4);
        assert_eq!(rows_seen, 4);
        c.check_invariants().unwrap();
        // the restored copy is independent and bit-exact
        let b2 = c.restore(sp).unwrap();
        let (mut got, mut want) = (Vec::new(), Vec::new());
        c.visit(b2, 0, Kv::V, 4, |_, r| got.extend_from_slice(r));
        c.visit(a, 0, Kv::V, 4, |_, r| want.extend_from_slice(r));
        assert_eq!(got, want);
        c.check_invariants().unwrap();
    }
}
