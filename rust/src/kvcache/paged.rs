//! Paged KV storage: fixed-size block pages in one shared arena with a
//! free-list allocator, plus per-sequence page tables.
//!
//! Every (sequence, layer, K|V) triple owns a page table: an ordered
//! list of page ids covering positions `[0, rows)`. Appends write into the
//! hot tail page; when a page fills it is *retired* — if quantization is
//! enabled the page is compressed through [`super::KvQuantizer`] and its
//! f32 buffer returns to a spare pool, so steady-state appends allocate
//! nothing. Eviction returns a sequence's pages to the free list, which is
//! how lockstep batches of different lengths share one arena.
//!
//! Reads go through [`PagedKvCache::visit`], which walks a table page by
//! page in position order. Quantized pages decode into a cache-owned
//! scratch one page at a time — the peak decoded working set is a single
//! page, the same bounded-materialization discipline as
//! `coordinator::decode_stream`.

use anyhow::{bail, Result};

use crate::linalg::Mat;
use crate::quant::traits::QuantizedGroup;

use super::quantized::KvQuantizer;
use super::{KvCacheOpts, KvCacheStats};

/// Which of the two per-layer tensors a page table tracks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kv {
    /// attention keys
    K,
    /// attention values
    V,
}

impl Kv {
    fn index(self) -> usize {
        match self {
            Kv::K => 0,
            Kv::V => 1,
        }
    }
}

/// Opaque handle to one cached sequence (stable until [`PagedKvCache::evict`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqId(usize);

/// One page's storage state.
enum PageSlot {
    /// unallocated (on the free list)
    Free,
    /// raw f32 rows (`page_rows × width`), the mutable hot form
    Hot(Vec<f32>),
    /// retired page compressed by the grouped lattice quantizer
    Quantized(QuantizedGroup),
}

/// The shared page store: slots + free list + spare f32 buffers.
struct PageArena {
    page_rows: usize,
    width: usize,
    slots: Vec<PageSlot>,
    free: Vec<usize>,
    /// f32 buffers from retired/freed pages, reused by later allocs
    spare: Vec<Vec<f32>>,
    max_pages: usize,
    hot_pages: usize,
    live_quantized_bytes: usize,
    peak_pages: usize,
}

impl PageArena {
    fn new(page_rows: usize, width: usize, max_pages: usize) -> PageArena {
        PageArena {
            page_rows,
            width,
            slots: Vec::new(),
            free: Vec::new(),
            spare: Vec::new(),
            max_pages,
            hot_pages: 0,
            live_quantized_bytes: 0,
            peak_pages: 0,
        }
    }

    fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    fn page_bytes(&self) -> usize {
        self.page_rows * self.width * 4
    }

    /// Allocate a zeroed hot page: reuse a freed slot (and a spare buffer)
    /// when possible, grow the arena otherwise.
    fn alloc(&mut self) -> Result<usize> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                if self.max_pages > 0 && self.slots.len() >= self.max_pages {
                    bail!("kv-cache arena exhausted ({} pages)", self.max_pages);
                }
                self.slots.push(PageSlot::Free);
                self.slots.len() - 1
            }
        };
        let buf = match self.spare.pop() {
            Some(mut b) => {
                b.fill(0.0);
                b
            }
            None => vec![0.0f32; self.page_rows * self.width],
        };
        self.slots[id] = PageSlot::Hot(buf);
        self.hot_pages += 1;
        self.peak_pages = self.peak_pages.max(self.in_use());
        Ok(id)
    }

    /// Return a page to the free list (its f32 buffer goes to the spare
    /// pool; a quantized payload is dropped).
    fn free(&mut self, id: usize) {
        match std::mem::replace(&mut self.slots[id], PageSlot::Free) {
            PageSlot::Hot(buf) => {
                self.hot_pages -= 1;
                self.spare.push(buf);
            }
            PageSlot::Quantized(g) => {
                self.live_quantized_bytes -= g.codes.payload_bytes() + g.side_bytes();
            }
            PageSlot::Free => return,
        }
        self.free.push(id);
    }
}

/// Ordered page list for one (sequence, layer, K|V) stream.
#[derive(Default)]
struct PageTable {
    pages: Vec<usize>,
    rows: usize,
}

struct SeqSlot {
    /// index = `2·layer + Kv::index()`
    tables: Vec<PageTable>,
}

/// The paged (optionally GLVQ-quantized) KV cache — see [`crate::kvcache`]
/// for the runtime story.
pub struct PagedKvCache {
    opts: KvCacheOpts,
    n_layer: usize,
    width: usize,
    arena: PageArena,
    seqs: Vec<Option<SeqSlot>>,
    quantizer: KvQuantizer,
    /// per-cache decode scratch (one page), reused across reads
    scratch: Mat,
    pages_quantized: usize,
    appended_rows: usize,
    decoded_bytes: usize,
    quantized_payload_bytes: usize,
}

impl PagedKvCache {
    /// Create a cache for `n_layer` transformer layers of row width
    /// `width` (= `d_model`).
    pub fn new(n_layer: usize, width: usize, opts: KvCacheOpts) -> PagedKvCache {
        assert!(width > 0, "kv cache width must be positive");
        let opts = KvCacheOpts { page_rows: opts.page_rows.max(1), ..opts };
        let quantizer = KvQuantizer {
            bits: opts.kv_bits.clamp(1, 8),
            lattice_dim: opts.lattice_dim.max(1),
            entropy: opts.entropy,
        };
        PagedKvCache {
            arena: PageArena::new(opts.page_rows, width, opts.max_pages),
            scratch: Mat::zeros(opts.page_rows, width),
            opts,
            n_layer,
            width,
            seqs: Vec::new(),
            quantizer,
            pages_quantized: 0,
            appended_rows: 0,
            decoded_bytes: 0,
            quantized_payload_bytes: 0,
        }
    }

    /// Register a new (empty) sequence, reusing a vacated slot when one
    /// exists.
    pub fn new_seq(&mut self) -> SeqId {
        let tables: Vec<PageTable> = (0..2 * self.n_layer).map(|_| PageTable::default()).collect();
        match self.seqs.iter().position(|s| s.is_none()) {
            Some(i) => {
                self.seqs[i] = Some(SeqSlot { tables });
                SeqId(i)
            }
            None => {
                self.seqs.push(Some(SeqSlot { tables }));
                SeqId(self.seqs.len() - 1)
            }
        }
    }

    /// Drop a sequence and return all of its pages to the free list.
    pub fn evict(&mut self, seq: SeqId) {
        if let Some(slot) = self.seqs.get_mut(seq.0).and_then(|s| s.take()) {
            for t in slot.tables {
                for pid in t.pages {
                    self.arena.free(pid);
                }
            }
        }
    }

    /// Cached positions for one (sequence, layer, K|V) stream.
    pub fn rows(&self, seq: SeqId, layer: usize, which: Kv) -> usize {
        self.seqs
            .get(seq.0)
            .and_then(|s| s.as_ref())
            .map(|s| s.tables[2 * layer + which.index()].rows)
            .unwrap_or(0)
    }

    /// Positions per page.
    pub fn page_rows(&self) -> usize {
        self.opts.page_rows
    }

    /// Row width (= `d_model`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Total slots ever allocated in the arena (free or not) — the arena's
    /// high-water capacity.
    pub fn arena_pages(&self) -> usize {
        self.arena.slots.len()
    }

    /// Resident cache bytes right now: hot pages at f32 plus the
    /// compressed payloads of live quantized pages.
    pub fn bytes_in_use(&self) -> usize {
        self.arena.hot_pages * self.arena.page_bytes() + self.arena.live_quantized_bytes
    }

    /// Current + cumulative counters (see [`KvCacheStats`]).
    pub fn stats(&self) -> KvCacheStats {
        KvCacheStats {
            pages_in_use: self.arena.in_use(),
            peak_pages: self.arena.peak_pages,
            hot_pages: self.arena.hot_pages,
            bytes_in_use: self.bytes_in_use(),
            pages_quantized: self.pages_quantized,
            appended_rows: self.appended_rows,
            decoded_bytes: self.decoded_bytes,
            quantized_payload_bytes: self.quantized_payload_bytes,
        }
    }

    /// Append one position row. Fills the hot tail page, allocating a new
    /// page on crossing a boundary; a page that becomes full is retired
    /// (quantized) when the cache was built with `quantize = true`.
    pub fn append(&mut self, seq: SeqId, layer: usize, which: Kv, row: &[f32]) -> Result<()> {
        assert_eq!(row.len(), self.width, "kv row width mismatch");
        let page_rows = self.opts.page_rows;
        let ti = 2 * layer + which.index();
        let rows = match self.seqs.get(seq.0).and_then(|s| s.as_ref()) {
            Some(slot) => slot.tables[ti].rows,
            None => bail!("append to unknown kv sequence {seq:?}"),
        };
        let off = rows % page_rows;
        if off == 0 {
            let pid = self.arena.alloc()?;
            self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti].pages.push(pid);
        }
        let table = &mut self.seqs[seq.0].as_mut().expect("sequence checked above").tables[ti];
        let pid = *table.pages.last().expect("tail page exists");
        table.rows += 1;
        match &mut self.arena.slots[pid] {
            PageSlot::Hot(buf) => {
                buf[off * self.width..(off + 1) * self.width].copy_from_slice(row)
            }
            _ => unreachable!("tail page must be hot"),
        }
        self.appended_rows += 1;
        if off + 1 == page_rows && self.opts.quantize {
            self.retire(pid);
        }
        Ok(())
    }

    /// Compress a full hot page through the lattice quantizer and recycle
    /// its f32 buffer.
    fn retire(&mut self, pid: usize) {
        let buf = match std::mem::replace(&mut self.arena.slots[pid], PageSlot::Free) {
            PageSlot::Hot(buf) => buf,
            other => {
                self.arena.slots[pid] = other;
                return;
            }
        };
        self.arena.hot_pages -= 1;
        let group = self.quantizer.quantize_page(&buf, self.opts.page_rows, self.width);
        let bytes = group.codes.payload_bytes() + group.side_bytes();
        self.arena.spare.push(buf);
        self.arena.slots[pid] = PageSlot::Quantized(group);
        self.arena.live_quantized_bytes += bytes;
        self.pages_quantized += 1;
        self.quantized_payload_bytes += bytes;
    }

    /// Visit rows `[0, limit)` of one stream, page by page in position
    /// order. `f(pos0, rows)` receives the absolute position of the first
    /// row and a `(k × width)` row-major slice. Hot pages are passed
    /// through by reference; quantized pages decode into the cache-owned
    /// scratch first (one page at a time), charging
    /// [`KvCacheStats::decoded_bytes`].
    pub fn visit<F: FnMut(usize, &[f32])>(
        &mut self,
        seq: SeqId,
        layer: usize,
        which: Kv,
        limit: usize,
        mut f: F,
    ) {
        let page_rows = self.opts.page_rows;
        let width = self.width;
        let Some(slot) = self.seqs.get(seq.0).and_then(|s| s.as_ref()) else {
            return;
        };
        let table = &slot.tables[2 * layer + which.index()];
        let limit = limit.min(table.rows);
        for (pi, &pid) in table.pages.iter().enumerate() {
            let pos0 = pi * page_rows;
            if pos0 >= limit {
                break;
            }
            let take = page_rows.min(limit - pos0);
            match &self.arena.slots[pid] {
                PageSlot::Hot(buf) => f(pos0, &buf[..take * width]),
                PageSlot::Quantized(g) => {
                    g.dequantize_into(&mut self.scratch);
                    self.decoded_bytes += take * width * 4;
                    f(pos0, &self.scratch.data[..take * width]);
                }
                PageSlot::Free => unreachable!("page table points at a freed page"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_row(rng: &mut Rng, w: usize) -> Vec<f32> {
        (0..w).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn append_visit_roundtrip_f32() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut c = PagedKvCache::new(2, 8, opts);
        let s = c.new_seq();
        let mut rng = Rng::new(1);
        let mut want: Vec<f32> = Vec::new();
        for _ in 0..11 {
            let r = rand_row(&mut rng, 8);
            c.append(s, 1, Kv::K, &r).unwrap();
            want.extend_from_slice(&r);
        }
        assert_eq!(c.rows(s, 1, Kv::K), 11);
        assert_eq!(c.rows(s, 1, Kv::V), 0);
        assert_eq!(c.rows(s, 0, Kv::K), 0);
        let mut got: Vec<f32> = Vec::new();
        let mut next = 0usize;
        c.visit(s, 1, Kv::K, 11, |pos0, rows| {
            assert_eq!(pos0, next);
            next += rows.len() / 8;
            got.extend_from_slice(rows);
        });
        assert_eq!(next, 11);
        assert_eq!(got, want, "f32 pages must round-trip exactly");
    }

    #[test]
    fn visit_respects_the_limit() {
        let opts = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut c = PagedKvCache::new(1, 2, opts);
        let s = c.new_seq();
        for i in 0..10 {
            c.append(s, 0, Kv::V, &[i as f32, -(i as f32)]).unwrap();
        }
        let mut seen = Vec::new();
        c.visit(s, 0, Kv::V, 5, |pos0, rows| seen.push((pos0, rows.len() / 2)));
        assert_eq!(seen, vec![(0, 4), (4, 1)]);
        // limit beyond the stream clamps to the stored rows
        let mut total = 0;
        c.visit(s, 0, Kv::V, 99, |_, rows| total += rows.len() / 2);
        assert_eq!(total, 10);
    }

    #[test]
    fn eviction_returns_pages_to_the_free_list() {
        let opts = KvCacheOpts { page_rows: 2, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let a = c.new_seq();
        let b = c.new_seq();
        let r = vec![1.0f32; 4];
        for _ in 0..6 {
            c.append(a, 0, Kv::K, &r).unwrap();
            c.append(b, 0, Kv::V, &r).unwrap();
        }
        assert_eq!(c.stats().pages_in_use, 6);
        assert_eq!(c.stats().peak_pages, 6);
        let capacity = c.arena_pages();
        c.evict(a);
        assert_eq!(c.stats().pages_in_use, 3);
        // a fresh sequence reuses the freed pages without growing the arena
        let d = c.new_seq();
        for _ in 0..6 {
            c.append(d, 0, Kv::K, &r).unwrap();
        }
        assert_eq!(c.arena_pages(), capacity, "free list not reused");
        assert_eq!(c.stats().pages_in_use, 6);
        assert!(c.bytes_in_use() > 0);
    }

    #[test]
    fn arena_capacity_is_enforced() {
        let opts = KvCacheOpts { page_rows: 2, max_pages: 2, ..Default::default() };
        let mut c = PagedKvCache::new(1, 4, opts);
        let s = c.new_seq();
        let r = vec![0.5f32; 4];
        for _ in 0..2 {
            c.append(s, 0, Kv::K, &r).unwrap();
        }
        for _ in 0..2 {
            c.append(s, 0, Kv::V, &r).unwrap();
        }
        let err = c.append(s, 0, Kv::K, &r);
        assert!(err.is_err(), "third page must exceed max_pages = 2");
        // eviction frees capacity again
        c.evict(s);
        let s2 = c.new_seq();
        assert!(c.append(s2, 0, Kv::K, &r).is_ok());
    }

    #[test]
    fn unknown_sequence_is_rejected_and_empty_visit_is_noop() {
        let mut c = PagedKvCache::new(1, 4, KvCacheOpts::default());
        let s = c.new_seq();
        c.evict(s);
        assert!(c.append(s, 0, Kv::K, &[0.0; 4]).is_err());
        let mut calls = 0;
        c.visit(s, 0, Kv::K, 10, |_, _| calls += 1);
        assert_eq!(calls, 0);
        assert_eq!(c.rows(s, 0, Kv::K), 0);
    }
}
