//! `glvq` — CLI for the GLVQ reproduction (L3 leader entrypoint).
//!
//! Subcommands:
//!   gen-data   write a synthetic corpus to a file
//!   train      train a model through the AOT train-step artifact
//!   quantize   quantize a trained checkpoint into a .glvq container
//!   eval       perplexity + zero-shot of a (quantized) checkpoint
//!   serve      batched generate/score server demo over stdin requests
//!   exp        regenerate a paper table (table1..table13 | all)
//!   info       print artifact / model inventory
//!
//! Hand-rolled argument parsing (clap is not in the vendored crate set).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use glvq::cluster::{
    PipeOpts, PipelineExec, PipelinePlan, PipelineWeights, PipelinedBackend, Router, RouterOpts,
};
use glvq::config::GlvqConfig;
use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::coordinator::scheduler;
use glvq::coordinator::server::{
    self, CachedNativeBackend, NativeBackend, Request, Response, ServerOpts,
    StreamingNativeBackend,
};
use glvq::serving::ContinuousOpts;
use glvq::data::corpus::{Corpus, Mix};
use glvq::eval::plan::ModelPlan;
use glvq::exp::{tables, Workspace};
use glvq::glvq::pipeline::PipelineOpts;
use glvq::info;
use glvq::kvcache::KvCacheOpts;
use glvq::obs::RequestTimeline;
use glvq::quant::format::QuantizedModel;
use glvq::shard::ShardOpts;
use glvq::spec::SpeculativeBackend;
use glvq::tensor::TensorStore;
use glvq::util::logging;

/// Minimal flag parser: positional args + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

const USAGE: &str = "usage: glvq <gen-data|train|quantize|eval|serve|exp|info> [--flags]
  gen-data  --mix wiki|web --bytes N --seed S --out FILE
  quantize  --model s|m --method glvq-8d|rtn|gptq|... --bits B [--entropy] --out FILE
  train     --model s|m|l --steps N --lr F --dir runs [--artifacts DIR]
  eval      --model s|m --method M --bits B [--zeroshot]
  serve     --model s|m [--quantized METHOD --bits B] [--streaming]
            [--fused] [--shards N] [--pipeline P] [--replicas R]
            [--threads N] [--panel-rows R] [--kv-cache] [--kv-bits B] [--kv-page R]
            [--kv-max-pages N] [--prefix-share]
            [--continuous] [--max-batch B] [--prefill-chunk C]
            [--max-tokens-in-flight T] [--max-queue Q] [--speculate K]
            [--metrics-out FILE] [--trace-out FILE]
            (reads 'gen <prompt>' | 'score <p>' | 'session <system>' |
             'say <user>' lines)
  exp       table1..table13 | all  [--dir runs]
  info      [--artifacts DIR] [--container FILE.glvq]

  --entropy    rANS entropy-code the packed lattice codes (.glvq v2):
               smaller files at the same nominal bits, decoded losslessly
               by the streaming runtime
  --streaming  serve directly from the compressed container through the
               batched StreamingMatmul engine: every linear layer decodes
               panel-by-panel per batch, no full dequantized layer is ever
               materialized (implies --quantized, default glvq-8d)
  --fused      pin the fused decode-GEMM execution mode for every decode
               engine in this process: lattice points decode straight
               into the accumulation loop (tiled, LUT-accelerated for
               2-3-bit lattice families) instead of through a panel
               buffer, and SIMD lane reduction is enabled when compiled
               in (--features simd). Default (no flag) is Auto, which
               already fuses eligible families; --fused 0/GLVQ_FUSED=0
               forces the classic slab path. Scalar fused output is
               bit-identical to slab mode
  --threads    decode worker threads for --streaming (default: cores - 1);
               with --shards, split across the shard workers (rounded up,
               so N shards get ceil(threads/N) decode threads each)
  --shards     tensor-parallel sharded execution: N persistent workers,
               each owning a group-aligned partition of every quantized
               tensor (its own decode scratch + rANS tables); outputs are
               bit-identical to single-shard serving at any shard count
               (implies serving from the compressed container, default
               glvq-8d; composes with --kv-cache and --continuous)
  --pipeline   pipeline-parallel lockstep execution: the layer walk is
               cut into P contiguous stages balanced by stored payload
               bytes, run by persistent stage workers streaming
               micro-batched activations over bounded channels; outputs
               stay bit-identical to single-engine serving at any stage
               count (composes with --shards — each stage owns its own
               sharded decode workers, a P x N grid — and with
               --replicas, but not with --kv-cache/--continuous/
               --speculate; implies the compressed container, default
               glvq-8d, unless --quantized none)
  --replicas   replicated serving: R independent engines behind a
               least-outstanding-tokens router with per-replica draining
               and {replica=\"N\"}-labeled metrics; every serve mode can
               be replicated, and the final report and metrics snapshot
               fold all replicas into one cluster view
  --kv-cache   serve through the paged KV cache: prefill once, then
               O(T) one-token lockstep steps instead of O(T^2) full
               recompute (composes with --streaming)
  --kv-bits    quantize retired KV pages with the grouped lattice
               quantizer at B bits (default 0 = keep all pages f32,
               which is bit-identical to serving without the cache)
  --kv-page    positions per KV page (default 16)
  --kv-max-pages  hard KV arena capacity in pages (default 0 = grow on
               demand); a bounded arena is what makes --continuous
               preemption observable
  --prefix-share  radix prefix sharing over the paged arena (implies
               --kv-cache): new requests claim the longest cached token
               prefix instead of re-prefilling it, divergences copy-on-
               write split, departed prefixes stay resident cold until
               page pressure evicts them LRU — multi-turn 'session' /
               'say' lines resume their transcript's KV this way; with
               --kv-bits set, cold shared prefixes are re-encoded through
               the lattice quantizer (quantize-on-share)
  --continuous continuous batching instead of lockstep (implies
               --kv-cache): requests join/leave the step batch per token,
               long prompts prefill in --prefill-chunk slices, finished
               sequences free KV pages immediately, and page pressure
               preempts the newest sequence (quantize-to-spill when
               --kv-bits is set) instead of failing; infeasible or
               over-budget requests are refused with a structured
               backpressure error
  --speculate  self-speculative decoding (implies --kv-cache): re-encode
               the loaded weights into a fixed-rate 2-bit draft view,
               draft K tokens per round through it, verify all K in one
               ragged target forward, roll rejected KV rows back
               page-granularly; greedy output stays bit-identical to
               K=0 and the report gains an accept_rate section
               (composes with --streaming, --shards, --continuous,
               --prefix-share; default 0 = off)
  --max-batch  sequences in flight under --continuous (default 16)
  --prefill-chunk      prompt tokens fed per scheduler step (default 32)
  --max-tokens-in-flight  token budget over admitted requests (default 4096)
  --max-queue  bounded admission-queue depth (default 256)
  --metrics-out  at shutdown, write the final metrics snapshot as
               Prometheus text exposition to FILE (counters, gauges and
               latency summaries — everything the report line shows)
  --trace-out  enable span tracing for the whole run and write a Chrome
               trace-event JSON to FILE at shutdown (load in Perfetto /
               chrome://tracing): per-thread span bars for scheduler
               phases, panel decodes, shard workers and KV operations,
               plus one virtual track per request timeline
  --container  inspect a .glvq file: per-tensor fixed-vs-entropy bytes";

/// Hand a cache-aware backend to the continuous scheduler, wrapped in
/// the self-speculative draft/verify loop when `--speculate K` is set.
fn start_continuous_maybe_spec<F>(
    make: F,
    copts: ContinuousOpts,
    spec_k: usize,
) -> server::ServerHandle
where
    F: FnOnce() -> Result<CachedNativeBackend> + Send + 'static,
{
    if spec_k > 0 {
        server::start_continuous(move || SpeculativeBackend::new(make()?, spec_k), copts)
    } else {
        server::start_continuous(make, copts)
    }
}

/// Same choice for the lockstep server: the backend (speculative or
/// plain) is boxed behind `LmBackend`.
fn start_lockstep_maybe_spec<F>(make: F, spec_k: usize) -> server::ServerHandle
where
    F: FnOnce() -> Result<CachedNativeBackend> + Send + 'static,
{
    if spec_k > 0 {
        server::start(
            move || Ok(Box::new(SpeculativeBackend::new(make()?, spec_k)?) as Box<_>),
            ServerOpts::default(),
        )
    } else {
        server::start(move || Ok(Box::new(make()?) as Box<_>), ServerOpts::default())
    }
}

/// Client front end for `serve`: one engine, or a router over R
/// replicated engines. Both expose the same call/session surface, so the
/// stdin loop below is identical either way.
enum Front {
    Single(server::ServerHandle),
    Routed(Router),
}

impl Front {
    fn call(&self, request: Request) -> Result<Response> {
        match self {
            Front::Single(h) => h.call(request),
            Front::Routed(r) => r.call(request),
        }
    }

    fn begin_session(&self, system: &[u8]) -> u64 {
        match self {
            Front::Single(h) => h.begin_session(system),
            Front::Routed(r) => r.begin_session(system),
        }
    }

    fn continue_session(&self, sid: u64, user: &[u8], max_new: usize) -> Result<Response> {
        match self {
            Front::Single(h) => h.continue_session(sid, user, max_new),
            Front::Routed(r) => r.continue_session(sid, user, max_new),
        }
    }

    fn end_session(&self, sid: u64) -> Option<Vec<u8>> {
        match self {
            Front::Single(h) => h.end_session(sid),
            Front::Routed(r) => r.end_session(sid),
        }
    }
}

fn main() -> Result<()> {
    logging::level_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let artifacts = args.get("artifacts", "artifacts");
    let dir = args.get("dir", "runs");

    match cmd.as_str() {
        "gen-data" => {
            let mix = if args.get("mix", "wiki") == "web" { Mix::Web } else { Mix::Wiki };
            let bytes = args.get_usize("bytes", 1 << 20);
            let seed = args.get_usize("seed", 42) as u64;
            let out = args.get("out", "corpus.txt");
            let text = Corpus::new(mix, seed).generate(bytes);
            std::fs::write(&out, &text)?;
            info!("wrote {bytes} bytes of {} corpus to {out}", mix.name());
        }
        "train" => {
            let model = args.get("model", "s");
            let mut ws = Workspace::new(&artifacts, &dir)?;
            let steps = args.get_usize("steps", Workspace::default_steps(&model));
            let lr = args.get_f64("lr", 3e-3) as f32;
            let store = ws.trained(&model, steps, lr)?;
            info!("trained model {model}: {} tensors", store.entries.len());
        }
        "quantize" => {
            let model = args.get("model", "s");
            let method = args.get("method", "glvq-16d");
            let bits = args.get_f64("bits", 2.0);
            let out = args.get("out", &format!("{dir}/{model}_{method}_{bits}b.glvq"));
            let mut ws = Workspace::new(&artifacts, &dir)?;
            let gs = args.get_usize("group-size", 128);
            let entropy = args.flags.get("entropy").is_some_and(|v| v != "false");
            let opts = PipelineOpts {
                group_size: gs,
                target_bits: bits,
                entropy,
                ..Default::default()
            };
            let (qm, _) = ws.quantize(&model, &method, bits, Some(opts))?;
            qm.save(std::path::Path::new(&out))?;
            let (payload, side) = qm.size_bytes();
            info!(
                "saved {out} (v{}): avg {:.3} bits, payload {payload} B, side {side} B ({:.2}%)",
                qm.container_version(),
                qm.avg_bits(),
                side as f64 / payload.max(1) as f64 * 100.0
            );
            if entropy {
                let fixed = qm.fixed_payload_bytes();
                info!(
                    "entropy coding: {payload} B vs {fixed} B fixed-width ({:.1}% saved)",
                    100.0 * (1.0 - payload as f64 / fixed.max(1) as f64)
                );
            }
        }
        "eval" => {
            let model = args.get("model", "s");
            let method = args.get("method", "none");
            let bits = args.get_f64("bits", 2.0);
            let mut ws = Workspace::new(&artifacts, &dir)?;
            let store = if method == "none" {
                ws.trained_default(&model)?
            } else {
                ws.quantize(&model, &method, bits, None)?.1
            };
            for mix in [Mix::Wiki, Mix::Web] {
                let r = ws.ppl(&model, &store, mix)?;
                println!(
                    "{} {} ppl({}) = {:.3}  (nll/tok {:.4}, {} tokens)",
                    model,
                    method,
                    mix.name(),
                    r.ppl,
                    r.nll_per_token,
                    r.tokens
                );
            }
            if args.flags.contains_key("zeroshot") {
                for (task, acc) in ws.zeroshot(&model, &store)? {
                    println!("{model} {method} {task}: {acc:.1}%");
                }
            }
        }
        "serve" => {
            let model = args.get("model", "s");
            let trace_out = args.flags.get("trace-out").cloned();
            let metrics_out = args.flags.get("metrics-out").cloned();
            if trace_out.is_some() {
                // must be on before the worker thread spawns so every span
                // from the first request onwards is captured
                glvq::obs::span::set_enabled(true);
            }
            let mut ws = Workspace::new(&artifacts, &dir)?;
            let streaming = args.flags.get("streaming").is_some_and(|v| v != "false");
            // --fused pins the fused decode-GEMM mode (and opts into SIMD
            // when compiled in) for every engine constructed from here on;
            // --fused 0 forces the classic slab path instead
            let fused = args.flags.get("fused").map(|v| v != "false" && v != "0");
            match fused {
                Some(true) => {
                    glvq::kernels::set_mode_override(Some(glvq::kernels::ExecMode::Fused));
                    glvq::kernels::set_simd_override(Some(true));
                }
                Some(false) => {
                    glvq::kernels::set_mode_override(Some(glvq::kernels::ExecMode::Slab));
                }
                None => {}
            }
            let shards = args.get_usize("shards", 0);
            let pipeline = args.get_usize("pipeline", 1).max(1);
            let replicas = args.get_usize("replicas", 1).max(1);
            let method = args.get(
                "quantized",
                if streaming || shards > 0 || pipeline > 1 { "glvq-8d" } else { "none" },
            );
            let bits = args.get_f64("bits", 2.0);
            let cfg = ws.model_cfg(&model)?;
            let continuous = args.flags.get("continuous").is_some_and(|v| v != "false");
            let prefix_share = args.flags.get("prefix-share").is_some_and(|v| v != "false");
            // --speculate K drafts through the 2-bit view and rolls
            // rejects back through the paged cache, so it implies
            // --kv-cache just like --prefix-share does
            let spec_k = args.get_usize("speculate", 0);
            let kv_cache = continuous
                || prefix_share
                || spec_k > 0
                || args.flags.get("kv-cache").is_some_and(|v| v != "false");
            let kv_bits = args.get_usize("kv-bits", 0);
            let kv_page = args.get_usize("kv-page", 16);
            let kv = KvCacheOpts {
                page_rows: kv_page.max(1),
                quantize: kv_bits > 0,
                kv_bits: kv_bits.clamp(1, 8) as u8,
                max_pages: args.get_usize("kv-max-pages", 0),
                prefix_share,
                quantize_shared: prefix_share && kv_bits > 0,
                ..KvCacheOpts::default()
            };
            if pipeline > 1 && (kv_cache || streaming) {
                bail!(
                    "--pipeline is a lockstep execution mode: it composes with --shards and \
                     --replicas, not with --streaming/--kv-cache/--continuous/--speculate"
                );
            }
            // --shards N: total --threads split across the persistent
            // shard workers, at least one decode thread each; rounded up
            // so a non-dividing thread count never idles requested cores
            // (shards=3 --threads 8 → 3 threads per worker, not 2)
            let shard_opts = |shards: usize, args: &Args| -> ShardOpts {
                let threads = args.get_usize("threads", scheduler::default_threads());
                ShardOpts {
                    shards,
                    panel_rows: args.get_usize("panel-rows", 16),
                    threads_per_shard: threads.div_ceil(shards.max(1)).max(1),
                }
            };
            // fetch the weights once, before the engine loop: replicas
            // clone the same data, so R engines serve bit-identical
            // copies of one container
            let needs_container = shards > 0 || streaming || (pipeline > 1 && method != "none");
            let qm0: Option<QuantizedModel> = if needs_container {
                // container-only quantization: no dense dequantized copy is
                // ever built, so the no-full-layer claim holds process-wide
                Some(ws.quantize_container(&model, &method, bits, None)?)
            } else {
                None
            };
            let store0: TensorStore = if needs_container || method == "none" {
                ws.trained_default(&model)?
            } else {
                ws.quantize(&model, &method, bits, None)?.1
            };
            if let Some(qm) = &qm0 {
                info!("container: {} tensors ({method}, {bits} bits)", qm.tensors.len());
            }
            let copts = ContinuousOpts {
                max_batch: args.get_usize("max-batch", 16),
                prefill_chunk: args.get_usize("prefill-chunk", 32),
                max_queue: args.get_usize("max-queue", 256),
                max_tokens_in_flight: args.get_usize("max-tokens-in-flight", 4096),
                quantize_spill: kv.quantize,
            };
            if continuous {
                info!(
                    "continuous scheduler: max_batch {}, prefill chunk {}, budget {} tokens, kv page {} rows, kv bits {}",
                    copts.max_batch,
                    copts.prefill_chunk,
                    copts.max_tokens_in_flight,
                    kv.page_rows,
                    if kv.quantize { kv.kv_bits.to_string() } else { "f32".to_string() }
                );
            }
            let mut engines: Vec<server::ServerHandle> = Vec::with_capacity(replicas);
            for _ in 0..replicas {
                let store = store0.clone();
                let qm = qm0.clone();
                let handle = if continuous {
                    // continuous batching over the cache-aware backend: the
                    // scheduler owns admission, chunked prefill, preemption
                    if shards > 0 {
                        // sharded + continuous: the scheduler's ragged steps
                        // run tensor-parallel across the shard workers
                        let sopts = shard_opts(shards, &args);
                        let qm = qm.expect("container fetched for sharded serve");
                        start_continuous_maybe_spec(
                            move || Ok(CachedNativeBackend::sharded(cfg, store, qm, sopts, kv)),
                            copts,
                            spec_k,
                        )
                    } else if streaming {
                        let threads = args.get_usize("threads", scheduler::default_threads());
                        let panel_rows = args.get_usize("panel-rows", 16);
                        let qm = qm.expect("container fetched for streaming serve");
                        start_continuous_maybe_spec(
                            move || {
                                let engine = StreamingMatmul::new(panel_rows, threads);
                                Ok(CachedNativeBackend::streaming(cfg, store, qm, engine, kv))
                            },
                            copts,
                            spec_k,
                        )
                    } else {
                        start_continuous_maybe_spec(
                            move || Ok(CachedNativeBackend::dense(cfg, store, kv)),
                            copts,
                            spec_k,
                        )
                    }
                } else if pipeline > 1 {
                    // pipeline-parallel lockstep: persistent stage workers
                    // execute contiguous layer runs of the plan, streaming
                    // micro-batched activations between them; with a
                    // container each stage owns its own sharded decode
                    // workers (a stages x shards grid), and the total
                    // --threads budget splits over every stage-shard cell
                    let threads = args.get_usize("threads", scheduler::default_threads());
                    let panel_rows = args.get_usize("panel-rows", 16);
                    let per_cell = threads.div_ceil(pipeline * shards.max(1)).max(1);
                    let weights = match qm {
                        Some(qm) => PipelineWeights::Sharded {
                            qm: Arc::new(qm),
                            opts: ShardOpts {
                                shards: shards.max(1),
                                panel_rows,
                                threads_per_shard: per_cell,
                            },
                        },
                        None => PipelineWeights::Dense,
                    };
                    server::start(
                        move || {
                            let pplan = match &weights {
                                PipelineWeights::Sharded { qm, .. } => {
                                    PipelinePlan::build(&ModelPlan::of(&cfg), qm, pipeline)
                                }
                                PipelineWeights::Dense => {
                                    PipelinePlan::dense(cfg.n_layer, pipeline)
                                }
                            };
                            let exec = PipelineExec::new(
                                cfg,
                                store,
                                pplan,
                                weights,
                                PipeOpts::default(),
                            );
                            Ok(Box::new(PipelinedBackend { exec }) as Box<_>)
                        },
                        ServerOpts::default(),
                    )
                } else if kv_cache && shards > 0 {
                    // sharded lockstep over the paged KV cache
                    let sopts = shard_opts(shards, &args);
                    let qm = qm.expect("container fetched for sharded serve");
                    start_lockstep_maybe_spec(
                        move || Ok(CachedNativeBackend::sharded(cfg, store, qm, sopts, kv)),
                        spec_k,
                    )
                } else if kv_cache && streaming {
                    // compressed weights + paged KV cache: prefill once,
                    // then one-token steps, every linear streamed from
                    // the container
                    let threads = args.get_usize("threads", scheduler::default_threads());
                    let panel_rows = args.get_usize("panel-rows", 16);
                    let qm = qm.expect("container fetched for streaming serve");
                    start_lockstep_maybe_spec(
                        move || {
                            let engine = StreamingMatmul::new(panel_rows, threads);
                            Ok(CachedNativeBackend::streaming(cfg, store, qm, engine, kv))
                        },
                        spec_k,
                    )
                } else if kv_cache {
                    start_lockstep_maybe_spec(
                        move || Ok(CachedNativeBackend::dense(cfg, store, kv)),
                        spec_k,
                    )
                } else if shards > 0 {
                    // cacheless sharded lockstep: every forward is
                    // tensor-parallel
                    let sopts = shard_opts(shards, &args);
                    let qm = qm.expect("container fetched for sharded serve");
                    server::start(
                        move || {
                            let b = server::ShardedNativeBackend::new(cfg, store, qm, sopts);
                            Ok(Box::new(b) as Box<_>)
                        },
                        ServerOpts::default(),
                    )
                } else if streaming {
                    // serve straight from the compressed container: the
                    // batched streaming engine decodes each group-panel
                    // once per batch
                    let threads = args.get_usize("threads", scheduler::default_threads());
                    let panel_rows = args.get_usize("panel-rows", 16);
                    let qm = qm.expect("container fetched for streaming serve");
                    server::start(
                        move || {
                            Ok(Box::new(StreamingNativeBackend {
                                cfg,
                                store,
                                qm,
                                engine: StreamingMatmul::new(panel_rows, threads),
                                stats: DecodeStats::default(),
                            }) as Box<_>)
                        },
                        ServerOpts::default(),
                    )
                } else {
                    server::start(
                        move || Ok(Box::new(NativeBackend { cfg, store }) as Box<_>),
                        ServerOpts::default(),
                    )
                };
                engines.push(handle);
            }
            let front = if replicas > 1 {
                info!("router: {replicas} replicas, least-outstanding placement");
                Front::Routed(Router::new(engines, RouterOpts::default()))
            } else {
                Front::Single(engines.pop().expect("one engine"))
            };
            info!("serving model {model} (quantized={method}, streaming={streaming}, mode={}, shards={shards}, pipeline={pipeline}, replicas={replicas}, kv-cache={kv_cache}, prefix-share={prefix_share}, continuous={continuous}, speculate={spec_k}); type: gen <prompt> | score <p> | session <system> | say <user> | quit", glvq::kernels::resolve_mode().name());
            let stdin = std::io::stdin();
            let mut line = String::new();
            let mut session: Option<u64> = None;
            loop {
                line.clear();
                if stdin.read_line(&mut line)? == 0 {
                    break;
                }
                let line = line.trim();
                if line == "quit" || line.is_empty() {
                    break;
                }
                let resp = if let Some(p) = line.strip_prefix("gen ") {
                    front.call(Request::Generate { prompt: p.as_bytes().to_vec(), max_new: 48 })?
                } else if let Some(p) = line.strip_prefix("score ") {
                    front.call(Request::Score {
                        prompt: p.as_bytes().to_vec(),
                        continuation: b". the".to_vec(),
                    })?
                } else if let Some(p) = line.strip_prefix("session ") {
                    // open a multi-turn session seeded with the system
                    // prompt; following 'say' lines resume its transcript
                    // (and, with --prefix-share, its cached KV prefix)
                    if let Some(old) = session.take() {
                        front.end_session(old);
                    }
                    let sid = front.begin_session(p.as_bytes());
                    session = Some(sid);
                    println!("session {sid} open");
                    continue;
                } else if let Some(p) = line.strip_prefix("say ") {
                    match session {
                        Some(sid) => front.continue_session(sid, p.as_bytes(), 48)?,
                        None => {
                            println!("no open session (start one with: session <system prompt>)");
                            continue;
                        }
                    }
                } else {
                    println!("unknown command");
                    continue;
                };
                match resp {
                    Response::Generated { text } => {
                        println!("→ {}", String::from_utf8_lossy(&text))
                    }
                    Response::Scored { logprob } => println!("→ logprob {logprob:.3}"),
                    Response::Error { message } => println!("error: {message}"),
                    Response::Rejected { reason } => println!("rejected: {reason}"),
                }
            }
            let (report, snapshot, timelines) = match front {
                Front::Single(h) => {
                    let m = h.shutdown();
                    (m.report(), m.snapshot(), m.timelines)
                }
                Front::Routed(r) => {
                    let m = r.shutdown();
                    let tls: Vec<RequestTimeline> =
                        m.replicas.iter().flat_map(|s| s.timelines.iter().cloned()).collect();
                    (m.report(), m.snapshot(), tls)
                }
            };
            info!("{report}");
            if let Some(path) = metrics_out {
                std::fs::write(&path, snapshot.to_prometheus())?;
                info!("wrote metrics snapshot to {path}");
            }
            if let Some(path) = trace_out {
                glvq::obs::span::set_enabled(false);
                let spans = glvq::obs::span::drain();
                let trace = glvq::obs::chrome_trace_json(&spans, &timelines);
                std::fs::write(&path, trace.to_string())?;
                info!("wrote {} spans + {} request timelines to {path}", spans.len(), timelines.len());
            }
        }
        "exp" => {
            let id = args
                .positional
                .first()
                .cloned()
                .unwrap_or_else(|| "table1".to_string());
            let mut ws = Workspace::new(&artifacts, &dir)?;
            tables::run(&mut ws, &id)?;
        }
        "info" => {
            if let Some(path) = args.flags.get("container") {
                // container inspection needs no artifacts/PJRT: report the
                // per-tensor fixed-vs-entropy byte accounting of a .glvq file
                let qm = QuantizedModel::load(std::path::Path::new(path))?;
                println!(
                    "{path}: container v{}, {} tensors, avg {:.3} bits",
                    qm.container_version(),
                    qm.tensors.len(),
                    qm.avg_bits()
                );
                println!(
                    "{:<24} {:>9} {:>11} {:>11} {:>8} {:>8}",
                    "tensor", "groups", "fixed B", "stored B", "save%", "side B"
                );
                for t in &qm.tensors {
                    let fixed = t.fixed_payload_bytes();
                    let stored = t.payload_bytes();
                    println!(
                        "{:<24} {:>9} {:>11} {:>11} {:>7.1}% {:>8}",
                        t.name,
                        t.groups.len(),
                        fixed,
                        stored,
                        100.0 * (1.0 - stored as f64 / fixed.max(1) as f64),
                        t.side_bytes()
                    );
                }
                let (payload, side) = qm.size_bytes();
                let fixed = qm.fixed_payload_bytes();
                println!(
                    "total: stored {payload} B vs fixed {fixed} B ({:.1}% saved), side {side} B",
                    100.0 * (1.0 - payload as f64 / fixed.max(1) as f64)
                );
                // serve-time cost of `serve --speculate`: the in-memory
                // 2-bit draft view re-encoded from this container (never
                // part of the file itself)
                let draft = glvq::spec::draft_view_of_container(&qm);
                let weights: usize = qm.tensors.iter().map(|t| t.rows * t.cols).sum();
                let eff_bits =
                    (payload + side + draft.total_bytes()) as f64 * 8.0 / weights.max(1) as f64;
                println!(
                    "draft view (serve --speculate): +{} B overhead ({} payload + {} side) at {} bits fixed; effective {:.3} bits/weight incl. draft (container alone {:.3})",
                    draft.total_bytes(),
                    draft.payload_bytes,
                    draft.side_bytes,
                    glvq::spec::DRAFT_BITS,
                    eff_bits,
                    (payload + side) as f64 * 8.0 / weights.max(1) as f64
                );
                return Ok(());
            }
            let ws = Workspace::new(&artifacts, &dir)?;
            for (name, m) in &ws.engine.models {
                println!(
                    "model {name}: d={} L={} H={} ff={} seq={} params={} programs={:?}",
                    m.config.d_model,
                    m.config.n_layer,
                    m.config.n_head,
                    m.config.d_ff,
                    m.config.seq_len,
                    m.params.len(),
                    m.programs.keys().collect::<Vec<_>>()
                );
            }
            for (d, g) in &ws.engine.glvq {
                println!("glvq d={d}: tile {}x{} ncal={} programs={:?}", g.r, g.n, g.ncal, g.programs.keys().collect::<Vec<_>>());
            }
            let _ = GlvqConfig::default();
        }
        other => {
            bail!("unknown command '{other}'\n{USAGE}");
        }
    }
    Ok(())
}

