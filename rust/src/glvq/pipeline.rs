//! Model-scope quantization pipeline: paper Alg. 1 lifted from one group to
//! the whole model, with SDBA bit allocation per tensor.
//!
//! For every quantizable tensor (stored (n_in × n_out)):
//!   1. transpose to the paper orientation Wᵀ (m × n_in),
//!   2. split n_in into column groups,
//!   3. compute per-group salience + run SDBA (or uniform allocation),
//!   4. quantize groups in parallel via the coordinator scheduler,
//!   5. assemble a [`QuantizedTensor`] with exact placement.
//!
//! Works with any [`GroupQuantizer`] — GLVQ and every baseline share this
//! driver, so method comparisons differ only in the quantizer itself.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::coordinator::scheduler::{default_threads, parallel_map};
use crate::glvq::group::{group_calib, group_panel, group_spans};
use crate::linalg::Mat;
use crate::model::ParamSpec;
use crate::quant::format::{QuantizedModel, QuantizedTensor};
use crate::quant::traits::{recon_error, GroupQuantizer};
use crate::salience::{allocate, group_salience, Allocation};
use crate::tensor::TensorStore;

/// Calibration activations per tensor: name → (n_in × N) input matrix.
#[derive(Clone, Debug, Default)]
pub struct CalibSet {
    pub acts: BTreeMap<String, Mat>,
}

impl CalibSet {
    /// Random calibration (unit normal) — for tests and for methods whose
    /// data-awareness is being deliberately ablated.
    pub fn random(specs: &[ParamSpec], n: usize, seed: u64) -> CalibSet {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut acts = BTreeMap::new();
        for s in specs.iter().filter(|s| s.quantizable) {
            let n_in = s.shape[0];
            acts.insert(s.name.clone(), Mat::random_normal(n_in, n, 1.0, &mut rng));
        }
        CalibSet { acts }
    }
}

/// Per-tensor quantization summary.
#[derive(Clone, Debug)]
pub struct TensorReport {
    pub name: String,
    pub groups: usize,
    pub avg_bits: f64,
    pub recon_error: f64,
    pub side_bytes: usize,
    pub payload_bytes: usize,
}

/// Whole-run report.
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub tensors: Vec<TensorReport>,
    pub wall_ms: f64,
}

impl PipelineReport {
    pub fn total_recon_error(&self) -> f64 {
        self.tensors.iter().map(|t| t.recon_error).sum()
    }

    pub fn avg_bits(&self) -> f64 {
        let (bits, weights): (f64, f64) = self.tensors.iter().fold((0.0, 0.0), |(b, w), t| {
            let n = (t.payload_bytes * 8) as f64;
            (b + n, w + n / t.avg_bits.max(1e-9))
        });
        bits / weights.max(1.0)
    }
}

/// Options orthogonal to the quantizer itself.
#[derive(Clone, Debug)]
pub struct PipelineOpts {
    pub group_size: usize,
    pub target_bits: f64,
    /// SDBA on/off (off ⇒ uniform round(target) bits everywhere)
    pub bit_allocation: bool,
    pub threads: usize,
    /// Losslessly re-encode each group's codes with the rANS backend
    /// (`.glvq` v2): same codes, same reconstruction, smaller payload
    /// whenever the code distribution is peaked (it is, post-Babai).
    pub entropy: bool,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts {
            group_size: 128,
            target_bits: 2.0,
            bit_allocation: true,
            threads: default_threads(),
            entropy: false,
        }
    }
}

/// Chunk length (in symbols) for entropy-coding a group of width `cols`:
/// whole rows, as close to [`crate::entropy::DEFAULT_CHUNK`] symbols as
/// possible, so streamed row panels touch the minimum number of chunks.
pub fn entropy_chunk_len(cols: usize) -> usize {
    let cols = cols.max(1);
    let rows = (crate::entropy::DEFAULT_CHUNK / cols).max(1);
    rows * cols
}

/// Quantize all quantizable tensors of `store`.
pub fn quantize_model(
    specs: &[ParamSpec],
    store: &TensorStore,
    calib: &CalibSet,
    quantizer: &(dyn GroupQuantizer + Sync),
    opts: &PipelineOpts,
) -> Result<(QuantizedModel, PipelineReport)> {
    let t0 = std::time::Instant::now();
    let mut model = QuantizedModel::default();
    let mut report = PipelineReport::default();

    for spec in specs.iter().filter(|s| s.quantizable) {
        let tensor = match store.get(&spec.name) {
            Some(t) => t,
            None => bail!("store missing quantizable tensor {}", spec.name),
        };
        if tensor.shape.len() != 2 {
            bail!("{} is not rank-2", spec.name);
        }
        let w = tensor.to_mat(); // (n_in × n_out)
        let wt = w.transpose(); // paper orientation (m × n_in)
        let n_in = wt.cols;
        let x = match calib.acts.get(&spec.name) {
            Some(x) => x,
            None => bail!("calibration set missing {}", spec.name),
        };
        if x.rows != n_in {
            bail!("{}: calib rows {} != n_in {}", spec.name, x.rows, n_in);
        }

        let spans = group_spans(n_in, opts.group_size);
        let panels: Vec<(Mat, Mat)> = spans
            .iter()
            .map(|&s| (group_panel(&wt, s), group_calib(x, s)))
            .collect();

        // ---- bit allocation ----
        let alloc: Allocation = if opts.bit_allocation {
            let base = opts.target_bits.round().max(1.0) as u8;
            let sal = parallel_map(opts.threads, &panels, |_, i, (pw, px)| {
                group_salience(i, pw, px, base)
            })
            .map_err(|(i, m)| anyhow::anyhow!("salience worker {i} panicked: {m}"))?;
            allocate(&sal, opts.target_bits)
        } else {
            Allocation::uniform(spans.len(), opts.target_bits.round().max(1.0) as u8)
        };

        // ---- per-group quantization (parallel, deterministic order) ----
        let jobs: Vec<(usize, &(Mat, Mat), u8)> = panels
            .iter()
            .enumerate()
            .map(|(i, p)| (i, p, alloc.bits[i]))
            .collect();
        let quantized = parallel_map(opts.threads, &jobs, |_, _, (gi, (pw, px), bits)| {
            let qg = quantizer.quantize(pw, px, *bits);
            let err = recon_error(pw, &qg.dequantize(), px);
            (*gi, qg, err)
        })
        .map_err(|(i, m)| anyhow::anyhow!("quantize worker {i} panicked: {m}"))?;

        let mut groups = Vec::with_capacity(quantized.len());
        let mut total_err = 0.0f64;
        let mut side_bytes = 0usize;
        let mut payload_bytes = 0usize;
        let mut total_bits = 0usize;
        for ((gi, mut qg, err), span) in quantized.into_iter().zip(&spans) {
            debug_assert_eq!(spans[gi].col0, span.col0);
            if opts.entropy {
                qg.codes = qg
                    .codes
                    .to_entropy(entropy_chunk_len(qg.cols), crate::entropy::DEFAULT_LANES);
            }
            total_err += err;
            side_bytes += qg.side_bytes();
            payload_bytes += qg.codes.payload_bytes();
            total_bits += qg.payload_bits();
            groups.push((0usize, span.col0, qg));
        }

        let qt = QuantizedTensor {
            name: spec.name.clone(),
            rows: wt.rows,
            cols: wt.cols,
            groups,
        };
        report.tensors.push(TensorReport {
            name: spec.name.clone(),
            groups: spans.len(),
            avg_bits: total_bits as f64 / (wt.rows * wt.cols) as f64,
            recon_error: total_err,
            side_bytes,
            payload_bytes,
        });
        model.tensors.push(qt);
    }

    report.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok((model, report))
}

/// Replace quantizable tensors in `store` with their dequantized versions
/// (original (n_in × n_out) orientation restored) — the eval path runs the
/// model with exactly the weights the container holds.
pub fn dequantized_store(model: &QuantizedModel, store: &TensorStore) -> TensorStore {
    let mut out = store.clone();
    for qt in &model.tensors {
        let wt_hat = qt.dequantize(); // (m × n_in)
        let w_hat = wt_hat.transpose(); // (n_in × n_out)
        out.insert(&qt.name, crate::tensor::Tensor::from_mat(&w_hat));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::config::GlvqConfig;
    use crate::glvq::optimizer::GlvqGroupQuantizer;
    use crate::model::{init_params, CONFIG_S};
    use crate::tensor::Tensor;

    fn tiny_specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "a".into(), shape: vec![64, 32], quantizable: true },
            ParamSpec { name: "g".into(), shape: vec![32], quantizable: false },
        ]
    }

    fn tiny_store(seed: u64) -> TensorStore {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut s = TensorStore::new();
        let mut data = vec![0.0f32; 64 * 32];
        rng.fill_normal(&mut data, 0.03);
        s.insert("a", Tensor::from_vec(&[64, 32], data));
        s.insert("g", Tensor::from_vec(&[32], vec![1.0; 32]));
        s
    }

    #[test]
    fn pipeline_quantizes_with_rtn_and_reports() {
        let specs = tiny_specs();
        let store = tiny_store(1);
        let calib = CalibSet::random(&specs, 32, 7);
        let opts = PipelineOpts { group_size: 32, target_bits: 3.0, bit_allocation: true, threads: 2, ..Default::default() };
        let (model, report) = quantize_model(&specs, &store, &calib, &RtnQuantizer, &opts).unwrap();
        assert_eq!(model.tensors.len(), 1);
        assert_eq!(report.tensors.len(), 1);
        let t = &report.tensors[0];
        assert_eq!(t.groups, 2); // n_in=64 / 32
        assert!((model.avg_bits() - 3.0).abs() < 1e-9, "{}", model.avg_bits());
        assert!(t.recon_error.is_finite() && t.recon_error > 0.0);
    }

    #[test]
    fn glvq_pipeline_beats_rtn_pipeline() {
        let specs = tiny_specs();
        let store = tiny_store(2);
        let calib = CalibSet::random(&specs, 48, 9);
        let opts = PipelineOpts { group_size: 32, target_bits: 2.0, bit_allocation: false, threads: 2, ..Default::default() };
        let mut cfg = GlvqConfig::default();
        cfg.lattice_dim = 8;
        cfg.group_size = 32;
        cfg.iters = 10;
        let glvq = GlvqGroupQuantizer::new(cfg);
        let (_, rep_glvq) = quantize_model(&specs, &store, &calib, &glvq, &opts).unwrap();
        let (_, rep_rtn) = quantize_model(&specs, &store, &calib, &RtnQuantizer, &opts).unwrap();
        assert!(
            rep_glvq.total_recon_error() < rep_rtn.total_recon_error(),
            "glvq {} vs rtn {}",
            rep_glvq.total_recon_error(),
            rep_rtn.total_recon_error()
        );
    }

    #[test]
    fn dequantized_store_preserves_non_quantized_and_shapes() {
        let specs = tiny_specs();
        let store = tiny_store(3);
        let calib = CalibSet::random(&specs, 16, 1);
        let opts = PipelineOpts { group_size: 32, target_bits: 4.0, bit_allocation: false, threads: 1, ..Default::default() };
        let (model, _) = quantize_model(&specs, &store, &calib, &RtnQuantizer, &opts).unwrap();
        let dq = dequantized_store(&model, &store);
        assert_eq!(dq.get("g").unwrap(), store.get("g").unwrap());
        let a = dq.get("a").unwrap();
        assert_eq!(a.shape, vec![64, 32]);
        // 4-bit RTN should be a close reconstruction
        let orig = store.get("a").unwrap();
        let err: f32 = orig
            .data
            .iter()
            .zip(&a.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.02, "max err {err}");
    }

    #[test]
    fn entropy_mode_is_lossless_and_smaller_or_reports_truthfully() {
        let specs = tiny_specs();
        let store = tiny_store(6);
        let calib = CalibSet::random(&specs, 32, 11);
        let base = PipelineOpts {
            group_size: 32,
            target_bits: 2.0,
            bit_allocation: false,
            threads: 2,
            ..Default::default()
        };
        let ent = PipelineOpts { entropy: true, ..base.clone() };
        let mut cfg = GlvqConfig::default();
        cfg.lattice_dim = 8;
        cfg.group_size = 32;
        cfg.iters = 8;
        let glvq = GlvqGroupQuantizer::new(cfg);
        let (qm, rep) = quantize_model(&specs, &store, &calib, &glvq, &base).unwrap();
        let (qme, repe) = quantize_model(&specs, &store, &calib, &glvq, &ent).unwrap();

        // identical codes and reconstruction — entropy coding is lossless
        assert_eq!(qm.tensors.len(), qme.tensors.len());
        for (t, te) in qm.tensors.iter().zip(&qme.tensors) {
            assert_eq!(t.dequantize().data, te.dequantize().data, "{}", t.name);
        }
        assert!(qme.has_entropy_payloads());
        assert!(!qm.has_entropy_payloads());
        // nominal rate accounting is unchanged; stored payload is reported
        // at its true (compressed) size
        assert!((qm.avg_bits() - qme.avg_bits()).abs() < 1e-12);
        let (payload_fixed, _) = qm.size_bytes();
        let (payload_ent, _) = qme.size_bytes();
        assert_eq!(repe.tensors[0].payload_bytes, payload_ent);
        assert_eq!(rep.tensors[0].payload_bytes, payload_fixed);
        assert_eq!(qme.fixed_payload_bytes(), payload_fixed);
    }

    #[test]
    fn entropy_chunking_aligns_to_rows() {
        assert_eq!(entropy_chunk_len(128), 4096);
        assert_eq!(entropy_chunk_len(100), 4000);
        assert_eq!(entropy_chunk_len(5000), 5000);
        assert_eq!(entropy_chunk_len(1), crate::entropy::DEFAULT_CHUNK);
    }

    #[test]
    fn missing_calibration_is_an_error() {
        let specs = tiny_specs();
        let store = tiny_store(4);
        let calib = CalibSet::default();
        let opts = PipelineOpts::default();
        assert!(quantize_model(&specs, &store, &calib, &RtnQuantizer, &opts).is_err());
    }

    #[test]
    fn full_model_s_shapes_flow_through() {
        // smoke the real model-S geometry (random weights, tiny calib)
        let cfg = CONFIG_S;
        let specs = cfg.param_specs();
        let store = init_params(&cfg, 5);
        let calib = CalibSet::random(&specs, 16, 2);
        let opts = PipelineOpts { group_size: 128, target_bits: 2.0, bit_allocation: false, threads: 4, ..Default::default() };
        let (model, report) = quantize_model(&specs, &store, &calib, &RtnQuantizer, &opts).unwrap();
        assert_eq!(model.tensors.len(), cfg.quantizable_names().len());
        assert!(report.wall_ms > 0.0);
    }
}
