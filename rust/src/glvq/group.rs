//! Group partitioning (paper §3.2): a weight tensor stored (n_in × n_out)
//! is viewed in the paper's orientation Wᵀ = (m × n) with m = n_out rows and
//! n = n_in input-feature columns; column groups of `group_size` along n are
//! the quantization units, and each group is further reshaped row-major into
//! d-length sub-blocks for the lattice.

use crate::linalg::Mat;

/// One group's placement within its tensor (paper column group).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GroupSpan {
    /// starting input-feature column (in the m×n orientation)
    pub col0: usize,
    /// number of columns (== group_size except possibly the last group)
    pub cols: usize,
}

/// Compute the column-group spans for input dimension `n_in`.
/// The tail group is shrunk (never padded) so every weight belongs to
/// exactly one group; spans are clamped to at least `d` columns.
pub fn group_spans(n_in: usize, group_size: usize) -> Vec<GroupSpan> {
    assert!(group_size > 0);
    let mut spans = Vec::new();
    let mut c = 0usize;
    while c < n_in {
        let cols = group_size.min(n_in - c);
        spans.push(GroupSpan { col0: c, cols });
        c += cols;
    }
    spans
}

/// Extract the (m × cols) panel for a span from the transposed weight
/// (wt: m × n_in) — this is `W_g` in the paper.
pub fn group_panel(wt: &Mat, span: GroupSpan) -> Mat {
    wt.slice(0, wt.rows, span.col0, span.col0 + span.cols)
}

/// Extract the (cols × N) calibration slice for a span from the layer's
/// activation matrix X (n_in × N).
pub fn group_calib(x: &Mat, span: GroupSpan) -> Mat {
    x.slice(span.col0, span.col0 + span.cols, 0, x.cols)
}

/// View a (m × n) group panel as a (B × d) block panel, B = m·n/d.
/// Because blocks are contiguous d-length runs within rows (row-major), the
/// underlying data is already in block order — this is a pure reshape.
pub fn as_blocks(w: &Mat, d: usize) -> Mat {
    assert_eq!(
        w.cols % d,
        0,
        "group width {} not divisible by lattice dim {d}",
        w.cols
    );
    Mat::from_vec(w.rows * w.cols / d, d, w.data.clone())
}

/// Inverse of [`as_blocks`].
pub fn from_blocks(blocks: &Mat, m: usize, n: usize) -> Mat {
    assert_eq!(blocks.rows * blocks.cols, m * n);
    Mat::from_vec(m, n, blocks.data.clone())
}

/// Covariance of block vectors (d × d): C = (1/B) Σ y_b y_bᵀ + eps·I.
/// Seeds the Cholesky lattice initialization (paper Eq. 8 context).
pub fn block_covariance(blocks: &Mat, eps: f32) -> Mat {
    let (bn, d) = (blocks.rows, blocks.cols);
    let mut c = Mat::zeros(d, d);
    for b in 0..bn {
        let row = blocks.row(b);
        for i in 0..d {
            let yi = row[i];
            if yi == 0.0 {
                continue;
            }
            for j in 0..d {
                *c.at_mut(i, j) += yi * row[j];
            }
        }
    }
    let scale = 1.0 / bn.max(1) as f32;
    for v in c.data.iter_mut() {
        *v *= scale;
    }
    for i in 0..d {
        *c.at_mut(i, i) += eps;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn spans_cover_exactly_once() {
        proptest(30, |rig| {
            let n = rig.usize_in(1, 2000);
            let gs = *rig.choice(&[32usize, 64, 128, 256, 512]);
            let spans = group_spans(n, gs);
            let mut covered = 0usize;
            for (i, s) in spans.iter().enumerate() {
                assert_eq!(s.col0, covered);
                covered += s.cols;
                if i + 1 < spans.len() {
                    assert_eq!(s.cols, gs);
                }
            }
            assert_eq!(covered, n);
        });
    }

    #[test]
    fn block_reshape_roundtrip_and_layout() {
        let w = Mat::from_vec(2, 4, vec![0., 1., 2., 3., 10., 11., 12., 13.]);
        let blocks = as_blocks(&w, 2);
        assert_eq!(blocks.rows, 4);
        // row-major d-runs: [0,1], [2,3], [10,11], [12,13]
        assert_eq!(blocks.row(0), &[0., 1.]);
        assert_eq!(blocks.row(1), &[2., 3.]);
        assert_eq!(blocks.row(2), &[10., 11.]);
        let back = from_blocks(&blocks, 2, 4);
        assert_eq!(back, w);
    }

    #[test]
    fn panel_and_calib_slices_align() {
        let mut rng = Rng::new(2);
        let wt = Mat::random_normal(6, 10, 1.0, &mut rng);
        let x = Mat::random_normal(10, 5, 1.0, &mut rng);
        let spans = group_spans(10, 4);
        assert_eq!(spans.len(), 3);
        let p = group_panel(&wt, spans[1]);
        assert_eq!((p.rows, p.cols), (6, 4));
        assert_eq!(p.at(0, 0), wt.at(0, 4));
        let c = group_calib(&x, spans[1]);
        assert_eq!((c.rows, c.cols), (4, 5));
        assert_eq!(c.at(0, 0), x.at(4, 0));
        // product of full pieces reconstructs the full product
        let full = wt.matmul(&x);
        let mut sum = Mat::zeros(6, 5);
        for s in spans {
            let part = group_panel(&wt, s).matmul(&group_calib(&x, s));
            sum = sum.add(&part);
        }
        assert!(sum.frob_dist(&full) < 1e-3);
    }

    #[test]
    fn covariance_is_symmetric_psd_diagonal_dominantish() {
        let mut rng = Rng::new(3);
        let blocks = Mat::random_normal(500, 8, 0.1, &mut rng);
        let c = block_covariance(&blocks, 1e-6);
        for i in 0..8 {
            for j in 0..8 {
                assert!((c.at(i, j) - c.at(j, i)).abs() < 1e-6);
            }
            assert!(c.at(i, i) > 0.0);
        }
        // cholesky must succeed (PSD + eps)
        assert!(crate::linalg::decomp::cholesky(&c).is_ok());
    }
}
