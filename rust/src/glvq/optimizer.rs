//! Per-group GLVQ alternating optimizer (paper Algorithm 1).
//!
//! Each iteration:
//!   1. **Z-step** (Eq. 6): codes `Z = assign(G⁻¹ F_μ(W))`, clamped to the
//!      b-bit range; `assign` is Babai rounding or GCD (ablation).
//!   2. **G/μ-step** (Eq. 7 + companding chain rule): analytic gradients of
//!      `L = ||W X − F_μ⁻¹(G Z) X||² + λ||G − G₀||²` w.r.t. G and μ with Z
//!      frozen; Adam update; spectral clamp of G to [σ_min, σ_max]; μ
//!      projected to [10, 255].
//! Stops when the relative loss improvement falls below ε (two consecutive
//! iterations) or the iteration budget is exhausted.
//!
//! Initialization: μ⁰ = 100·tanh(κ/10) (Eq. 12) and G₀ = α·chol(cov(Y))
//! (the paper's covariance-Cholesky init) with α chosen so Babai codes fill
//! the b-bit range.
//!
//! The analytic gradients are verified against the JAX AD graph
//! (`glvq_step_d*.hlo.txt`) in rust/tests/pjrt_parity.rs.

use crate::compand::MuLaw;
use crate::config::{Assignment, GlvqConfig};
use crate::glvq::group::{as_blocks, block_covariance};
use crate::lattice::babai::babai_batch_shifted_into;
use crate::lattice::gcd::GcdEncoder;
use crate::lattice::{GenLattice, LatticeEncoder};
use crate::linalg::decomp::cholesky;
use crate::linalg::matrix::matmul_into;
use crate::linalg::spectral::spectral_clamp;
use crate::linalg::Mat;
use crate::quant::pack::{clamp_code, code_range, PackedCodes};
use crate::quant::traits::{GroupQuantizer, QuantizedGroup, SideInfo};

/// Result of fitting one group: quantized codes + diagnostics.
#[derive(Clone, Debug)]
pub struct GroupFit {
    pub quantized: QuantizedGroup,
    pub final_loss: f64,
    pub initial_loss: f64,
    pub iters_run: usize,
    pub mu: f32,
}

/// Scalar Adam state for the μ parameter and matrix Adam for G.
struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: f32,
}

impl Adam {
    fn new(n: usize) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0.0 }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.t += 1.0;
        let bc1 = 1.0 - B1.powf(self.t);
        let bc2 = 1.0 - B2.powf(self.t);
        for i in 0..params.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grads[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grads[i] * grads[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            params[i] -= lr * mh / (vh.sqrt() + EPS);
        }
    }
}

/// The GLVQ group quantizer (implements [`GroupQuantizer`]).
pub struct GlvqGroupQuantizer {
    pub cfg: GlvqConfig,
    /// shared fixed basis for the Table-7 ablation (adaptive_lattice=false);
    /// None ⇒ per-group scaled identity seed
    pub fixed_mu: f32,
}

impl GlvqGroupQuantizer {
    pub fn new(cfg: GlvqConfig) -> GlvqGroupQuantizer {
        GlvqGroupQuantizer { cfg, fixed_mu: 50.0 }
    }

    /// Fit one group; the full Alg. 1 loop.
    pub fn fit(&self, w: &Mat, x: &Mat, bits: u8) -> GroupFit {
        let cfg = &self.cfg;
        let d = cfg.lattice_dim;
        let (m, n) = (w.rows, w.cols);
        assert_eq!(n % d, 0, "group width {n} not divisible by d={d}");

        // ---- companding init (Eq. 12) ----
        let mut comp = if cfg.adaptive_companding {
            MuLaw::init_from_kurtosis(&w.data)
        } else {
            MuLaw::new(self.fixed_mu)
        };

        // normalize weights into [-1, 1] for μ-law domain; the scale folds
        // into G (decode = s · F⁻¹(G z) with s absorbed by regenerating G′ =
        // ... we instead keep an explicit normalization and fold it into G
        // at the end via the lattice scale).
        let wmax = w.max_abs().max(1e-8);
        let wn = w.scale(1.0 / wmax);

        // companded blocks Y (B × d)
        let mut y = as_blocks(&wn, d);
        comp.forward_slice(&mut y.data);

        // ---- lattice init: α · chol(cov(Y)), α grid-searched ----
        let (lo, hi) = code_range(bits);
        let code_span = 0.5 * (hi - lo) as f32; // ≈ 2^{b-1}
        let alpha0 = 4.0 / ((1u32 << bits) as f32); // step ≈ ±2σ range / 2^b
        let shape = if cfg.adaptive_lattice {
            let cov = block_covariance(&y, 1e-7);
            match cholesky(&cov) {
                Ok(l) => l,
                Err(_) => Mat::eye(d).scale(crate::linalg::stats::std_dev(&y.data) as f32),
            }
        } else {
            // fixed-basis ablation: scaled identity (per-group scalar only)
            Mat::eye(d).scale(crate::linalg::stats::std_dev(&y.data).max(1e-6) as f32)
        };
        // pick the init scale by direct search on companded-domain MSE
        let mut best_init: Option<(f64, f32)> = None;
        for mult in [0.4f32, 0.6, 0.85, 1.2, 1.7, 2.4] {
            let cand = shape.scale(alpha0 * mult);
            let lat_c = match GenLattice::new(cand) {
                Ok(l) => l,
                Err(_) => continue,
            };
            let mut zc = Mat::zeros(y.rows, d);
            babai_batch_shifted_into(&lat_c, &y, &mut zc);
            let mut err = 0.0f64;
            for (b, row) in (0..zc.rows).map(|b| (b, zc.row(b))) {
                for i in 0..d {
                    // shifted-grid decode ŷ = G (z + ½)
                    let mut acc = 0.0f32;
                    for j in 0..d {
                        acc += lat_c.g.at(i, j) * (clamp_code(row[j], bits) as f32 + 0.5);
                    }
                    err += ((y.at(b, i) - acc) as f64).powi(2);
                }
            }
            if best_init.as_ref().map_or(true, |(e, _)| err < *e) {
                best_init = Some((err, alpha0 * mult));
            }
        }
        let alpha = best_init.map(|(_, a)| a).unwrap_or(alpha0);
        let _ = code_span;

        let mut lat = GenLattice::new(shape.scale(alpha)).unwrap_or_else(|_| {
            GenLattice::scaled_identity(d, alpha * 0.05)
        });
        let g0_ref = lat.g.clone();
        // learning rates are relative to the basis magnitude so the same
        // config works across groups of very different scales
        let g_mag = (lat.g.frob_norm() / (d as f32)).max(1e-6);
        let lr_g_eff = cfg.lr_g * g_mag;
        // spectral band relative to the initial spectrum
        let sigma0 = crate::linalg::spectral::sigma_max(&lat.g, 30).max(1e-8);
        let (band_lo, band_hi) = (cfg.sigma_min * sigma0, cfg.sigma_max * sigma0);

        // scratch buffers reused across iterations (hot path)
        let nblocks = m * n / d;
        let mut z = Mat::zeros(nblocks, d);
        let mut v = Mat::zeros(nblocks, d); // decoded lattice points G z
        let mut w_hat = Mat::zeros(m, n);
        let mut diff = Mat::zeros(m, n); // D = (W − Ŵ), raw units
        let mut dsn = Mat::zeros(m, n); // D·S
        // §Perf: precompute the calibration Gram matrix S = X Xᵀ once —
        // the loss tr(D S Dᵀ) and its gradient −2·D·S then cost m·n² per
        // iteration instead of 2·m·n·N (3× fewer MACs at N=256, and the
        // per-iteration cost no longer scales with the calibration size).
        let s_gram = x.matmul(&x.transpose());

        let mut adam_g = Adam::new(d * d);
        let mut adam_mu = Adam::new(1);

        let gcd = GcdEncoder::default();
        let mut losses: Vec<f64> = Vec::with_capacity(cfg.iters);
        let mut best: Option<(f64, Mat, f32)> = None; // (loss, G, mu)

        for iter in 0..cfg.iters {
            // ---- Z-step ----
            // refresh Y under current μ
            y.data.copy_from_slice(&wn.data);
            comp.forward_slice(&mut y.data);
            let half = crate::lattice::babai::half_shift(&lat.g);
            match cfg.assignment {
                Assignment::Babai => babai_batch_shifted_into(&lat, &y, &mut z),
                Assignment::Gcd => {
                    // GCD on the shifted target: z = gcd(y − G·½)
                    let mut ysh = vec![0.0f32; d];
                    for b in 0..nblocks {
                        for (i, v) in ysh.iter_mut().enumerate() {
                            *v = y.at(b, i) - half[i];
                        }
                        let zz = gcd.encode(&lat, &ysh);
                        z.row_mut(b).copy_from_slice(&zz);
                    }
                }
            }
            for c in z.data.iter_mut() {
                *c = clamp_code(*c, bits) as f32;
            }

            // ---- decode + loss (half-integer grid: V = (Z+½) Gᵀ) ----
            let mut zs = z.clone();
            for c in zs.data.iter_mut() {
                *c += 0.5;
            }
            let gt = lat.g.transpose();
            matmul_into(&zs, &gt, &mut v); // V = (Z+½) Gᵀ  (B × d)
            w_hat.data.copy_from_slice(&v.data);
            comp.inverse_slice(&mut w_hat.data); // Ŵn = F⁻¹(V) (as m×n layout)

            for i in 0..diff.data.len() {
                diff.data[i] = (wn.data[i] - w_hat.data[i]) * wmax;
            }
            matmul_into(&diff, &s_gram, &mut dsn); // D·S
            let recon: f64 = diff
                .data
                .iter()
                .zip(&dsn.data)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let reg: f64 = cfg.lambda as f64 * (lat.g.frob_dist(&g0_ref) as f64).powi(2);
            let loss = recon + reg;
            losses.push(loss);

            if best.as_ref().map_or(true, |(bl, _, _)| loss < *bl) {
                best = Some((loss, lat.g.clone(), comp.mu));
            }

            // convergence check (relative improvement below ε twice)
            if iter >= 2 {
                let a = losses[iter - 1];
                let b = losses[iter];
                let rel = |p: f64, q: f64| (p - q).abs() / p.abs().max(1e-12);
                if rel(a, b) < cfg.epsilon as f64 && rel(losses[iter - 2], a) < cfg.epsilon as f64 {
                    break;
                }
            }
            if iter + 1 == cfg.iters {
                break;
            }

            // ---- gradients ----
            // dL/dŴn = −2 wmax · D S  (m × n) — D·S already computed above
            let dldw = &mut dsn;
            for g in dldw.data.iter_mut() {
                *g *= -2.0 * wmax;
            }
            // chain through F⁻¹: dL/dV = dL/dŴn ⊙ F⁻¹'(V); also dμ term
            let mu = comp.mu;
            let log1p_mu = (1.0 + mu).ln();
            let mut dmu = 0.0f64;
            // reuse w_hat buffer as dL/dV (same layout as V)
            for i in 0..v.data.len() {
                let vv = v.data[i];
                let t = vv.abs();
                let a = (t * log1p_mu).exp(); // (1+mu)^{|v|}
                let dfdv = a * log1p_mu / mu;
                let g_up = dldw.data[i];
                // ∂F⁻¹/∂μ = sgn(v)( a·t·μ/(1+μ) − (a−1) ) / μ²
                let dfdmu = vv.signum() * (a * t * mu / (1.0 + mu) - (a - 1.0)) / (mu * mu);
                dmu += (g_up * dfdmu) as f64;
                w_hat.data[i] = g_up * dfdv; // dL/dV
            }
            // dL/dG = (dL/dV panel)ᵀ @ (Z+½) + 2λ(G − G0)
            let dldv = Mat::from_vec(nblocks, d, w_hat.data.clone());
            let mut dg = dldv.transpose().matmul(&zs);
            dg.axpy(2.0 * cfg.lambda, &lat.g.sub(&g0_ref));

            // ---- updates ----
            if cfg.adaptive_lattice {
                let mut gnew = lat.g.clone();
                adam_g.step(&mut gnew.data, &dg.data, lr_g_eff);
                gnew = spectral_clamp(&gnew, band_lo, band_hi);
                if lat.set_g(gnew).is_err() {
                    break; // singular update — keep previous basis, stop
                }
            }
            if cfg.adaptive_companding {
                let mut mu_arr = [comp.mu];
                adam_mu.step(&mut mu_arr, &[dmu as f32], cfg.lr_mu);
                comp = MuLaw { mu: mu_arr[0] };
                comp.project();
            }
        }

        // restore the best (G, μ) seen
        let (best_loss, best_g, best_mu) = best.expect("at least one iteration ran");
        let _ = lat.set_g(best_g);
        comp = MuLaw::new(best_mu);

        // ---- final encode with the best parameters (shifted grid) ----
        y.data.copy_from_slice(&wn.data);
        comp.forward_slice(&mut y.data);
        let half = crate::lattice::babai::half_shift(&lat.g);
        match cfg.assignment {
            Assignment::Babai => babai_batch_shifted_into(&lat, &y, &mut z),
            Assignment::Gcd => {
                let mut ysh = vec![0.0f32; d];
                for b in 0..nblocks {
                    for (i, v) in ysh.iter_mut().enumerate() {
                        *v = y.at(b, i) - half[i];
                    }
                    let zz = gcd.encode(&lat, &ysh);
                    z.row_mut(b).copy_from_slice(&zz);
                }
            }
        }
        let codes: Vec<i32> = z.data.iter().map(|&c| clamp_code(c, bits)).collect();

        // Side info: G, μ, plus the group normalization scale (decode chain
        // ŵ = wmax·F⁻¹(Gz) — bit-exact with the training objective).
        let side = SideInfo::Lattice { d, g: lat.g.data.clone(), mu: comp.mu, scale: wmax };
        let quantized = QuantizedGroup {
            method: if self.cfg.adaptive_lattice { "glvq" } else { "glvq_fixed" },
            bits,
            rows: m,
            cols: n,
            codes: PackedCodes::pack(&codes, bits).into(),
            side,
        };

        GroupFit {
            quantized,
            final_loss: best_loss,
            initial_loss: losses[0],
            iters_run: losses.len(),
            mu: comp.mu,
        }
    }
}

impl GroupQuantizer for GlvqGroupQuantizer {
    fn quantize(&self, w: &Mat, x: &Mat, bits: u8) -> QuantizedGroup {
        self.fit(w, x, bits).quantized
    }

    fn name(&self) -> &'static str {
        if self.cfg.adaptive_lattice {
            "glvq"
        } else {
            "glvq_fixed"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::traits::recon_error;
    use crate::util::rng::Rng;

    fn setup(m: usize, n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        // heavy-tailed weights like LLM groups
        let data: Vec<f32> = (0..m * n).map(|_| rng.student_t(5.0) as f32 * 0.02).collect();
        let w = Mat::from_vec(m, n, data);
        let x = Mat::random_normal(n, 64, 1.0, &mut rng);
        (w, x)
    }

    fn cfg(d: usize) -> GlvqConfig {
        let mut c = GlvqConfig::default();
        c.lattice_dim = d;
        c.iters = 12;
        c
    }

    #[test]
    fn optimization_reduces_loss() {
        let (w, x) = setup(32, 64, 1);
        let q = GlvqGroupQuantizer::new(cfg(8));
        let fit = q.fit(&w, &x, 3);
        assert!(
            fit.final_loss <= fit.initial_loss,
            "final {} > initial {}",
            fit.final_loss,
            fit.initial_loss
        );
        assert!(fit.final_loss.is_finite());
        assert!(fit.iters_run >= 3);
    }

    #[test]
    fn dequantize_matches_training_loss_scale() {
        let (w, x) = setup(16, 32, 2);
        let q = GlvqGroupQuantizer::new(cfg(8));
        let fit = q.fit(&w, &x, 4);
        let w_hat = fit.quantized.dequantize();
        let e = recon_error(&w, &w_hat, &x);
        // the container decode chain is bit-exact with the training
        // objective (minus the λ||G−G0||² regularizer), so the measured
        // reconstruction error must not exceed the recorded training loss
        assert!(
            e <= fit.final_loss * 1.02 + 1e-6,
            "container error {e} vs training loss {}",
            fit.final_loss
        );
    }

    #[test]
    fn glvq_beats_plain_rtn_on_heavy_tails() {
        let (w, x) = setup(32, 64, 3);
        let q = GlvqGroupQuantizer::new(cfg(8));
        let fit = q.fit(&w, &x, 2);
        let w_hat = fit.quantized.dequantize();
        let e_glvq = recon_error(&w, &w_hat, &x);

        // RTN at the same rate
        let maxabs = w.max_abs();
        let levels = 3.0f32;
        let scale = 2.0 * maxabs / levels;
        let mut rtn = w.clone();
        for v in rtn.data.iter_mut() {
            *v = ((*v + maxabs) / scale).round().clamp(0.0, levels) * scale - maxabs;
        }
        let e_rtn = recon_error(&w, &rtn, &x);
        assert!(
            e_glvq < e_rtn,
            "glvq {e_glvq} should beat rtn {e_rtn} on heavy-tailed weights"
        );
    }

    #[test]
    fn more_bits_less_error() {
        let (w, x) = setup(16, 32, 4);
        let q = GlvqGroupQuantizer::new(cfg(8));
        let e2 = {
            let f = q.fit(&w, &x, 2);
            recon_error(&w, &f.quantized.dequantize(), &x)
        };
        let e4 = {
            let f = q.fit(&w, &x, 4);
            recon_error(&w, &f.quantized.dequantize(), &x)
        };
        assert!(e4 < e2, "4-bit {e4} vs 2-bit {e2}");
    }

    #[test]
    fn fixed_lattice_ablation_is_worse_or_equal() {
        let (w, x) = setup(32, 64, 5);
        let adaptive = GlvqGroupQuantizer::new(cfg(8)).fit(&w, &x, 2);
        let mut c = cfg(8);
        c.adaptive_lattice = false;
        let fixed = GlvqGroupQuantizer::new(c).fit(&w, &x, 2);
        let ea = recon_error(&w, &adaptive.quantized.dequantize(), &x);
        let ef = recon_error(&w, &fixed.quantized.dequantize(), &x);
        assert!(ea <= ef * 1.1, "adaptive {ea} vs fixed {ef}");
    }

    #[test]
    fn codes_respect_bit_range() {
        let (w, x) = setup(16, 32, 6);
        for bits in [1u8, 2, 3, 4] {
            let fit = GlvqGroupQuantizer::new(cfg(8)).fit(&w, &x, bits);
            let (lo, hi) = code_range(bits);
            for c in fit.quantized.codes.unpack() {
                assert!(c >= lo && c <= hi);
            }
            assert_eq!(fit.quantized.bits, bits);
        }
    }

    #[test]
    fn gcd_assignment_also_converges() {
        let (w, x) = setup(16, 32, 7);
        let mut c = cfg(8);
        c.assignment = Assignment::Gcd;
        c.iters = 6;
        let fit = GlvqGroupQuantizer::new(c).fit(&w, &x, 3);
        assert!(fit.final_loss.is_finite());
        assert!(fit.final_loss <= fit.initial_loss * 1.01);
    }

    #[test]
    fn mu_stays_in_band() {
        let (w, x) = setup(16, 32, 8);
        let fit = GlvqGroupQuantizer::new(cfg(8)).fit(&w, &x, 2);
        assert!((10.0..=255.0).contains(&fit.mu), "mu={}", fit.mu);
    }
}
