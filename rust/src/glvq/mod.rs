//! The paper's core contribution: Grouped Lattice Vector Quantization.
//!
//! - [`group`] — partitioning weight tensors into column groups and d-length
//!   sub-blocks (paper §3.2 reshape),
//! - [`optimizer`] — the per-group alternating optimizer (Alg. 1): Babai/GCD
//!   Z-step, analytic-gradient Adam G/μ-step, spectral clamp, Frobenius
//!   regularization, ε-convergence,
//! - [`pipeline`] — model-scope orchestration: salience → bit allocation →
//!   per-group optimization → `.glvq` container assembly.

pub mod group;
pub mod optimizer;
pub mod pipeline;

pub use optimizer::{GlvqGroupQuantizer, GroupFit};
pub use pipeline::{quantize_model, CalibSet, PipelineReport};
