//! μ-law companding (paper §3.3, Eq. 9): group-specific learnable
//! non-linearity that uniformizes heavy-tailed weight distributions before
//! lattice quantization.
//!
//!   F_μ(x)   = sgn(x) · ln(1 + μ|x|) / ln(1 + μ)
//!   F_μ⁻¹(y) = sgn(y) · ((1 + μ)^|y| − 1) / μ
//!
//! μ is clamped to [10, 255] (paper) and initialized from the group's
//! kurtosis: μ⁰ = 100 · tanh(κ/10) (Eq. 12), floored at MU_MIN.

use crate::linalg::stats::kurtosis;

pub const MU_MIN: f32 = 10.0;
pub const MU_MAX: f32 = 255.0;

/// A (possibly learnable) μ-law compander.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MuLaw {
    pub mu: f32,
}

impl MuLaw {
    pub fn new(mu: f32) -> MuLaw {
        MuLaw { mu: mu.clamp(MU_MIN, MU_MAX) }
    }

    /// Identity-like compander used by "no companding" ablations: μ at the
    /// minimum of the legal band is the flattest curve we can express.
    pub fn weakest() -> MuLaw {
        MuLaw { mu: MU_MIN }
    }

    /// Paper Eq. 12: kurtosis-driven init, projected to [MU_MIN, MU_MAX].
    pub fn init_from_kurtosis(weights: &[f32]) -> MuLaw {
        let k = kurtosis(weights) as f32;
        MuLaw::new(100.0 * (k / 10.0).tanh())
    }

    #[inline]
    pub fn forward(&self, x: f32) -> f32 {
        let denom = (1.0 + self.mu).ln();
        x.signum() * (1.0 + self.mu * x.abs()).ln() / denom
    }

    #[inline]
    pub fn inverse(&self, y: f32) -> f32 {
        let log1p_mu = (1.0 + self.mu).ln();
        y.signum() * ((y.abs() * log1p_mu).exp() - 1.0) / self.mu
    }

    /// dF⁻¹/dμ and dF⁻¹/dy are what the gradient path needs; the native
    /// optimizer uses the analytic dμ derivative of the full chain instead
    /// (see glvq/optimizer.rs), so here we expose only the forwards.
    pub fn forward_slice(&self, xs: &mut [f32]) {
        let denom = (1.0 + self.mu).ln();
        for x in xs.iter_mut() {
            *x = x.signum() * (1.0 + self.mu * x.abs()).ln() / denom;
        }
    }

    pub fn inverse_slice(&self, ys: &mut [f32]) {
        let log1p_mu = (1.0 + self.mu).ln();
        for y in ys.iter_mut() {
            *y = y.signum() * ((y.abs() * log1p_mu).exp() - 1.0) / self.mu;
        }
    }

    /// Clamp μ back into the legal band after a gradient update (paper:
    /// "After each update we project μ onto the practical range [10, 255]").
    pub fn project(&mut self) {
        self.mu = self.mu.clamp(MU_MIN, MU_MAX);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_identity_within_unit_interval() {
        proptest(50, |rig| {
            let mu = rig.f32_in(MU_MIN, MU_MAX);
            let c = MuLaw::new(mu);
            let x = rig.f32_in(-1.0, 1.0);
            let back = c.inverse(c.forward(x));
            assert!((back - x).abs() < 1e-5 * (1.0 + x.abs()), "x={x} mu={mu} back={back}");
        });
    }

    #[test]
    fn forward_is_odd_and_monotone() {
        let c = MuLaw::new(100.0);
        let mut prev = f32::NEG_INFINITY;
        for i in -100..=100 {
            let x = i as f32 / 100.0;
            let y = c.forward(x);
            assert!(y >= prev, "not monotone at {x}");
            prev = y;
            assert!((c.forward(-x) + y).abs() < 1e-6, "not odd at {x}");
        }
    }

    #[test]
    fn maps_unit_interval_onto_itself() {
        let c = MuLaw::new(255.0);
        assert!((c.forward(1.0) - 1.0).abs() < 1e-6);
        assert!((c.forward(-1.0) + 1.0).abs() < 1e-6);
        assert_eq!(c.forward(0.0), 0.0);
    }

    #[test]
    fn expands_resolution_near_zero() {
        // |F'(x)| near 0 must exceed 1 (finer resolution for small weights)
        let c = MuLaw::new(100.0);
        let eps = 1e-4;
        let slope0 = (c.forward(eps) - c.forward(0.0)) / eps;
        let slope1 = (c.forward(1.0) - c.forward(1.0 - eps)) / eps;
        assert!(slope0 > 5.0, "slope near 0 = {slope0}");
        assert!(slope1 < 0.5, "slope near 1 = {slope1}");
    }

    #[test]
    fn kurtosis_init_monotone_in_tail_weight() {
        let mut rng = Rng::new(1);
        let normal: Vec<f32> = (0..30_000).map(|_| rng.normal_f32() * 0.02).collect();
        let heavy: Vec<f32> = (0..30_000).map(|_| rng.student_t(3.0) as f32 * 0.02).collect();
        let mn = MuLaw::init_from_kurtosis(&normal).mu;
        let mh = MuLaw::init_from_kurtosis(&heavy).mu;
        assert!(mh > mn, "heavy {mh} vs normal {mn}");
        assert!((MU_MIN..=MU_MAX).contains(&mn));
        assert!((MU_MIN..=MU_MAX).contains(&mh));
    }

    #[test]
    fn clamp_projects_out_of_band_values() {
        assert_eq!(MuLaw::new(1.0).mu, MU_MIN);
        assert_eq!(MuLaw::new(1e6).mu, MU_MAX);
        let mut c = MuLaw { mu: 500.0 };
        c.project();
        assert_eq!(c.mu, MU_MAX);
    }

    #[test]
    fn slice_ops_match_scalar_ops() {
        proptest(20, |rig| {
            let mu = rig.f32_in(MU_MIN, MU_MAX);
            let c = MuLaw::new(mu);
            let xs = rig.vec_f32(64, -1.0, 1.0);
            let mut fwd = xs.clone();
            c.forward_slice(&mut fwd);
            for (x, f) in xs.iter().zip(&fwd) {
                assert!((c.forward(*x) - f).abs() < 1e-7);
            }
            let mut inv = fwd.clone();
            c.inverse_slice(&mut inv);
            for (x, i) in xs.iter().zip(&inv) {
                assert!((x - i).abs() < 1e-5);
            }
        });
    }
}
