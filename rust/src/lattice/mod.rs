//! Lattice quantization core.
//!
//! A lattice Λ = { G z : z ∈ Z^d } is defined by its generation matrix G
//! ([`GenLattice`]). Encoding finds integer coordinates whose lattice point
//! approximates a target vector; this crate ships three encoders:
//!
//! - [`babai`] — Babai rounding `z = round(G⁻¹ y)` (the paper's choice,
//!   Eq. 6, with the Appendix-A error bound),
//! - [`gcd`] — greedy coordinate descent (the paper's ablation competitor,
//!   Tables 12–13),
//! - [`fixed`] — classic structured lattices (Zⁿ, D4, E8) with exact
//!   Conway–Sloane nearest-point decoders, used by the QuIP#-lite baseline.

pub mod babai;
pub mod fixed;
pub mod gcd;

use crate::linalg::decomp::{inverse, DecompError};
use crate::linalg::Mat;

/// A full-rank lattice with learnable generation matrix (paper §2.2).
#[derive(Clone, Debug)]
pub struct GenLattice {
    /// Generation matrix G (d×d); columns are the basis vectors.
    pub g: Mat,
    /// Cached inverse G⁻¹ kept in sync by [`GenLattice::set_g`].
    pub ginv: Mat,
}

impl GenLattice {
    pub fn new(g: Mat) -> Result<GenLattice, DecompError> {
        let ginv = inverse(&g)?;
        Ok(GenLattice { g, ginv })
    }

    pub fn dim(&self) -> usize {
        self.g.rows
    }

    /// Replace G (re-inverts; call after each optimizer update).
    pub fn set_g(&mut self, g: Mat) -> Result<(), DecompError> {
        self.ginv = inverse(&g)?;
        self.g = g;
        Ok(())
    }

    /// Decode integer coordinates to the lattice point y = G z.
    pub fn decode(&self, z: &[f32]) -> Vec<f32> {
        self.g.matvec(z)
    }

    /// Scaled identity lattice (step·Zⁿ) — the RTN-equivalent baseline and
    /// the "fixed lattice" ablation seed.
    pub fn scaled_identity(d: usize, step: f32) -> GenLattice {
        let g = Mat::eye(d).scale(step);
        GenLattice::new(g).expect("identity is invertible")
    }
}

/// Encode trait: assign integer lattice coordinates to each target column.
pub trait LatticeEncoder {
    /// y (len d) → z (len d, integer-valued f32).
    fn encode(&self, lat: &GenLattice, y: &[f32]) -> Vec<f32>;

    fn name(&self) -> &'static str;
}

/// Size of the code space a `bits`-wide, `d`-dimensional block spans —
/// (2^bits)^d distinct code vectors — when it fits a `usize` index.
/// The fused kernel's code→vector tables
/// ([`crate::kernels::lut::LutTable`]) are direct-indexed over exactly
/// this space.
pub fn code_space(bits: u8, d: usize) -> Option<usize> {
    let total = (bits as usize).checked_mul(d)?;
    if total >= usize::BITS as usize {
        return None;
    }
    Some(1usize << total)
}

/// Write the `idx`-th code block into `out` (one signed code per
/// coordinate): field j of the index, bits `[j·bits, (j+1)·bits)`, holds
/// the offset code `z_j − lo` — the same LSB-first field order
/// [`crate::quant::pack::PackedCodes`] packs, so ranking/unranking
/// round-trips through the packed payload's raw bit patterns.
pub fn unrank_codes(idx: usize, bits: u8, out: &mut [i32]) {
    let lo = crate::quant::pack::code_range(bits).0;
    let b = bits as usize;
    let mask = (1usize << b) - 1;
    for (j, o) in out.iter_mut().enumerate() {
        *o = ((idx >> (j * b)) & mask) as i32 + lo;
    }
}

/// Quantization error ||y - G z||₂ for a given assignment.
pub fn encode_error(lat: &GenLattice, y: &[f32], z: &[f32]) -> f32 {
    let rec = lat.decode(z);
    y.iter()
        .zip(&rec)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_lattice_decode_is_scaling() {
        let lat = GenLattice::scaled_identity(4, 0.5);
        let z = vec![1.0, -2.0, 0.0, 3.0];
        assert_eq!(lat.decode(&z), vec![0.5, -1.0, 0.0, 1.5]);
    }

    #[test]
    fn code_space_counts_and_guards_overflow() {
        assert_eq!(code_space(2, 8), Some(1 << 16));
        assert_eq!(code_space(3, 4), Some(1 << 12));
        // 8 bits × d=8 = 64 index bits: does not fit a usize index
        assert_eq!(code_space(8, 8), None);
        assert_eq!(code_space(1, 1), Some(2));
    }

    #[test]
    fn unrank_enumerates_every_block_exactly_once() {
        let (bits, d) = (2u8, 3usize);
        let space = code_space(bits, d).unwrap();
        let mut seen = std::collections::HashSet::new();
        let mut codes = vec![0i32; d];
        let (lo, hi) = crate::quant::pack::code_range(bits);
        for idx in 0..space {
            unrank_codes(idx, bits, &mut codes);
            assert!(codes.iter().all(|&c| c >= lo && c <= hi), "{codes:?}");
            // re-rank: field j is (c_j - lo) << (j*bits)
            let rank: usize = codes
                .iter()
                .enumerate()
                .map(|(j, &c)| ((c - lo) as usize) << (j * bits as usize))
                .sum();
            assert_eq!(rank, idx);
            assert!(seen.insert(codes.clone()), "duplicate block {codes:?}");
        }
        assert_eq!(seen.len(), space);
    }

    #[test]
    fn set_g_keeps_inverse_in_sync() {
        let mut lat = GenLattice::scaled_identity(3, 1.0);
        let g = Mat::from_vec(3, 3, vec![2.0, 0.0, 0.0, 0.0, 4.0, 0.0, 0.0, 1.0, 1.0]);
        lat.set_g(g.clone()).unwrap();
        let prod = lat.g.matmul(&lat.ginv);
        assert!(prod.frob_dist(&Mat::eye(3)) < 1e-5);
    }
}
