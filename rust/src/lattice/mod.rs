//! Lattice quantization core.
//!
//! A lattice Λ = { G z : z ∈ Z^d } is defined by its generation matrix G
//! ([`GenLattice`]). Encoding finds integer coordinates whose lattice point
//! approximates a target vector; this crate ships three encoders:
//!
//! - [`babai`] — Babai rounding `z = round(G⁻¹ y)` (the paper's choice,
//!   Eq. 6, with the Appendix-A error bound),
//! - [`gcd`] — greedy coordinate descent (the paper's ablation competitor,
//!   Tables 12–13),
//! - [`fixed`] — classic structured lattices (Zⁿ, D4, E8) with exact
//!   Conway–Sloane nearest-point decoders, used by the QuIP#-lite baseline.

pub mod babai;
pub mod fixed;
pub mod gcd;

use crate::linalg::decomp::{inverse, DecompError};
use crate::linalg::Mat;

/// A full-rank lattice with learnable generation matrix (paper §2.2).
#[derive(Clone, Debug)]
pub struct GenLattice {
    /// Generation matrix G (d×d); columns are the basis vectors.
    pub g: Mat,
    /// Cached inverse G⁻¹ kept in sync by [`GenLattice::set_g`].
    pub ginv: Mat,
}

impl GenLattice {
    pub fn new(g: Mat) -> Result<GenLattice, DecompError> {
        let ginv = inverse(&g)?;
        Ok(GenLattice { g, ginv })
    }

    pub fn dim(&self) -> usize {
        self.g.rows
    }

    /// Replace G (re-inverts; call after each optimizer update).
    pub fn set_g(&mut self, g: Mat) -> Result<(), DecompError> {
        self.ginv = inverse(&g)?;
        self.g = g;
        Ok(())
    }

    /// Decode integer coordinates to the lattice point y = G z.
    pub fn decode(&self, z: &[f32]) -> Vec<f32> {
        self.g.matvec(z)
    }

    /// Scaled identity lattice (step·Zⁿ) — the RTN-equivalent baseline and
    /// the "fixed lattice" ablation seed.
    pub fn scaled_identity(d: usize, step: f32) -> GenLattice {
        let g = Mat::eye(d).scale(step);
        GenLattice::new(g).expect("identity is invertible")
    }
}

/// Encode trait: assign integer lattice coordinates to each target column.
pub trait LatticeEncoder {
    /// y (len d) → z (len d, integer-valued f32).
    fn encode(&self, lat: &GenLattice, y: &[f32]) -> Vec<f32>;

    fn name(&self) -> &'static str;
}

/// Quantization error ||y - G z||₂ for a given assignment.
pub fn encode_error(lat: &GenLattice, y: &[f32], z: &[f32]) -> f32 {
    let rec = lat.decode(z);
    y.iter()
        .zip(&rec)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_lattice_decode_is_scaling() {
        let lat = GenLattice::scaled_identity(4, 0.5);
        let z = vec![1.0, -2.0, 0.0, 3.0];
        assert_eq!(lat.decode(&z), vec![0.5, -1.0, 0.0, 1.5]);
    }

    #[test]
    fn set_g_keeps_inverse_in_sync() {
        let mut lat = GenLattice::scaled_identity(3, 1.0);
        let g = Mat::from_vec(3, 3, vec![2.0, 0.0, 0.0, 0.0, 4.0, 0.0, 0.0, 1.0, 1.0]);
        lat.set_g(g.clone()).unwrap();
        let prod = lat.g.matmul(&lat.ginv);
        assert!(prod.frob_dist(&Mat::eye(3)) < 1e-5);
    }
}
