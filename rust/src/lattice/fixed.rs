//! Classic structured lattices with exact nearest-point decoders
//! (Conway & Sloane, "Sphere Packings, Lattices and Groups" ch. 20):
//!
//! - Zⁿ  — round each coordinate,
//! - Dₙ  — integer points with even coordinate sum,
//! - E₈  — D₈ ∪ (D₈ + ½·1), the densest 8-d packing; the codebook QuIP#
//!   builds on, here used by the `quip_lite` baseline and the fixed-lattice
//!   ablation (Table 7).
//!
//! These decoders return the *exact* nearest lattice point, which makes
//! them strong reference implementations to test Babai against.

/// Nearest point in Zⁿ.
pub fn nearest_zn(y: &[f32]) -> Vec<f32> {
    y.iter().map(|v| v.round()).collect()
}

/// Nearest point in Dₙ (sum of coordinates even).
pub fn nearest_dn(y: &[f32]) -> Vec<f32> {
    let mut f: Vec<f32> = y.iter().map(|v| v.round()).collect();
    let sum: i64 = f.iter().map(|&v| v as i64).sum();
    if sum % 2 != 0 {
        // flip the coordinate where rounding the "wrong" way costs least
        let mut best = 0usize;
        let mut best_cost = f32::INFINITY;
        for i in 0..y.len() {
            let delta = y[i] - f[i];
            // moving f[i] one unit toward the other side
            let dir = if delta >= 0.0 { 1.0 } else { -1.0 };
            let cost = (y[i] - (f[i] + dir)).abs() - delta.abs();
            if cost < best_cost {
                best_cost = cost;
                best = i;
            }
        }
        let delta = y[best] - f[best];
        f[best] += if delta >= 0.0 { 1.0 } else { -1.0 };
    }
    f
}

/// Nearest point in E₈ = D₈ ∪ (D₈ + ½·1).
pub fn nearest_e8(y: &[f32]) -> Vec<f32> {
    assert_eq!(y.len(), 8);
    let a = nearest_dn(y);
    let shifted: Vec<f32> = y.iter().map(|v| v - 0.5).collect();
    let mut b = nearest_dn(&shifted);
    for v in b.iter_mut() {
        *v += 0.5;
    }
    let da: f32 = y.iter().zip(&a).map(|(p, q)| (p - q) * (p - q)).sum();
    let db: f32 = y.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
    if da <= db {
        a
    } else {
        b
    }
}

/// Exact nearest-point decode for a named lattice family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FixedLattice {
    Zn,
    Dn,
    E8,
}

impl FixedLattice {
    pub fn nearest(&self, y: &[f32]) -> Vec<f32> {
        match self {
            FixedLattice::Zn => nearest_zn(y),
            FixedLattice::Dn => nearest_dn(y),
            FixedLattice::E8 => nearest_e8(y),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FixedLattice::Zn => "Zn",
            FixedLattice::Dn => "Dn",
            FixedLattice::E8 => "E8",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    fn dist2(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn dn_points_have_even_sum() {
        proptest(50, |rig| {
            let n = rig.usize_in(2, 10);
            let y = rig.vec_normal(n, 2.0);
            let p = nearest_dn(&y);
            let sum: i64 = p.iter().map(|&v| v as i64).sum();
            assert_eq!(sum.rem_euclid(2), 0, "{p:?}");
        });
    }

    #[test]
    fn dn_beats_or_matches_brute_force_neighbourhood() {
        // exact check: compare against exhaustive search over the ±1 cube
        // around the rounded point (which contains the true nearest for Dn).
        proptest(30, |rig| {
            let n = rig.usize_in(2, 5);
            let y = rig.vec_normal(n, 1.5);
            let p = nearest_dn(&y);
            let base: Vec<i64> = y.iter().map(|v| v.round() as i64).collect();
            let mut best = f32::INFINITY;
            let cube = 3usize.pow(n as u32);
            for code in 0..cube {
                let mut c = code;
                let mut cand = Vec::with_capacity(n);
                for i in 0..n {
                    cand.push((base[i] + (c % 3) as i64 - 1) as f32);
                    c /= 3;
                }
                let s: i64 = cand.iter().map(|&v| v as i64).sum();
                if s % 2 == 0 {
                    best = best.min(dist2(&y, &cand));
                }
            }
            assert!(dist2(&y, &p) <= best + 1e-5);
        });
    }

    #[test]
    fn e8_contains_half_integer_points() {
        let y = vec![0.5f32; 8];
        let p = nearest_e8(&y);
        assert_eq!(p, vec![0.5f32; 8]); // ½·1 ∈ E8 (sum of D8 part even)
    }

    #[test]
    fn e8_never_worse_than_d8_or_z8_rounding() {
        proptest(60, |rig| {
            let y = rig.vec_normal(8, 1.2);
            let e = nearest_e8(&y);
            let d = nearest_dn(&y);
            assert!(dist2(&y, &e) <= dist2(&y, &d) + 1e-5);
        });
    }

    #[test]
    fn e8_coordinates_all_integer_or_all_half_integer() {
        proptest(40, |rig| {
            let y = rig.vec_normal(8, 2.0);
            let p = nearest_e8(&y);
            let frac: Vec<f32> = p.iter().map(|v| (v - v.floor()).abs()).collect();
            let all_int = frac.iter().all(|f| *f < 1e-6 || *f > 1.0 - 1e-6);
            let all_half = frac.iter().all(|f| (f - 0.5).abs() < 1e-6);
            assert!(all_int || all_half, "{p:?}");
        });
    }

    #[test]
    fn zn_is_plain_rounding() {
        assert_eq!(nearest_zn(&[0.4, -1.6, 2.5]), vec![0.0, -2.0, 3.0]);
    }
}
