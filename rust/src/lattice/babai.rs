//! Babai rounding (paper Eq. 6 / Appendix A): z = round(G⁻¹ y).
//!
//! O(d²) per vector with the cached inverse; this is the encoder used by
//! GLVQ training and final encoding. The batch variant is the native hot
//! path (see EXPERIMENTS.md §Perf) — it processes a (rows × d) panel with
//! the blocked matmul and rounds in place, allocation-free per panel.

use super::{GenLattice, LatticeEncoder};
use crate::linalg::matrix::matmul_into;
use crate::linalg::Mat;

#[derive(Clone, Copy, Debug, Default)]
pub struct BabaiEncoder;

impl LatticeEncoder for BabaiEncoder {
    fn encode(&self, lat: &GenLattice, y: &[f32]) -> Vec<f32> {
        debug_assert_eq!(y.len(), lat.dim());
        let x = lat.ginv.matvec(y);
        x.into_iter().map(|v| v.round()).collect()
    }

    fn name(&self) -> &'static str {
        "babai"
    }
}

/// Batch Babai: each row of `y_panel` (shape rows×d) is one target vector.
/// Returns the integer coordinate panel (rows×d). `scratch` must be rows×d
/// and is overwritten — callers reuse it across panels to avoid allocation.
pub fn babai_batch_into(lat: &GenLattice, y_panel: &Mat, scratch: &mut Mat) {
    assert_eq!(y_panel.cols, lat.dim());
    assert_eq!((scratch.rows, scratch.cols), (y_panel.rows, y_panel.cols));
    // z_row = round(Ginv @ y_row)  ⇔  Z = round(Y @ Ginv^T)
    let ginv_t = lat.ginv.transpose();
    matmul_into(y_panel, &ginv_t, scratch);
    for v in scratch.data.iter_mut() {
        *v = v.round();
    }
}

pub fn babai_batch(lat: &GenLattice, y_panel: &Mat) -> Mat {
    let mut out = Mat::zeros(y_panel.rows, y_panel.cols);
    babai_batch_into(lat, y_panel, &mut out);
    out
}

/// Shifted-grid batch Babai: codes for the *half-integer* lattice
/// Λ_½ = { G (z + ½·1) : z ∈ Z^d } — z = round(G⁻¹y − ½). GLVQ stores these
/// codes because the reconstruction levels are symmetric at every bit width
/// (at 1 bit the plain grid degenerates to {−s, 0}; the shifted grid gives
/// ±s/2 — sign quantization), matching QuIP#'s E8+½ convention.
pub fn babai_batch_shifted_into(lat: &GenLattice, y_panel: &Mat, scratch: &mut Mat) {
    assert_eq!(y_panel.cols, lat.dim());
    assert_eq!((scratch.rows, scratch.cols), (y_panel.rows, y_panel.cols));
    let ginv_t = lat.ginv.transpose();
    matmul_into(y_panel, &ginv_t, scratch);
    for v in scratch.data.iter_mut() {
        *v = (*v - 0.5).round();
    }
}

/// The decode offset for the shifted grid: h = G · (½·1), i.e.
/// h_i = ½ Σ_j G_ij. Decode is ŷ = G z + h.
pub fn half_shift(g: &Mat) -> Vec<f32> {
    (0..g.rows)
        .map(|i| 0.5 * g.row(i).iter().sum::<f32>())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::encode_error;
    use crate::util::proptest::proptest;

    fn near_identity_lattice(d: usize, rig: &mut crate::util::proptest::Rig) -> GenLattice {
        let mut g = Mat::eye(d).scale(rig.f32_in(0.01, 0.1));
        for v in g.data.iter_mut() {
            *v += rig.f32_in(-0.002, 0.002);
        }
        GenLattice::new(g).unwrap()
    }

    #[test]
    fn exact_on_lattice_points() {
        proptest(30, |rig| {
            let d = *rig.choice(&[2, 4, 8, 16]);
            let lat = near_identity_lattice(d, rig);
            let z0: Vec<f32> = (0..d).map(|_| rig.usize_in(0, 12) as f32 - 6.0).collect();
            let y = lat.decode(&z0);
            let z1 = BabaiEncoder.encode(&lat, &y);
            assert_eq!(z0, z1, "d={d}");
        });
    }

    #[test]
    fn batch_matches_single_vector_encoder() {
        proptest(20, |rig| {
            let d = *rig.choice(&[4, 8, 16]);
            let rows = rig.usize_in(1, 40);
            let lat = near_identity_lattice(d, rig);
            let panel = Mat::from_vec(rows, d, rig.vec_normal(rows * d, 0.1));
            let z = babai_batch(&lat, &panel);
            for r in 0..rows {
                let single = BabaiEncoder.encode(&lat, panel.row(r));
                assert_eq!(z.row(r), &single[..], "row {r}");
            }
        });
    }

    #[test]
    fn error_bounded_by_half_diameter_for_orthogonal_basis() {
        // For diagonal G with steps s_i, Babai is exact-nearest; the error in
        // each coordinate is at most s_i/2.
        proptest(20, |rig| {
            let d = rig.usize_in(1, 8);
            let steps: Vec<f32> = (0..d).map(|_| rig.f32_in(0.02, 0.3)).collect();
            let mut g = Mat::zeros(d, d);
            for i in 0..d {
                *g.at_mut(i, i) = steps[i];
            }
            let lat = GenLattice::new(g).unwrap();
            let y = rig.vec_normal(d, 1.0);
            let z = BabaiEncoder.encode(&lat, &y);
            let rec = lat.decode(&z);
            for i in 0..d {
                assert!((y[i] - rec[i]).abs() <= steps[i] / 2.0 + 1e-5);
            }
        });
    }

    #[test]
    fn error_metric_consistent() {
        let lat = GenLattice::scaled_identity(2, 1.0);
        let y = vec![0.4, -0.2];
        let z = BabaiEncoder.encode(&lat, &y);
        assert_eq!(z, vec![0.0, 0.0]);
        let e = encode_error(&lat, &y, &z);
        assert!((e - (0.4f32 * 0.4 + 0.04).sqrt()).abs() < 1e-6);
    }
}

/// Allocating variant of [`babai_batch_shifted_into`].
pub fn babai_batch_shifted(lat: &GenLattice, y_panel: &Mat) -> Mat {
    let mut out = Mat::zeros(y_panel.rows, y_panel.cols);
    babai_batch_shifted_into(lat, y_panel, &mut out);
    out
}
