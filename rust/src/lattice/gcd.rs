//! Greedy coordinate descent (GCD) lattice encoder — the paper's ablation
//! competitor to Babai rounding (Appendix I, Tables 12–13).
//!
//! Starting from the Babai point, GCD iteratively perturbs single integer
//! coordinates (±1) accepting any move that reduces ||y − G z||², until no
//! single-coordinate move helps or the sweep budget is exhausted. The paper
//! finds it *worse* than Babai in final model quality despite being a local
//! refinement — we reproduce that comparison; the encoder is also useful as
//! an independent check that Babai is near-optimal for well-conditioned G.

use super::{GenLattice, LatticeEncoder};

#[derive(Clone, Copy, Debug)]
pub struct GcdEncoder {
    /// maximum full coordinate sweeps
    pub max_sweeps: usize,
}

impl Default for GcdEncoder {
    fn default() -> Self {
        GcdEncoder { max_sweeps: 8 }
    }
}

impl LatticeEncoder for GcdEncoder {
    fn encode(&self, lat: &GenLattice, y: &[f32]) -> Vec<f32> {
        let d = lat.dim();
        debug_assert_eq!(y.len(), d);
        // start from round(Ginv y) like Babai
        let mut z: Vec<f32> = lat.ginv.matvec(y).into_iter().map(|v| v.round()).collect();
        // residual r = y - G z, maintained incrementally
        let mut rec = lat.decode(&z);
        let mut r: Vec<f32> = y.iter().zip(&rec).map(|(a, b)| a - b).collect();
        let mut err: f32 = r.iter().map(|v| v * v).sum();

        for _ in 0..self.max_sweeps {
            let mut improved = false;
            for j in 0..d {
                // column g_j of G
                for step in [1.0f32, -1.0] {
                    // candidate: z_j += step → r' = r - step * g_j
                    let mut err_new = 0.0f32;
                    for i in 0..d {
                        let ri = r[i] - step * lat.g.at(i, j);
                        err_new += ri * ri;
                    }
                    if err_new + 1e-9 < err {
                        z[j] += step;
                        for i in 0..d {
                            r[i] -= step * lat.g.at(i, j);
                        }
                        err = err_new;
                        improved = true;
                        break;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        let _ = &mut rec;
        z
    }

    fn name(&self) -> &'static str {
        "gcd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::babai::BabaiEncoder;
    use crate::lattice::encode_error;
    use crate::linalg::Mat;
    use crate::util::proptest::proptest;

    #[test]
    fn gcd_never_worse_than_babai_in_raw_distance() {
        // GCD starts at the Babai point and only accepts improving moves, so
        // its geometric encode error is ≤ Babai's (the paper's point is that
        // *training dynamics* with GCD are worse, not single-shot distance).
        proptest(30, |rig| {
            let d = *rig.choice(&[2, 4, 8]);
            let mut g = Mat::eye(d).scale(0.05);
            for v in g.data.iter_mut() {
                *v += rig.f32_in(-0.015, 0.015);
            }
            let lat = match GenLattice::new(g) {
                Ok(l) => l,
                Err(_) => return,
            };
            let y = rig.vec_normal(d, 0.1);
            let zb = BabaiEncoder.encode(&lat, &y);
            let zg = GcdEncoder::default().encode(&lat, &y);
            let eb = encode_error(&lat, &y, &zb);
            let eg = encode_error(&lat, &y, &zg);
            assert!(eg <= eb + 1e-5, "gcd {eg} vs babai {eb}");
        });
    }

    #[test]
    fn exact_on_lattice_points() {
        proptest(20, |rig| {
            let d = *rig.choice(&[2, 4, 8]);
            let lat = GenLattice::scaled_identity(d, 0.07);
            let z0: Vec<f32> = (0..d).map(|_| rig.usize_in(0, 10) as f32 - 5.0).collect();
            let y = lat.decode(&z0);
            let z1 = GcdEncoder::default().encode(&lat, &y);
            assert_eq!(z0, z1);
        });
    }

    #[test]
    fn improves_on_babai_for_skewed_basis() {
        // a deliberately skewed basis where plain rounding is suboptimal
        let g = Mat::from_vec(2, 2, vec![1.0, 0.95, 0.0, 0.31]);
        let lat = GenLattice::new(g).unwrap();
        let mut wins = 0;
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..200 {
            let y = vec![rng.normal_f32(), rng.normal_f32()];
            let eb = encode_error(&lat, &y, &BabaiEncoder.encode(&lat, &y));
            let eg = encode_error(&lat, &y, &GcdEncoder::default().encode(&lat, &y));
            if eg < eb - 1e-6 {
                wins += 1;
            }
        }
        assert!(wins > 10, "gcd should strictly improve sometimes, wins={wins}");
    }
}
