//! Batched LM serving loop: the L3 request path over the quantized model.
//!
//! A worker thread owns the model backend (native forward or PJRT logits
//! artifact), drains the request queue into bounded batches, and answers
//! generate/score requests; [`super::metrics::ServerMetrics`] tracks
//! latency/throughput (the Table-4 runtime story at serving granularity).

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::eval::native_fwd;
use crate::model::ModelConfig;
use crate::runtime::exec::LogitsExec;
use crate::runtime::Engine;
use crate::tensor::TensorStore;

use super::metrics::ServerMetrics;

/// Model backend abstraction: last-position logits for a token prefix.
/// Backends are created *inside* the server thread (PJRT handles are not
/// Send), so [`start`] takes a factory closure.
pub trait LmBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
}

/// Native-forward backend (no artifacts needed).
pub struct NativeBackend {
    pub cfg: ModelConfig,
    pub store: TensorStore,
}

impl LmBackend for NativeBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let t = self.cfg.seq_len;
        let keep = tokens.len().min(t);
        let mut x = tokens[tokens.len() - keep..].to_vec();
        let last = keep.max(1) - 1;
        x.resize(t, 0);
        let logits = native_fwd::forward(&self.cfg, &self.store, &x, 1, None)?;
        Ok(logits.row(last).to_vec())
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}

/// PJRT backend over the logits artifact.
pub struct PjrtBackend {
    exec: LogitsExec,
    params: Vec<crate::runtime::exec::StagedBuf>,
}

impl PjrtBackend {
    pub fn new(engine: &Engine, model: &str, store: &TensorStore) -> Result<PjrtBackend> {
        let exec = LogitsExec::new(engine, model)?;
        let params = exec.stage_params(store)?;
        Ok(PjrtBackend { exec, params })
    }
}

impl LmBackend for PjrtBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let t = self.exec.seq;
        let keep = tokens.len().min(t);
        let mut x = tokens[tokens.len() - keep..].to_vec();
        let last = keep.max(1) - 1;
        x.resize(t, 0);
        let logits = self.exec.logits(&self.params, &x)?;
        let v = self.exec.vocab;
        Ok(logits[last * v..(last + 1) * v].to_vec())
    }

    fn seq_len(&self) -> usize {
        self.exec.seq
    }

    fn vocab(&self) -> usize {
        self.exec.vocab
    }
}

/// A client request.
#[derive(Clone, Debug)]
pub enum Request {
    /// greedy-decode `max_new` bytes after the prompt
    Generate { prompt: Vec<u8>, max_new: usize },
    /// total log P(continuation | prompt)
    Score { prompt: Vec<u8>, continuation: Vec<u8> },
}

/// The server's answer.
#[derive(Clone, Debug)]
pub enum Response {
    Generated { text: Vec<u8> },
    Scored { logprob: f64 },
    Error { message: String },
}

struct Job {
    request: Request,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
}

/// Handle used by clients to submit requests.
pub struct ServerHandle {
    tx: mpsc::Sender<Job>,
    join: Option<std::thread::JoinHandle<ServerMetrics>>,
}

impl ServerHandle {
    /// Submit a request; returns the response receiver.
    pub fn submit(&self, request: Request) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Job { request, reply, submitted: Instant::now() });
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, request: Request) -> Result<Response> {
        self.submit(request).recv().context("server dropped the reply")
    }

    /// Stop the worker and return final metrics.
    pub fn shutdown(mut self) -> ServerMetrics {
        drop(self.tx);
        self.join
            .take()
            .expect("shutdown called once")
            .join()
            .expect("server thread panicked")
    }
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// max requests drained into one processing batch
    pub max_batch: usize,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts { max_batch: 8 }
    }
}

/// Start the serving loop on its own thread. `make_backend` runs inside the
/// worker thread (PJRT clients/executables are thread-local).
pub fn start<F>(make_backend: F, opts: ServerOpts) -> ServerHandle
where
    F: FnOnce() -> Result<Box<dyn LmBackend>> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Job>();
    let join = std::thread::spawn(move || {
        let mut backend = make_backend().expect("backend construction failed");
        let mut metrics = ServerMetrics::default();
        loop {
            // block for the first job, then drain up to max_batch
            let first = match rx.recv() {
                Ok(j) => j,
                Err(_) => break, // all senders dropped → shutdown
            };
            let mut batch = vec![first];
            while batch.len() < opts.max_batch {
                match rx.try_recv() {
                    Ok(j) => batch.push(j),
                    Err(_) => break,
                }
            }
            metrics.batches += 1;
            for job in batch {
                let response = handle(&mut *backend, &job.request, &mut metrics);
                metrics.requests += 1;
                metrics
                    .latency
                    .record(job.submitted.elapsed().as_secs_f64() * 1e3);
                let _ = job.reply.send(response);
            }
        }
        metrics
    });
    ServerHandle { tx, join: Some(join) }
}

fn handle(backend: &mut dyn LmBackend, request: &Request, metrics: &mut ServerMetrics) -> Response {
    match request {
        Request::Generate { prompt, max_new } => {
            let mut tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
            let start = tokens.len();
            for _ in 0..*max_new {
                let logits = match backend.logits_last(&tokens) {
                    Ok(l) => l,
                    Err(e) => return Response::Error { message: e.to_string() },
                };
                let next = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0);
                tokens.push(next);
                metrics.tokens_out += 1;
            }
            let text: Vec<u8> = tokens[start..].iter().map(|&t| t.clamp(0, 255) as u8).collect();
            Response::Generated { text }
        }
        Request::Score { prompt, continuation } => {
            let mut tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
            let mut total = 0.0f64;
            for &b in continuation {
                let logits = match backend.logits_last(&tokens) {
                    Ok(l) => l,
                    Err(e) => return Response::Error { message: e.to_string() },
                };
                let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                let lse: f32 = logits.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
                total += (logits[b as usize] - lse) as f64;
                tokens.push(b as i32);
                metrics.tokens_out += 1;
            }
            Response::Scored { logprob: total }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ModelConfig};

    fn tiny_backend() -> Result<Box<dyn LmBackend>> {
        let cfg = ModelConfig {
            name: "t",
            vocab: 256,
            d_model: 32,
            n_layer: 1,
            n_head: 2,
            d_ff: 64,
            seq_len: 32,
            batch_train: 2,
            batch_eval: 2,
        };
        let store = init_params(&cfg, 0);
        Ok(Box::new(NativeBackend { cfg, store }))
    }

    #[test]
    fn generate_and_score_roundtrip() {
        let handle = start(tiny_backend, ServerOpts::default());
        match handle.call(Request::Generate { prompt: b"the kama ".to_vec(), max_new: 5 }).unwrap()
        {
            Response::Generated { text } => assert_eq!(text.len(), 5),
            other => panic!("unexpected {other:?}"),
        }
        match handle
            .call(Request::Score { prompt: b"the ".to_vec(), continuation: b"ka".to_vec() })
            .unwrap()
        {
            Response::Scored { logprob } => assert!(logprob < 0.0 && logprob.is_finite()),
            other => panic!("unexpected {other:?}"),
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 2);
        assert_eq!(metrics.tokens_out, 7);
        assert!(metrics.latency.quantile(0.5) >= 0.0);
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let handle = start(tiny_backend, ServerOpts { max_batch: 4 });
        let receivers: Vec<_> = (0..10)
            .map(|i| {
                handle.submit(Request::Generate {
                    prompt: format!("req {i} ").into_bytes(),
                    max_new: 2,
                })
            })
            .collect();
        for rx in receivers {
            match rx.recv().unwrap() {
                Response::Generated { text } => assert_eq!(text.len(), 2),
                other => panic!("unexpected {other:?}"),
            }
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 10);
        assert!(metrics.batches <= 10);
    }

    #[test]
    fn deterministic_generation() {
        let h1 = start(tiny_backend, ServerOpts::default());
        let h2 = start(tiny_backend, ServerOpts::default());
        let r1 = h1.call(Request::Generate { prompt: b"abc".to_vec(), max_new: 4 }).unwrap();
        let r2 = h2.call(Request::Generate { prompt: b"abc".to_vec(), max_new: 4 }).unwrap();
        match (r1, r2) {
            (Response::Generated { text: a }, Response::Generated { text: b }) => {
                assert_eq!(a, b)
            }
            _ => panic!(),
        }
        h1.shutdown();
        h2.shutdown();
    }
}
